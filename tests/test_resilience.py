"""Failure-domain layer: circuit breakers, deadline-aware retry, the
output health sentinel, seam-level fault injection, tiered fallback
routing, poison-batch bisection, and lane stall supervision.

The fallback bit-identity matrix here is the robustness counterpart of
test_service.py's route-invisibility matrix: every DEGRADED route must
return the same image the healthy route would have — bit-identical for
fused1->fused3 and sharded->local, <=0.1 dB for the bs16->f32 precision
step (f32 is the verification tier the gate itself is measured against).
"""
import asyncio
import itertools
import math
import time

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.sar import build_pipeline, paper_targets, simulate_cached
from repro.core.sar.metrics import compare_pipelines
from repro.core.sar.geometry import test_scene as make_test_scene
from repro.service import (
    BatchKey,
    BreakerBoard,
    ChaosBackend,
    CircuitBreaker,
    FaultInjector,
    FaultSpec,
    FocusService,
    HealthSentinel,
    LocalBackend,
    OutputCorrupted,
    RetryPolicy,
    ServiceConfig,
    SimulatedFailure,
    scene_digest,
    seeded_schedule,
)
from repro.service.faults import SEAMS
from repro.service.resilience import LaneStalled

CFG = make_test_scene(128)
TARGETS = paper_targets(CFG)


def fast_backend(**kw):
    return LocalBackend(sweep=((None, None),), **kw)


def scene():
    return simulate_cached(CFG, TARGETS)


def reference(variant="fused3", **kw):
    return np.asarray(build_pipeline(CFG, variant, **kw).run(
        jnp.asarray(scene())))


# ---------------------------------------------------------------------------
# CircuitBreaker / RetryPolicy / HealthSentinel units
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def test_circuit_breaker_opens_after_threshold_and_half_open_probes():
    clk = _Clock()
    br = CircuitBreaker(threshold=3, cooldown_s=10.0, clock=clk)
    assert br.state == "closed" and br.allow()
    br.record_failure()
    br.record_failure()
    assert br.allow(), "below threshold: still closed"
    br.record_failure()
    assert br.state == "open" and not br.allow()
    clk.t = 9.9
    assert not br.allow(), "cooldown not elapsed"
    clk.t = 10.0
    assert br.allow(), "cooldown elapsed: half-open probe admitted"
    assert br.state == "half_open"
    assert not br.allow(), "only ONE probe while half-open"
    br.record_success()
    assert br.state == "closed" and br.allow()


def test_circuit_breaker_half_open_failure_rearms_cooldown():
    clk = _Clock()
    br = CircuitBreaker(threshold=1, cooldown_s=5.0, clock=clk)
    br.record_failure()
    assert br.state == "open" and br.trips == 1
    clk.t = 5.0
    assert br.allow()
    br.record_failure()                   # the probe failed
    assert br.state == "open" and br.trips == 2
    clk.t = 9.0
    assert not br.allow(), "cooldown restarted at the probe failure"
    clk.t = 10.0
    assert br.allow()


def test_circuit_breaker_vanished_probe_reprobes_after_cooldown():
    """A half-open probe that never records an outcome (the probe
    request was shed or deadline-dropped before its dispatch resolved)
    must not wedge the breaker: after another cooldown a fresh probe is
    admitted."""
    clk = _Clock()
    br = CircuitBreaker(threshold=1, cooldown_s=5.0, clock=clk)
    br.record_failure()
    clk.t = 5.0
    assert br.allow(), "cooldown elapsed: probe admitted"
    assert br.state == "half_open"
    assert not br.allow(), "probe in flight"
    clk.t = 9.9
    assert not br.allow(), "probe-vanish window not elapsed"
    clk.t = 10.0
    assert br.allow(), "vanished probe: a fresh probe is admitted"
    assert not br.allow(), "again only ONE probe at a time"
    br.record_success()
    assert br.state == "closed"


def test_breaker_board_is_per_name_and_snapshots():
    board = BreakerBoard(threshold=1, cooldown_s=99.0, clock=_Clock())
    board.get("route:a").record_failure()
    assert not board.get("route:a").allow()
    assert board.get("route:b").allow(), "breakers are per route"
    snap = board.snapshot()
    assert snap["route:a"]["state"] == "open"
    assert snap["route:b"]["state"] == "closed"


def test_retry_policy_is_seeded_deterministic_and_bounded():
    a = RetryPolicy(max_retries=3, backoff_s=0.01, seed=7,
                    clock=_Clock())
    b = RetryPolicy(max_retries=3, backoff_s=0.01, seed=7,
                    clock=_Clock())
    da = [a.budget(i) for i in range(4)]
    db = [b.budget(i) for i in range(4)]
    assert da == db, "same seed, same jittered schedule"
    assert all(d > 0 for d in da[:3])
    assert da[1] > da[0] * 1.0, "exponential growth dominates jitter"
    assert da[3] is None, "budget exhausted at max_retries"


def test_retry_policy_never_schedules_past_deadline():
    clk = _Clock(100.0)
    pol = RetryPolicy(max_retries=5, backoff_s=1.0, jitter=0.0, clock=clk)
    assert pol.budget(0, t_deadline=math.inf) == pytest.approx(1.0)
    # a retry that would land at/after the deadline is refused outright
    assert pol.budget(0, t_deadline=101.0) is None
    assert pol.budget(0, t_deadline=101.5) == pytest.approx(1.0)


def test_health_sentinel_flags_corruption_modes_and_passes_real_images():
    sent = HealthSentinel(envelope=1e6)
    raw = np.asarray(scene())
    img = reference()
    assert sent.check(raw, img) is None, "healthy pipeline output passes"
    nan = img.copy()
    nan.flat[0] = np.nan
    assert "non-finite" in sent.check(raw, nan)
    inf = img.copy()
    inf.flat[3] = np.inf
    assert "non-finite" in sent.check(raw, inf)
    assert "all-zero" in sent.check(raw, np.zeros_like(img))
    assert "envelope" in sent.check(raw, img * 1e9)
    assert sent.check(np.zeros_like(raw), np.zeros_like(img)) is None, \
        "a zero pad scene maps to zero output: healthy"


def test_retry_after_hint_clamped_to_positive_floor():
    """Satellite: a cold or degenerate service-time EWMA must never
    produce a non-positive retry hint (callers would hammer the bound)."""
    from repro.service import RequestQueue, ServiceOverloaded, FocusRequest

    async def main():
        q = RequestQueue(1)
        # drive the EWMA toward zero with degenerate service times
        for _ in range(200):
            q.note_service_time(1e-12)
        assert q.retry_after_hint(0) >= 1e-3
        loop = asyncio.get_running_loop()
        req = FocusRequest(raw=np.zeros((2, 2), np.complex64), scene=CFG,
                           variant="fused3", precision=None,
                           future=loop.create_future(), t_submit=0.0)
        q.put(req)
        with pytest.raises(ServiceOverloaded) as ei:
            q.put(req)
        assert ei.value.retry_after_hint > 0
        assert "retry_after_hint=" in str(ei.value)
        # the rendered hint is a positive number, not 0.000
        assert "retry_after_hint=0.000s" not in str(ei.value)

    asyncio.run(main())


# ---------------------------------------------------------------------------
# Fault injector / seeded schedule
# ---------------------------------------------------------------------------

def test_seeded_schedule_is_deterministic_and_covers_seams():
    seams = ("dispatch_error", "nan_output", "lane_hang", "straggler")
    a = seeded_schedule(20260808, 12, seams)
    b = seeded_schedule(20260808, 12, seams)
    c = seeded_schedule(1, 12, seams)
    assert a == b, "same seed, same schedule"
    assert a != c, "different seed, different placement"
    assert sorted(s.seam for s in a) == sorted(seams)
    ordinals = [s.at_dispatch for s in a]
    assert len(set(ordinals)) == len(ordinals), "distinct ordinals"
    assert min(ordinals) >= 2, "earliest dispatches stay clean"


def test_fault_spec_validates_seams():
    with pytest.raises(ValueError):
        FaultSpec(seam="nope", at_dispatch=0)
    with pytest.raises(ValueError):
        FaultSpec(seam="dispatch_error")       # needs at_dispatch
    with pytest.raises(ValueError):
        FaultSpec(seam="poison_scene")         # needs a digest
    assert set(SEAMS) >= {"dispatch_error", "nan_output", "lane_hang"}


def test_ordinal_fault_not_shadowed_by_poison_hit():
    """A poison-scene match and an ordinal-keyed fault colliding on the
    same dispatch: the ordinal fault fires (its ordinal never comes
    back) and the poison still fires on the scene's NEXT dispatch, so
    seams_fired() undercounts neither."""
    raw = np.asarray(scene())
    inj = FaultInjector([
        FaultSpec(seam="dispatch_error", at_dispatch=0),
        FaultSpec(seam="poison_scene", match=scene_digest(raw))])
    with pytest.raises(SimulatedFailure, match="dispatch error"):
        inj.begin([raw])                   # ordinal fault wins the tie
    with pytest.raises(SimulatedFailure, match="poison"):
        inj.begin([raw])                   # the poison re-fires next
    assert inj.seams_fired() == ["dispatch_error", "poison_scene"]


def test_chaos_backend_injects_dispatch_error_once_then_recovers():
    backend = ChaosBackend(
        fast_backend(),
        FaultInjector([FaultSpec(seam="dispatch_error", at_dispatch=0)]))
    key = BatchKey(CFG, "fused3", None, False)
    raw = np.asarray(scene())[None]
    with pytest.raises(SimulatedFailure):
        backend.execute(key, raw)
    out = backend.execute(key, raw)        # ordinal 1: clean
    assert np.array_equal(out[0], reference())
    assert backend.injector.seams_fired() == ["dispatch_error"]


def test_chaos_backend_nan_output_corrupts_scene_zero_only():
    backend = ChaosBackend(
        fast_backend(),
        FaultInjector([FaultSpec(seam="nan_output", at_dispatch=0)]))
    key = BatchKey(CFG, "fused3", None, False)
    raw = np.asarray(scene())
    out = backend.execute(key, np.stack([raw, raw * 0.5]))
    assert not np.all(np.isfinite(out[0]))
    assert np.all(np.isfinite(out[1])), "coalesced neighbor stays healthy"


# ---------------------------------------------------------------------------
# Service-level recovery: retry, sentinel, bisection, lane supervision
# ---------------------------------------------------------------------------

def _svc_config(**kw):
    base = dict(max_batch=4, max_delay_ms=20.0, precision=None,
                lanes=1, inflight_cap=1, max_retries=2,
                retry_backoff_ms=5.0, stall_floor_s=30.0)
    base.update(kw)
    return ServiceConfig(**base)


def test_service_retries_injected_dispatch_error_transparently():
    raw = scene()
    ref = reference()
    backend = ChaosBackend(
        fast_backend(),
        FaultInjector([FaultSpec(seam="dispatch_error", at_dispatch=0)]))

    async def main():
        svc = FocusService(_svc_config(), backend=backend)
        await svc.start(warm=[(CFG, "fused3", None)])
        outs = await asyncio.gather(*[svc.focus(raw, CFG)
                                      for _ in range(3)])
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    for o in outs:
        assert np.array_equal(o, ref), \
            "retried batch must stay bit-identical"
    assert snap["dispatch_failures"] == 1
    assert snap["retries"] == 1
    assert snap["failed"] == 0 and snap["completed"] == 3


def test_service_sentinel_turns_nan_output_into_retry_then_success():
    raw = scene()
    ref = reference()
    backend = ChaosBackend(
        fast_backend(),
        FaultInjector([FaultSpec(seam="nan_output", at_dispatch=0)]))

    async def main():
        svc = FocusService(_svc_config(), backend=backend)
        await svc.start(warm=[(CFG, "fused3", None)])
        outs = await asyncio.gather(svc.focus(raw, CFG),
                                    svc.focus(raw * 0.5, CFG))
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert np.array_equal(outs[0], ref)
    assert np.array_equal(outs[1], np.asarray(build_pipeline(
        CFG, "fused3").run(jnp.asarray(raw) * 0.5)))
    assert snap["corrupted"] == 1, "exactly the injected scene flagged"
    assert snap["retries"] >= 1
    assert snap["failed"] == 0


def test_service_sentinel_exhausted_raises_output_corrupted():
    """A backend that ALWAYS produces NaN output must surface a typed
    OutputCorrupted error, not a silent wrong image or a hang."""
    raw = scene()

    class _AlwaysNan:
        def warm(self, key, max_batch=4):
            pass

        def execute(self, key, batch):
            out = np.full_like(batch, np.nan)
            return out

        def execute_streamed(self, key, raw, strips=4):
            return np.full_like(raw, np.nan)

    async def main():
        svc = FocusService(_svc_config(max_retries=1, bisect=False),
                           backend=_AlwaysNan())
        await svc.start()
        with pytest.raises(OutputCorrupted):
            await svc.focus(raw, CFG)
        await svc.stop()
        return svc.metrics.snapshot()

    snap = asyncio.run(main())
    assert snap["corrupted"] >= 1
    assert snap["failed"] == 1


def test_poison_batch_bisection_isolates_one_bad_scene():
    """A coalesced batch with one poison scene: retries can't help (the
    poison is content-keyed and deterministic), so the domain bisects —
    the three healthy neighbors serve bit-identically and ONLY the
    poison request gets the typed error."""
    raw = np.asarray(scene())
    poison = raw * 0.25
    backend = ChaosBackend(
        fast_backend(),
        FaultInjector([FaultSpec(seam="poison_scene",
                                 match=scene_digest(poison))]))
    ref = reference()

    async def main():
        svc = FocusService(_svc_config(max_retries=0, max_delay_ms=100.0),
                           backend=backend)
        await svc.start(warm=[(CFG, "fused3", None)])
        outs = await asyncio.gather(
            svc.focus(raw, CFG), svc.focus(poison.copy(), CFG),
            svc.focus(raw, CFG), svc.focus(raw, CFG),
            return_exceptions=True)
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert np.array_equal(outs[0], ref)
    assert isinstance(outs[1], SimulatedFailure), \
        "the poison request fails alone, with the typed error"
    assert np.array_equal(outs[2], ref)
    assert np.array_equal(outs[3], ref)
    assert snap["bisections"] >= 1
    assert snap["completed"] == 3 and snap["failed"] == 1


def test_lane_stall_watchdog_restarts_lane_and_retries():
    """An injected lane hang must trip the stall watchdog: the lane
    restarts (fresh executor thread, generation bump), the batch retries
    on the fresh thread, and the request still resolves correctly."""
    raw = scene()
    ref = reference()
    injector = FaultInjector([FaultSpec(seam="lane_hang", at_dispatch=1)],
                             hang_timeout_s=60.0)
    backend = ChaosBackend(fast_backend(), injector)

    async def main():
        svc = FocusService(
            _svc_config(stall_factor=3.0, stall_floor_s=1.0,
                        max_retries=2),
            backend=backend)
        await svc.start(warm=[(CFG, "fused3", None)])
        first = await svc.focus(raw, CFG)       # ordinal 0: clean, warms EWMA
        second = await svc.focus(raw, CFG)      # ordinal 1: hangs
        pool_snap = svc.pool.snapshot()
        await svc.stop()
        return first, second, pool_snap, svc.metrics.snapshot()

    try:
        first, second, pool_snap, snap = asyncio.run(main())
    finally:
        injector.release_hangs()
    assert np.array_equal(first, ref)
    assert np.array_equal(second, ref), \
        "the retried batch (fresh lane thread) stays bit-identical"
    assert snap["lane_stalls"] == 1
    assert snap["failed"] == 0
    lane = pool_snap["fused0"]
    assert lane["stalls"] == 1 and lane["generation"] == 1


def test_lane_stall_releases_gate_lock_for_exclusive_work():
    """The hung thread held the gate lock's read side; the restart must
    force-release it so exclusive work (warms, gate measurements) after
    the stall does not deadlock."""
    raw = scene()
    injector = FaultInjector([FaultSpec(seam="lane_hang", at_dispatch=1)],
                             hang_timeout_s=60.0)
    backend = ChaosBackend(fast_backend(), injector)

    async def main():
        svc = FocusService(
            _svc_config(stall_factor=3.0, stall_floor_s=1.0),
            backend=backend)
        await svc.start(warm=[(CFG, "fused3", None)])
        await svc.focus(raw, CFG)
        await svc.focus(raw, CFG)               # stalls + recovers
        # exclusive-side work must still be possible (no reader leak)
        out = await asyncio.wait_for(
            svc.pool.run_exclusive(lambda: "ok"), timeout=10.0)
        await svc.stop()
        return out

    try:
        assert asyncio.run(main()) == "ok"
    finally:
        injector.release_hangs()


def test_stall_clock_counts_running_time_not_queue_wait():
    """A batch queued behind its lane sibling on the single worker
    thread must not accrue queue wait toward its own stall timeout (a
    healthy lane serving one long batch + one queued batch would
    false-trip the watchdog), and its busy/baseline seconds must be the
    RUN time, not submit-to-done wall time."""
    from repro.service.workers import WorkerPool

    async def main():
        pool = WorkerPool(lanes=1, inflight_cap=2)
        pool.start()
        lane = pool.batch_lanes[0]
        # A runs 1.2s (within ITS 5s watchdog) while B — watchdog 0.3s,
        # far shorter than A's remaining run — waits in queue
        ta = asyncio.ensure_future(
            pool.run_batch(lane, time.sleep, 1.2, stall_timeout=5.0))
        await asyncio.sleep(0.1)        # A is on the worker thread
        tb = asyncio.ensure_future(
            pool.run_batch(lane, lambda: "ok", stall_timeout=0.3))
        (_, secs_a), (out_b, secs_b) = await asyncio.gather(ta, tb)
        snap = (lane.stalls, lane.generation)
        pool.shutdown()
        return out_b, secs_a, secs_b, snap

    out_b, secs_a, secs_b, (stalls, generation) = asyncio.run(main())
    assert out_b == "ok", "queued batch served after its sibling"
    assert stalls == 0 and generation == 0, \
        "queue wait must not trip the watchdog"
    assert secs_a > 1.0
    assert secs_b < 0.3, \
        "busy/baseline seconds are run time, not submit-to-done wall"


def test_queued_handoff_cancelled_by_restart_resolves_not_hangs():
    """THE no-pending-future contract under a sibling stall: when the
    watchdog restarts a lane, a hand-off already queued on the torn-down
    executor is cancelled — that cancellation must surface as a
    retryable LaneStalled inside the recovery ladder (CancelledError is
    a BaseException the ladder's `except Exception` never sees), so the
    queued batch re-dispatches and every request still resolves."""
    raw = scene()
    ref = reference()
    injector = FaultInjector([FaultSpec(seam="lane_hang", at_dispatch=1)],
                             hang_timeout_s=60.0)
    backend = ChaosBackend(fast_backend(), injector)

    async def main():
        svc = FocusService(
            _svc_config(max_batch=1, inflight_cap=2,
                        stall_factor=3.0, stall_floor_s=1.0,
                        max_retries=2, max_delay_ms=5.0),
            backend=backend)
        await svc.start(warm=[(CFG, "fused3", None)])
        first = await svc.focus(raw, CFG)       # ordinal 0: warms EWMA
        # the hang (ordinal 1) and a sibling queued behind it on the
        # same lane executor; the sibling's future must still resolve
        outs = await asyncio.wait_for(
            asyncio.gather(svc.focus(raw, CFG), svc.focus(raw, CFG)),
            timeout=60.0)
        await svc.stop()
        return first, outs, svc.metrics.snapshot(), svc.pool.snapshot()

    try:
        first, outs, snap, pool_snap = asyncio.run(main())
    finally:
        injector.release_hangs()
    assert np.array_equal(first, ref)
    for out in outs:
        assert np.array_equal(out, ref), \
            "both the stalled and the cancelled-queued batch recover"
    assert snap["failed"] == 0
    assert pool_snap["fused0"]["stalls"] >= 1


def test_tier_probe_dispatch_failure_reopens_breaker():
    """A half-open tier probe whose batch dies on the DISPATCH-error
    path must record an outcome: the breaker re-opens (cooldown
    re-armed) instead of wedging half_open with the default tier pinned
    to f32 and no further re-probes."""
    raw = scene()
    clk = _Clock()
    backend = ChaosBackend(
        fast_backend(),
        FaultInjector([FaultSpec(seam="dispatch_error", at_dispatch=0)]))

    async def main():
        svc = FocusService(
            _svc_config(precision="bs16", max_retries=0, bisect=False),
            backend=backend, precision_deviation=lambda p: 0.0)
        svc._tier_breakers = BreakerBoard(threshold=1, cooldown_s=10.0,
                                          clock=clk)
        await svc.start(warm=[(CFG, "fused3", "bs16")])
        br = svc._tier_breakers.get("tier:bs16")
        br.record_failure()                # tier breaker opens
        assert br.state == "open"
        clk.t = 10.0                       # cooldown over: probe admitted
        with pytest.raises(SimulatedFailure):
            await svc.focus(raw, CFG)      # the probe dies mid-dispatch
        assert br.state == "open", \
            "dispatch-path death recorded an outcome (no half-open wedge)"
        clk.t = 20.0                       # next cooldown: fresh probe
        out = await svc.focus(raw, CFG)    # ordinal 1: clean
        assert br.state == "closed", "successful probe closes the breaker"
        await svc.stop()
        return out

    out = asyncio.run(main())
    assert np.array_equal(out, reference(precision="bs16")), \
        "the recovered probe serves the reduced tier bit-identically"


# ---------------------------------------------------------------------------
# Fallback bit-identity matrix (the degraded-route counterpart of the
# route-invisibility matrix)
# ---------------------------------------------------------------------------

class _Boom:
    calls = None

    def __init__(self):
        self.calls = 0

    def __call__(self, *a, **k):
        self.calls += 1
        raise RuntimeError("injected tier failure")


@pytest.mark.parametrize("precision", [None, "bf16", "bs16"])
def test_fallback_fused1_to_fused3_bit_identical(precision):
    """Tier degradation fused1 -> fused3: when the megakernel tier
    fails, the per-axis tier serves the SAME image bit-for-bit at every
    precision (they are twins by construction)."""
    backend = fast_backend()
    key = BatchKey(CFG, "fused3", precision, False)
    assert backend._route_variant(key) == "fused1", \
        "128^2 fits VMEM: the megakernel tier must be tier 0"
    boom = _Boom()
    backend._fns[(key, "fused1")] = boom       # tier 0 dispatches fail
    raw = np.asarray(scene())[None]
    out = backend.execute(key, raw)
    kw = {} if precision is None else {"precision": precision}
    ref = np.asarray(build_pipeline(CFG, "fused3", **kw).run(
        jnp.asarray(raw[0])))
    assert boom.calls == 1
    assert np.array_equal(out[0], ref)
    assert backend.fallbacks["serve:plan"] == 1


def test_fallback_breaker_opens_then_half_open_probe_recovers():
    """Repeated tier-0 failures open the route breaker (the hot path
    stops paying the failed dispatch); after the cooldown one probe
    re-tries fused1 and a success closes the breaker again."""
    clk = _Clock()
    backend = fast_backend(
        breakers=BreakerBoard(threshold=2, cooldown_s=10.0, clock=clk))
    key = BatchKey(CFG, "fused3", None, False)
    boom = _Boom()
    real = backend._fn(key, "fused1")          # keep the real fn around
    backend._fns[(key, "fused1")] = boom
    raw = np.asarray(scene())[None]
    ref = reference()
    name = f"fused1:fused1:{CFG.na}x{CFG.nr}:None"
    for _ in range(2):                         # trip the breaker
        assert np.array_equal(backend.execute(key, raw)[0], ref)
    assert backend.breakers.get(name).state == "open"
    backend.execute(key, raw)
    assert boom.calls == 2, "open breaker: fused1 not even attempted"
    clk.t = 10.0                               # cooldown elapses
    backend._fns[(key, "fused1")] = real       # the route healed
    out = backend.execute(key, raw)            # half-open probe
    assert np.array_equal(out[0], ref)
    assert backend.breakers.get(name).state == "closed"


def test_fallback_defused_last_resort_serves_when_both_fused_tiers_fail():
    """fused1 AND fused3 failing still serves through the defused chain
    — numerically equivalent (<=0.1 dB point-target SNR delta), by
    design not bit-identical, and infinitely better than an error."""
    backend = fast_backend()
    key = BatchKey(CFG, "fused3", None, False)
    backend._fns[(key, "fused1")] = _Boom()
    backend._fns[(key, "fused3")] = _Boom()
    raw = np.asarray(scene())[None]
    out = backend.execute(key, raw)
    np.testing.assert_allclose(out[0], reference("unfused"),
                               rtol=1e-4, atol=1e-5)
    rep = compare_pipelines(out[0], reference(), CFG, TARGETS)
    assert max(rep["snr_delta_db"]) <= 0.1
    assert backend.fallbacks["serve:defused"] == 1


def test_fallback_sharded_to_local_stream_bit_identical(monkeypatch):
    """The big-scene sharded route failing mid-serve falls back to the
    single-device strip path, bit-identical (same math, same precision,
    different partitioning)."""
    backend = fast_backend()
    key = BatchKey(CFG, "fused3", None, True)
    monkeypatch.setattr(backend, "_sharded_twin", lambda k: "fused1")
    monkeypatch.setattr(backend, "_sharded_fn",
                        lambda k: _Boom())
    raw = np.asarray(scene())
    out = backend.execute_streamed(key, raw, strips=4)
    ref = np.asarray(build_pipeline(CFG, "fused3").run_streamed(
        raw, strips=4))
    assert np.array_equal(out, ref)
    assert backend.fallbacks["serve:local_stream"] == 1


def test_gate_trip_on_default_tier_falls_back_to_f32():
    """The DEFAULT serving tier tripping the SNR gate degrades to the
    f32 verification path (<=0.1 dB by the gate's own definition —
    here bit-equal to the f32 reference) instead of erroring; EXPLICIT
    per-request precisions keep the strict SnrGateViolation contract."""
    from repro.service import SnrGateViolation
    raw = scene()
    ref_f32 = reference()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=2, max_delay_ms=20.0,
                          precision="bs16", lanes=1),
            backend=fast_backend(),
            precision_deviation=lambda p: 0.5)   # out of the 0.1 dB gate
        await svc.start()
        out = await svc.focus(raw, CFG)          # default tier: degrades
        with pytest.raises(SnrGateViolation):
            await svc.focus(raw, CFG, precision="bs16")  # explicit: raises
        await svc.stop()
        return out, svc.metrics.snapshot()

    out, snap = asyncio.run(main())
    assert np.array_equal(out, ref_f32), \
        "the degraded request serves the f32 verification image"
    assert snap["tier_fallbacks"] >= 1
    assert snap["gate_rejected"] >= 1
    rep = compare_pipelines(out, reference(precision="bs16"), CFG, TARGETS)
    assert max(rep["snr_delta_db"]) <= 0.1, \
        "precision step stays within the gate bound on this scene"


def test_gate_trip_breaker_skips_measurement_after_threshold():
    """After `breaker_threshold` gate trips the tier breaker opens:
    admission routes default-tier requests straight to f32 without
    re-consulting the gate until the cooldown expires."""
    calls = []

    def deviation(p):
        calls.append(p)
        return 0.5

    raw = scene()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=1, max_delay_ms=5.0, precision="bs16",
                          lanes=1, breaker_threshold=2,
                          breaker_cooldown_s=3600.0),
            backend=fast_backend(), precision_deviation=deviation)
        await svc.start()
        for _ in range(4):
            await svc.focus(raw, CFG)
        await svc.stop()
        return svc.metrics.snapshot()

    snap = asyncio.run(main())
    assert len(calls) == 1, "deviation measured once (gate cache)"
    assert snap["tier_fallbacks"] == 4
    assert snap["completed"] == 4


# ---------------------------------------------------------------------------
# Mini chaos replay: 0 lost requests across >=3 seams
# ---------------------------------------------------------------------------

def test_chaos_replay_loses_no_requests():
    """End-to-end chaos property at test scale: a seeded schedule firing
    dispatch_error + nan_output + lane_hang over a request stream must
    leave NO lost request — every future resolves to the bit-identical
    image or a typed error — and the service keeps serving afterwards."""
    raw = np.asarray(scene())
    ref = reference()
    # 14 requests at max_batch=2 guarantee 7 dispatches before any
    # retries, so every ordinal in [2, 7) is reached
    injector = FaultInjector(
        seeded_schedule(20260808, 7,
                        ("dispatch_error", "nan_output", "lane_hang")),
        hang_timeout_s=60.0)
    backend = ChaosBackend(fast_backend(), injector)

    async def main():
        svc = FocusService(
            _svc_config(max_batch=2, lanes=2, inflight_cap=1,
                        stall_factor=3.0, stall_floor_s=1.5,
                        max_retries=2),
            backend=backend)
        await svc.start(warm=[(CFG, "fused3", None)])
        outs = await asyncio.gather(
            *[svc.focus(raw, CFG) for _ in range(14)],
            return_exceptions=True)
        await svc.stop()
        return outs, svc.metrics.snapshot()

    try:
        outs, snap = asyncio.run(main())
    finally:
        injector.release_hangs()
    assert len(injector.seams_fired()) == 3, injector.seams_fired()
    lost = sum(1 for o in outs
               if not (isinstance(o, np.ndarray)
                       and np.array_equal(o, ref))
               and not isinstance(o, (SimulatedFailure, OutputCorrupted,
                                      LaneStalled)))
    assert lost == 0, f"{lost} lost requests: {outs}"
    typed_errors = sum(1 for o in outs if isinstance(o, Exception))
    assert snap["completed"] == 14 - typed_errors
    assert snap["completed"] >= 11, \
        "retries + bisection must recover most faulted requests"
