"""Per-kernel allclose sweeps + hypothesis property tests vs the jnp oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - see requirements-dev.txt
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.transpose import transpose

RNG = np.random.default_rng(7)


def rand(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


def assert_close(got, want, tol=2e-4):
    gr, gi = got
    wr, wi = want
    scale = max(float(jnp.max(jnp.abs(wr))), float(jnp.max(jnp.abs(wi))), 1e-30)
    np.testing.assert_allclose(np.asarray(gr), np.asarray(wr),
                               atol=tol * scale, rtol=0)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(wi),
                               atol=tol * scale, rtol=0)


# ---------------------------------------------------------------------------
# Shape / impl / axis sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["matmul", "stockham"])
@pytest.mark.parametrize("n", [16, 64, 256, 1024])
@pytest.mark.parametrize("axis", [0, 1])
def test_fft_sweep(impl, n, axis):
    lines = 6
    shape = (lines, n) if axis == 1 else (n, lines)
    xr, xi = rand(*shape), rand(*shape)
    got = ops.spectral_op(jnp.asarray(xr), jnp.asarray(xi), fwd=True,
                          inv=False, axis=axis, fft_impl=impl, block=2)
    assert_close(got, ref.fft_ref(xr, xi, axis=axis))


@pytest.mark.parametrize("impl", ["matmul", "stockham"])
@pytest.mark.parametrize("n", [64, 512])
def test_ifft_sweep(impl, n):
    xr, xi = rand(4, n), rand(4, n)
    got = ops.ifft_rows(jnp.asarray(xr), jnp.asarray(xi), fft_impl=impl,
                        block=4)
    assert_close(got, ref.ifft_ref(xr, xi, axis=1))


@pytest.mark.parametrize("mode", ["shared", "full", "outer", "shared_outer"])
def test_fused_filter_modes(mode):
    n, lines = 128, 8
    xr, xi = rand(lines, n), rand(lines, n)
    kw = dict(fwd=True, inv=True, axis=1, block=4, filter_mode=mode)
    if mode in ("shared", "full"):
        shape = (n,) if mode == "shared" else (lines, n)
        hr, hi = rand(*shape), rand(*shape)
        got = ops.spectral_op(jnp.asarray(xr), jnp.asarray(xi),
                              hr=jnp.asarray(hr), hi=jnp.asarray(hi), **kw)
        hb = (hr[None, :], hi[None, :]) if mode == "shared" else (hr, hi)
        want = ref.spectral_ref(xr, xi, axis=1, fwd=True, inv=True,
                                hr=hb[0], hi=hb[1])
    elif mode == "outer":
        u, v = rand(lines, 2), rand(n, 2)
        got = ops.spectral_op(jnp.asarray(xr), jnp.asarray(xi),
                              u=jnp.asarray(u), v=jnp.asarray(v), **kw)
        want = ref.spectral_ref(xr, xi, axis=1, fwd=True, inv=True, u=u, v=v)
    else:
        hr, hi = rand(n), rand(n)
        u, v = rand(lines), rand(n)
        got = ops.spectral_op(jnp.asarray(xr), jnp.asarray(xi),
                              hr=jnp.asarray(hr), hi=jnp.asarray(hi),
                              u=jnp.asarray(u), v=jnp.asarray(v), **kw)
        want = ref.spectral_ref(xr, xi, axis=1, fwd=True, inv=True,
                                hr=hr[None, :], hi=hi[None, :], u=u, v=v)
    assert_close(got, want)


@pytest.mark.parametrize("n1,n2", [(8, 8), (16, 4), (32, 32), (128, 8)])
def test_factorizations(n1, n2):
    n = n1 * n2
    xr, xi = rand(4, n), rand(4, n)
    got = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), n1=n1, n2=n2,
                       block=4)
    assert_close(got, ref.fft_ref(xr, xi, axis=1))


def test_karatsuba_and_bf16():
    xr, xi = rand(4, 512), rand(4, 512)
    want = ref.fft_ref(xr, xi, axis=1)
    got = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), karatsuba=True,
                       block=4)
    assert_close(got, want)
    got = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), compute_dtype="bf16",
                       block=4)
    assert_close(got, want, tol=5e-2)


def test_line_padding():
    xr, xi = rand(5, 64), rand(5, 64)
    got = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), block=4)
    assert_close(got, ref.fft_ref(xr, xi, axis=1))


@pytest.mark.parametrize("r,c", [(64, 64), (128, 256), (96, 32)])
def test_transpose(r, c):
    x = rand(r, c)
    np.testing.assert_array_equal(np.asarray(transpose(jnp.asarray(x), tile=32)),
                                  x.T)


def test_paper_n4096():
    """The paper's exact FFT size (N = 4096, the 32 KiB line)."""
    xr, xi = rand(2, 4096), rand(2, 4096)
    got = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), block=2)
    assert_close(got, ref.fft_ref(xr, xi, axis=1), tol=5e-4)


# ---------------------------------------------------------------------------
# Batched multi-scene dispatch + mixed-radix three-factor decompositions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B", [1, 3])
@pytest.mark.parametrize("n", [512, 4096, 8192])
def test_batched_fused_pipeline_vs_ref(B, n):
    """The batched fused dispatch (FFT * H * IFFT over (B, L, n)) matches
    the unfused per-scene jnp.fft reference at the seed tolerance."""
    lines = 4
    xr, xi = rand(B, lines, n), rand(B, lines, n)
    hr, hi = rand(n), rand(n)
    got = ops.fused_fft_mult_ifft_rows(
        jnp.asarray(xr), jnp.asarray(xi), jnp.asarray(hr), jnp.asarray(hi),
        block=2)
    assert got[0].shape == (B, lines, n)
    want = ref.spectral_ref(xr, xi, axis=-1, fwd=True, inv=True, hr=hr, hi=hi)
    assert_close(got, want, tol=5e-4)


@pytest.mark.parametrize("B", [1, 3])
@pytest.mark.parametrize("n", [512, 4096, 8192])
def test_batched_fft_rows_and_cols(B, n):
    lines = 4
    xr, xi = rand(B, lines, n), rand(B, lines, n)
    got = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), block=2)
    assert_close(got, ref.fft_ref(xr, xi, axis=-1), tol=5e-4)
    xr, xi = rand(B, n, lines), rand(B, n, lines)
    got = ops.fft_cols(jnp.asarray(xr), jnp.asarray(xi), block=2)
    assert_close(got, ref.fft_ref(xr, xi, axis=-2), tol=5e-4)


@pytest.mark.parametrize("B", [1, 3])
@pytest.mark.parametrize("n1,n2,n3", [(8, 8, 8), (16, 8, 4), (32, 16, 16)])
def test_three_factor_explicit(B, n1, n2, n3):
    n = n1 * n2 * n3
    xr, xi = rand(B, 4, n), rand(B, 4, n)
    got = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), n1=n1, n2=n2, n3=n3,
                       block=2)
    assert_close(got, ref.fft_ref(xr, xi, axis=-1), tol=5e-4)


def test_three_factor_default_32768():
    """Lengths past 128*128 decompose to three factors instead of erroring."""
    from repro.kernels.fft4step import default_factorization
    fs = default_factorization(32768)
    assert len(fs) == 3 and all(f <= 128 for f in fs)
    xr, xi = rand(2, 32768), rand(2, 32768)
    got = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), block=2)
    assert_close(got, ref.fft_ref(xr, xi, axis=1), tol=1e-3)


def test_batched_outer_and_full_filters():
    B, lines, n = 2, 4, 128
    xr, xi = rand(B, lines, n), rand(B, lines, n)
    u, v = rand(lines, 2), rand(n, 2)
    got = ops.spectral_op(jnp.asarray(xr), jnp.asarray(xi),
                          u=jnp.asarray(u), v=jnp.asarray(v),
                          fwd=True, inv=True, axis=1, block=2,
                          filter_mode="outer")
    want = ref.spectral_ref(xr, xi, axis=-1, fwd=True, inv=True, u=u, v=v)
    assert_close(got, want, tol=5e-4)
    hr, hi = rand(lines, n), rand(lines, n)
    got = ops.spectral_op(jnp.asarray(xr), jnp.asarray(xi),
                          hr=jnp.asarray(hr), hi=jnp.asarray(hi),
                          fwd=True, inv=True, axis=1, block=2,
                          filter_mode="full")
    want = ref.spectral_ref(xr, xi, axis=-1, fwd=True, inv=True, hr=hr, hi=hi)
    assert_close(got, want, tol=5e-4)


def test_unbatched_equals_b1():
    """The 2-D public API is exactly the B=1 slice of the batched path."""
    xr, xi = rand(4, 256), rand(4, 256)
    a = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), block=2)
    b = ops.fft_rows(jnp.asarray(xr)[None], jnp.asarray(xi)[None], block=2)
    assert a[0].shape == (4, 256) and b[0].shape == (1, 4, 256)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0][0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1][0]))


def test_batched_transpose():
    x = rand(3, 64, 64)
    got = np.asarray(transpose(jnp.asarray(x), tile=32))
    np.testing.assert_array_equal(got, np.swapaxes(x, -1, -2))


# ---------------------------------------------------------------------------
# Property tests (hypothesis)
# ---------------------------------------------------------------------------

shapes = st.sampled_from([(2, 16), (4, 64), (2, 256)])


@settings(max_examples=20, deadline=None)
@given(shape=shapes, seed=st.integers(0, 2**31 - 1),
       a=st.floats(-3, 3), b=st.floats(-3, 3))
def test_linearity(shape, seed, a, b):
    r = np.random.default_rng(seed)
    x = r.standard_normal(shape).astype(np.float32)
    y = r.standard_normal(shape).astype(np.float32)
    z = np.zeros(shape, np.float32)
    fx = ops.fft_rows(jnp.asarray(x), jnp.asarray(z), block=2)
    fy = ops.fft_rows(jnp.asarray(y), jnp.asarray(z), block=2)
    fxy = ops.fft_rows(jnp.asarray(a * x + b * y), jnp.asarray(z), block=2)
    want = (a * fx[0] + b * fy[0], a * fx[1] + b * fy[1])
    assert_close(fxy, want, tol=1e-3)


@settings(max_examples=20, deadline=None)
@given(shape=shapes, seed=st.integers(0, 2**31 - 1))
def test_parseval(shape, seed):
    r = np.random.default_rng(seed)
    xr = r.standard_normal(shape).astype(np.float32)
    xi = r.standard_normal(shape).astype(np.float32)
    fr, fi = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), block=2)
    e_t = np.sum(xr**2 + xi**2)
    e_f = float(jnp.sum(fr**2 + fi**2)) / shape[1]
    np.testing.assert_allclose(e_f, e_t, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(shape=shapes, seed=st.integers(0, 2**31 - 1))
def test_ifft_inverts_fft(shape, seed):
    r = np.random.default_rng(seed)
    xr = r.standard_normal(shape).astype(np.float32)
    xi = r.standard_normal(shape).astype(np.float32)
    fr, fi = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), block=2)
    br, bi = ops.ifft_rows(fr, fi, block=2)
    assert_close((br, bi), (xr, xi), tol=1e-3)


@settings(max_examples=40, deadline=None)
@given(mag=st.floats(-60, 60), axis=st.sampled_from([0, 1]),
       seed=st.integers(0, 2**31 - 1))
def test_bs16_codec_round_trip(mag, axis, seed):
    """The bs16 exponent codec: extract -> remove -> apply is the EXACT
    identity (power-of-two scaling never rounds a normal float), and the
    f16-quantized round trip stays within the half-float mantissa bound
    (2^-10 of each line's amax), for line magnitudes across 2^-60..2^60
    — the dynamic range the per-line exponents exist to absorb."""
    from repro.kernels.fft4step import apply_exponents, line_exponents, \
        remove_exponents
    r = np.random.default_rng(seed)
    shape = (4, 32)
    scale = np.float32(2.0) ** np.float32(mag)
    xr = (r.standard_normal(shape) * scale).astype(np.float32)
    xi = (r.standard_normal(shape) * scale).astype(np.float32)
    exp = line_exponents(jnp.asarray(xr), jnp.asarray(xi), axis)
    sr, si = remove_exponents(jnp.asarray(xr), jnp.asarray(xi), exp)
    # scaled magnitudes land in [0, 1]: representable in f16 verbatim
    assert float(jnp.max(jnp.abs(sr))) <= 1.0
    assert float(jnp.max(jnp.abs(si))) <= 1.0
    rr, ri = apply_exponents(sr, si, exp)
    np.testing.assert_array_equal(np.asarray(rr), xr)
    np.testing.assert_array_equal(np.asarray(ri), xi)
    # quantizing the scaled mantissas to f16 bounds the error per LINE
    qr = np.asarray(sr).astype(np.float16).astype(np.float32)
    qi = np.asarray(si).astype(np.float16).astype(np.float32)
    qrr, qri = apply_exponents(jnp.asarray(qr), jnp.asarray(qi), exp)
    red = 1 if axis == 1 else 0
    amax = np.maximum(np.abs(xr).max(axis=red, keepdims=True),
                      np.abs(xi).max(axis=red, keepdims=True))
    bound = amax * 2.0 ** -10
    assert np.all(np.abs(np.asarray(qrr) - xr) <= bound)
    assert np.all(np.abs(np.asarray(qri) - xi) <= bound)


@settings(max_examples=15, deadline=None)
@given(shape=shapes, seed=st.integers(0, 2**31 - 1))
def test_fused_equals_composed(shape, seed):
    """The paper's core claim: one fused dispatch == the 3-dispatch chain."""
    r = np.random.default_rng(seed)
    lines, n = shape
    xr = r.standard_normal(shape).astype(np.float32)
    xi = r.standard_normal(shape).astype(np.float32)
    hr = r.standard_normal(n).astype(np.float32)
    hi = r.standard_normal(n).astype(np.float32)
    fused = ops.fused_fft_mult_ifft_rows(
        jnp.asarray(xr), jnp.asarray(xi), jnp.asarray(hr), jnp.asarray(hi),
        block=2)
    fr, fi = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), block=2)
    mr, mi = fr * hr - fi * hi, fr * hi + fi * hr
    want = ops.ifft_rows(mr, mi, block=2)
    assert_close(fused, (np.asarray(want[0]), np.asarray(want[1])), tol=1e-3)
