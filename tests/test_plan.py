"""SpectralPlan IR: serialization, fusion legality, backend equivalence,
streaming tiles, the ω-K plan, and the per-stage precision policy."""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import plan as planlib
from repro.core.plan import (
    SpectralPlan,
    Stage,
    plan_dispatch_count,
    plan_from_json,
    plan_to_json,
)
from repro.core.sar import (
    build_pipeline,
    documented_dispatches,
    metrics,
    paper_targets,
    simulate_cached,
    variant_names,
)
from repro.core.sar.geometry import test_scene as make_test_scene
from repro.kernels import ops, ref

CFG = make_test_scene(256)
TARGETS = paper_targets(CFG)

ALL_VARIANTS = ("unfused", "fused", "fused_tfree", "fused3",
                "csa", "csa_fused", "omegak")


def scene():
    return jnp.asarray(simulate_cached(CFG, TARGETS))


@pytest.fixture(scope="module")
def rda_reference():
    return np.asarray(build_pipeline(CFG, "unfused").run(scene()))


# ---------------------------------------------------------------------------
# IR round-trip + fusion legality
# ---------------------------------------------------------------------------

def test_all_variants_registered():
    assert set(ALL_VARIANTS) <= set(variant_names())


@pytest.mark.parametrize("variant", ALL_VARIANTS)
def test_plan_serialization_roundtrip(variant):
    var = planlib.get_variant(variant)
    plan = var.plan_fn()
    assert plan_from_json(plan_to_json(plan)) == plan
    # and with non-default plan parameters where the variant has them
    if "r_ref" in var.plan_kw:
        plan2 = var.plan_fn(r_ref=1234.5)
        assert plan_from_json(plan_to_json(plan2)) == plan2
        assert plan2.param_dict()["r_ref"] == 1234.5


@pytest.mark.parametrize("variant", ALL_VARIANTS)
def test_fusion_legality_dispatch_count(variant):
    """The fusion compiler must reproduce each variant's documented
    dispatch count exactly — no over- or under-fusion."""
    var = planlib.get_variant(variant)
    fuse = dict(var.compile_defaults).get("fuse", True)
    assert plan_dispatch_count(var.plan_fn(), fuse=fuse) == var.dispatches
    p = build_pipeline(CFG, variant)
    assert p.dispatches == documented_dispatches(variant) == var.dispatches


def test_fusion_grammar_barriers():
    """mul-after-ifft and fft-after-fft never fuse; transposes are walls."""
    two_ffts = SpectralPlan("p", (
        Stage("a", axis=1, fwd=True),
        Stage("b", axis=1, fwd=True),
    ))
    assert plan_dispatch_count(two_ffts) == 2
    mul_after_inv = SpectralPlan("p", (
        Stage("a", axis=1, fwd=True, inv=True, filters=("range_mf",)),
        Stage("b", axis=1, filters=("range_mf",)),
    ))
    assert plan_dispatch_count(mul_after_inv) == 2
    across_transpose = SpectralPlan("p", (
        Stage("a", axis=1, fwd=True),
        Stage("t", kind="transpose"),
        Stage("b", axis=0, inv=True),
    ))
    assert plan_dispatch_count(across_transpose) == 3
    # the canonical fusion: fft + two muls + ifft on one axis is ONE dispatch
    fused3_mid = SpectralPlan("p", (
        Stage("a", axis=1, fwd=True, inv=True,
              filters=("range_mf", "rcmc_shift")),
    ))
    assert plan_dispatch_count(fused3_mid) == 1


# ---------------------------------------------------------------------------
# Executor equivalences
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["fused3", "csa_fused", "omegak"])
def test_pallas_matches_xla_backend(variant):
    """Interpret-mode equivalence: the same plan compiled to fused Pallas
    dispatches and to unfused jnp oracle ops agrees at FP32 roundoff."""
    a = np.asarray(build_pipeline(CFG, variant).run(scene()))
    b = np.asarray(build_pipeline(CFG, variant, backend="xla",
                                  fuse=False).run(scene()))
    assert metrics.l2_relative_error(a, b) < 1e-5


def test_unfused_fuses_to_four_dispatches():
    """One plan, two compilations: the textbook RDA plan fused collapses
    3+1+1+2 atoms to [rc][az_fft][sinc][az_comp]."""
    var = planlib.get_variant("unfused")
    assert plan_dispatch_count(var.plan_fn(), fuse=True) == 4
    img_fused = np.asarray(planlib.compile_plan(
        var.plan_fn(), CFG, fuse=True).run(scene()))
    img_ref = np.asarray(build_pipeline(CFG, "unfused").run(scene()))
    assert metrics.l2_relative_error(img_fused, img_ref) < 1e-5


# ---------------------------------------------------------------------------
# Streaming tiles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["fused3", "omegak", "fused_tfree",
                                     "csa_fused"])
def test_streaming_bit_identical(variant):
    """The streaming executor over >=4 azimuth strips is bit-identical to
    the in-memory path (the kernel treats line blocks independently)."""
    p = build_pipeline(CFG, variant)
    raw = simulate_cached(CFG, TARGETS)
    mem = np.asarray(p.run(jnp.asarray(raw)))
    assert np.array_equal(p.run_streamed(raw, strips=4), mem)
    # ragged strip sizes must not change the numerics either
    assert np.array_equal(p.run_streamed(raw, strips=5), mem)


def test_streaming_rejects_transposed_plans():
    p = build_pipeline(CFG, "fused")   # the paper variant needs transposes
    with pytest.raises(ValueError, match="streaming"):
        p.run_streamed(simulate_cached(CFG, TARGETS), strips=4)


# ---------------------------------------------------------------------------
# The ω-K plan (tentpole acceptance)
# ---------------------------------------------------------------------------

def test_omegak_peaks_within_1px_of_rda(rda_reference):
    from repro.core.sar.rda import focus
    img = np.asarray(focus(scene(), CFG, variant="omegak"))
    ref_reps = metrics.analyze_scene(rda_reference, CFG, TARGETS)
    got_reps = metrics.analyze_scene(img, CFG, TARGETS)
    for tgt, r, g in zip(TARGETS, ref_reps, got_reps):
        assert abs(g.row - r.row) <= 1 and abs(g.col - r.col) <= 1, \
            (tgt, (g.row, g.col), (r.row, r.col))
        assert g.snr_db > 30.0, (tgt, g)


def test_omegak_batched_matches_unbatched():
    p = build_pipeline(CFG, "omegak")
    raw = scene()
    batch = jnp.stack([raw, 0.5 * raw])
    out = np.asarray(p.run(batch))
    one = np.asarray(p.run(raw))
    np.testing.assert_array_equal(out[0], one)
    scale = float(np.max(np.abs(one)))
    np.testing.assert_allclose(out[1], 0.5 * one, atol=1e-5 * scale, rtol=0)


# ---------------------------------------------------------------------------
# Precision policy
# ---------------------------------------------------------------------------

def test_bs16_block_scaling_rescues_f16_overflow():
    rng = np.random.default_rng(3)
    xr = rng.standard_normal((4, 512)).astype(np.float32) * 1e6
    xi = rng.standard_normal((4, 512)).astype(np.float32) * 1e6
    want = ref.fft_ref(xr, xi, axis=1)
    plain = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), precision="f16",
                         block=4)
    assert not np.isfinite(np.asarray(plain[0])).all()   # f16 overflows
    got = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi), precision="bs16",
                       block=4)
    scale = float(jnp.max(jnp.abs(want[0])))
    assert np.isfinite(np.asarray(got[0])).all()
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=2e-3 * scale, rtol=0)


def test_bs16_beats_bf16_accuracy():
    """The point of block scaling: f16's 11-bit mantissa under a shared
    exponent is markedly more accurate than bf16's 8-bit mantissa."""
    rng = np.random.default_rng(4)
    xr = rng.standard_normal((8, 1024)).astype(np.float32)
    xi = rng.standard_normal((8, 1024)).astype(np.float32)
    want = ref.fft_ref(xr, xi, axis=1)

    def err(precision):
        got = ops.fft_rows(jnp.asarray(xr), jnp.asarray(xi),
                           precision=precision, block=4)
        return float(jnp.max(jnp.abs(got[0] - want[0])))

    assert err("bs16") < err("bf16") / 2


def test_stage_precision_threads_through_plan():
    """A per-stage precision override reaches the kernel: a bs16-stage
    pipeline differs from f32 but stays within narrow-float tolerance."""
    img32 = np.asarray(build_pipeline(CFG, "fused3", tune="off").run(scene()))
    img16 = np.asarray(build_pipeline(CFG, "fused3", tune="off",
                                      precision="bs16").run(scene()))
    assert not np.array_equal(img16, img32)
    c = metrics.compare_pipelines(img16, img32, CFG, TARGETS)
    assert max(c["snr_delta_db"]) < 0.3, c["snr_delta_db"]


def test_precision_gate_function():
    from benchmarks.bench_quality import precision_snr_deviation
    dev = precision_snr_deviation("bs16")
    assert 0.0 <= dev < 0.3


# ---------------------------------------------------------------------------
# Filter cache
# ---------------------------------------------------------------------------

def test_filter_cache_skips_host_math_on_recompile():
    cfg = dataclasses.replace(CFG, seed=999)   # a key no other test warms
    build_pipeline(cfg, "omegak")
    before = planlib.filter_cache_stats()
    build_pipeline(cfg, "omegak")              # a "new scene" with same cfg
    after = planlib.filter_cache_stats()
    assert after["misses"] == before["misses"]


def test_unknown_filter_and_variant_raise():
    bad = SpectralPlan("p", (Stage("a", axis=1, fwd=True,
                                   filters=("nope",)),))
    with pytest.raises(KeyError, match="nope"):
        planlib.compile_plan(bad, CFG)
    with pytest.raises(KeyError, match="variant"):
        build_pipeline(CFG, "not_a_variant")
