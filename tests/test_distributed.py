"""Multi-device behaviour (8 fake CPU devices, subprocess-isolated so the
main test process keeps the host's real device count)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_distributed_sar_corner2_and_halo():
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.sar import test_scene, paper_targets, simulate, build_pipeline, metrics
from repro.core.sar.distributed import build_corner2, build_halo

cfg = test_scene(256)
targets = paper_targets(cfg)
raw = simulate(cfg, targets)
mesh = jax.make_mesh((8,), ("data",))

f3 = np.asarray(build_pipeline(cfg, "fused3").run(raw))
img = np.asarray(build_corner2(cfg, mesh)(raw))
assert float(np.max(np.abs(img - f3))) == 0.0, "corner2 != fused3"

un = np.asarray(build_pipeline(cfg, "unfused").run(raw))
img_h = np.asarray(build_halo(cfg, mesh)(raw))
c = metrics.compare_pipelines(img_h, un, cfg, targets)
assert c["l2_relative_error"] < 1e-5, c["l2_relative_error"]
assert max(c["snr_delta_db"]) < 0.01

# multi-axis mesh (pod x data)
mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
img2 = np.asarray(build_corner2(cfg, mesh2, axes=("pod", "data"))(raw))
assert float(np.max(np.abs(img2 - img))) == 0.0
print("DIST_SAR_OK")
""")
    assert "DIST_SAR_OK" in out


@pytest.mark.slow
def test_compressed_psum_matches_mean():
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp, functools
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.optim import compress

mesh = jax.make_mesh((8,), ("dp",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
e = jnp.zeros((8, 64), jnp.float32)

@functools.partial(shard_map, mesh=mesh, in_specs=(P("dp"), P("dp")),
                   out_specs=(P("dp"), P("dp")))
def f(gl, el):
    m, ne = compress.compressed_psum({"g": gl}, {"g": el}, "dp")
    return m["g"], ne["g"]

mean, new_e = f(g, e)
true_mean = np.tile(np.asarray(g).mean(0), (8, 1))
err = np.abs(np.asarray(mean) - true_mean).max()
amax = np.abs(np.asarray(g)).max()
assert err < 2 * amax / 127.0, (err, amax / 127)
# error feedback residual bounded by one quant step per shard
assert np.abs(np.asarray(new_e)).max() <= amax / 127.0 + 1e-6
print("COMPRESS_OK", err)
""")
    assert "COMPRESS_OK" in out


@pytest.mark.slow
def test_lm_sharded_train_step_matches_single_device():
    """One train step under a 4x2 (data x model) mesh == single-device."""
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import registry
from repro.launch import sharding as shd
from repro.launch.mesh import activation_rules
from repro.launch import steps as steps_mod
from repro.models import Model, use_mesh_rules
from repro.optim import AdamWConfig, adamw
from repro.data import DataConfig, TokenStream

cfg = registry.smoke("minitron-4b", seq=64)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = adamw.init(params)
data = TokenStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                              global_batch=8))
batch = data.batch(0)
ocfg = AdamWConfig(warmup_steps=0)

# single device
step = steps_mod.build_train_step(model, ocfg)
p1, s1, st1 = jax.jit(step)(params, opt, batch)

# sharded. The jitted callable MUST be a fresh function object traced inside
# the mesh-rules context (exactly how launch/train.py builds it): jax's
# trace cache is keyed on the function object, so re-jitting the same
# `step` would silently reuse the jaxpr traced OUTSIDE the context — no
# sharding constraints, no ZeRO-3 use-site gather, and bf16 partial-sum
# contractions over the FSDP-sharded dims that drift the loss by units.
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = activation_rules(mesh)
p_sh = shd.param_shardings(params, cfg, mesh, rules)
params_s = jax.device_put(params, p_sh)
opt_s = adamw.init(params_s)
with use_mesh_rules(mesh, rules):
    step_s = steps_mod.build_train_step(model, ocfg)
    p2, s2, st2 = jax.jit(step_s)(params_s, opt_s, batch)

l1, l2 = float(st1["loss"]), float(st2["loss"])
assert abs(l1 - l2) < 5e-3, (l1, l2)
d = max(float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert d < 5e-3, d
print("SHARDED_TRAIN_OK", l1, l2, d)
""")
    assert "SHARDED_TRAIN_OK" in out


@pytest.mark.slow
def test_long_decode_seq_parallel_kv():
    """Batch-1 decode with a sequence-sharded KV cache == single device."""
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import registry
from repro.launch import sharding as shd
from repro.launch.mesh import activation_rules
from repro.models import Model, use_mesh_rules

cfg = registry.smoke("gemma3-12b", seq=64)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0,
                          cfg.vocab_size, jnp.int32)
cache, _ = model.prefill(params, {"tokens": toks[:, :63]}, max_len=64)
l1, _ = model.decode_step(params, cache, toks[:, 63:64])

mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = activation_rules(mesh)
with use_mesh_rules(mesh, rules):
    c_sh = shd.cache_shardings(jax.eval_shape(lambda: cache), cfg, mesh,
                               rules, batch=1)
    cache_s = jax.device_put(cache, c_sh)
    l2, _ = jax.jit(model.decode_step)(params, cache_s, toks[:, 63:64])
d = float(jnp.max(jnp.abs(l1 - l2)))
assert d < 5e-3, d
print("SEQPAR_DECODE_OK", d)
""")
    assert "SEQPAR_DECODE_OK" in out
