"""SAR system behaviour: focusing quality, fused-vs-unfused equivalence
(paper Table IV), CSA baseline, pipeline dispatch accounting."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.sar import (
    build_pipeline,
    metrics,
    paper_targets,
    simulate_cached,
)
from repro.core.sar.geometry import test_scene as make_test_scene
from repro.core.sar.csa import build_csa, build_csa_fused

CFG = make_test_scene(256)
TARGETS = paper_targets(CFG)


def scene():
    return jnp.asarray(simulate_cached(CFG, TARGETS))


def focused(variant, **kw):
    return np.asarray(build_pipeline(CFG, variant, **kw).run(scene()))


@pytest.fixture(scope="module")
def images():
    return {
        "unfused": focused("unfused"),
        "unfused_fourier": focused("unfused", rcmc_mode="fourier"),
        "fused": focused("fused"),
        "fused_tfree": focused("fused_tfree"),
        "fused3": focused("fused3"),
    }


@pytest.fixture(scope="module")
def image512():
    """Larger scene for PSLR/ISLR: at 256 px the five targets sit 32 samples
    apart and leak into each other's sidelobe windows."""
    cfg = make_test_scene(512)
    tgts = paper_targets(cfg)
    img = np.asarray(build_pipeline(cfg, "unfused").run(
        jnp.asarray(simulate_cached(cfg, tgts))))
    return cfg, tgts, img


def test_targets_focus_at_predicted_pixels(image512):
    cfg, tgts, img = image512
    reps = metrics.analyze_scene(img, cfg, tgts)
    for tgt, rep in zip(tgts, reps):
        er, ec = metrics.expected_pixel(cfg, tgt)
        assert abs(rep.row - er) <= 1 and abs(rep.col - ec) <= 1, \
            (tgt, (rep.row, rep.col), (er, ec))


def test_quality_metrics(image512):
    cfg, tgts, img = image512
    reps = metrics.analyze_scene(img, cfg, tgts)
    for rep in reps:
        assert rep.pslr_range_db < -10.0, rep
        assert rep.pslr_azimuth_db < -10.0, rep
        assert rep.snr_db > 30.0, rep


def test_fused_equals_unfused(images):
    """Paper Table IV: FP32-roundoff-level equivalence, 0.0 dB SNR delta."""
    c = metrics.compare_pipelines(images["fused"], images["unfused"],
                                  CFG, TARGETS)
    assert c["l2_relative_error"] < 1e-5, c["l2_relative_error"]
    assert max(c["snr_delta_db"]) < 0.01


def test_tfree_equals_fourier_oracle(images):
    c = metrics.compare_pipelines(images["fused_tfree"],
                                  images["unfused_fourier"], CFG, TARGETS)
    assert c["l2_relative_error"] < 1e-5
    assert max(c["snr_delta_db"]) < 0.01


def test_fused3_equals_fourier_oracle(images):
    """Range compression commutes with the azimuth FFT: the 3-dispatch
    reordered RDA matches the standard-order pipeline."""
    c = metrics.compare_pipelines(images["fused3"],
                                  images["unfused_fourier"], CFG, TARGETS)
    assert c["l2_relative_error"] < 1e-4
    assert max(c["snr_delta_db"]) < 0.01


def test_all_variants_focus(images):
    for name, img in images.items():
        reps = metrics.analyze_scene(img, CFG, TARGETS)
        for rep in reps:
            assert rep.snr_db > 30.0, (name, rep)


def test_dispatch_accounting():
    assert build_pipeline(CFG, "unfused").dispatches == 7
    assert build_pipeline(CFG, "fused").dispatches == 8
    assert build_pipeline(CFG, "fused").hbm_roundtrips < 100
    assert build_pipeline(CFG, "fused_tfree").dispatches == 4
    assert build_pipeline(CFG, "fused3").dispatches == 3


def test_csa_focuses():
    img = np.asarray(build_csa(CFG).run(scene()))
    reps = metrics.analyze_scene(img, CFG, TARGETS)
    for tgt, rep in zip(TARGETS, reps):
        er, ec = metrics.expected_pixel(CFG, tgt)
        assert abs(rep.row - er) <= 1 and abs(rep.col - ec) <= 1
        assert rep.snr_db > 25.0


def test_csa_fused_equals_csa():
    a = np.asarray(build_csa(CFG).run(scene()))
    b = np.asarray(build_csa_fused(CFG).run(scene()))
    assert metrics.l2_relative_error(b, a) < 1e-5


def test_csa_parity_unbatched(images):
    """Fused CSA vs unfused CSA vs the RDA unfused reference on the
    5-point-target scene: matching peak positions, <= 0.1 dB SNR dev."""
    csa_img = np.asarray(build_csa(CFG).run(scene()))
    fused_img = np.asarray(build_csa_fused(CFG).run(scene()))
    c = metrics.compare_pipelines(fused_img, csa_img, CFG, TARGETS)
    assert max(c["snr_delta_db"]) <= 0.1, c["snr_delta_db"]
    rda_reps = metrics.analyze_scene(images["unfused"], CFG, TARGETS)
    for reps in (metrics.analyze_scene(csa_img, CFG, TARGETS),
                 metrics.analyze_scene(fused_img, CFG, TARGETS)):
        for tgt, r, g in zip(TARGETS, rda_reps, reps):
            assert abs(g.row - r.row) <= 1 and abs(g.col - r.col) <= 1, \
                (tgt, (g.row, g.col), (r.row, r.col))


def test_csa_parity_batched():
    """The same parity holds for a (B, na, nr) batch through the single
    batched dispatch sequence, and the batch slices equal the unbatched
    images exactly."""
    raw = scene()
    batch = jnp.stack([raw, raw])
    fused_b = np.asarray(build_csa_fused(CFG).run(batch))
    np.testing.assert_array_equal(fused_b[0], fused_b[1])
    fused_1 = np.asarray(build_csa_fused(CFG).run(raw))
    np.testing.assert_array_equal(fused_b[0], fused_1)
    csa_b = np.asarray(build_csa(CFG).run(batch))
    c = metrics.compare_pipelines(fused_b[0], csa_b[0], CFG, TARGETS)
    assert max(c["snr_delta_db"]) <= 0.1, c["snr_delta_db"]


def test_simulator_determinism():
    a = simulate_cached(CFG, TARGETS)
    b = np.asarray(__import__("repro.core.sar.simulate",
                              fromlist=["x"]).simulate(CFG, TARGETS))
    np.testing.assert_array_equal(a, b)
