"""Docs-consistency checks: the plan-JSON example embedded in
docs/plan_ir.md must stay a living artifact — it has to deserialize,
round-trip, reference only registered filters, and compile to the
dispatch count the document claims. If the IR, the filter registry, or
the fusion grammar changes incompatibly, this fails and the docs get
updated in the same PR instead of rotting."""
import os
import re

import pytest

from repro.core import plan as planlib
from repro.core.plan import (
    plan_dispatch_count,
    plan_from_json,
    plan_to_json,
)
from repro.core.sar.geometry import test_scene as make_test_scene

DOCS = os.path.join(os.path.dirname(__file__), "..", "docs")
PLAN_IR_MD = os.path.join(DOCS, "plan_ir.md")


def _extract(path: str, pattern: str, what: str) -> str:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    m = re.search(pattern, text, re.DOTALL)
    assert m, f"docs/{os.path.basename(path)} lost its {what}"
    return m.group(1)


def doc_plan_json() -> str:
    return _extract(PLAN_IR_MD, r"```json\n(.*?)```", "plan JSON example")


def doc_dispatch_count() -> int:
    return int(_extract(PLAN_IR_MD, r"<!--\s*dispatch_count:\s*(\d+)\s*-->",
                        "dispatch_count marker"))


def test_docs_exist():
    for name in ("plan_ir.md", "serving.md", "distributed.md"):
        assert os.path.exists(os.path.join(DOCS, name)), name


def test_plan_ir_example_roundtrips():
    plan = plan_from_json(doc_plan_json())
    assert plan_from_json(plan_to_json(plan)) == plan
    # the documented example is the shipped fused3 plan, verbatim
    from repro.core.sar.rda import plan_fused3
    assert plan == plan_fused3()


def test_plan_ir_example_compiles_to_documented_dispatch_count():
    plan = plan_from_json(doc_plan_json())
    documented = doc_dispatch_count()
    assert plan_dispatch_count(plan) == documented
    # and an actual compile agrees (filters exist, grammar holds)
    pipe = planlib.compile_plan(plan, make_test_scene(128))
    assert pipe.dispatches == documented


def test_plan_ir_example_filters_are_registered():
    import repro.core.sar  # noqa: F401  (registers the filter builders)
    plan = plan_from_json(doc_plan_json())
    known = set(planlib.filter_names())
    used = {f for s in plan.stages for f in s.filters}
    assert used <= known, f"doc references unknown filters {used - known}"


def test_docs_consistency_catches_breakage():
    """The checker itself must fail on a rotten example (guard the
    guard): an unknown filter name must not compile."""
    import json
    d = json.loads(doc_plan_json())
    d["stages"][1]["filters"] = ["no_such_filter"]
    bad = plan_from_json(json.dumps(d))
    with pytest.raises(KeyError, match="no_such_filter"):
        planlib.compile_plan(bad, make_test_scene(128))
