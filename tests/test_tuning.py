"""repro.tuning: layering (src never imports benchmarks), batch-bucket
key normalization, KernelConfig plumbing, factorizations invariants, the
roofline cost model, the versioned cache + legacy migration, the guided
search policy, and the one-config-path bit-identity guarantees."""
import ast
import json
import math
import os

import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - see requirements-dev.txt
    from _hypothesis_fallback import given, settings, strategies as st

from repro import tuning
from repro.tuning import cost
from repro.kernels import ops, ref
from repro.kernels.fft4step import (
    MAX_FACTOR,
    SpectralSpec,
    build_spectral_call,
    default_factorization,
)

SRC_ROOT = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


# ---------------------------------------------------------------------------
# Layering: src/repro must not import benchmarks (the old inversion)
# ---------------------------------------------------------------------------

def test_src_never_imports_benchmarks():
    """core/plan.py used to reach *up* into benchmarks.autotune at compile
    time and service.py into benchmarks.bench_quality at admission; both
    now resolve through repro.tuning. Enforce it for the whole tree."""
    offenders = []
    for dirpath, _, files in os.walk(SRC_ROOT):
        for fname in files:
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            with open(path) as f:
                tree = ast.parse(f.read(), filename=path)
            for node in ast.walk(tree):
                if isinstance(node, ast.Import):
                    names = [a.name for a in node.names]
                elif isinstance(node, ast.ImportFrom):
                    names = [node.module or ""]
                else:
                    continue
                for name in names:
                    if name == "benchmarks" or \
                            name.startswith("benchmarks."):
                        offenders.append(f"{path}:{node.lineno}")
    assert not offenders, f"src/repro imports benchmarks: {offenders}"


# ---------------------------------------------------------------------------
# Keys: batch bucketing + device fingerprint
# ---------------------------------------------------------------------------

def test_batch_buckets_are_service_buckets():
    from repro.service import backends
    for b in (1, 2, 3, 4, 5, 7, 8, 9):
        assert tuning.bucket_batch(b) == backends._bucket(b)
    assert [tuning.bucket_batch(b) for b in (1, 2, 3, 5, 8, 9)] == \
        [1, 2, 4, 8, 8, 16]


def test_tune_key_normalizes_batch_and_requires_buckets():
    k3 = tuning.TuneKey.kernel(512, 3)
    k4 = tuning.TuneKey.kernel(512, 4)
    assert k3 == k4 and k3.batch == 4
    with pytest.raises(ValueError, match="bucket"):
        tuning.TuneKey(kind="kernel", backend="cpu", device="cpu",
                       n=512, batch=3, lines=16)


def test_padded_batch_hits_exact_batch_cache_entry(tmp_path):
    """The satellite fix: the batcher pads B=3 to the B=4 bucket, so a
    config tuned at B=4 must be what a B=3 lookup resolves to."""
    cache = tuning.TuneCache(str(tmp_path / "c.json"))
    cfg = tuning.KernelConfig(block=16, n1=32, n2=16)
    cache.put(tuning.TuneKey.kernel(512, 4), cfg)
    assert tuning.cached_config(512, 3, cache=cache) == cfg
    assert tuning.cached_config(512, 4, cache=cache) == cfg
    assert tuning.cached_config(512, 5, cache=cache) is None  # bucket 8


def test_tune_key_encode_decode_roundtrip():
    for key in (tuning.TuneKey.kernel(4096, 3),
                tuning.TuneKey.pipeline("fused3", 256, 512, batch=2,
                                        precision="bs16")):
        assert tuning.TuneKey.decode(key.encode()) == key


def test_device_fingerprint_is_part_of_the_key(tmp_path):
    """'Beating vDSP': the winning decomposition is device-specific — a
    config tuned on another device kind must be invisible here."""
    cache = tuning.TuneCache(str(tmp_path / "c.json"))
    other = tuning.TuneKey.kernel(512, 1, device="TPU-v99")
    cache.put(other, tuning.KernelConfig(block=4))
    assert tuning.cached_config(512, 1, cache=cache) is None
    here = tuning.TuneKey.kernel(512, 1)
    cache.put(here, tuning.KernelConfig(block=4))
    assert tuning.cached_config(512, 1, cache=cache) is not None


# ---------------------------------------------------------------------------
# KernelConfig: the one config record
# ---------------------------------------------------------------------------

def test_kernel_config_spectral_kwargs_drop_deferred_knobs():
    c = tuning.KernelConfig(block=8, n1=64, n2=8, karatsuba=True)
    assert c.spectral_kwargs() == {"block": 8, "n1": 64, "n2": 8,
                                   "karatsuba": True}
    # col_block is pipeline-level: kernels must never see it
    assert "col_block" not in tuning.KernelConfig(
        col_block=256).spectral_kwargs()
    # an all-deferred config defers everything — karatsuba included
    # (tri-state), so a partial config never scrubs a pinned spec knob
    assert tuning.KernelConfig().spectral_kwargs() == {}


def test_kernel_config_from_dict_tolerates_legacy_extras():
    legacy = {"block": 16, "n1": 32, "n2": 16, "n3": None,
              "karatsuba": False, "precision": None, "seconds": 0.01}
    c = tuning.KernelConfig.from_dict(legacy)
    assert (c.block, c.factors()) == (16, (32, 16))
    with pytest.raises(ValueError, match="power of two"):
        tuning.KernelConfig(n1=96)
    with pytest.raises(ValueError, match="precision"):
        tuning.KernelConfig(precision="f8")


def test_merge_overrides_replaces_factorization_wholesale():
    tuned = tuning.KernelConfig(block=8, n1=64, n2=8, n3=None,
                                precision="bf16")
    m = tuned.merge_overrides({"n1": 16, "n2": 32})
    assert m.factors() == (16, 32) and m.n3 is None
    assert m.precision == "bf16" and m.block == 8
    m2 = tuned.merge_overrides({"block": 4, "karatsuba": True})
    assert m2.factors() == (64, 8) and m2.block == 4 and m2.karatsuba


def test_build_spectral_call_accepts_kernel_config():
    """The kernels layer consumes a KernelConfig directly (duck-typed):
    same call as spelling the spec out by hand, bit for bit."""
    n = 256
    rng = np.random.default_rng(0)
    xr = jnp.asarray(rng.standard_normal((1, 8, n)), jnp.float32)
    xi = jnp.asarray(rng.standard_normal((1, 8, n)), jnp.float32)
    cfg = tuning.KernelConfig(block=4, n1=64, n2=4, karatsuba=True)
    base = SpectralSpec(n=n, fwd=True, inv=False, filter_mode="none")
    got = build_spectral_call(base, 8, batch=1, interpret=True,
                              config=cfg)(xr, xi)
    # a partial config must not scrub knobs the spec pins (tri-state
    # karatsuba): block-only config on a karatsuba spec keeps karatsuba
    pinned = SpectralSpec(n=n, fwd=True, inv=False, filter_mode="none",
                          karatsuba=True)
    applied = tuning.KernelConfig(block=4).apply(pinned)
    assert applied.karatsuba and applied.block == 4
    explicit = SpectralSpec(n=n, fwd=True, inv=False, filter_mode="none",
                            block=4, n1=64, n2=4, karatsuba=True)
    want = build_spectral_call(explicit, 8, batch=1, interpret=True)(xr, xi)
    assert np.array_equal(np.asarray(got[0]), np.asarray(want[0]))
    assert np.array_equal(np.asarray(got[1]), np.asarray(want[1]))
    wantr = ref.fft_ref(np.asarray(xr[0]), np.asarray(xi[0]), axis=1)
    np.testing.assert_allclose(np.asarray(got[0][0]), wantr[0],
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# factorizations(): the satellite invariants
# ---------------------------------------------------------------------------

def test_factorizations_invariants_up_to_2_21():
    n = 2
    while n <= 2 ** 21:
        fs = tuning.factorizations(n)
        assert fs, f"empty candidate set for n={n}"
        for f in fs:
            assert list(f) == sorted(f, reverse=True), (n, f)
            assert all(x <= MAX_FACTOR for x in f), (n, f)
            assert math.prod(f) == n, (n, f)
        kick_in = n > MAX_FACTOR * MAX_FACTOR
        assert all((len(f) == 3) == kick_in for f in fs), \
            f"3-factor must kick in exactly past 128*128 (n={n}: {fs})"
        n *= 2


def test_factorizations_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        tuning.factorizations(96)


# ---------------------------------------------------------------------------
# Cost model: ranking quality + feasibility never empties the space
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,batch", [(512, 1), (4096, 1), (4096, 4)])
def test_cost_model_ranks_known_best_in_top3(n, batch):
    """The paper's known-good shape — the ~sqrt factorization (4096 =
    64*64) — must appear in the model's top-3 for the reference points,
    else the guided search would skip the winner the exhaustive sweep
    finds (acceptance: same winner, strictly fewer timed)."""
    key = tuning.TuneKey.kernel(n, batch)
    ranked = cost.rank(tuning.candidates(n), key)
    top3 = [c.factors() for c in ranked[:3]]
    assert default_factorization(n) in top3, (top3, default_factorization(n))


def test_feasibility_cut_never_excludes_every_candidate():
    """Even when the VMEM budget rejects every candidate (a 2^20-point
    line slab cannot fit any block in 16 MiB) the ranking must fall back
    to structural feasibility rather than emptying the search space."""
    n = 256
    while n <= 2 ** 21:
        key = tuning.TuneKey.kernel(n, 1)
        assert cost.rank(tuning.candidates(n), key), \
            f"feasibility cut emptied n={n}"
        n *= 4
    # and the strict cut does cut: a huge batch-block slab is over budget
    big = tuning.TuneKey.kernel(2 ** 20, 16, lines=128)
    cands = tuning.candidates(2 ** 20, blocks=(128,))
    assert any(not cost.feasible(c, big) for c in cands)
    assert cost.rank(cands, big)      # ...yet the ranking still ranks


def test_cost_model_is_finite_positive_and_orders_precisions():
    key = tuning.TuneKey.kernel(4096, 4)
    f32 = tuning.KernelConfig(block=8, n1=64, n2=64, precision="f32")
    bf16 = tuning.KernelConfig(block=8, n1=64, n2=64, precision="bf16")
    t32 = cost.predicted_seconds(f32, key)
    t16 = cost.predicted_seconds(bf16, key)
    assert 0 < t16 <= t32 < 1.0
    assert cost.nominal_flops(key) > 0


# ---------------------------------------------------------------------------
# Cache: schema, migration, validation
# ---------------------------------------------------------------------------

def test_cache_migrates_legacy_flat_format(tmp_path):
    """A pre-subsystem cache file (flat exact-batch keys) must be read
    transparently: entries land under bucketed, device-stamped keys
    (fastest wins a bucket collision) and the next put() rewrites the
    file in schema 1."""
    path = str(tmp_path / "autotune_cache.json")
    legacy = {
        "cpu_B3_n512": {"block": 8, "n1": 32, "n2": 16, "n3": None,
                        "karatsuba": False, "precision": None,
                        "seconds": 0.010},
        "cpu_B4_n512": {"block": 16, "n1": 64, "n2": 8, "n3": None,
                        "karatsuba": True, "precision": None,
                        "seconds": 0.005},
        "cpu_B1_n4096": {"block": 4, "n1": 64, "n2": 64, "n3": None,
                         "karatsuba": False, "precision": "bf16",
                         "seconds": 0.020},
        "garbage": "not-a-config",
    }
    with open(path, "w") as f:
        json.dump(legacy, f)
    cache = tuning.TuneCache(path)
    # B3 and B4 collide in the B=4 bucket; the faster (B4) entry wins
    hit = cache.get(tuning.TuneKey.kernel(512, 3, backend="cpu"))
    assert hit is not None and hit.factors() == (64, 8) and hit.karatsuba
    hit2 = cache.get(tuning.TuneKey.kernel(4096, 1, backend="cpu"))
    assert hit2 is not None and hit2.precision == "bf16"
    # a put rewrites the file as a validated schema-1 document
    cache.put(tuning.TuneKey.kernel(256, 1), tuning.KernelConfig(block=8))
    with open(path) as f:
        doc = json.load(f)
    assert doc["schema"] == tuning.CACHE_SCHEMA
    tuning.validate_cache_doc(doc)
    assert len(doc["entries"]) == 3          # garbage dropped, B3/B4 merged


def test_cache_validation_rejects_malformed_docs():
    ok = {"schema": 1, "entries": {
        tuning.TuneKey.kernel(512, 1).encode(): {
            "config": {"block": 8}, "seconds": 0.1}}}
    tuning.validate_cache_doc(ok)
    with pytest.raises(ValueError, match="schema"):
        tuning.validate_cache_doc({"schema": 99, "entries": {}})
    with pytest.raises(ValueError, match="entries"):
        tuning.validate_cache_doc({"schema": 1})
    with pytest.raises(ValueError, match="TuneKey|malformed"):
        tuning.validate_cache_doc(
            {"schema": 1, "entries": {"bad key": {"config": {}}}})
    with pytest.raises(ValueError, match="config"):
        tuning.validate_cache_doc(
            {"schema": 1,
             "entries": {tuning.TuneKey.kernel(8, 1).encode(): {}}})


def test_cache_in_process_layer_rereads_on_file_change(tmp_path):
    path = str(tmp_path / "c.json")
    a = tuning.TuneCache(path)
    key = tuning.TuneKey.kernel(512, 1)
    assert a.get(key) is None
    b = tuning.TuneCache(path)               # independent view, same file
    b.put(key, tuning.KernelConfig(block=16))
    got = a.get(key)                         # a must observe b's write
    assert got is not None and got.block == 16


def test_cache_quarantines_truncated_json_and_rebuilds(tmp_path, caplog):
    """Corruption recovery: a truncated (mid-token) cache file must not
    poison every subsequent load — the unreadable bytes are quarantined
    to <path>.corrupt for post-mortem, the corruption is logged ONCE,
    and the cache rebuilds empty so puts/gets work again immediately."""
    import logging
    path = str(tmp_path / "c.json")
    cache = tuning.TuneCache(path)
    key = tuning.TuneKey.kernel(512, 1)
    cache.put(key, tuning.KernelConfig(block=16))
    with open(path, "r+b") as f:             # truncate mid-token
        f.truncate(17)
    cache._mtime = None                      # drop the in-process layer
    with caplog.at_level(logging.WARNING, logger="repro.tuning.cache"):
        assert cache.get(key) is None, "corrupt file reads as empty"
        assert cache.get(key) is None
    assert os.path.exists(path + ".corrupt"), \
        "the corrupt bytes are preserved for post-mortem"
    assert not os.path.exists(path)
    warned = [r for r in caplog.records if "quarantined" in r.getMessage()]
    assert len(warned) == 1, "corruption is logged once, not per load"
    # the cache is live again: a fresh put persists and round-trips
    cache.put(key, tuning.KernelConfig(block=32))
    assert tuning.TuneCache(path).get(key).block == 32


def test_cache_quarantines_wrong_shape_json(tmp_path):
    """Well-formed JSON of a foreign shape (a list, say) is corruption
    too: quarantine and rebuild rather than raising on every load."""
    path = str(tmp_path / "c.json")
    with open(path, "w") as f:
        json.dump([1, 2, 3], f)
    cache = tuning.TuneCache(path)
    assert cache.get(tuning.TuneKey.kernel(512, 1)) is None
    assert os.path.exists(path + ".corrupt")


# ---------------------------------------------------------------------------
# Guided search policy
# ---------------------------------------------------------------------------

def _fake_measure(times):
    calls = []

    def measure(cand, iters):
        calls.append(cand)
        return times[cand]

    return measure, calls


def test_search_times_strictly_fewer_candidates_and_finds_best(tmp_path):
    """With a deterministic oracle whose best config the cost model ranks
    in its top fraction, the guided search must return that best while
    timing strictly fewer distinct candidates than the space holds."""
    key = tuning.TuneKey.kernel(512, 1)
    space = tuning.candidates(512)
    ranked = cost.rank(space, key)
    best = ranked[1]                          # inside the measured half
    times = {c: (0.5 if c == best else 1.0 + i * 0.01)
             for i, c in enumerate(space)}
    measure, calls = _fake_measure(times)
    cache = tuning.TuneCache(str(tmp_path / "c.json"))
    res = tuning.search_kernel(key, measure=measure, cache=cache)
    assert res.config == best
    assert res.measured < len(space) and res.measured <= res.space
    assert res.predicted_rank == 1
    # the winner persisted: compile-time lookups now see it
    assert tuning.cached_config(512, 1, cache=cache) == best


def test_search_respects_snr_gate_without_timing_gated_configs():
    key = tuning.TuneKey.kernel(256, 1)
    space = tuning.candidates(256, precisions=("f32", "bs16"))
    times = {c: 1.0 for c in space}
    measure, calls = _fake_measure(times)
    gate_calls = []

    def gate(p):
        gate_calls.append(p)
        return 9.9                            # way out of gate

    res = tuning.search_kernel(key, precisions=("f32", "bs16"),
                               measure=measure, gate=gate, persist=False)
    assert gate_calls == ["bs16"]             # consulted once, not per cand
    assert all(c.precision == "f32" for c in calls)
    assert res.config.precision == "f32"


def test_measured_search_drops_raising_candidates():
    def measure(cand, iters):
        if cand == "bad":
            raise RuntimeError("infeasible at trace time")
        return {"a": 3.0, "b": 1.0}[cand]

    best, t, trace = tuning.measured_search(["bad", "a", "b"], measure,
                                            rungs=(1,))
    assert best == "b" and t == 1.0
    assert ("bad", None) in trace


# ---------------------------------------------------------------------------
# The one config path: plans + service resolve through repro.tuning
# ---------------------------------------------------------------------------

def test_plan_compile_resolves_config_through_tuning(tmp_path, monkeypatch):
    """Seed the tuning cache with a distinctive config; a compiled plan's
    range dispatch must carry exactly those knobs, and the focused image
    must be bit-identical to compiling with the same config passed
    explicitly (the pre-refactor fft_kw path)."""
    import dataclasses

    from repro.core import plan as planlib
    from repro.core.sar import build_pipeline
    from repro.core.sar.geometry import test_scene

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    tuning.clear_memory_cache()
    planlib.clear_pipeline_cache()
    # rectangular on purpose: the cache entry is keyed n=nr=128, so the
    # azimuth (n=64) dispatches stay on defaults — mirroring fft_kw,
    # which configures range-axis dispatches only
    cfg = dataclasses.replace(test_scene(128), na=64)
    rng = np.random.default_rng(7)
    raw = jnp.asarray(rng.standard_normal((64, 128))
                      + 1j * rng.standard_normal((64, 128)), jnp.complex64)
    tuned = tuning.KernelConfig(block=4, n1=16, n2=8, karatsuba=True)
    tuning.get_cache().put(tuning.TuneKey.kernel(128, 1), tuned)

    pipe = build_pipeline(cfg, "fused3")
    row_steps = [s for s in pipe.steps
                 if s.kind == "spectral" and s.phys_axis == 1]
    assert row_steps, "fused3 must have a rows dispatch"
    for s in row_steps:
        kk = s.kernel_kw
        assert (kk["n1"], kk["n2"], kk["block"], kk["karatsuba"]) == \
            (16, 8, 4, True), kk

    img_tuned = np.asarray(pipe.run(raw))
    explicit = build_pipeline(cfg, "fused3", tune="off",
                              fft_kw=dict(block=4, n1=16, n2=8,
                                          karatsuba=True))
    assert np.array_equal(img_tuned, np.asarray(explicit.run(raw)))

    tuning.clear_memory_cache()
    planlib.clear_pipeline_cache()


def test_empty_cache_compiles_identically_to_tune_off(tmp_path,
                                                     monkeypatch):
    """A cache miss must leave the pipeline exactly on library defaults —
    bit-identical to tune='off' (the refactor cannot perturb outputs)."""
    from repro.core import plan as planlib
    from repro.core.sar import build_pipeline
    from repro.core.sar.geometry import test_scene

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    tuning.clear_memory_cache()
    planlib.clear_pipeline_cache()
    cfg = test_scene(128)
    rng = np.random.default_rng(11)
    raw = jnp.asarray(rng.standard_normal((128, 128))
                      + 1j * rng.standard_normal((128, 128)), jnp.complex64)
    a = np.asarray(build_pipeline(cfg, "fused3").run(raw))
    b = np.asarray(build_pipeline(cfg, "fused3", tune="off").run(raw))
    assert np.array_equal(a, b)
    tuning.clear_memory_cache()
    planlib.clear_pipeline_cache()


def test_service_warm_sweep_persists_and_is_reused(tmp_path, monkeypatch):
    """The serving warm sweep runs through tuning.measured_search and its
    winner lands in the shared cache under a pipeline-kind key, so a
    fresh backend (a restarted process) skips the sweep entirely."""
    from repro.core.sar.geometry import test_scene
    from repro.service import LocalBackend
    from repro.service.queue import BatchKey

    path = str(tmp_path / "c.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    tuning.clear_memory_cache()
    cfg = test_scene(128)
    bkey = BatchKey(cfg, "fused3", None, False)

    b1 = LocalBackend(sweep=((None, None), (32, -1)))
    b1.warm(bkey, max_batch=2)
    assert bkey in b1._best
    with open(path) as f:
        doc = json.load(f)
    tuning.validate_cache_doc(doc)
    pipe_entries = [k for k in doc["entries"]
                    if k.startswith(tuning.KIND_PIPELINE)]
    assert len(pipe_entries) == 1
    key = tuning.TuneKey.decode(pipe_entries[0])
    assert (key.variant, key.n, key.lines, key.batch) == ("fused3", 128,
                                                          128, 2)

    # a restarted process: same sweep config, but the cache pre-empts it
    def boom(*a, **k):
        raise AssertionError("swept despite a cache hit")

    monkeypatch.setattr(tuning, "measured_search", boom)
    b2 = LocalBackend(sweep=((None, None), (32, -1)))
    b2.warm(bkey, max_batch=2)
    assert b2._best[bkey] == b1._best[bkey]
    tuning.clear_memory_cache()


def test_shim_best_config_matches_subsystem(tmp_path, monkeypatch):
    """benchmarks/autotune.py is a thin shim: its dict API must resolve
    through the same cache the subsystem writes."""
    from benchmarks import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    tuning.clear_memory_cache()
    cfg = tuning.KernelConfig(block=16, n1=64, n2=8)
    tuning.get_cache().put(tuning.TuneKey.kernel(512, 2), cfg)
    d = autotune.best_config(512, 2, tune_missing=False)
    assert tuning.KernelConfig.from_dict(d) == cfg
    assert autotune.spectral_kwargs(d) == cfg.spectral_kwargs()
    # miss -> library defaults, never a sweep with tune_missing=False
    d2 = autotune.best_config(8192, 1, tune_missing=False)
    assert d2["n1"] is None and d2["block"] == 8
    tuning.clear_memory_cache()

# ---------------------------------------------------------------------------
# Property tests: key/config/schedule round-trips (hypothesis or fallback)
# ---------------------------------------------------------------------------

_PROP_NS = (64, 128, 256, 512, 1024)


@settings(max_examples=30, deadline=None)
@given(kind=st.sampled_from([tuning.KIND_KERNEL, tuning.KIND_PIPELINE]),
       n=st.sampled_from(_PROP_NS), bexp=st.integers(0, 6),
       lines=st.sampled_from([16, 64, 128]),
       precision=st.sampled_from([None, "f32", "bf16", "bs16"]),
       variant=st.sampled_from([None, "fused3", "csa_fused"]))
def test_prop_tune_key_encode_decode_roundtrip(kind, n, bexp, lines,
                                               precision, variant):
    key = tuning.TuneKey(kind=kind, backend="cpu", device="cpu", n=n,
                         batch=2 ** bexp, lines=lines,
                         precision=precision, variant=variant)
    assert tuning.TuneKey.decode(key.encode()) == key


@settings(max_examples=40, deadline=None)
@given(n=st.sampled_from(_PROP_NS), fi=st.integers(0, 10 ** 6),
       block=st.sampled_from([None, 4, 8, 16]),
       karatsuba=st.sampled_from([None, False, True]),
       precision=st.sampled_from([None, "f32", "bf16", "bs16"]),
       col_block=st.sampled_from([None, 128, 256]),
       residency=st.sampled_from([None, "vmem", "staged"]),
       phase_block=st.sampled_from([None, 8, 16]),
       buffer_depth=st.sampled_from([None, 1, 2, 3]))
def test_prop_kernel_config_dict_roundtrip(n, fi, block, karatsuba,
                                           precision, col_block, residency,
                                           phase_block, buffer_depth):
    """to_dict/from_dict must round-trip every knob — the tri-state
    karatsuba, the mega knobs incl. buffer_depth — including through the
    JSON wire format the cache stores."""
    fs = tuning.factorizations(n)
    f = (tuple(fs[fi % len(fs)]) + (None,))[:3]
    cfg = tuning.KernelConfig(block=block, n1=f[0], n2=f[1], n3=f[2],
                              karatsuba=karatsuba, precision=precision,
                              col_block=col_block, residency=residency,
                              phase_block=phase_block,
                              buffer_depth=buffer_depth)
    assert tuning.KernelConfig.from_dict(cfg.to_dict()) == cfg
    assert tuning.KernelConfig.from_dict(
        json.loads(json.dumps(cfg.to_dict()))) == cfg


@settings(max_examples=30, deadline=None)
@given(n=st.sampled_from(_PROP_NS), nseg=st.integers(1, 3),
       fi=st.integers(0, 10 ** 6),
       karatsuba=st.sampled_from([None, False, True]),
       residency=st.sampled_from([None, "vmem", "staged"]),
       buffer_depth=st.sampled_from([None, 1, 2]))
def test_prop_schedule_dict_roundtrip(n, nseg, fi, karatsuba, residency,
                                      buffer_depth):
    fs = tuning.factorizations(n)
    segs = tuple(
        tuning.SegmentConfig(*(tuple(fs[(fi + i) % len(fs)]) + (None,))[:3],
                             karatsuba=karatsuba)
        for i in range(nseg))
    s = tuning.Schedule(segments=segs, block=8, residency=residency,
                        buffer_depth=buffer_depth)
    assert tuning.Schedule.from_dict(s.to_dict()) == s
    assert tuning.Schedule.from_dict(
        json.loads(json.dumps(s.to_dict()))) == s


def test_kernel_config_is_degenerate_one_segment_schedule():
    cfg = tuning.KernelConfig(block=8, n1=32, n2=16, karatsuba=True,
                              residency="staged", phase_block=8,
                              buffer_depth=2)
    s = tuning.Schedule.from_config(cfg)
    assert s.uniform() and s.to_config() == cfg
    multi = tuning.Schedule(segments=(tuning.SegmentConfig(32, 16),
                                      tuning.SegmentConfig(16, 32)),
                            block=8)
    assert not multi.uniform()
    assert multi.to_config().n1 is None   # flat-inexpressible, by design


def test_timeit_enforces_repeat_floor():
    """A 1-iteration halving rung must still take TIMING_REPEATS_FLOOR
    timed samples so the median washes out scheduler jitter."""
    from repro.tuning import search as searchlib

    calls = []

    def fn():
        calls.append(1)
        return jnp.zeros(())

    searchlib._timeit(fn, warmup=1, iters=1)
    assert len(calls) == 1 + max(1, tuning.TIMING_REPEATS_FLOOR)
    calls.clear()
    searchlib._timeit(fn, warmup=0, iters=tuning.TIMING_REPEATS_FLOOR + 4)
    assert len(calls) == tuning.TIMING_REPEATS_FLOOR + 4


# ---------------------------------------------------------------------------
# The schedule graph: cache schema 2, migration, search, compiler, service
# ---------------------------------------------------------------------------

def test_cache_schema1_migrates_to_schema2_without_research(tmp_path,
                                                           monkeypatch):
    """A schema-1 file must resolve through the schema-2 cache with NO
    re-search: flat entries serve both get() and get_schedule() (as the
    degenerate one-segment schedule), their payload — the fastest-known
    measurement — passes through untouched, and the next put rewrites
    the file in schema 2 keeping the migrated entry."""
    key = tuning.TuneKey.kernel(512, 1)
    cfg = tuning.KernelConfig(block=16, n1=32, n2=16, karatsuba=True)
    path = str(tmp_path / "c.json")
    with open(path, "w") as f:
        json.dump({"schema": 1, "entries": {key.encode(): {
            "config": cfg.to_dict(), "seconds": 3.25e-4,
            "source": "search", "updated_utc": "2026-01-01T00:00:00Z"}}}, f)

    def boom(*a, **k):
        raise AssertionError("re-searched a migrated schema-1 entry")

    monkeypatch.setattr(tuning, "measured_search", boom)
    monkeypatch.setattr(tuning, "search_kernel", boom)
    cache = tuning.TuneCache(path)
    doc = cache.doc()
    assert doc["schema"] == tuning.CACHE_SCHEMA == 2
    assert cache.get(key) == cfg
    sched = cache.get_schedule(key)
    assert sched == tuning.Schedule.from_config(cfg)
    assert sched.to_config() == cfg
    assert cache.get_entry(key)["seconds"] == 3.25e-4

    cache.put(tuning.TuneKey.kernel(256, 1), tuning.KernelConfig(block=8))
    with open(path) as f:
        ondisk = json.load(f)
    assert ondisk["schema"] == 2
    assert ondisk["entries"][key.encode()]["config"] == cfg.to_dict()


def test_cache_schedule_roundtrip_and_flat_view(tmp_path):
    """put_schedule persists the Schedule AND its derived flat view, so
    schedule consumers round-trip exactly while flat-only consumers keep
    resolving the entry; the stored document schema-validates."""
    path = str(tmp_path / "c.json")
    key = tuning.TuneKey.kernel(256, 1)
    sched = tuning.Schedule(
        segments=(tuning.SegmentConfig(16, 16, None, True),
                  tuning.SegmentConfig(8, 32, None, False)),
        block=8, precision="f32", residency="staged", phase_block=8,
        buffer_depth=2)
    tuning.TuneCache(path).put_schedule(key, sched, seconds=1e-3)

    fresh = tuning.TuneCache(path)           # independent view, same file
    assert fresh.get_schedule(key) == sched
    flat = fresh.get(key)
    assert flat == sched.to_config()
    assert flat.n1 is None        # non-uniform: no flat factorization
    assert flat.residency == "staged" and flat.buffer_depth == 2
    tuning.validate_cache_doc(fresh.doc())


def test_graph_search_finds_flat_inexpressible_schedule():
    """The acceptance bar for the schedule graph: on a multi-segment
    megakernel problem whose axes differ, the search returns a schedule
    with DIFFERENT factorizations across segments (no flat KernelConfig
    can express it) whose predicted and measured cost match-or-beat the
    best flat-expressible schedule."""
    from repro.kernels.fft4step import default_factorization

    problem = tuning.ScheduleProblem.mega_2d(
        na=64, nr=256,
        segments=(tuning.SegmentShape(0, fwd=True),
                  tuning.SegmentShape(1, fwd=True, inv=True, filtered=True),
                  tuning.SegmentShape(0, inv=True, filtered=True)))

    def measure(s, iters):                 # deterministic oracle
        return cost.schedule_seconds(s, problem)

    res = tuning.search_schedule(problem, k=8, measure=measure,
                                 persist=False)
    win = res.schedule
    assert win is not None and len(win.segments) == 3
    assert not win.uniform()
    assert win.to_config().n1 is None      # the flat sweep can't say this

    # flat baseline: what compiling WITHOUT a schedule reaches — one
    # global candidates(nr) config (range segments take its split,
    # azimuth segments fall back to the default factorization), same
    # residency lane as the winner for a fair comparison
    def flat_schedule(c):
        segs = []
        for shp in problem.segments:
            if shp.axis == 1:
                segs.append(tuning.SegmentConfig(c.n1, c.n2, c.n3,
                                                 bool(c.karatsuba)))
            else:
                f = (tuple(default_factorization(problem.na)) + (None,))[:3]
                segs.append(tuning.SegmentConfig(*f, bool(c.karatsuba)))
        return tuning.Schedule(
            segments=tuple(segs), block=c.block, precision=c.precision,
            residency=win.residency, phase_block=win.phase_block,
            buffer_depth=win.buffer_depth)

    flats = [flat_schedule(c) for c in tuning.candidates(problem.nr)]
    flat_best = min(cost.schedule_seconds(s, problem) for s in flats)
    assert cost.schedule_seconds(win, problem) <= flat_best   # predicted
    assert res.seconds <= min(measure(s, 1) for s in flats)   # measured


def test_plan_compiles_through_schedule_to_kernel(tmp_path, monkeypatch):
    """compile_plan(schedule=...) routes per-segment factorization and
    karatsuba into the megakernel's extended segment records (and
    buffer_depth into the kernel kwargs), and the scheduled image stays
    allclose to the unscheduled pipeline."""
    from repro.core import plan as planlib
    from repro.core.sar import build_pipeline
    from repro.core.sar.geometry import test_scene

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    tuning.clear_memory_cache()
    planlib.clear_pipeline_cache()
    cfg = test_scene(128)
    sched = tuning.Schedule(
        segments=(tuning.SegmentConfig(16, 8, None, True),
                  tuning.SegmentConfig(8, 16, None, False),
                  tuning.SegmentConfig(8, 16, None, None)),
        residency="staged", phase_block=8, buffer_depth=3)
    pipe = build_pipeline(cfg, "fused1", schedule=sched)
    mega = [s for s in pipe.steps if s.kind == "mega"]
    assert len(mega) == 1
    kk = mega[0].kernel_kw
    assert kk["residency"] == "staged" and kk["buffer_depth"] == 3
    assert [rec[4:] for rec in kk["segments"]] == [
        (16, 8, None, True), (8, 16, None, False), (8, 16, None, None)]

    rng = np.random.default_rng(3)
    raw = jnp.asarray(rng.standard_normal((128, 128))
                      + 1j * rng.standard_normal((128, 128)), jnp.complex64)
    img = np.asarray(pipe.run(raw))
    ref_img = np.asarray(build_pipeline(cfg, "fused1", tune="off").run(raw))
    scale = max(1.0, float(np.abs(ref_img).max()))
    np.testing.assert_allclose(img, ref_img, atol=2e-4 * scale, rtol=0)
    tuning.clear_memory_cache()
    planlib.clear_pipeline_cache()


def test_service_warm_consumes_persisted_schedule(tmp_path, monkeypatch):
    """A graph-search Schedule persisted under the pipeline key must be
    picked up by the warm path and compiled into the served pipeline —
    its per-segment decisions reaching each dispatch in step order."""
    from repro.core import plan as planlib
    from repro.core.sar.geometry import test_scene
    from repro.service import LocalBackend
    from repro.service.queue import BatchKey

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    tuning.clear_memory_cache()
    planlib.clear_pipeline_cache()
    cfg = test_scene(128)
    bkey = BatchKey(cfg, "fused3", None, False)
    tkey = tuning.TuneKey.pipeline(variant="fused3", na=128, nr=128, batch=2)
    sched = tuning.Schedule(
        segments=(tuning.SegmentConfig(16, 8, None, True),
                  tuning.SegmentConfig(8, 16, None, False),
                  tuning.SegmentConfig(16, 8, None, True)),
        block=4, col_block=128)
    tuning.get_cache().put_schedule(tkey, sched, seconds=1e-3)

    b = LocalBackend(sweep=((None, None), (32, -1)), fused1="off")
    b.warm(bkey, max_batch=2)
    assert b._sched[bkey] == sched
    spect = [s for s in b._pipeline(bkey).steps if s.kind == "spectral"]
    assert [(s.kernel_kw["n1"], s.kernel_kw["n2"], s.kernel_kw["karatsuba"])
            for s in spect] == [(16, 8, True), (8, 16, False), (16, 8, True)]
    tuning.clear_memory_cache()
    planlib.clear_pipeline_cache()
