"""FFTConvMixer: the paper's fused kernel inside an LM block (LTI long conv)
matches the unfused jnp.fft oracle, and the convolution is causal."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models.fftconv import (fftconv_forward, fftconv_reference,
                                  init_fftconv)


def test_fused_matches_reference():
    b, s, d = 2, 64, 16
    p = init_fftconv(jax.random.PRNGKey(0), d, s)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((b, s, d)),
                    jnp.float32)
    got = fftconv_forward(p, x)
    want = fftconv_reference(p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4)


def test_causality():
    """Changing x at position t only affects outputs at positions >= t."""
    b, s, d = 1, 32, 8
    p = init_fftconv(jax.random.PRNGKey(1), d, s)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    t = 20
    x2 = x.at[:, t].add(1.0)
    # compare the conv branch only (gate is pointwise — still causal)
    y1 = np.asarray(fftconv_reference(p, x))
    y2 = np.asarray(fftconv_reference(p, x2))
    assert np.abs(y2[:, :t] - y1[:, :t]).max() < 1e-5
    assert np.abs(y2[:, t:] - y1[:, t:]).max() > 1e-4


def test_gradients_flow():
    b, s, d = 2, 32, 8
    p = init_fftconv(jax.random.PRNGKey(2), d, s)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((b, s, d)),
                    jnp.float32)

    def loss(p):
        return jnp.sum(fftconv_forward(p, x) ** 2)

    g = jax.grad(loss)(p)
    gn = jnp.sqrt(sum(jnp.sum(v ** 2) for v in jax.tree.leaves(g)))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0
