"""fused1: the true single-dispatch 2-D SAR megakernel.

Covers the ISSUE-5 acceptance gates: the compiler invariant
(``dispatches == 1`` under the cross-axis grammar), f32 bit-identity to
the 3-dispatch ``fused3`` pipeline, scratch-staged vs VMEM-resident
equivalence, the narrow-precision SNR gate, the execution-surface guards
(``run_streamed`` / ``lower_sharded`` must reject a cross-axis step),
and the serving route that sends VMEM-fitting scenes through fused1.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import plan as planlib
from repro.core.plan import FUSE_MEGA, SpectralPlan, Stage, \
    plan_dispatch_count
from repro.core.sar import (
    build_pipeline,
    documented_dispatches,
    metrics,
    paper_targets,
    simulate_cached,
)
from repro.core.sar.geometry import test_scene as make_test_scene
from repro.core.sar.rda import plan_fused1, plan_fused3
from repro import tuning

CFG = make_test_scene(256)
TARGETS = paper_targets(CFG)

FUSED1_VARIANTS = ("fused1", "csa_fused1", "omegak_fused1")


def scene():
    return jnp.asarray(simulate_cached(CFG, TARGETS))


# ---------------------------------------------------------------------------
# Compiler invariants: the cross-axis grammar
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", FUSED1_VARIANTS)
def test_fused1_compiles_to_one_dispatch(variant):
    """The acceptance criterion: every fused1 variant is EXACTLY one
    dispatch, as a static plan property and as the compiled pipeline."""
    var = planlib.get_variant(variant)
    assert var.dispatches == 1
    assert plan_dispatch_count(var.plan_fn(), fuse=FUSE_MEGA) == 1
    p = build_pipeline(CFG, variant, tune="off")
    assert p.dispatches == documented_dispatches(variant) == 1
    assert p.hbm_roundtrips == 1
    assert p.steps[0].kind == "mega"


def test_mega_grammar_segment_rules_still_hold():
    """Cross-axis fusion must not relax the per-axis grammar: within a
    segment an ifft still closes and an fft still only opens — but an
    axis change always opens a fresh segment."""
    # fft(1) then fft(1): two dispatches even under mega
    two_ffts = SpectralPlan("p", (
        Stage("a", axis=1, fwd=True),
        Stage("b", axis=1, fwd=True),
    ))
    assert plan_dispatch_count(two_ffts, fuse=FUSE_MEGA) == 2
    # mul after ifft on the SAME axis: still two
    mul_after_inv = SpectralPlan("p", (
        Stage("a", axis=1, fwd=True, inv=True, filters=("range_mf",)),
        Stage("b", axis=1, filters=("range_mf",)),
    ))
    assert plan_dispatch_count(mul_after_inv, fuse=FUSE_MEGA) == 2
    # but fft(1) then fft(0) — an axis change — is ONE megakernel dispatch
    cross = SpectralPlan("p", (
        Stage("a", axis=1, fwd=True),
        Stage("b", axis=0, fwd=True),
    ))
    assert plan_dispatch_count(cross, fuse=FUSE_MEGA) == 1
    assert plan_dispatch_count(cross, fuse=True) == 2
    # transposes and custom stages stay walls under mega fusion too
    walled = SpectralPlan("p", (
        Stage("a", axis=1, fwd=True),
        Stage("t", kind="transpose"),
        Stage("b", axis=0, inv=True),
    ))
    assert plan_dispatch_count(walled, fuse=FUSE_MEGA) == 3


def test_fused1_plan_matches_fused3_stages():
    """fused1 is the SAME stage list as fused3 — only the fusion level
    differs; the megakernel is a compilation strategy, not an algorithm."""
    a, b = plan_fused1(), plan_fused3()
    assert a.stages == b.stages
    assert plan_dispatch_count(a, fuse=True) == 3       # per-axis: 3
    assert plan_dispatch_count(a, fuse=FUSE_MEGA) == 1  # cross-axis: 1


# ---------------------------------------------------------------------------
# Numerics: bit-identity and residency-mode equivalence
# ---------------------------------------------------------------------------

def test_fused1_bit_identical_to_fused3_f32():
    """The megakernel runs the exact same per-segment math (same DFT
    constants, same filter application, same ordering), so collapsing
    3 dispatches to 1 must not move a single f32 bit."""
    a = np.asarray(build_pipeline(CFG, "fused1", tune="off").run(scene()))
    b = np.asarray(build_pipeline(CFG, "fused3", tune="off").run(scene()))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("variant", ["csa_fused1", "omegak_fused1"])
def test_fused1_family_bit_identical_to_per_axis(variant):
    twin = {"csa_fused1": "csa_fused", "omegak_fused1": "omegak"}[variant]
    a = np.asarray(build_pipeline(CFG, variant, tune="off").run(scene()))
    b = np.asarray(build_pipeline(CFG, twin, tune="off").run(scene()))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("variant", FUSED1_VARIANTS)
def test_staged_equals_vmem_resident(variant):
    """Both residency modes run identical per-segment math on different
    block partitions — every segment treats line blocks independently, so
    the DMA-staged image equals the VMEM-resident image bit-for-bit.
    (csa/omegak also exercise the FULL-filter DMA-slicing path.)"""
    a = np.asarray(build_pipeline(CFG, variant, tune="off",
                                  residency="vmem").run(scene()))
    b = np.asarray(build_pipeline(CFG, variant, tune="off",
                                  residency="staged",
                                  phase_block=32).run(scene()))
    np.testing.assert_array_equal(a, b)
    # a different phase split must not change the numerics either
    c = np.asarray(build_pipeline(CFG, variant, tune="off",
                                  residency="staged",
                                  phase_block=8).run(scene()))
    np.testing.assert_array_equal(a, c)


def test_fused1_batched_matches_unbatched():
    p = build_pipeline(CFG, "fused1", tune="off")
    raw = scene()
    out = np.asarray(p.run(jnp.stack([raw, 0.5 * raw])))
    one = np.asarray(p.run(raw))
    np.testing.assert_array_equal(out[0], one)
    scale = float(np.max(np.abs(one)))
    np.testing.assert_allclose(out[1], 0.5 * one, atol=1e-5 * scale, rtol=0)


def test_fused1_matches_xla_oracle():
    """The mega step compiled to the unfused jnp oracle chain agrees at
    f32 roundoff — the megakernel is the same math as 7 XLA ops."""
    a = np.asarray(build_pipeline(CFG, "fused1", tune="off").run(scene()))
    b = np.asarray(build_pipeline(CFG, "fused1", tune="off", backend="xla",
                                  fuse=FUSE_MEGA).run(scene()))
    assert metrics.l2_relative_error(a, b) < 1e-5


@pytest.mark.parametrize("precision", ["bf16", "bs16"])
def test_fused1_narrow_precision_snr_gate(precision):
    """Narrow matmul operands through the megakernel stay inside the
    serving quality gate: <= 0.1 dB per-target SNR deviation vs the
    fused1 f32 image (the same gate the service enforces per request)."""
    img32 = np.asarray(build_pipeline(CFG, "fused1", tune="off").run(scene()))
    imgN = np.asarray(build_pipeline(CFG, "fused1", tune="off",
                                     precision=precision).run(scene()))
    assert not np.array_equal(imgN, img32)
    c = metrics.compare_pipelines(imgN, img32, CFG, TARGETS)
    assert max(c["snr_delta_db"]) <= 0.1, c["snr_delta_db"]


# ---------------------------------------------------------------------------
# Execution-surface guards
# ---------------------------------------------------------------------------

def test_run_streamed_rejects_mega_step():
    """A cross-axis step has no single free axis to strip a host scene
    along — the streaming executor must refuse, not silently mis-slice."""
    p = build_pipeline(CFG, "fused1", tune="off")
    with pytest.raises(ValueError, match="streaming"):
        p.run_streamed(np.asarray(simulate_cached(CFG, TARGETS)), strips=4)


def test_lower_sharded_accepts_mega_step():
    """The shard_map lowering splits a mega step at its in-kernel turn
    boundaries into per-device segment groups: 3 megakernel dispatches
    per device, the 2 turns now collectives — and on a 1-device mesh the
    result stays bit-identical to the local fused3 reference."""
    mesh = jax.make_mesh((1,), ("data",))
    p = build_pipeline(CFG, "fused1", tune="off")
    run = p.lower_sharded(mesh)
    assert run.devices == 1
    assert run.dispatches_per_device == 3
    assert run.turns == 2
    assert all(u["kind"] == "mega" for u in run.unit_info)
    raw = scene()
    ref = np.asarray(build_pipeline(CFG, "fused3", tune="off").run(raw))
    np.testing.assert_array_equal(np.asarray(run(raw)), ref)


def test_lower_sharded_rejects_transposing_plan():
    """Transpose stages reorder the whole scene — no per-device slab can
    do that locally, and the error must say what to compile instead."""
    mesh = jax.make_mesh((1,), ("data",))
    p = build_pipeline(CFG, "fused", tune="off")   # transposing variant
    with pytest.raises(ValueError, match="fused1"):
        p.lower_sharded(mesh)


def test_mega_rejected_inside_transposed_section():
    bad = SpectralPlan("p", (
        Stage("t", kind="transpose"),
        Stage("a", axis=1, fwd=True),
        Stage("b", axis=0, inv=True),
        Stage("t2", kind="transpose"),
    ))
    with pytest.raises(ValueError, match="transposed"):
        planlib.compile_plan(bad, CFG, fuse=FUSE_MEGA)


# ---------------------------------------------------------------------------
# Residency selection: tuning knobs + the VMEM feasibility cut
# ---------------------------------------------------------------------------

def test_auto_residency_follows_vmem_budget():
    small = make_test_scene(256)
    assert tuning.cost.mega_residency(small.na, small.nr) == "vmem"
    assert tuning.cost.mega_residency(4096, 4096) == "staged"
    # the compiled step records the resolved mode
    p = build_pipeline(small, "fused1", tune="off")
    assert p.steps[0].kernel_kw["residency"] == "vmem"
    p = build_pipeline(small, "fused1", tune="off", residency="staged")
    assert p.steps[0].kernel_kw["residency"] == "staged"


def test_kernel_config_mega_knobs_validate_and_roundtrip():
    cfg = tuning.KernelConfig(residency="staged", phase_block=16)
    assert tuning.KernelConfig.from_dict(cfg.to_dict()) == cfg
    # the knobs never leak into the per-axis kernel kwargs
    assert "residency" not in cfg.spectral_kwargs()
    with pytest.raises(ValueError, match="residency"):
        tuning.KernelConfig(residency="hbm")
    with pytest.raises(ValueError, match="phase_block"):
        tuning.KernelConfig(phase_block=12)


# ---------------------------------------------------------------------------
# Serving route
# ---------------------------------------------------------------------------

def test_local_backend_routes_vmem_scenes_to_fused1():
    from repro.service.backends import FUSED1_TWINS, LocalBackend
    from repro.service.queue import BatchKey
    cfg = make_test_scene(128)
    raw = np.asarray(simulate_cached(cfg, paper_targets(cfg))
                     ).astype(np.complex64)
    key = BatchKey(cfg, "fused3", None, False)
    routed = LocalBackend(sweep=((None, None),))
    pinned = LocalBackend(sweep=((None, None),), fused1="off")
    assert FUSED1_TWINS["fused3"] == "fused1"
    assert routed._route_variant(key) == "fused1"
    assert pinned._route_variant(key) == "fused3"
    # the route is invisible to the caller: same images bit-for-bit
    np.testing.assert_array_equal(routed.execute(key, raw[None]),
                                  pinned.execute(key, raw[None]))
    # a scene past the VMEM budget keeps its per-axis variant
    big = make_test_scene(4096)
    assert routed._route_variant(
        BatchKey(big, "fused3", None, False)) == "fused3"
    # unknown-twin variants are never rerouted
    assert routed._route_variant(
        BatchKey(cfg, "fused", None, False)) == "fused"
    # block-scaled precisions route too: the megakernel carries per-line
    # exponents through its corner turns, so bs16 is bit-invisible as well
    assert routed._route_variant(
        BatchKey(cfg, "fused3", "bs16", False)) == "fused1"
    assert routed._route_variant(
        BatchKey(cfg, "fused3", "bf16", False)) == "fused1"


# ---------------------------------------------------------------------------
# Satellites that ride along with the megakernel
# ---------------------------------------------------------------------------

def test_dft_constants_memoized_per_factorization():
    """build_spectral_call / re-traces must hit the lru_cache instead of
    rebuilding the numpy DFT matrices."""
    from repro.kernels.fft4step import SpectralSpec, build_spectral_call, \
        dft_constants
    dft_constants.cache_clear()
    a = dft_constants(16, 8)
    before = dft_constants.cache_info()
    b = dft_constants(16, 8)
    after = dft_constants.cache_info()
    assert after.hits == before.hits + 1 and after.misses == before.misses
    assert all(x is y for x, y in zip(a, b))          # the SAME arrays
    assert not a[0].flags.writeable                    # shared -> read-only
    # two kernel builds for the same spec: second build misses nothing
    spec = SpectralSpec(n=128, fwd=True, filter_mode="none", inv=False)
    build_spectral_call(spec, lines=8, interpret=True)
    misses = dft_constants.cache_info().misses
    build_spectral_call(spec, lines=8, interpret=True)
    assert dft_constants.cache_info().misses == misses


@pytest.mark.parametrize("r,c", [(96, 40), (100, 36), (7, 5)])
def test_transpose_ragged_shapes_stay_exact(r, c):
    """Ragged scenes go through the padded Pallas tile path (no XLA
    fallback) and still transpose exactly."""
    from repro.kernels.transpose import transpose
    rng = np.random.default_rng(5)
    x = rng.standard_normal((r, c)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(transpose(jnp.asarray(x), tile=32)), x.T)
    xb = rng.standard_normal((2, r, c)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(transpose(jnp.asarray(xb), tile=32)),
        np.swapaxes(xb, -1, -2))


def test_bench_schema_interpret_flag():
    """Rows may carry an optional `interpret` bool; anything else fails
    validation (the ratchet relies on the flag to avoid diffing emulator
    wall time against compiled wall time)."""
    from benchmarks.common import BENCH_SCHEMA, utc_now_iso, \
        validate_bench_doc
    doc = {
        "schema": BENCH_SCHEMA, "git_sha": "x", "backend": "cpu",
        "jax_version": "0", "python": "3", "generated_utc": utc_now_iso(),
        "rows": [{"section": "s", "name": "rda_fused1", "wall_ms": 1.0,
                  "interpret": True}],
    }
    validate_bench_doc(doc)
    doc["rows"][0]["interpret"] = "yes"
    with pytest.raises(ValueError, match="interpret"):
        validate_bench_doc(doc)


def test_bench_ratchet_detects_regression_and_respects_flags():
    import importlib.util
    import os
    spec = importlib.util.spec_from_file_location(
        "bench_compare_script",
        os.path.join(os.path.dirname(__file__), "..", "scripts",
                     "bench_compare.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    def doc(ms, interpret=True, ref_ms=None, name="rda_fused1"):
        rows = [{"section": "t", "name": name, "wall_ms": ms,
                 "interpret": interpret}]
        if ref_ms is not None:
            rows.append({"section": "t", "name": "rda_unfused",
                         "wall_ms": ref_ms, "interpret": False})
        return {"rows": rows}

    pat = r"rda_(?!un).*fused"
    ok = mod.compare(doc(100.0), doc(110.0), pat, 1.3, 1.0)
    assert ok == []
    bad = mod.compare(doc(100.0), doc(150.0), pat, 1.3, 1.0)
    assert len(bad) == 1 and "1.50x" in bad[0]
    # interpret-flag mismatch is skipped, never a failure
    mixed = mod.compare(doc(100.0, interpret=False), doc(150.0), pat,
                        1.3, 1.0)
    assert mixed == []
    # the default pattern never gates the informational unfused oracle
    unfused = mod.compare(doc(1.0, name="rda_unfused", interpret=False),
                          doc(100.0, name="rda_unfused", interpret=False),
                          pat, 1.3, 0.0)
    assert unfused == []
    # reference-row normalization: a uniformly 2x slower machine (both
    # the fused row AND the reference doubled) does not trip the ratchet
    norm = mod.compare(doc(100.0, ref_ms=10.0), doc(200.0, ref_ms=20.0),
                       pat, 1.3, 1.0)
    assert norm == []
    # ...but a real fused-only regression still does
    real = mod.compare(doc(100.0, ref_ms=10.0), doc(200.0, ref_ms=10.0),
                       pat, 1.3, 1.0)
    assert len(real) == 1
