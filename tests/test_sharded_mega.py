"""The sharded megakernel lowering (ISSUE 7): mega steps through
shard_map — one megakernel dispatch per device per phase group, in-kernel
corner turns becoming all_to_all collectives.

Fast tests cover the pure-math pieces (the corner-turn permutation
property, the collective-bytes cost terms, the routing predicate, the
mesh helper, the compiler's per-segment payload record). The 8-device
parity suite runs in subprocesses (`run_sub`) under the slow marker —
CI's multi-device job executes it with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from tests._hypothesis_fallback import given, settings, strategies as st

from tests.test_distributed import run_sub

from repro.tuning import cost
from repro.tuning.space import ScheduleProblem, SegmentShape


# ---------------------------------------------------------------------------
# Property: the corner turn is a pure permutation
# ---------------------------------------------------------------------------
#
# A numpy model of jax.lax.all_to_all(tiled=True): each device splits its
# local slab into P parts along split_axis, sends part e to device e, and
# concatenates what it receives along concat_axis. The lowering's claim is
# that shard -> turn -> unshard moves every element to where a plain
# re-shard along the other axis would put it — a permutation, no
# arithmetic — so f32 bit-identity of the sharded pipeline follows from
# per-slab kernel bit-identity.

def _np_all_to_all(slabs, split_axis, concat_axis):
    p = len(slabs)
    parts = [np.array_split(s, p, axis=split_axis) for s in slabs]
    return [np.concatenate([parts[e][d] for e in range(p)],
                           axis=concat_axis) for d in range(p)]


def _shard(x, axis, p):
    return np.array_split(x, p, axis=axis)


def _unshard(slabs, axis):
    return np.concatenate(slabs, axis=axis)


@settings(max_examples=40, deadline=None)
@given(b=st.integers(1, 3),
       na_blocks=st.integers(1, 6),
       nr_blocks=st.integers(1, 6),
       p=st.sampled_from([1, 2, 4, 8]),
       stream=st.sampled_from([0, 1]),
       batched=st.sampled_from([False, True]))
def test_corner_turn_is_permutation_identity(b, na_blocks, nr_blocks, p,
                                             stream, batched):
    """shard(stream) -> all_to_all -> unshard(other) == identity, for
    arbitrary (B, na, nr) and any device count dividing the sharded axis
    — and a second turn restores the original sharding exactly."""
    na, nr = p * na_blocks, p * nr_blocks
    shape = (b, na, nr) if batched else (na, nr)
    bpre = len(shape) - 2
    x = np.arange(int(np.prod(shape)), dtype=np.int64).reshape(shape)

    slabs = _shard(x, bpre + stream, p)
    # the lowering's _turn: split the OTHER scene axis, concat the current
    split_axis = bpre + (1 - stream)
    concat_axis = bpre + stream
    turned = _np_all_to_all(slabs, split_axis, concat_axis)
    np.testing.assert_array_equal(
        _unshard(turned, bpre + (1 - stream)), x)
    # turning back is the inverse permutation
    back = _np_all_to_all(turned, concat_axis, split_axis)
    np.testing.assert_array_equal(_unshard(back, bpre + stream), x)


@settings(max_examples=40, deadline=None)
@given(na_blocks=st.integers(1, 6),
       nr_blocks=st.integers(1, 6),
       p=st.sampled_from([1, 2, 4, 8]),
       stream=st.sampled_from([0, 1]))
def test_carried_exponent_turn_is_pair_permutation(na_blocks, nr_blocks, p,
                                                   stream):
    """The bs16 carried-exponent corner turn is a pure permutation of
    (value, exponent) pairs: the data slab rides all_to_all while its
    per-line exponents ride all_gather along the OLD stream axis
    (distributed.lower_pipeline). Applying each line's exponent before
    the turn (per-shard exponent slices) and after it (gathered vector
    broadcast over the now-full stream axis) must reassemble the same
    image — no pair is split, scaled twice, or dropped."""
    na, nr = p * na_blocks, p * nr_blocks
    x = np.arange(na * nr, dtype=np.float64).reshape(na, nr) + 1.0
    n_lines = na if stream == 0 else nr
    e = np.arange(n_lines, dtype=np.float64) % 7 - 3   # per-line exponents
    ecol = e.reshape(-1, 1) if stream == 0 else e.reshape(1, -1)
    want = x * 2.0 ** ecol

    slabs = _shard(x, stream, p)
    eslabs = _shard(ecol, stream, p)
    # before the turn each device holds its own lines' exponents
    pre = [s * 2.0 ** es for s, es in zip(slabs, eslabs)]
    np.testing.assert_array_equal(_unshard(pre, stream), want)
    # the turn: data all_to_all, exponents all_gather (tiled concat)
    turned = _np_all_to_all(slabs, 1 - stream, stream)
    egather = _unshard(eslabs, stream)       # full vector on every device
    post = [t * 2.0 ** egather for t in turned]
    np.testing.assert_array_equal(_unshard(post, 1 - stream), want)


# ---------------------------------------------------------------------------
# Cost model: the collective-bytes terms
# ---------------------------------------------------------------------------

MEGA_SEGS = (SegmentShape(axis=0, fwd=True),
             SegmentShape(axis=1, fwd=True, inv=True, filtered=True),
             SegmentShape(axis=0, inv=True, filtered=True))


def test_collective_turn_bytes_matches_doc_math():
    """docs/distributed.md: one turn moves 2·4·na·nr·(P-1)/P bytes per
    split-f32 re/im pair per device."""
    na = nr = 4096
    p = 8
    slab = 2 * 4 * na * nr // p                       # re+im local slab
    assert cost.collective_turn_bytes(na, nr, devices=p) == slab * 7 // 8
    # bf16 wire format halves it
    assert cost.collective_turn_bytes(na, nr, devices=p, elem_bytes=2) \
        == slab * 7 // 16
    # one device: nothing crosses links
    assert cost.collective_turn_bytes(na, nr, devices=1) == 0


def test_turn_seconds_sharded_is_collective_priced():
    local = ScheduleProblem.mega_2d(2048, 2048, MEGA_SEGS)
    shard = ScheduleProblem.mega_2d(2048, 2048, MEGA_SEGS, devices=8)
    # sharded turns cost wire time even for VMEM-resident slabs...
    assert cost.turn_seconds(local, residency="vmem") == 0.0
    assert cost.turn_seconds(shard, residency="vmem") > 0.0
    # ...and depth>=2 double-buffering earns the overlap credit
    full = cost.turn_seconds(shard, residency="staged", buffer_depth=1)
    overlapped = cost.turn_seconds(shard, residency="staged",
                                   buffer_depth=2)
    assert overlapped == pytest.approx(full * cost.TURN_OVERLAP)


def test_sharded_problem_divides_lines_not_transforms():
    shard = ScheduleProblem.mega_2d(2048, 1024, MEGA_SEGS, devices=8)
    range_seg, az_seg = MEGA_SEGS[1], MEGA_SEGS[0]
    assert shard.seg_n(range_seg) == 1024              # transform whole
    assert shard.seg_lines(range_seg) == 2048 // 8     # free axis 1/P
    assert shard.seg_n(az_seg) == 2048
    assert shard.seg_lines(az_seg) == 1024 // 8
    with pytest.raises(ValueError, match="devices"):
        ScheduleProblem.mega_2d(100, 100, MEGA_SEGS, devices=8)


def test_sharded_preferred_routes_big_scenes_only():
    # VMEM-fitting scenes keep the local single-dispatch route
    assert not cost.sharded_preferred(512, 512, devices=8)
    # the paper scale shards
    assert cost.sharded_preferred(4096, 4096, devices=8)
    assert cost.sharded_preferred(1024, 1024, devices=8)
    # degenerate meshes / non-tiling scenes never route
    assert not cost.sharded_preferred(4096, 4096, devices=1)
    assert not cost.sharded_preferred(4100, 4100, devices=8)


def test_schedule_frontier_ranks_sharded_schedules():
    """The graph search prices devices>1 problems end-to-end: the
    frontier comes back non-empty, cost-ascending, and cheaper than the
    identical local problem (1/P compute + slab terms dominate the added
    wire cost at paper scale)."""
    from repro.tuning.search import schedule_frontier
    shard = ScheduleProblem.mega_2d(4096, 4096, MEGA_SEGS, devices=8)
    local = ScheduleProblem.mega_2d(4096, 4096, MEGA_SEGS)
    ranked = schedule_frontier(shard, k=4)
    assert ranked
    costs = [cost.schedule_seconds(s, shard) for s in ranked]
    assert costs == sorted(costs)
    best_local = min(cost.schedule_seconds(s, local)
                     for s in schedule_frontier(local, k=4))
    assert costs[0] < best_local


# ---------------------------------------------------------------------------
# Compiler + lowering surface (single device, tier-1)
# ---------------------------------------------------------------------------

def test_mega_step_records_per_segment_payloads():
    from repro.core import plan as planlib
    from repro.core.sar.geometry import test_scene
    p = planlib.build_variant(test_scene(256), "fused1", tune="off")
    step = p.steps[0]
    assert step.kind == "mega"
    segs = step.kernel_kw["segments"]
    assert step.seg_filter_args is not None
    assert len(step.seg_filter_args) == len(segs)
    # flat mega_spectral_op order == concatenation of per-segment tuples
    flat = [a for fa in step.seg_filter_args for a in fa]
    modes = [rec[3] for rec in segs]
    per_mode = {"none": 0, "shared": 2, "full": 2, "outer": 2,
                "shared_outer": 4}
    assert len(flat) == sum(per_mode[m] for m in modes)


def test_make_sar_mesh_single_host():
    import jax
    from repro.core.sar.distributed import make_sar_mesh
    mesh = make_sar_mesh()
    assert mesh.axis_names == ("data",)
    assert mesh.devices.size == len(jax.devices())
    with pytest.raises(ValueError, match="axis names"):
        make_sar_mesh(axes=("a", "b", "c"))


# ---------------------------------------------------------------------------
# 8-device parity (slow, subprocess — the CI multi-device job)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_mega_parity_8_devices():
    """The acceptance criterion: 8 devices, one megakernel dispatch per
    device per phase group (3 groups, 2 collective turns), f32
    bit-identical to the LOCAL per-axis reference for fused1/csa_fused1
    and <= 0.1 dB for omegak_fused1 — in both residency modes and
    batched."""
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.sar import test_scene, paper_targets, simulate, metrics
from repro.core import plan as planlib
import repro.core.sar.csa, repro.core.sar.omegak  # register variants

cfg = test_scene(256)
targets = paper_targets(cfg)
raw = jnp.asarray(simulate(cfg, targets))
mesh = jax.make_mesh((8,), ("data",))

for variant, twin in (("fused1", "fused3"), ("csa_fused1", "csa_fused"),
                      ("omegak_fused1", "omegak")):
    run = planlib.build_variant(cfg, variant, tune="off").lower_sharded(mesh)
    assert run.devices == 8 and run.dispatches_per_device == 3 \
        and run.turns == 2, (run.devices, run.dispatches_per_device,
                             run.turns)
    img = np.asarray(run(raw))
    ref = np.asarray(planlib.build_variant(cfg, twin, tune="off").run(raw))
    if variant == "omegak_fused1":
        c = metrics.compare_pipelines(img, ref, cfg, targets)
        assert max(c["snr_delta_db"]) <= 0.1, c["snr_delta_db"]
    else:
        assert np.array_equal(img, ref), variant
    # the sharded image also matches the LOCAL megakernel bit-for-bit
    mega = np.asarray(planlib.build_variant(cfg, variant, tune="off").run(raw))
    assert np.array_equal(img, mega), variant

# staged residency: per-device DMA-staged megakernels, same bits
p1 = planlib.build_variant(cfg, "fused1", tune="off")
run_s = p1.lower_sharded(mesh, residency="staged")
assert [u["residency"] for u in run_s.unit_info] == ["staged"] * 3
ref = np.asarray(planlib.build_variant(cfg, "fused3", tune="off").run(raw))
assert np.array_equal(np.asarray(run_s(raw)), ref)

# batched (B, na, nr): one lowering, same bits per scene
rawb = jnp.stack([raw, 2 * raw])
run_b = p1.lower_sharded(mesh)
refb = np.asarray(planlib.build_variant(cfg, "fused3", tune="off").run(rawb))
assert np.array_equal(np.asarray(run_b(rawb)), refb)

# multi-host-shaped mesh path: processes x local devices layout
from repro.core.sar.distributed import make_sar_mesh
mesh2 = make_sar_mesh(axes=("pod", "data"))
assert mesh2.devices.shape[0] == 1          # single-host: 1 x 8
run2 = p1.lower_sharded(mesh2, axes=("pod", "data"))
assert np.array_equal(np.asarray(run2(raw)), ref)
print("SHARDED_MEGA_OK")
""")
    assert "SHARDED_MEGA_OK" in out


@pytest.mark.slow
def test_sharded_service_route_8_devices():
    """LocalBackend.execute_streamed routes a big (locally-staged) scene
    to the sharded megakernel twin when the cost model prefers it — and
    the served image is bit-identical to the per-axis reference, so the
    route is invisible."""
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.sar import test_scene
from repro.core import plan as planlib
from repro.service.backends import LocalBackend
from repro.service.queue import BatchKey
from repro.tuning import cost

cfg = test_scene(1024)
assert cost.mega_residency(cfg.na, cfg.nr) == "staged"  # over budget
key = BatchKey(cfg, "fused3", None, True)
rng = np.random.default_rng(0)
raw = (rng.standard_normal((1024, 1024))
       + 1j * rng.standard_normal((1024, 1024))).astype(np.complex64)

backend = LocalBackend()
assert backend._sharded_twin(key) == "fused1"
img = backend.execute_streamed(key, raw)
assert key in backend._sharded_fns            # the sharded path ran
ref = np.asarray(planlib.build_variant(cfg, "fused3", tune="off")
                 .run(jnp.asarray(raw)))
assert np.array_equal(img, ref)

# opting out pins the host-strip path
off = LocalBackend(sharded="off")
assert off._sharded_twin(key) is None
print("SHARDED_ROUTE_OK")
""")
    assert "SHARDED_ROUTE_OK" in out
