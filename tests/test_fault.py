"""Fault tolerance: failure-injected training restarts from checkpoint and
produces EXACTLY the same final parameters as an uninterrupted run (the
checkpoint + counted-data-stream guarantee)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.data import DataConfig, TokenStream
from repro.distributed import (
    FailureInjector,
    PreemptionHandler,
    SimulatedFailure,
    StragglerWatchdog,
    run_with_restarts,
)
from repro.launch.mesh import make_host_mesh, activation_rules
from repro.launch import train as T
from repro.models import Model
from repro.optim import AdamWConfig, adamw


def _setup(tmp_path):
    arch = "stablelm-1.6b"
    model, cfg, mesh, rules, p_shard, jitted, data = T.build(
        arch, smoke=True, batch=4, seq=32)
    run0 = T.init_state(model, mesh, rules, p_shard)
    return model, mesh, rules, jitted, data, run0


def test_restart_reproduces_uninterrupted_run(tmp_path):
    model, mesh, rules, jitted, data, run0 = _setup(tmp_path)
    n = 8

    # snapshot the initial state first (the jitted step donates its inputs,
    # so each run must start from a fresh restore)
    mgr = CheckpointManager(str(tmp_path))
    like = jax.tree.map(np.asarray, {"params": run0.params,
                                     "opt": run0.opt_state})
    mgr.save(0, like)

    def restore():
        tree, step = mgr.restore(like)
        return T.TrainRun(tree["params"], tree["opt"], step)

    # uninterrupted reference
    ref, _, _ = T.train_loop(restore(), jitted, data, mesh, rules, n,
                             log_every=0)

    # failure-injected run: checkpoint every 2 steps, die at step 5
    injector = FailureInjector(at_steps=(5,))

    def train(state):
        out, _, _ = T.train_loop(state, jitted, data, mesh, rules, n,
                                 ckpt=mgr, ckpt_every=2, injector=injector,
                                 log_every=0, async_ckpt=False)
        return out

    final, restarts = run_with_restarts(train, restore)
    assert restarts == 1

    for a, b in zip(jax.tree.leaves(final.params), jax.tree.leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_injector_fires_once():
    inj = FailureInjector(at_steps=(3,))
    inj.check(2)
    with pytest.raises(SimulatedFailure):
        inj.check(3)
    inj.check(3)  # second pass: already fired


def test_watchdog_flags_straggler():
    wd = StragglerWatchdog(factor=3.0)
    for i in range(8):
        wd.record(i, 0.1)
    assert wd.record(8, 1.0) is True
    assert wd.flagged and wd.flagged[0][0] == 8


def test_preemption_checkpoint(tmp_path):
    model, mesh, rules, jitted, data, run0 = _setup(tmp_path)
    mgr = CheckpointManager(str(tmp_path))
    pre = PreemptionHandler(install=False)
    pre.trigger()
    run = T.TrainRun(run0.params, run0.opt_state, 0)
    run, _, _ = T.train_loop(run, jitted, data, mesh, rules, 10, ckpt=mgr,
                             ckpt_every=100, preempt=pre, log_every=0)
    # stopped after one step and wrote a final checkpoint
    assert run.step == 1
    assert mgr.latest_step() == 1


def test_elastic_reshard_restore(tmp_path):
    """Checkpoint written under one sharding restores under another (the
    elastic-restart path; on one device the shardings differ logically)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh1 = make_host_mesh(model=1)
    tree = {"w": jnp.ones((8, 4)), "b": jnp.zeros((4,))}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    sh = {"w": NamedSharding(mesh1, P("data", None)),
          "b": NamedSharding(mesh1, P())}
    out, _ = mgr.restore(jax.tree.map(np.zeros_like, tree), shardings=sh)
    assert out["w"].sharding.is_equivalent_to(sh["w"], 2)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((8, 4)))
