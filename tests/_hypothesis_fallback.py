"""Deterministic stand-in for the tiny hypothesis subset the tests use.

``hypothesis`` is declared in requirements-dev.txt, but some runtimes (this
container included) cannot install extra packages. Rather than skip the
property tests there, this module re-implements just `given`, `settings`,
and the three strategies the suite draws from, with a fixed per-test seed so
every run exercises the same examples. Real hypothesis is preferred whenever
it is importable (see the try/except at each import site); shrinkage and
example databases are the only features lost in the fallback.
"""
from __future__ import annotations

import functools
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def sample(self, rng):
        return self._draw(rng)


class strategies:
    @staticmethod
    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def given(**strats):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", 10)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)
        # NOT functools.wraps: __wrapped__ would make pytest introspect the
        # original signature and demand fixtures for the drawn arguments.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._fallback_given = True
        return wrapper
    return deco


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
