"""Golden quality regression: the five-point-target reference scene.

The fixture in ``tests/golden/point_targets_n128.json`` stores, for each
algorithm family (RDA / CSA / omega-K), the per-target peak location and
SNR of the f32 per-axis reference image. Every serving route must
reproduce it:

* f32 — at exactly 0.0 dB deviation (the routes are bit-identical, so
  the measured SNR equals the stored SNR to the last ulp), for fused3,
  fused1 VMEM-resident, fused1 DMA-staged, and (slow) the 8-device
  sharded lowering;
* bf16 / bs16 — within the 0.1 dB serving gate, same routes. The full
  precision matrix runs for RDA; CSA and omega-K check f32 + bs16 (the
  block-scaled tier is the serving default and the route most likely to
  regress — its exponents are carried through the kernels);
* raw f16 — asserted OUT of gate: the un-scaled half float overflows on
  FFT intermediates (NaN image), which is exactly why the serving tier
  is bs16 (f16 storage behind per-line block exponents), not f16.

Regenerate the fixture after an INTENDED quality change with::

    PYTHONPATH=src python tests/test_quality_regression.py --regen
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.sar import build_pipeline, metrics, paper_targets, \
    simulate_cached
from repro.core.sar.geometry import test_scene as make_test_scene

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "point_targets_n128.json")

N = 128
# tests/golden runs the 128^2 scene for speed; the default guard (64)
# would mask the whole image there, so the corpus pins a 16-px guard.
GUARD = 16

# family -> (per-axis variant, megakernel twin); mirrors
# repro.service.backends.FUSED1_TWINS
FAMILIES = {
    "rda": ("fused3", "fused1"),
    "csa": ("csa_fused", "csa_fused1"),
    "omegak": ("omegak", "omegak_fused1"),
}

GATE_DB = 0.1
PRECISIONS_FULL = (None, "bf16", "bs16")
PRECISIONS_TIER = (None, "bs16")

_scene_cache = {}


def scene():
    if "raw" not in _scene_cache:
        cfg = make_test_scene(N)
        _scene_cache["cfg"] = cfg
        _scene_cache["targets"] = paper_targets(cfg)
        _scene_cache["raw"] = np.asarray(
            simulate_cached(cfg, _scene_cache["targets"]), np.complex64)
    return (_scene_cache["cfg"], _scene_cache["targets"],
            _scene_cache["raw"])


def golden_reports(img, cfg, targets):
    """(row, col, snr_db) per target, with the corpus guard width."""
    noise = metrics.noise_rms(img, cfg, targets, guard=GUARD)
    out = []
    for t in targets:
        rep = metrics.analyze_target(img, cfg, t, noise)
        out.append({"row": rep.row, "col": rep.col, "snr_db": rep.snr_db})
    return out


def focus(variant, precision=None, residency=None):
    cfg, _targets, raw = scene()
    kw = {"tune": "off"}
    if precision is not None:
        kw["precision"] = precision
    if residency is not None:
        kw["residency"] = residency
    return np.asarray(build_pipeline(cfg, variant, **kw).run(
        jnp.asarray(raw)))


def load_golden():
    with open(GOLDEN_PATH) as f:
        doc = json.load(f)
    assert doc["scene_n"] == N and doc["guard"] == GUARD
    return doc


# route id -> (use twin?, residency kwarg)
ROUTES = {
    "fused3": (False, None),
    "fused1": (True, None),             # VMEM-resident megakernel
    "fused1_staged": (True, "staged"),  # DMA-staged megakernel
}


def _check(family, route, precision):
    golden = load_golden()["families"][family]
    cfg, targets, _raw = scene()
    per_axis, twin = FAMILIES[family]
    use_twin, residency = ROUTES[route]
    img = focus(twin if use_twin else per_axis, precision=precision,
                residency=residency)
    got = golden_reports(img, cfg, targets)
    for i, (g, m) in enumerate(zip(golden["targets"], got)):
        dev = abs(m["snr_db"] - g["snr_db"])
        if precision is None:
            # f32 routes are bit-identical: peak pixel AND SNR exact
            assert (m["row"], m["col"]) == (g["row"], g["col"]), \
                f"target {i}: f32 peak moved {g['row'], g['col']} -> " \
                f"{m['row'], m['col']} ({family}/{route})"
            assert dev == 0.0, \
                f"target {i}: f32 SNR deviated {dev} dB " \
                f"({family}/{route}) — the f32 route must be exact"
        else:
            # narrow precisions: quantization can tip a near-tied
            # mainlobe sample, so the peak may drift a pixel or two —
            # the gate is the SNR deviation, not the argmax
            assert (abs(m["row"] - g["row"]) <= 2
                    and abs(m["col"] - g["col"]) <= 2), \
                f"target {i}: {precision} peak moved " \
                f"{g['row'], g['col']} -> {m['row'], m['col']} " \
                f"({family}/{route})"
            assert dev <= GATE_DB, \
                f"target {i}: {precision} SNR deviation {dev:.4f} dB " \
                f"exceeds the {GATE_DB} dB gate ({family}/{route})"


@pytest.mark.parametrize("precision", PRECISIONS_FULL,
                         ids=[p or "f32" for p in PRECISIONS_FULL])
@pytest.mark.parametrize("route", sorted(ROUTES))
def test_rda_golden_quality(route, precision):
    _check("rda", route, precision)


@pytest.mark.parametrize("precision", PRECISIONS_TIER,
                         ids=[p or "f32" for p in PRECISIONS_TIER])
@pytest.mark.parametrize("route", sorted(ROUTES))
@pytest.mark.parametrize("family", ["csa", "omegak"])
def test_csa_omegak_golden_quality(family, route, precision):
    _check(family, route, precision)


def test_raw_f16_is_out_of_gate():
    """The negative control the bs16 tier exists for: UN-scaled f16
    overflows on FFT intermediates (its max finite value is 65504), so
    the raw-f16 image fails the golden corpus outright. If this ever
    starts passing, the scene stopped exercising the dynamic range that
    motivates block scaling — regenerate it with a harder one."""
    golden = load_golden()["families"]["rda"]
    cfg, targets, _raw = scene()
    img = focus("fused3", precision="f16")
    got = golden_reports(img, cfg, targets)
    devs = [abs(m["snr_db"] - g["snr_db"])
            for g, m in zip(golden["targets"], got)]
    assert any(not np.isfinite(d) or d > GATE_DB for d in devs), devs


@pytest.mark.slow
def test_sharded_golden_quality_8_devices():
    """Subprocess (8 fake CPU devices): the sharded fused1 lowering must
    hit the same golden corpus — f32 exactly, bs16 within the gate (its
    carried exponents ride the all_to_all corner turns)."""
    code = f"""
import json, numpy as np, jax, jax.numpy as jnp
from repro.core.sar import build_pipeline, metrics, paper_targets, \\
    simulate_cached
from repro.core.sar.geometry import test_scene

golden = json.load(open({GOLDEN_PATH!r}))["families"]["rda"]["targets"]
cfg = test_scene({N})
targets = paper_targets(cfg)
raw = jnp.asarray(np.asarray(simulate_cached(cfg, targets), np.complex64))
mesh = jax.make_mesh((8,), ("data",))

for precision, exact in ((None, True), ("bs16", False)):
    kw = {{"tune": "off"}}
    if precision is not None:
        kw["precision"] = precision
    img = np.asarray(
        build_pipeline(cfg, "fused1", **kw).lower_sharded(mesh)(raw))
    noise = metrics.noise_rms(img, cfg, targets, guard={GUARD})
    for i, (g, t) in enumerate(zip(golden, targets)):
        rep = metrics.analyze_target(img, cfg, t, noise)
        assert (rep.row, rep.col) == (g["row"], g["col"]), (precision, i)
        dev = abs(rep.snr_db - g["snr_db"])
        if exact:
            assert dev == 0.0, (precision, i, dev)
        else:
            assert dev <= {GATE_DB}, (precision, i, dev)
print("SHARDED_GOLDEN_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC + os.pathsep + os.path.join(SRC, ".."))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SHARDED_GOLDEN_OK" in r.stdout


def regen():
    """Rewrite the golden fixture from the f32 per-axis references."""
    cfg, targets, _raw = scene()
    doc = {
        "scene_n": N,
        "guard": GUARD,
        "comment": "f32 per-axis reference; regenerate with "
                   "PYTHONPATH=src python tests/test_quality_regression.py"
                   " --regen",
        "families": {},
    }
    for family, (per_axis, _twin) in FAMILIES.items():
        img = focus(per_axis)
        doc["families"][family] = {
            "variant": per_axis,
            "targets": golden_reports(img, cfg, targets),
        }
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    if "--regen" in sys.argv:
        regen()
    else:
        sys.exit("usage: test_quality_regression.py --regen")
