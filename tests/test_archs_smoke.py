"""Per-architecture smoke tests (assignment deliverable): a REDUCED config of
the same family runs one forward/train step on CPU — output shapes + no NaNs.
Full configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import Model

B, S = 2, 64
RNG = np.random.default_rng(0)


def make_batch(cfg):
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jnp.asarray(
            RNG.standard_normal((B, 16, cfg.d_model)), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            RNG.standard_normal((B, cfg.encoder.n_frames, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(registry.ARCHS))
def test_arch_smoke(arch):
    cfg = registry.smoke(arch, seq=S)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    # forward: shape + finite
    x, aux, _ = model.forward(params, batch, train=False)
    assert x.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(x).all()), arch

    # one train step: loss + grads finite
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert bool(jnp.isfinite(loss)), arch
    gnorm = jnp.sqrt(sum(jnp.sum(g ** 2) for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)), arch


@pytest.mark.parametrize("arch", ["gemma3-12b", "recurrentgemma-9b",
                                  "falcon-mamba-7b", "whisper-tiny",
                                  "llama4-scout-17b-a16e"])
def test_prefill_decode_consistency(arch):
    """Decode with cache == full forward, for every cache kind (full KV,
    ring KV, recurrent state, cross-attention)."""
    cfg = registry.smoke(arch, seq=S)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    t = S // 2

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :t]
    pre.pop("labels")
    cache, logits_pre = model.prefill(params, pre, max_len=S)
    logits_dec, cache = model.decode_step(params, cache,
                                          batch["tokens"][:, t:t + 1])

    full = dict(batch)
    full["tokens"] = batch["tokens"][:, :t + 1]
    x, _, _ = model.forward(params, full, train=False)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    want_pre = np.asarray(x[:, t - 1] @ table.T)
    want_dec = np.asarray(x[:, t] @ table.T)
    np.testing.assert_allclose(np.asarray(logits_pre), want_pre, atol=2e-3)
    np.testing.assert_allclose(np.asarray(logits_dec), want_dec, atol=2e-3)


def test_long_decode_ring_cache():
    """Local-attention ring cache: decoding far past the window keeps the
    cache size fixed and matches a windowed full-attention oracle."""
    cfg = registry.smoke("gemma3-12b", seq=S)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    t = S - 8
    cache, _ = model.prefill(params, {"tokens": toks[:, :t]}, max_len=S)
    for i in range(4):
        logits, cache = model.decode_step(params, cache, toks[:, t + i:t + i + 1])
    x, _, _ = model.forward(params, {"tokens": toks[:, :t + 5]}, train=False)
    want = np.asarray(x[:, t + 3] @ params["embed"]["table"].T)
    np.testing.assert_allclose(np.asarray(logits), want, atol=2e-3)


def test_param_count_matches_analytic():
    for arch in ["minitron-4b", "yi-34b", "falcon-mamba-7b"]:
        cfg = registry.smoke(arch)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.02, \
            (arch, actual, predicted)


def test_full_config_dims():
    """The exact assigned dimensions are preserved in the full configs."""
    spec = {
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
    }
    for arch, (nl, d, h, kv, ff, v) in spec.items():
        cfg = registry.get(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (nl, d, h, kv, ff, v), arch
    # MoE / SSM extras
    assert registry.get("llama4-scout-17b-a16e").moe.n_experts == 16
    assert registry.get("llama4-scout-17b-a16e").moe.top_k == 1
    assert registry.get("granite-moe-3b-a800m").moe.n_experts == 40
    assert registry.get("granite-moe-3b-a800m").moe.top_k == 8
    assert registry.get("falcon-mamba-7b").ssm.state_dim == 16


def test_cells_matrix():
    cells = registry.cells(include_skipped=True)
    assert len(cells) == 40
    skipped = [c for c in cells if c[2] is not None]
    assert len(skipped) == 7  # 7 archs skip long_500k
    run = [c for c in cells if c[2] is None]
    assert ("falcon-mamba-7b", "long_500k", None) in run
    assert ("recurrentgemma-9b", "long_500k", None) in run
    assert ("gemma3-12b", "long_500k", None) in run
