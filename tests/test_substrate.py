"""Data pipeline, optimizer, gradient compression, checkpointing."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - see requirements-dev.txt
    from _hypothesis_fallback import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, TokenStream
from repro.optim import AdamWConfig, adamw, compress


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------

def test_data_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=3)
    s1, s2 = TokenStream(cfg), TokenStream(cfg)
    b5 = s1.batch(5)
    # fresh stream seeks straight to step 5 — exact resume
    for step, b in s2.batches(start_step=5):
        np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                      np.asarray(b5["tokens"]))
        break
    assert not np.array_equal(np.asarray(s1.batch(6)["tokens"]),
                              np.asarray(b5["tokens"]))


def test_data_is_learnable_structure():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4, noise=0.0)
    b = TokenStream(cfg).batch(0)
    t = np.asarray(b["tokens"])
    d = np.diff(t, axis=1) % 128
    # affine progressions: constant step per row
    assert (d == d[:, :1]).all()


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init(params)
    cfg = AdamWConfig(lr_peak=0.2, warmup_steps=0, decay_steps=200,
                      weight_decay=0.0, clip_norm=None)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        return adamw.update(p, g, s, cfg)

    for _ in range(150):
        params, state, _ = step(params, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_accumulation_equivalence():
    """accum_steps microbatching == full-batch gradients (linear loss)."""
    w0 = {"w": jnp.ones((4,))}

    def loss(p, batch):
        return jnp.mean(batch["x"] @ p["w"])

    cfg = AdamWConfig(warmup_steps=0, clip_norm=None, weight_decay=0.0)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 4)),
                    jnp.float32)
    s1 = adamw.make_train_step(loss, cfg, accum_steps=1)
    s4 = adamw.make_train_step(loss, cfg, accum_steps=4)
    p1, _, st1 = s1(w0, adamw.init(w0), {"x": x})
    p4, _, st4 = s4(w0, adamw.init(w0), {"x": x})
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               atol=1e-6)
    np.testing.assert_allclose(float(st1["loss"]), float(st4["loss"]),
                               atol=1e-6)


def test_clip_norm():
    params = {"w": jnp.zeros((3,))}
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    g = {"w": jnp.asarray([30.0, 40.0, 0.0])}
    _, _, stats = adamw.update(params, g, adamw.init(params), cfg)
    assert abs(float(stats["grad_norm"]) - 50.0) < 1e-3


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=10,
                      decay_steps=100)
    lr = adamw.cosine_schedule(cfg)
    assert float(lr(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(lr(jnp.asarray(10))), 1e-3, rtol=1e-5)
    np.testing.assert_allclose(float(lr(jnp.asarray(100))), 1e-4, rtol=1e-3)
    np.testing.assert_allclose(float(lr(jnp.asarray(1000))), 1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# Int8 compression with error feedback
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
def test_quantize_roundtrip_bound(seed, scale):
    x = np.random.default_rng(seed).standard_normal(256).astype(np.float32)
    x = x * scale
    q, s = compress.quantize_int8(jnp.asarray(x))
    err = np.abs(compress.dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """Sum of compressed updates tracks the sum of true gradients: the
    residual never escapes (it is bounded by one quantization step)."""
    rng = np.random.default_rng(0)
    err = jnp.zeros((64,))
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    for i in range(50):
        g = jnp.asarray(rng.standard_normal(64), jnp.float32)
        total_true += np.asarray(g)
        x = g + err
        q, s = compress.quantize_int8(x)
        deq = compress.dequantize_int8(q, s)
        err = x - deq
        total_sent += np.asarray(deq)
    resid = np.abs(total_true - total_sent).max()
    assert resid <= float(np.abs(np.asarray(err)).max()) + 1e-5


def test_compressed_bytes():
    p = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((5,))}
    assert compress.compressed_bytes(p) == 100 + 4 + 5 + 4


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def make_tree(seed=0):
    r = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(r.standard_normal((8, 4)), jnp.float32),
                   "scale": jnp.asarray(r.standard_normal(4), jnp.float32)},
        "opt": {"mu": {"w": jnp.zeros((8, 4))}, "step": jnp.asarray(7)},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = make_tree()
    mgr.save(3, tree)
    out, step = mgr.restore(jax.tree.map(np.zeros_like, tree))
    assert step == 3
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, make_tree(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4
    out, _ = mgr.restore(make_tree(), step=3)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(make_tree(3)["params"]["w"]))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_atomic_no_partial(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, make_tree())
    names = os.listdir(tmp_path)
    assert names == ["step_000000005"]
    assert "manifest.json" in os.listdir(tmp_path / "step_000000005")


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(make_tree())
