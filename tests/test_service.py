"""Focusing service: coalescing bit-identity, deadline flush,
backpressure, the precision SNR gate, the streaming route, metrics
artifacts, and sharded-backend parity."""
import asyncio
import functools
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from benchmarks.common import validate_bench_doc, validate_bench_file, \
    write_bench_json
from repro.core.sar import build_pipeline, paper_targets, simulate_cached
from repro.core.sar.geometry import test_scene as make_test_scene
from repro.service import (
    FocusService,
    LocalBackend,
    ServiceConfig,
    ServiceOverloaded,
    ShardedBackend,
    SnrGateViolation,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
CFG = make_test_scene(128)
TARGETS = paper_targets(CFG)

def fast_backend():
    # single-config backend: tests don't need the warm-time block sweep
    return LocalBackend(sweep=((None, None),))


def scene():
    return simulate_cached(CFG, TARGETS)


def reference(variant="fused3", **kw):
    return np.asarray(build_pipeline(CFG, variant, **kw).run(
        jnp.asarray(scene())))


# ---------------------------------------------------------------------------
# Coalescing semantics
# ---------------------------------------------------------------------------

def test_coalesced_batch_bit_identical_to_per_request_run():
    """Four requests coalesced into ONE (4, na, nr) dispatch sequence must
    reproduce per-request Pipeline.run bit-for-bit — batching is a kernel
    grid extension, not a numerical rewrite."""
    raw = scene()
    ref = reference()
    ref_half = np.asarray(build_pipeline(CFG, "fused3").run(
        jnp.asarray(raw) * 0.5))

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=4, max_delay_ms=500.0, precision=None),
            backend=fast_backend())
        await svc.start()
        outs = await asyncio.gather(
            svc.focus(raw, CFG), svc.focus(raw * 0.5, CFG),
            svc.focus(raw, CFG), svc.focus(raw, CFG))
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert snap["batch_size_hist"] == {4: 1}, snap  # actually coalesced
    assert np.array_equal(outs[0], ref)
    assert np.array_equal(outs[1], ref_half)
    assert np.array_equal(outs[2], ref)
    assert np.array_equal(outs[3], ref)


def test_partial_batch_pads_to_bucket_bit_identical():
    """A 3-request batch pads to the B=4 bucket; the zero pad scene must
    not perturb the real scenes' images."""
    raw = scene()
    ref = reference()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=3, max_delay_ms=500.0, precision=None),
            backend=fast_backend())
        await svc.start()
        outs = await asyncio.gather(*[svc.focus(raw, CFG) for _ in range(3)])
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert snap["batch_size_hist"] == {3: 1}
    for o in outs:
        assert np.array_equal(o, ref)


def test_deadline_flush_fires_for_partial_batch():
    """Two requests under max_batch=8 must not wait forever: the
    max_delay deadline flushes the partial bucket."""
    raw = scene()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=8, max_delay_ms=50.0, precision=None),
            backend=fast_backend())
        await svc.start()
        t0 = time.monotonic()
        outs = await asyncio.gather(svc.focus(raw, CFG),
                                    svc.focus(raw, CFG))
        elapsed = time.monotonic() - t0
        await svc.stop()
        return outs, elapsed, svc.metrics.snapshot()

    outs, elapsed, snap = asyncio.run(main())
    assert snap["batch_size_hist"] == {2: 1}, snap
    assert len(outs) == 2
    # generous bound: 50 ms deadline + one small-scene batch + slack
    assert elapsed < 30.0


def test_requests_with_different_keys_do_not_coalesce():
    raw = scene()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=4, max_delay_ms=50.0, precision=None),
            backend=fast_backend())
        await svc.start()
        a, b = await asyncio.gather(
            svc.focus(raw, CFG, variant="fused3"),
            svc.focus(raw, CFG, variant="omegak"))
        await svc.stop()
        return a, b, svc.metrics.snapshot()

    a, b, snap = asyncio.run(main())
    assert snap["batch_size_hist"] == {1: 2}, snap
    assert np.array_equal(a, reference("fused3"))
    assert np.array_equal(b, reference("omegak"))


# ---------------------------------------------------------------------------
# Backpressure + SNR gate
# ---------------------------------------------------------------------------

class _GatedBackend:
    """Backend that blocks until released — lets tests hold a batch in
    flight while the queue fills behind it."""

    def __init__(self):
        self.release = threading.Event()

    def warm(self, key, max_batch=4):
        pass

    def execute(self, key, batch):
        assert self.release.wait(30)
        return np.zeros_like(batch)

    def execute_streamed(self, key, raw, strips=4):
        assert self.release.wait(30)
        return np.zeros_like(raw)


def test_backpressure_rejects_past_queue_bound():
    raw = scene()
    backend = _GatedBackend()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=1, max_queue=2, precision=None),
            backend=backend)
        await svc.start()
        t1 = asyncio.ensure_future(svc.focus(raw, CFG))
        await asyncio.sleep(0.1)        # batch 1 now executing (blocked)
        t2 = asyncio.ensure_future(svc.focus(raw, CFG))
        t3 = asyncio.ensure_future(svc.focus(raw, CFG))
        await asyncio.sleep(0.1)        # queue now at bound (2)
        with pytest.raises(ServiceOverloaded):
            await svc.focus(raw, CFG)
        backend.release.set()
        outs = await asyncio.gather(t1, t2, t3)
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert len(outs) == 3
    assert snap["rejected"] == 1
    assert snap["completed"] == 3


def test_snr_gate_rejects_out_of_gate_precision():
    raw = scene()

    async def main(deviation):
        svc = FocusService(
            ServiceConfig(max_batch=1, snr_gate_db=0.1),
            backend=fast_backend(),
            precision_deviation=lambda p: deviation)
        await svc.start()
        try:
            out = await svc.focus(raw, CFG, precision="bs16")
        finally:
            await svc.stop()
        return out, svc.metrics.snapshot()

    with pytest.raises(SnrGateViolation, match="0.1 dB gate"):
        asyncio.run(main(0.5))

    out, snap = asyncio.run(main(0.05))
    assert snap["gate_rejected"] == 0
    # the precision threads through to the compiled kernels
    assert not np.array_equal(out, reference())
    assert np.array_equal(
        out, np.asarray(build_pipeline(CFG, "fused3",
                                       precision="bs16").run(
            jnp.asarray(raw))))


def test_f32_requests_never_consult_the_gate():
    """The verification path — precision=None default tier disabled, or
    an explicit 'f32' request — must never trigger a gate measurement."""
    raw = scene()

    def boom(p):
        raise AssertionError("gate consulted for f32")

    async def main():
        svc = FocusService(ServiceConfig(max_batch=1, precision=None),
                           backend=fast_backend(), precision_deviation=boom)
        await svc.start()
        a = await svc.focus(raw, CFG)
        b = await svc.focus(raw, CFG, precision="f32")
        await svc.stop()
        return a, b

    a, b = asyncio.run(main())
    ref = reference()
    assert np.array_equal(a, ref)
    assert np.array_equal(b, ref)


def test_default_serving_tier_is_bs16():
    """Out of the box the service serves the block-scaled throughput
    tier: an un-annotated request resolves to ServiceConfig.precision
    ('bs16') — still gated — and an explicit precision='f32' request
    takes the full-precision verification path. Both ride the fused1
    route, so each must equal its per-axis fused3 reference bit-exact."""
    raw = scene()

    async def main():
        svc = FocusService(ServiceConfig(max_batch=1),
                           backend=fast_backend(),
                           precision_deviation=lambda p: 0.05)
        await svc.start()
        tier = await svc.focus(raw, CFG)
        verify = await svc.focus(raw, CFG, precision="f32")
        await svc.stop()
        return tier, verify

    tier, verify = asyncio.run(main())
    assert np.array_equal(tier, reference(precision="bs16"))
    assert np.array_equal(verify, reference())
    assert not np.array_equal(tier, verify)


def test_service_restarts_after_stop():
    """stop() tears down the device executor; start() must rebuild it so
    the same FocusService instance can serve again."""
    raw = scene()

    async def main():
        svc = FocusService(ServiceConfig(max_batch=1, precision=None),
                           backend=fast_backend())
        await svc.start()
        a = await svc.focus(raw, CFG)
        await svc.stop()
        await svc.start()
        b = await svc.focus(raw, CFG)
        await svc.stop()
        return a, b

    a, b = asyncio.run(main())
    ref = reference()
    assert np.array_equal(a, ref)
    assert np.array_equal(b, ref)


def test_focus_rejected_when_service_not_running():
    raw = scene()

    async def main():
        svc = FocusService(ServiceConfig(max_batch=1, precision=None),
                           backend=fast_backend())
        with pytest.raises(RuntimeError, match="not running"):
            await svc.focus(raw, CFG)          # never started
        await svc.start()
        out = await svc.focus(raw, CFG)
        await svc.stop()
        with pytest.raises(RuntimeError, match="not running"):
            await svc.focus(raw, CFG)          # after stop
        return out

    assert np.array_equal(asyncio.run(main()), reference())


def test_halo_schedule_rejects_unsupported_options():
    """The halo schedule must refuse precision/turn_dtype rather than
    silently serving unlabelled f32 results."""
    from repro.core.sar.distributed import build_sharded
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="precision"):
        build_sharded(CFG, "fused3", mesh, schedule="halo",
                      precision="bf16")
    with pytest.raises(ValueError, match="turn_dtype"):
        build_sharded(CFG, "fused3", mesh, schedule="halo",
                      turn_dtype=jnp.bfloat16)


# ---------------------------------------------------------------------------
# Route invisibility
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _per_axis_reference(precision):
    kw = {} if precision is None else {"precision": precision}
    return np.asarray(build_pipeline(CFG, "fused3", **kw).run(
        jnp.asarray(scene())))


@pytest.mark.parametrize("precision", [None, "bf16", "f16", "bs16"])
@pytest.mark.parametrize("sharded", ["auto", "off"])
@pytest.mark.parametrize("fused1", ["auto", "off"])
def test_route_invisibility_matrix(fused1, sharded, precision):
    """Serving output must be IDENTICAL whichever route the backend
    picks: fused1 megakernel vs three per-axis dispatches, sharded twin
    enabled or pinned off, at every precision — bs16 included, whose
    per-line exponents are carried through the in-kernel corner turns
    precisely so this matrix holds bit-for-bit."""
    from repro.service.queue import BatchKey
    raw = np.asarray(scene(), np.complex64)[None]
    backend = LocalBackend(sweep=((None, None),), fused1=fused1,
                           sharded=sharded)
    out = backend.execute(BatchKey(CFG, "fused3", precision, False), raw)
    np.testing.assert_array_equal(out[0], _per_axis_reference(precision))


# ---------------------------------------------------------------------------
# Streaming route
# ---------------------------------------------------------------------------

def test_over_budget_scene_takes_streaming_route():
    raw = scene()
    ref = reference()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=4, max_delay_ms=200.0, precision=None,
                          device_budget_bytes=raw.nbytes - 1),
            backend=fast_backend())
        await svc.start()
        outs = await asyncio.gather(svc.focus(raw, CFG),
                                    svc.focus(raw, CFG))
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert snap["streamed"] == 2            # never coalesced
    for o in outs:
        assert np.array_equal(o, ref)       # streamed == in-memory


# ---------------------------------------------------------------------------
# Metrics artifact
# ---------------------------------------------------------------------------

def test_service_metrics_emit_valid_schema2_bench_doc(tmp_path):
    raw = scene()

    async def main():
        svc = FocusService(ServiceConfig(max_batch=2, max_delay_ms=100.0,
                                         precision=None),
                           backend=fast_backend())
        await svc.start()
        await asyncio.gather(svc.focus(raw, CFG), svc.focus(raw, CFG))
        await svc.stop()
        return svc

    svc = asyncio.run(main())
    doc = svc.metrics.to_bench_doc(section="service_test")
    validate_bench_doc(doc)                 # schema 2, ISO-8601 stamp
    path = tmp_path / "BENCH_service_test.json"
    svc.metrics.write_bench_json(str(path))
    validate_bench_file(str(path))
    snap = svc.metrics.snapshot()
    assert snap["completed"] == 2
    assert snap["latency_p99_ms"] >= snap["latency_p50_ms"] > 0


def test_write_bench_json_schema2_and_validation(tmp_path):
    path = str(tmp_path / "BENCH_x.json")
    rows = [{"section": "s", "name": "n", "wall_ms": 1.0, "derived": ""}]
    write_bench_json(path, rows, smoke=True)
    doc = validate_bench_file(path)
    assert doc["schema"] == 2 and "generated_unix" not in doc
    with pytest.raises(ValueError, match="schema"):
        validate_bench_doc({**doc, "schema": 1})
    with pytest.raises(ValueError, match="ISO-8601"):
        validate_bench_doc({**doc, "generated_utc": 1234.5})
    with pytest.raises(ValueError, match="wall_ms"):
        validate_bench_doc({**doc, "rows": [{"section": "s", "name": "n"}]})


# ---------------------------------------------------------------------------
# Sharded backend
# ---------------------------------------------------------------------------

def test_sharded_backend_reachable_and_matches_local():
    """The sharded backend through the service API (single host device:
    a 1-device mesh — the wiring, specs, and collectives all execute)."""
    raw = scene()
    ref = reference()

    async def main():
        mesh = jax.make_mesh((1,), ("data",))
        svc = FocusService(
            ServiceConfig(backend="sharded", max_batch=2,
                          max_delay_ms=200.0, precision=None),
            backend=ShardedBackend(mesh=mesh))
        await svc.start()
        outs = await asyncio.gather(svc.focus(raw, CFG),
                                    svc.focus(raw, CFG))
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert snap["batch_size_hist"] == {2: 1}
    for o in outs:
        assert np.array_equal(o, ref)


@pytest.mark.slow
def test_sharded_backend_parity_8_devices():
    """Subprocess (8 fake CPU devices): the service's sharded backend —
    generic corner-turn lowering AND the halo schedule — vs the local
    backend at <= 0.1 dB (the generic lowering is in fact bit-identical,
    and reproduces hand-written corner2 exactly)."""
    code = """
import asyncio, numpy as np, jax, jax.numpy as jnp
from repro.core.sar import build_pipeline, paper_targets, simulate_cached, metrics
from repro.core.sar.geometry import test_scene
from repro.core.sar.distributed import build_corner2, lower_pipeline
from repro.service import FocusService, ServiceConfig, ShardedBackend

cfg = test_scene(256)
targets = paper_targets(cfg)
raw = simulate_cached(cfg, targets)
mesh = jax.make_mesh((8,), ("data",))

local = np.asarray(build_pipeline(cfg, "fused3").run(jnp.asarray(raw)))

# generic plan lowering == hand-written corner2, bit for bit
pipe = build_pipeline(cfg, "fused3")
gen = np.asarray(pipe.lower_sharded(mesh)(jnp.asarray(raw)))
c2 = np.asarray(build_corner2(cfg, mesh)(jnp.asarray(raw)))
assert np.array_equal(gen, c2), "generic lowering != corner2"
assert np.array_equal(gen, local), "generic lowering != local pipeline"

async def serve(schedule, variant):
    svc = FocusService(
        ServiceConfig(backend="sharded", max_batch=2, max_delay_ms=200.0,
                      precision=None),
        backend=ShardedBackend(mesh=mesh, schedule=schedule))
    await svc.start()
    outs = await asyncio.gather(svc.focus(raw, cfg, variant=variant),
                                svc.focus(raw, cfg, variant=variant))
    await svc.stop()
    return outs

outs = asyncio.run(serve("corner2", "fused3"))
for o in outs:
    assert np.array_equal(o, local), "service sharded != local"

# halo: paper-ordered RDA with one corner turn + ring-halo RCMC; parity
# gate vs the local unfused reference
un = np.asarray(build_pipeline(cfg, "unfused").run(jnp.asarray(raw)))
outs_h = asyncio.run(serve("halo", "fused3"))
for o in outs_h:
    c = metrics.compare_pipelines(o, un, cfg, targets)
    assert max(c["snr_delta_db"]) <= 0.1, c["snr_delta_db"]
print("SERVICE_SHARDED_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC + os.pathsep + os.path.join(SRC, ".."))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SERVICE_SHARDED_OK" in r.stdout
