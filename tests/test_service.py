"""Focusing service: coalescing bit-identity, deadline flush,
backpressure, the precision SNR gate, the streaming route, metrics
artifacts, and sharded-backend parity."""
import asyncio
import functools
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from benchmarks.common import validate_bench_doc, validate_bench_file, \
    write_bench_json
from repro.core.sar import build_pipeline, paper_targets, simulate_cached
from repro.core.sar.geometry import test_scene as make_test_scene
from repro.service import (
    BatchKey,
    FocusRequest,
    FocusService,
    LocalBackend,
    MicroBatcher,
    RequestCancelled,
    RequestQueue,
    ServiceConfig,
    ServiceOverloaded,
    ShardedBackend,
    SnrGateViolation,
    WorkerPool,
)
from repro.service.queue import now as svc_now

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
CFG = make_test_scene(128)
TARGETS = paper_targets(CFG)

def fast_backend():
    # single-config backend: tests don't need the warm-time block sweep
    return LocalBackend(sweep=((None, None),))


def scene():
    return simulate_cached(CFG, TARGETS)


def reference(variant="fused3", **kw):
    return np.asarray(build_pipeline(CFG, variant, **kw).run(
        jnp.asarray(scene())))


# ---------------------------------------------------------------------------
# Coalescing semantics
# ---------------------------------------------------------------------------

def test_coalesced_batch_bit_identical_to_per_request_run():
    """Four requests coalesced into ONE (4, na, nr) dispatch sequence must
    reproduce per-request Pipeline.run bit-for-bit — batching is a kernel
    grid extension, not a numerical rewrite."""
    raw = scene()
    ref = reference()
    ref_half = np.asarray(build_pipeline(CFG, "fused3").run(
        jnp.asarray(raw) * 0.5))

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=4, max_delay_ms=500.0, precision=None),
            backend=fast_backend())
        await svc.start()
        outs = await asyncio.gather(
            svc.focus(raw, CFG), svc.focus(raw * 0.5, CFG),
            svc.focus(raw, CFG), svc.focus(raw, CFG))
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert snap["batch_size_hist"] == {4: 1}, snap  # actually coalesced
    assert np.array_equal(outs[0], ref)
    assert np.array_equal(outs[1], ref_half)
    assert np.array_equal(outs[2], ref)
    assert np.array_equal(outs[3], ref)


def test_partial_batch_pads_to_bucket_bit_identical():
    """A 3-request batch pads to the B=4 bucket; the zero pad scene must
    not perturb the real scenes' images."""
    raw = scene()
    ref = reference()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=3, max_delay_ms=500.0, precision=None),
            backend=fast_backend())
        await svc.start()
        outs = await asyncio.gather(*[svc.focus(raw, CFG) for _ in range(3)])
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert snap["batch_size_hist"] == {3: 1}
    for o in outs:
        assert np.array_equal(o, ref)


def test_deadline_flush_fires_for_partial_batch():
    """Two requests under max_batch=8 must not wait forever: the
    max_delay deadline flushes the partial bucket."""
    raw = scene()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=8, max_delay_ms=50.0, precision=None),
            backend=fast_backend())
        await svc.start()
        t0 = time.monotonic()
        outs = await asyncio.gather(svc.focus(raw, CFG),
                                    svc.focus(raw, CFG))
        elapsed = time.monotonic() - t0
        await svc.stop()
        return outs, elapsed, svc.metrics.snapshot()

    outs, elapsed, snap = asyncio.run(main())
    assert snap["batch_size_hist"] == {2: 1}, snap
    assert len(outs) == 2
    # generous bound: 50 ms deadline + one small-scene batch + slack
    assert elapsed < 30.0


def test_requests_with_different_keys_do_not_coalesce():
    raw = scene()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=4, max_delay_ms=50.0, precision=None),
            backend=fast_backend())
        await svc.start()
        a, b = await asyncio.gather(
            svc.focus(raw, CFG, variant="fused3"),
            svc.focus(raw, CFG, variant="omegak"))
        await svc.stop()
        return a, b, svc.metrics.snapshot()

    a, b, snap = asyncio.run(main())
    assert snap["batch_size_hist"] == {1: 2}, snap
    assert np.array_equal(a, reference("fused3"))
    assert np.array_equal(b, reference("omegak"))


# ---------------------------------------------------------------------------
# Backpressure + SNR gate
# ---------------------------------------------------------------------------

class _GatedBackend:
    """Backend that blocks until released — lets tests hold a batch in
    flight while the queue fills behind it."""

    def __init__(self):
        self.release = threading.Event()

    def warm(self, key, max_batch=4):
        pass

    def execute(self, key, batch):
        assert self.release.wait(30)
        return np.zeros_like(batch)

    def execute_streamed(self, key, raw, strips=4):
        assert self.release.wait(30)
        return np.zeros_like(raw)


def test_backpressure_rejects_past_queue_bound():
    """The admission bound covers the TOTAL pre-dispatch backlog: queued
    requests plus the batcher's bucketed/awaiting-slot requests. With one
    lane of one slot: t1 holds the slot in flight (not backlog), t2's
    flush parks awaiting the slot (backlog 1), t3 sits in the queue
    (backlog 2 = bound) — the fourth submit is rejected. None of the
    waiters carry deadlines, so shedding (deadline-aware) cannot admit
    the arrival and the caller sees ServiceOverloaded."""
    raw = scene()
    backend = _GatedBackend()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=1, max_queue=2, precision=None,
                          lanes=1, inflight_cap=1,
                          sentinel=False),   # stub returns zero images
            backend=backend)
        await svc.start()
        t1 = asyncio.ensure_future(svc.focus(raw, CFG))
        await asyncio.sleep(0.1)        # batch 1 now executing (blocked)
        t2 = asyncio.ensure_future(svc.focus(raw, CFG))
        t3 = asyncio.ensure_future(svc.focus(raw, CFG))
        await asyncio.sleep(0.1)        # backlog now at bound (2)
        with pytest.raises(ServiceOverloaded) as exc_info:
            await svc.focus(raw, CFG)
        backend.release.set()
        outs = await asyncio.gather(t1, t2, t3)
        await svc.stop()
        return outs, exc_info.value, svc.metrics.snapshot()

    outs, err, snap = asyncio.run(main())
    assert len(outs) == 3
    assert err.depth == 2 and err.bound == 2
    assert snap["rejected"] == 1
    assert snap["completed"] == 3


def test_service_overloaded_carries_depth_bound_and_retry_hint():
    """ServiceOverloaded is machine-readable: depth, bound, and a
    retry_after_hint priced by the service-time EWMA all ride on the
    exception (and render into its message)."""

    async def main():
        q = RequestQueue(2)
        loop = asyncio.get_running_loop()

        def mk():
            return FocusRequest(
                raw=np.zeros((2, 2), np.complex64), scene=CFG,
                variant="fused3", precision=None,
                future=loop.create_future(), t_submit=svc_now())

        q.put(mk())
        q.put(mk())
        with pytest.raises(ServiceOverloaded) as ei:
            q.put(mk())
        err = ei.value
        assert err.depth == 2 and err.bound == 2
        assert err.retry_after_hint == pytest.approx(q.retry_after_hint(2))
        assert err.retry_after_hint > 0
        msg = str(err)
        assert "depth 2 >= bound 2" in msg
        assert f"retry_after_hint={err.retry_after_hint:.3f}s" in msg

        # `extra` backlog (the batcher's buckets) counts toward the bound
        with pytest.raises(ServiceOverloaded) as e2:
            q.put(mk(), extra=5)
        assert e2.value.depth == 7

        # the hint tracks observed service time: slower batches -> a
        # longer suggested backoff
        h0 = q.retry_after_hint(2)
        q.note_service_time(1.0)
        assert q.retry_after_hint(2) > h0

    asyncio.run(main())


# ---------------------------------------------------------------------------
# Continuous batching, deadlines, worker pool
# ---------------------------------------------------------------------------

class _RecordingBackend:
    """Delegating backend that records the concurrency of execute calls
    (for overlap / in-flight-cap assertions) while computing real images."""

    def __init__(self, inner, delay: float = 0.0):
        self.inner = inner
        self.delay = delay
        self._lock = threading.Lock()
        self._active = 0
        self.max_active = 0
        self.batch_sizes = []

    def warm(self, key, max_batch=4):
        self.inner.warm(key, max_batch)

    def _enter(self):
        with self._lock:
            self._active += 1
            self.max_active = max(self.max_active, self._active)

    def _exit(self):
        with self._lock:
            self._active -= 1

    def execute(self, key, batch):
        self._enter()
        try:
            if self.delay:
                time.sleep(self.delay)
            self.batch_sizes.append(batch.shape[0])
            return self.inner.execute(key, batch)
        finally:
            self._exit()

    def execute_streamed(self, key, raw, strips=4):
        self._enter()
        try:
            if self.delay:
                time.sleep(self.delay)
            return self.inner.execute_streamed(key, raw, strips)
        finally:
            self._exit()


def _mk_req(loop, variant="fused3", deadline_ms=None, priority=0):
    return FocusRequest(
        raw=np.zeros((2, 2), np.complex64), scene=CFG, variant=variant,
        precision=None, future=loop.create_future(), t_submit=svc_now(),
        deadline_ms=deadline_ms, priority=priority)


def test_stop_mid_drain_flushes_remaining_buckets_in_deadline_order():
    """Regression: STOP dequeued mid-drain used to break out before the
    final deadline sweep, and the post-loop flush walked the buckets in
    dict-insertion order. Remaining buckets must flush earliest-deadline
    first even on the shutdown path."""

    async def main():
        q = RequestQueue(16)
        order = []

        async def execute(key, reqs):
            order.append(key.variant)
            for r in reqs:
                r.future.set_result(None)

        b = MicroBatcher(q, execute, max_batch=8, max_delay_ms=1000.0)
        loop = asyncio.get_running_loop()
        # later deadline inserted FIRST: dict order would flush it first
        q.put(_mk_req(loop, "fused3", deadline_ms=500.0))
        q.put(_mk_req(loop, "omegak", deadline_ms=50.0))
        q.put_stop()
        await b.run()
        return order

    assert asyncio.run(main()) == ["omegak", "fused3"]


def test_deadline_request_not_starved_by_hot_competing_key():
    """EDF across buckets: a deadline-carrying request on a cold key
    flushes before a hotter (more-requests, earlier-arrival) key whose
    requests carry no deadline."""

    async def main():
        q = RequestQueue(64)
        order = []

        async def execute(key, reqs):
            order.append(key.variant)
            for r in reqs:
                r.future.set_result(None)

        # max_delay 0: every bucket's flush deadline fires immediately,
        # so the sweep ranks ALL buckets — pure EDF ordering
        b = MicroBatcher(q, execute, max_batch=8, max_delay_ms=0.0)
        loop = asyncio.get_running_loop()
        for _ in range(3):
            q.put(_mk_req(loop, "fused3"))          # hot, no deadline
        q.put(_mk_req(loop, "omegak", deadline_ms=80.0))
        q.put_stop()
        await b.run()
        return order

    assert asyncio.run(main()) == ["omegak", "fused3"]


def test_max_batch_one_degenerates_to_sequential_bit_identical():
    """max_batch=1 is the sequential path: every request is its own
    batch and every image equals its per-request Pipeline.run."""
    raw = scene()
    refs = [reference(), np.asarray(build_pipeline(CFG, "fused3").run(
        jnp.asarray(raw) * 0.5))]

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=1, max_delay_ms=50.0, precision=None),
            backend=fast_backend())
        await svc.start()
        outs = await asyncio.gather(svc.focus(raw, CFG),
                                    svc.focus(raw * 0.5, CFG),
                                    svc.focus(raw, CFG))
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert snap["batch_size_hist"] == {1: 3}, snap
    assert np.array_equal(outs[0], refs[0])
    assert np.array_equal(outs[1], refs[1])
    assert np.array_equal(outs[2], refs[0])


def test_inflight_cap_backpressure_coalesces_backlog_bit_identical():
    """One lane, one in-flight slot: while batch 1 runs, arrivals park
    behind the cap and coalesce into a FULL batch — and both batches'
    images stay bit-identical to the per-request path."""
    raw = scene()
    ref = reference()
    backend = _RecordingBackend(fast_backend(), delay=0.3)

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=4, max_delay_ms=5.0, precision=None,
                          lanes=1, inflight_cap=1),
            backend=backend)
        await svc.start(warm=[(CFG, "fused3", None)])
        t1 = asyncio.ensure_future(svc.focus(raw, CFG))
        await asyncio.sleep(0.15)       # batch 1 in flight on the lane
        rest = [asyncio.ensure_future(svc.focus(raw, CFG))
                for _ in range(4)]
        outs = await asyncio.gather(t1, *rest)
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert backend.max_active == 1          # the cap held
    assert snap["batch_size_hist"] == {1: 1, 4: 1}, snap
    for o in outs:
        assert np.array_equal(o, ref)


def test_continuous_batching_overlaps_batches_across_lanes():
    """Two different-key batches must run CONCURRENTLY on two lanes —
    the host/device overlap the worker pool exists for — with both
    images bit-identical to their per-request references."""
    raw = scene()
    ref3, refo = reference(), reference("omegak")
    backend = _RecordingBackend(fast_backend(), delay=0.3)

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=2, max_delay_ms=20.0, precision=None,
                          lanes=2, inflight_cap=2),
            backend=backend)
        await svc.start()
        outs = await asyncio.gather(
            svc.focus(raw, CFG), svc.focus(raw, CFG),
            svc.focus(raw, CFG, variant="omegak"),
            svc.focus(raw, CFG, variant="omegak"))
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert backend.max_active == 2          # batches genuinely overlapped
    assert snap["batch_size_hist"] == {2: 2}, snap
    assert sum(snap["lane_batches"].values()) == 2
    assert len(snap["lane_batches"]) == 2   # routed to distinct lanes
    assert np.array_equal(outs[0], ref3)
    assert np.array_equal(outs[1], ref3)
    assert np.array_equal(outs[2], refo)
    assert np.array_equal(outs[3], refo)


def test_past_deadline_request_dropped_with_request_cancelled():
    """A request whose deadline expires while still bucketed is dropped
    before padding — its future raises RequestCancelled and no device
    work happens for it."""
    raw = scene()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=4, max_delay_ms=400.0, precision=None),
            backend=fast_backend())
        await svc.start()
        with pytest.raises(RequestCancelled, match="deadline_ms=50"):
            await svc.focus(raw, CFG, deadline_ms=50.0)
        await svc.stop()
        return svc.metrics.snapshot()

    snap = asyncio.run(main())
    assert snap["cancelled"] == 1
    assert snap["deadline_dropped"] == 1
    assert snap["deadline_miss_rate"] == 1.0
    assert snap["batch_size_hist"] == {}    # nothing reached a lane


def test_client_cancelled_request_dropped_before_dispatch():
    raw = scene()
    ref = reference()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=4, max_delay_ms=200.0, precision=None),
            backend=fast_backend())
        await svc.start()
        t_cancel = asyncio.ensure_future(svc.focus(raw * 0.5, CFG))
        t_keep = asyncio.ensure_future(svc.focus(raw, CFG))
        await asyncio.sleep(0.05)           # both bucketed, flush at 200ms
        t_cancel.cancel()
        out = await t_keep
        with pytest.raises(asyncio.CancelledError):
            await t_cancel
        await svc.stop()
        return out, svc.metrics.snapshot()

    out, snap = asyncio.run(main())
    assert snap["cancelled"] == 1
    assert snap["deadline_dropped"] == 0
    assert snap["batch_size_hist"] == {1: 1}    # cancelled never padded in
    assert np.array_equal(out, ref)


def test_overload_sheds_latest_deadline_pending_request():
    """At the admission bound, an earlier-deadline arrival evicts the
    latest-deadline pending request (RequestCancelled) instead of being
    rejected."""
    raw = scene()
    ref = reference()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=4, max_delay_ms=400.0, precision=None,
                          max_queue=1),
            backend=fast_backend())
        await svc.start()
        victim = asyncio.ensure_future(svc.focus(raw * 0.5, CFG))
        await asyncio.sleep(0.05)           # victim bucketed: backlog = 1
        out = await svc.focus(raw, CFG, deadline_ms=5000.0)
        with pytest.raises(RequestCancelled, match="shed under overload"):
            await victim
        await svc.stop()
        return out, svc.metrics.snapshot()

    out, snap = asyncio.run(main())
    assert snap["shed"] == 1
    assert snap["rejected"] == 0
    assert np.array_equal(out, ref)


def test_worker_pool_routing_and_cost_weights():
    pool = WorkerPool(lanes=2, inflight_cap=2)
    k = BatchKey(CFG, "fused3", None, False)
    ks = BatchKey(CFG, "fused3", None, True)
    assert pool.route(ks) is pool.stream_lane
    assert pool.route(k) is pool.batch_lanes[0]     # tie -> lowest lane
    # the roofline prices lane load: bigger batches and bigger scenes
    # weigh more
    assert pool.predicted_seconds(k, batch=1) > 0
    assert (pool.predicted_seconds(k, batch=8)
            > pool.predicted_seconds(k, batch=1))
    big = BatchKey(make_test_scene(512), "fused3", None, False)
    assert pool.predicted_seconds(big) > pool.predicted_seconds(k)
    # a backlogged lane loses the next batch to the idle one
    pool.batch_lanes[0].backlog_s = 10.0
    assert pool.route(k) is pool.batch_lanes[1]


def test_snr_gate_rejects_out_of_gate_precision():
    raw = scene()

    async def main(deviation):
        svc = FocusService(
            ServiceConfig(max_batch=1, snr_gate_db=0.1),
            backend=fast_backend(),
            precision_deviation=lambda p: deviation)
        await svc.start()
        try:
            out = await svc.focus(raw, CFG, precision="bs16")
        finally:
            await svc.stop()
        return out, svc.metrics.snapshot()

    with pytest.raises(SnrGateViolation, match="0.1 dB gate"):
        asyncio.run(main(0.5))

    out, snap = asyncio.run(main(0.05))
    assert snap["gate_rejected"] == 0
    # the precision threads through to the compiled kernels
    assert not np.array_equal(out, reference())
    assert np.array_equal(
        out, np.asarray(build_pipeline(CFG, "fused3",
                                       precision="bs16").run(
            jnp.asarray(raw))))


def test_f32_requests_never_consult_the_gate():
    """The verification path — precision=None default tier disabled, or
    an explicit 'f32' request — must never trigger a gate measurement."""
    raw = scene()

    def boom(p):
        raise AssertionError("gate consulted for f32")

    async def main():
        svc = FocusService(ServiceConfig(max_batch=1, precision=None),
                           backend=fast_backend(), precision_deviation=boom)
        await svc.start()
        a = await svc.focus(raw, CFG)
        b = await svc.focus(raw, CFG, precision="f32")
        await svc.stop()
        return a, b

    a, b = asyncio.run(main())
    ref = reference()
    assert np.array_equal(a, ref)
    assert np.array_equal(b, ref)


def test_default_serving_tier_is_bs16():
    """Out of the box the service serves the block-scaled throughput
    tier: an un-annotated request resolves to ServiceConfig.precision
    ('bs16') — still gated — and an explicit precision='f32' request
    takes the full-precision verification path. Both ride the fused1
    route, so each must equal its per-axis fused3 reference bit-exact."""
    raw = scene()

    async def main():
        svc = FocusService(ServiceConfig(max_batch=1),
                           backend=fast_backend(),
                           precision_deviation=lambda p: 0.05)
        await svc.start()
        tier = await svc.focus(raw, CFG)
        verify = await svc.focus(raw, CFG, precision="f32")
        await svc.stop()
        return tier, verify

    tier, verify = asyncio.run(main())
    assert np.array_equal(tier, reference(precision="bs16"))
    assert np.array_equal(verify, reference())
    assert not np.array_equal(tier, verify)


def test_service_restarts_after_stop():
    """stop() tears down the device executor; start() must rebuild it so
    the same FocusService instance can serve again."""
    raw = scene()

    async def main():
        svc = FocusService(ServiceConfig(max_batch=1, precision=None),
                           backend=fast_backend())
        await svc.start()
        a = await svc.focus(raw, CFG)
        await svc.stop()
        await svc.start()
        b = await svc.focus(raw, CFG)
        await svc.stop()
        return a, b

    a, b = asyncio.run(main())
    ref = reference()
    assert np.array_equal(a, ref)
    assert np.array_equal(b, ref)


def test_focus_rejected_when_service_not_running():
    raw = scene()

    async def main():
        svc = FocusService(ServiceConfig(max_batch=1, precision=None),
                           backend=fast_backend())
        with pytest.raises(RuntimeError, match="not running"):
            await svc.focus(raw, CFG)          # never started
        await svc.start()
        out = await svc.focus(raw, CFG)
        await svc.stop()
        with pytest.raises(RuntimeError, match="not running"):
            await svc.focus(raw, CFG)          # after stop
        return out

    assert np.array_equal(asyncio.run(main()), reference())


def test_halo_schedule_rejects_unsupported_options():
    """The halo schedule must refuse precision/turn_dtype rather than
    silently serving unlabelled f32 results."""
    from repro.core.sar.distributed import build_sharded
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="precision"):
        build_sharded(CFG, "fused3", mesh, schedule="halo",
                      precision="bf16")
    with pytest.raises(ValueError, match="turn_dtype"):
        build_sharded(CFG, "fused3", mesh, schedule="halo",
                      turn_dtype=jnp.bfloat16)


# ---------------------------------------------------------------------------
# Route invisibility
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _per_axis_reference(precision):
    kw = {} if precision is None else {"precision": precision}
    return np.asarray(build_pipeline(CFG, "fused3", **kw).run(
        jnp.asarray(scene())))


@pytest.mark.parametrize("precision", [None, "bf16", "f16", "bs16"])
@pytest.mark.parametrize("sharded", ["auto", "off"])
@pytest.mark.parametrize("fused1", ["auto", "off"])
def test_route_invisibility_matrix(fused1, sharded, precision):
    """Serving output must be IDENTICAL whichever route the backend
    picks: fused1 megakernel vs three per-axis dispatches, sharded twin
    enabled or pinned off, at every precision — bs16 included, whose
    per-line exponents are carried through the in-kernel corner turns
    precisely so this matrix holds bit-for-bit."""
    from repro.service.queue import BatchKey
    raw = np.asarray(scene(), np.complex64)[None]
    backend = LocalBackend(sweep=((None, None),), fused1=fused1,
                           sharded=sharded)
    out = backend.execute(BatchKey(CFG, "fused3", precision, False), raw)
    np.testing.assert_array_equal(out[0], _per_axis_reference(precision))


# ---------------------------------------------------------------------------
# Streaming route
# ---------------------------------------------------------------------------

def test_over_budget_scene_takes_streaming_route():
    raw = scene()
    ref = reference()

    async def main():
        svc = FocusService(
            ServiceConfig(max_batch=4, max_delay_ms=200.0, precision=None,
                          device_budget_bytes=raw.nbytes - 1),
            backend=fast_backend())
        await svc.start()
        outs = await asyncio.gather(svc.focus(raw, CFG),
                                    svc.focus(raw, CFG))
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert snap["streamed"] == 2            # never coalesced
    for o in outs:
        assert np.array_equal(o, ref)       # streamed == in-memory


# ---------------------------------------------------------------------------
# Metrics artifact
# ---------------------------------------------------------------------------

def test_service_metrics_emit_valid_schema2_bench_doc(tmp_path):
    raw = scene()

    async def main():
        svc = FocusService(ServiceConfig(max_batch=2, max_delay_ms=100.0,
                                         precision=None),
                           backend=fast_backend())
        await svc.start()
        await asyncio.gather(svc.focus(raw, CFG), svc.focus(raw, CFG))
        await svc.stop()
        return svc

    svc = asyncio.run(main())
    doc = svc.metrics.to_bench_doc(section="service_test")
    validate_bench_doc(doc)                 # schema 2, ISO-8601 stamp
    path = tmp_path / "BENCH_service_test.json"
    svc.metrics.write_bench_json(str(path))
    validate_bench_file(str(path))
    snap = svc.metrics.snapshot()
    assert snap["completed"] == 2
    assert snap["latency_p99_ms"] >= snap["latency_p50_ms"] > 0
    # worker-pool observability: batch-fill histogram (exact "k/max"
    # keys) and the per-lane occupancy row, all inside the validated doc
    assert snap["batch_fill_hist"] == {"2/2": 1}
    assert sum(snap["lane_batches"].values()) == 1
    assert set(snap["lane_occupancy"]) == {"fused0", "fused1", "stream"}
    rows = {r["name"]: r for r in doc["rows"]}
    assert "lanes=3" in rows["lanes"]["derived"]
    assert "occ_fused0=" in rows["lanes"]["derived"]
    assert "fill_hist=" in rows["batching"]["derived"]
    assert "goodput_rps=" in rows["throughput"]["derived"]
    assert "deadline_miss_rate=" in rows["throughput"]["derived"]


def test_serve_ratchet_gates_load_replay_structure():
    """scripts/bench_compare.py --serve must gate the deterministic
    load-replay structure: lane count may not shrink, the smoke
    deadline-miss rate may not grow, and the goodput-gain row (plus the
    family itself) must exist. The chaos family is gated the same way:
    zero lost requests, every scheduled seam fired, goodput ratio at or
    above its bar, family presence."""
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "bench_compare_script",
        os.path.join(os.path.dirname(__file__), "..", "scripts",
                     "bench_compare.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    def doc(lanes=3, miss="0.0000", with_gain=True, with_smoke=True,
            lost=0, seams=3, ratio="0.84x", with_chaos=True):
        rows = [
            {"section": "t", "name": "serve_tier_gate_bs16", "wall_ms": 0.0,
             "derived": "snr_deviation_db=0.0026;gate_db=0.1;admitted=True"},
            {"section": "t", "name": "serve_tier_bs16_burst_B4_per_request",
             "wall_ms": 1.0, "derived": ""},
            {"section": "t", "name": "serve_load_burst_replay",
             "wall_ms": 1.0, "derived": "goodput_rps=10.0"},
        ]
        if with_gain:
            rows.append({"section": "t", "name": "serve_load_goodput_gain",
                         "wall_ms": 0.0,
                         "derived": "gain_vs_single_flight=2.00x;bar=1.5x"})
        if with_smoke:
            rows.append({"section": "t", "name": "serve_load_smoke",
                         "wall_ms": 0.0,
                         "derived": f"lanes={lanes};"
                                    f"deadline_miss_rate={miss}"})
        if with_chaos:
            rows.append({"section": "t", "name": "serve_chaos_smoke",
                         "wall_ms": 0.0,
                         "derived": f"lost={lost};completed=24;requests=24;"
                                    f"seams={seams}"})
            rows.append({"section": "t",
                         "name": "serve_chaos_goodput_ratio",
                         "wall_ms": 0.0,
                         "derived": f"ratio_vs_fault_free={ratio};"
                                    "bar=0.5x"})
        return {"rows": rows}

    base = doc()
    assert mod.compare_serve(base, doc()) == []
    assert any("lane count shrank" in f
               for f in mod.compare_serve(base, doc(lanes=2)))
    assert any("deadline_miss_rate grew" in f
               for f in mod.compare_serve(base, doc(miss="0.2500")))
    assert any("goodput_gain row missing" in f
               for f in mod.compare_serve(base, doc(with_gain=False)))
    no_loads = {"rows": [r for r in doc()["rows"]
                         if not r["name"].startswith("serve_load_")]}
    assert any("load-replay family is gone" in f
               for f in mod.compare_serve(base, no_loads))
    # chaos structure: lost requests, missing seams, a sunk goodput
    # ratio, and dropping the family outright all fail the ratchet
    assert any("lost under the seeded fault replay" in f
               for f in mod.compare_serve(base, doc(lost=2)))
    assert any("fault seams fired" in f
               for f in mod.compare_serve(base, doc(seams=2)))
    assert any("recovery overhead regressed" in f
               for f in mod.compare_serve(base, doc(ratio="0.30x")))
    assert any("chaos-replay family is gone" in f
               for f in mod.compare_serve(base, doc(with_chaos=False)))
    # lane GROWTH and new rows land freely (ratchet, not a freeze)
    assert mod.compare_serve(base, doc(lanes=4)) == []


def test_write_bench_json_schema2_and_validation(tmp_path):
    path = str(tmp_path / "BENCH_x.json")
    rows = [{"section": "s", "name": "n", "wall_ms": 1.0, "derived": ""}]
    write_bench_json(path, rows, smoke=True)
    doc = validate_bench_file(path)
    assert doc["schema"] == 2 and "generated_unix" not in doc
    with pytest.raises(ValueError, match="schema"):
        validate_bench_doc({**doc, "schema": 1})
    with pytest.raises(ValueError, match="ISO-8601"):
        validate_bench_doc({**doc, "generated_utc": 1234.5})
    with pytest.raises(ValueError, match="wall_ms"):
        validate_bench_doc({**doc, "rows": [{"section": "s", "name": "n"}]})


# ---------------------------------------------------------------------------
# Sharded backend
# ---------------------------------------------------------------------------

def test_sharded_backend_reachable_and_matches_local():
    """The sharded backend through the service API (single host device:
    a 1-device mesh — the wiring, specs, and collectives all execute)."""
    raw = scene()
    ref = reference()

    async def main():
        mesh = jax.make_mesh((1,), ("data",))
        svc = FocusService(
            ServiceConfig(backend="sharded", max_batch=2,
                          max_delay_ms=200.0, precision=None),
            backend=ShardedBackend(mesh=mesh))
        await svc.start()
        outs = await asyncio.gather(svc.focus(raw, CFG),
                                    svc.focus(raw, CFG))
        await svc.stop()
        return outs, svc.metrics.snapshot()

    outs, snap = asyncio.run(main())
    assert snap["batch_size_hist"] == {2: 1}
    for o in outs:
        assert np.array_equal(o, ref)


@pytest.mark.slow
def test_sharded_backend_parity_8_devices():
    """Subprocess (8 fake CPU devices): the service's sharded backend —
    generic corner-turn lowering AND the halo schedule — vs the local
    backend at <= 0.1 dB (the generic lowering is in fact bit-identical,
    and reproduces hand-written corner2 exactly)."""
    code = """
import asyncio, numpy as np, jax, jax.numpy as jnp
from repro.core.sar import build_pipeline, paper_targets, simulate_cached, metrics
from repro.core.sar.geometry import test_scene
from repro.core.sar.distributed import build_corner2, lower_pipeline
from repro.service import FocusService, ServiceConfig, ShardedBackend

cfg = test_scene(256)
targets = paper_targets(cfg)
raw = simulate_cached(cfg, targets)
mesh = jax.make_mesh((8,), ("data",))

local = np.asarray(build_pipeline(cfg, "fused3").run(jnp.asarray(raw)))

# generic plan lowering == hand-written corner2, bit for bit
pipe = build_pipeline(cfg, "fused3")
gen = np.asarray(pipe.lower_sharded(mesh)(jnp.asarray(raw)))
c2 = np.asarray(build_corner2(cfg, mesh)(jnp.asarray(raw)))
assert np.array_equal(gen, c2), "generic lowering != corner2"
assert np.array_equal(gen, local), "generic lowering != local pipeline"

async def serve(schedule, variant):
    svc = FocusService(
        ServiceConfig(backend="sharded", max_batch=2, max_delay_ms=200.0,
                      precision=None),
        backend=ShardedBackend(mesh=mesh, schedule=schedule))
    await svc.start()
    outs = await asyncio.gather(svc.focus(raw, cfg, variant=variant),
                                svc.focus(raw, cfg, variant=variant))
    await svc.stop()
    return outs

outs = asyncio.run(serve("corner2", "fused3"))
for o in outs:
    assert np.array_equal(o, local), "service sharded != local"

# halo: paper-ordered RDA with one corner turn + ring-halo RCMC; parity
# gate vs the local unfused reference
un = np.asarray(build_pipeline(cfg, "unfused").run(jnp.asarray(raw)))
outs_h = asyncio.run(serve("halo", "fused3"))
for o in outs_h:
    c = metrics.compare_pipelines(o, un, cfg, targets)
    assert max(c["snr_delta_db"]) <= 0.1, c["snr_delta_db"]
print("SERVICE_SHARDED_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC + os.pathsep + os.path.join(SRC, ".."))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SERVICE_SHARDED_OK" in r.stdout
