import os
import sys

# NOTE: no XLA_FLAGS here on purpose — tests see the host's real device
# count (the 512-device farm exists only inside launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
