"""Serve a small model with batched requests: prefill the prompt batch, then
batched single-token decode steps against the KV caches. Exercises every
cache kind via --arch (full KV, sliding-window ring, recurrent state).

  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-9b
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch.serve import generate
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = registry.smoke(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # batched "requests": affine progressions the model could learn; here we
    # serve from random weights, so we check throughput + shape/finite only
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    t0 = time.perf_counter()
    toks = generate(model, params, prompts, args.max_new,
                    max_len=args.prompt_len + args.max_new)
    dt = time.perf_counter() - t0
    toks = np.asarray(toks)
    assert toks.shape == (args.batch, args.max_new)
    n = toks.size
    print(f"arch={cfg.name}: {n} tokens in {dt:.1f}s "
          f"({n/dt:.1f} tok/s incl. compile on CPU)")
    for b in range(args.batch):
        print(f"  req{b}: {toks[b][:12].tolist()} ...")


if __name__ == "__main__":
    main()
