"""Serving quickstart: the async continuous-batching SAR focusing service.

Simulates a handful of clients firing concurrent focusing requests at a
FocusService — mixed precisions, some carrying deadlines (EDF-scheduled,
dropped with RequestCancelled when they can no longer be met) — then
prints the service's latency/batching/lane metrics. With more than one
host device (e.g. XLA_FLAGS=--xla_force_host_platform_\
device_count=8) pass --backend sharded to run the same requests through
the shard_map corner-turn backend.

  PYTHONPATH=src python examples/serve_sar.py --n 256 --requests 8
  PYTHONPATH=src python examples/serve_sar.py --backend sharded
"""
from __future__ import annotations

import argparse
import asyncio

import numpy as np

from repro.core.sar import paper_targets, simulate_cached
from repro.core.sar.geometry import test_scene
from repro.service import (
    FocusService,
    RequestCancelled,
    ServiceConfig,
    ShardedBackend,
    SnrGateViolation,
)


async def main(args) -> None:
    cfg = test_scene(args.n)
    raw = simulate_cached(cfg, paper_targets(cfg))

    backend = None
    if args.backend == "sharded":
        backend = ShardedBackend(schedule=args.schedule)
    svc = FocusService(
        ServiceConfig(
            variant=args.variant, backend=args.backend,
            max_batch=args.max_batch, max_delay_ms=args.max_delay_ms,
            device_budget_bytes=args.budget_bytes,
            lanes=args.lanes),
        backend=backend)

    print(f"warming {args.variant} for {cfg.na}x{cfg.nr} scenes ...")
    await svc.start(warm=[(cfg, args.variant, svc.config.precision)])

    async def client(i: int):
        # un-annotated requests take the default serving tier (bs16:
        # block-scaled f16, admitted only while its measured SNR
        # deviation clears the 0.1 dB gate — fails closed when the
        # quality harness is unavailable); every 4th request pins the
        # f32 verification path, which never consults the gate
        precision = "f32" if i % 4 == 3 else None
        # every other request carries a deadline: buckets flush
        # earliest-deadline-first, and a request still undispatched
        # past its deadline is dropped without costing a kernel launch
        deadline_ms = args.deadline_ms if i % 2 == 0 else None
        try:
            img = await svc.focus(raw * (1.0 + 0.1 * i), cfg,
                                  precision=precision,
                                  deadline_ms=deadline_ms)
        except SnrGateViolation as e:
            print(f"  request {i}: rejected by SNR gate ({e})")
            return None
        except RequestCancelled as e:
            print(f"  request {i}: dropped ({e})")
            return None
        print(f"  request {i}: focused, peak={float(np.abs(img).max()):.1f}"
              f" precision={precision or svc.config.precision or 'f32'}"
              + (f" deadline_ms={deadline_ms:g}" if deadline_ms else ""))
        return img

    await asyncio.gather(*[client(i) for i in range(args.requests)])
    await svc.stop()

    snap = svc.metrics.snapshot()
    print("\nservice metrics:")
    for k in ("completed", "rejected", "gate_rejected", "streamed",
              "cancelled", "deadline_met", "deadline_miss_rate",
              "latency_p50_ms", "latency_p99_ms", "throughput_rps",
              "goodput_rps", "mean_batch_size", "batch_size_hist",
              "batch_fill_hist", "lane_occupancy", "queue_depth_max"):
        print(f"  {k:18} {snap[k]}")
    if args.bench_json:
        svc.metrics.write_bench_json(args.bench_json)
        print(f"wrote {args.bench_json}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--variant", default="fused3")
    ap.add_argument("--backend", default="local",
                    choices=["local", "sharded"])
    ap.add_argument("--schedule", default="corner2",
                    choices=["corner2", "halo"])
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-delay-ms", type=float, default=10.0)
    ap.add_argument("--lanes", type=int, default=2,
                    help="worker-pool batch lanes (plus one stream lane)")
    ap.add_argument("--deadline-ms", type=float, default=30_000.0,
                    help="deadline attached to every other request")
    ap.add_argument("--budget-bytes", type=int, default=None,
                    help="device-memory budget; larger scenes stream")
    ap.add_argument("--bench-json", default=None,
                    help="write service metrics as a BENCH_*.json")
    asyncio.run(main(ap.parse_args()))
