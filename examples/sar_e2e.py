"""End-to-end driver (the paper's kind is imaging/inference): process a
batch of SAR scenes through every RDA variant, validate radar quality, and
print the paper's Tables II-IV analogs.

  PYTHONPATH=src python examples/sar_e2e.py                # 512^2, 3 scenes
  PYTHONPATH=src python examples/sar_e2e.py --n 4096 --scenes 1   # paper size
"""
import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.sar import (build_pipeline, metrics, paper_targets, simulate,
                            test_scene)
from repro.core.sar.geometry import paper_scene


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--scenes", type=int, default=3)
    args = ap.parse_args()

    cfg = paper_scene() if args.n == 4096 else test_scene(args.n)
    targets = paper_targets(cfg)

    print(f"scene {cfg.na}x{cfg.nr}: Kr={cfg.kr:.2e} Hz/s Ka={cfg.ka:.1f} Hz/s "
          f"res=({cfg.range_res:.2f} m, {cfg.azimuth_res:.2f} m) "
          f"noise={cfg.noise_db} dB")

    # batched requests: each scene has shifted targets + its own noise seed
    raws = []
    for s in range(args.scenes):
        c = dataclasses.replace(cfg, seed=1234 + s)
        raws.append(simulate(c, targets))
    print(f"simulated {args.scenes} scene(s)")

    variants = ["unfused", "fused", "fused_tfree", "fused3", "fused1",
                "omegak"]
    pipes = {v: build_pipeline(cfg, v) for v in variants}
    fns = {v: p.jitted() for v, p in pipes.items()}
    images, times = {}, {}
    for v in variants:
        jax.block_until_ready(fns[v](raws[0]))  # compile
        t0 = time.perf_counter()
        outs = [fns[v](r) for r in raws]
        jax.block_until_ready(outs)
        times[v] = (time.perf_counter() - t0) / args.scenes
        images[v] = np.asarray(outs[0])

    print("\n== Table II analog: end-to-end (per scene, CPU wall;"
          " on-device dispatch counts are the architecture story) ==")
    for v in variants:
        p = pipes[v]
        print(f"  {v:<12} {times[v]*1e3:9.1f} ms   dispatches={p.dispatches}"
              f"  hbm_roundtrips={p.hbm_roundtrips}"
              f"  speedup_model={pipes['unfused'].hbm_roundtrips/p.hbm_roundtrips:.1f}x(HBM)")

    print("\n== Table IV analog: quality (variant vs unfused) ==")
    for v in variants[1:]:
        c = metrics.compare_pipelines(images[v], images["unfused"], cfg,
                                      targets)
        print(f"  {v:<12} L2rel={c['l2_relative_error']:.3e} "
              f"maxabs={c['max_abs_error']:.3e} "
              f"snr_delta_max={max(c['snr_delta_db']):.4f} dB")

    print("\n== point targets (fused3 image) ==")
    for i, rep in enumerate(metrics.analyze_scene(images["fused3"], cfg,
                                                  targets)):
        print(f"  target {i}: ({rep.row},{rep.col}) snr={rep.snr_db:.1f} dB "
              f"pslr=({rep.pslr_range_db:.1f},{rep.pslr_azimuth_db:.1f}) dB "
              f"islr=({rep.islr_range_db:.1f},{rep.islr_azimuth_db:.1f}) dB")


if __name__ == "__main__":
    main()
