"""Train an assigned-architecture LM (reduced config) on the synthetic
affine-sequence task: loss drops from ~ln(V) toward the structure floor,
with checkpointing + simulated preemption restart along the way.

  PYTHONPATH=src python examples/train_lm.py --arch gemma3-12b --steps 120
"""
import argparse
import tempfile

import numpy as np
import jax

from repro.checkpoint import CheckpointManager
from repro.distributed import FailureInjector, run_with_restarts
from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fail-at", type=int, default=60,
                    help="inject a simulated node failure at this step")
    args = ap.parse_args()

    model, cfg, mesh, rules, p_shard, jitted, data = T.build(
        args.arch, smoke=True, batch=args.batch, seq=args.seq)
    print(f"arch={cfg.name} params={cfg.param_count():,}")

    run0 = T.init_state(model, mesh, rules, p_shard)
    ckdir = tempfile.mkdtemp(prefix="ck_")
    mgr = CheckpointManager(ckdir)
    like = jax.tree.map(np.asarray, {"params": run0.params,
                                     "opt": run0.opt_state})
    mgr.save(0, like)
    injector = FailureInjector(at_steps=(args.fail_at,))
    losses = []

    def restore():
        tree, step = mgr.restore(like)
        if step:
            print(f"[restart] restored checkpoint step {step}")
        return T.TrainRun(tree["params"], tree["opt"], step)

    def train(state):
        out, ls, wd = T.train_loop(state, jitted, data, mesh, rules,
                                   args.steps, ckpt=mgr, ckpt_every=20,
                                   injector=injector, log_every=20)
        losses.extend(ls)
        return out

    final, restarts = run_with_restarts(train, restore)
    print(f"finished at step {final.step} after {restarts} restart(s); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training did not reduce the loss"


if __name__ == "__main__":
    main()
