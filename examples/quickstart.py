"""Quickstart: the paper's fused kernel in three calls.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.core.sar import (build_pipeline, metrics, paper_targets, simulate,
                            test_scene)

# --- 1. One fused dispatch: FFT -> matched filter -> IFFT ------------------
rng = np.random.default_rng(0)
xr = jnp.asarray(rng.standard_normal((8, 4096)), jnp.float32)   # 8 range lines
xi = jnp.asarray(rng.standard_normal((8, 4096)), jnp.float32)
hr = jnp.asarray(rng.standard_normal(4096), jnp.float32)        # matched filter
hi = jnp.asarray(rng.standard_normal(4096), jnp.float32)

yr, yi = ops.fused_fft_mult_ifft_rows(xr, xi, hr, hi)           # ONE dispatch
wr, wi = ref.spectral_ref(xr, xi, axis=1, fwd=True, inv=True,
                          hr=hr[None], hi=hi[None])             # 3-stage oracle
err = float(jnp.max(jnp.abs(yr - wr)))
print(f"fused kernel vs unfused oracle: max|err| = {err:.2e}")

# --- 2. A full SAR scene through the fused Range Doppler pipeline ----------
cfg = test_scene(256)
targets = paper_targets(cfg)
raw = simulate(cfg, targets)                  # chirp echo + 20 dB noise
image = build_pipeline(cfg, "fused3").run(raw)  # 3 fused dispatches total

# --- 3. Point-target quality (the paper's Table IV metrics) ----------------
# (PSLR/ISLR need the 512^2 scene where targets don't share sidelobe
#  windows — see examples/sar_e2e.py and tests/test_sar.py)
for i, rep in enumerate(metrics.analyze_scene(np.asarray(image), cfg, targets)):
    print(f"target {i}: peak@({rep.row},{rep.col}) snr={rep.snr_db:.1f} dB")
