"""Sharded megakernel demo: one 2048^2 SAR scene focused across 8
emulated devices, one staged megakernel dispatch per device per phase
group — the fused1 pipeline's in-kernel corner turns lowered to
all_to_all collectives (ROADMAP: paper scale beyond one device).

  PYTHONPATH=src python examples/sharded_scene.py            # 2048^2
  PYTHONPATH=src python examples/sharded_scene.py --n 1024   # quicker

The device-count flag must reach XLA before jax initializes, so it is
set here at import time; on real multi-device hardware drop the flag and
`make_sar_mesh()` picks up every visible device (multi-host capable:
devices sort by (process_index, id) so each host owns a contiguous block
of the sharded axis).
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse
import time

import numpy as np
import jax

from repro.core.sar import (build_pipeline, metrics, paper_targets,
                            simulate_cached, test_scene)
from repro.core.sar.distributed import make_sar_mesh
from repro.core.sar.geometry import paper_scene


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    args = ap.parse_args()

    # the rescaled CPU test scene aliases in azimuth past ~1900 lines
    # (fixed 400 Hz PRF); at 2048^2 and beyond the paper's own X-band
    # geometry is valid, so the demo runs the real regime there.
    cfg = paper_scene(args.n, args.n) if args.n >= 2048 else \
        test_scene(args.n)
    targets = paper_targets(cfg)
    raw = simulate_cached(cfg, targets)
    print(f"scene {cfg.na}x{cfg.nr} on {len(jax.devices())} "
          f"{jax.default_backend()} devices")

    # local single-device reference: the 3-dispatch fused3 pipeline the
    # sharded megakernel must reproduce (f32 bit-exact for the RDA family)
    ref_fn = build_pipeline(cfg, "fused3").jitted()
    jax.block_until_ready(ref_fn(raw))
    t0 = time.perf_counter()
    ref = np.asarray(ref_fn(raw))
    t_local = time.perf_counter() - t0

    # the sharded lowering: fused1's single mega step splits at its
    # in-kernel turn boundaries into per-device phase groups
    fn = build_pipeline(cfg, "fused1").lower_sharded(make_sar_mesh())
    jax.block_until_ready(fn(raw))
    t0 = time.perf_counter()
    img = np.asarray(fn(raw))
    t_shard = time.perf_counter() - t0

    print(f"\n== dispatch structure ({fn.devices} devices) ==")
    print(f"  dispatches per device: {fn.dispatches_per_device} "
          f"(one per phase group)")
    print(f"  collective corner turns: {fn.turns}")
    for u in fn.unit_info:
        print(f"    {u['name']:<16} stream_axis={u['stream_axis']} "
              f"kind={u['kind']} residency={u['residency']}")

    cmp = metrics.compare_pipelines(img, ref, cfg, targets)
    print(f"\n== parity vs local fused3 ==")
    print(f"  max |err|: {cmp['max_abs_error']:.3e}  "
          f"l2 rel: {cmp['l2_relative_error']:.3e}  "
          f"bit-identical: {np.array_equal(img, ref)}")
    for i, (snr, d) in enumerate(zip(cmp["snr_a_db"],
                                     cmp["snr_delta_db"])):
        print(f"  target {i}: snr={snr:.1f} dB (delta {d:.4f} dB)")
    print(f"\n  local fused3 {t_local*1e3:9.1f} ms | sharded fused1 "
          f"{t_shard*1e3:9.1f} ms (emulated devices; wall time measures "
          "the interpreter, the dispatch counts are the story)")


if __name__ == "__main__":
    main()
