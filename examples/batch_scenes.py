"""Batched multi-scene SAR focusing — the production serving shape.

A constellation downlink delivers many scenes with identical acquisition
geometry; focusing them one at a time leaves the accelerator idle between
dispatches. This example stacks B raw scenes into a (B, na, nr) batch and
runs the fused RDA ONCE — every stage is a single Pallas dispatch whose
grid spans B x line-blocks, so dispatch overhead and the DFT-constant loads
amortize across the batch — then verifies the batched images are bit-exact
against per-scene focusing and reports the per-scene latency win.

  PYTHONPATH=src python examples/batch_scenes.py                 # 256^2, B=4
  PYTHONPATH=src python examples/batch_scenes.py --n 512 --batch 8
  PYTHONPATH=src python examples/batch_scenes.py --variant fused_tfree
"""
import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.sar import build_pipeline, metrics, paper_targets, simulate
from repro.core.sar.geometry import test_scene


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--variant", default="fused3",
                    choices=["unfused", "fused", "fused_tfree", "fused3",
                             "omegak", "csa_fused"])
    args = ap.parse_args()

    cfg = test_scene(args.n)
    targets = paper_targets(cfg)

    # B scenes, same geometry, different noise realizations
    print(f"simulating {args.batch} scenes of {cfg.na}x{cfg.nr} ...")
    scenes = [simulate(dataclasses.replace(cfg, seed=s), targets)
              for s in range(args.batch)]
    raw_batch = jnp.stack(scenes)                      # (B, na, nr)

    pipe = build_pipeline(cfg, args.variant)
    focus = pipe.jitted()

    # per-scene reference (B separate dispatch sequences)
    one = jax.jit(pipe.run)
    imgs_seq = [one(s) for s in scenes]
    jax.block_until_ready(imgs_seq)
    t0 = time.perf_counter()
    imgs_seq = [one(s) for s in scenes]
    jax.block_until_ready(imgs_seq)
    t_seq = time.perf_counter() - t0

    # batched: one dispatch sequence for all B scenes
    imgs_b = focus(raw_batch)
    jax.block_until_ready(imgs_b)
    t0 = time.perf_counter()
    imgs_b = focus(raw_batch)
    jax.block_until_ready(imgs_b)
    t_batch = time.perf_counter() - t0

    err = float(jnp.max(jnp.abs(imgs_b - jnp.stack(imgs_seq))))
    print(f"batched vs per-scene max abs diff: {err:.3e}")
    assert err == 0.0, f"batched focusing diverged from per-scene: {err}"

    for i in range(args.batch):
        reps = metrics.analyze_scene(np.asarray(imgs_b[i]), cfg, targets)
        worst = min(r.snr_db for r in reps)
        print(f"scene {i}: worst target SNR {worst:.1f} dB")

    print(f"\nvariant={args.variant}  B={args.batch}")
    print(f"  per-scene (sequential): {t_seq / args.batch * 1e3:8.1f} ms")
    print(f"  per-scene (batched):    {t_batch / args.batch * 1e3:8.1f} ms")
    print(f"  amortization:           {t_seq / t_batch:8.2f}x")


if __name__ == "__main__":
    main()
