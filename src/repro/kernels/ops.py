"""Jit'd public wrappers around the fused spectral Pallas kernel.

All functions take/return split re/im float32 arrays. `interpret=None`
auto-selects interpret mode off-TPU (this container is CPU-only; on a real
TPU fleet the same code lowers to Mosaic).

Batching: every wrapper accepts either one scene — (lines, N) rows layout /
(N, lines) cols layout — or a batch of scenes with a leading batch
dimension, (B, lines, N) / (B, N, lines). Batched inputs run as ONE fused
dispatch with the Pallas grid spanning B x line-blocks (see fft4step.py);
2-D inputs are transparently treated as B=1 and squeezed on return. Filter
arguments are always unbatched (scenes share the SceneConfig filters).

The wrappers handle line-count padding so callers never worry about the
block size; the kernel itself assumes divisibility.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fft4step import (
    FILTER_FULL,
    FILTER_NONE,
    FILTER_OUTER,
    FILTER_SHARED,
    FILTER_SHARED_OUTER,
    RESIDENT_VMEM,
    MegaSpec,
    SegmentSpec,
    SpectralSpec,
    apply_exponents,
    auto_interpret,
    build_mega_call,
    build_spectral_call,
    line_exponents,
    remove_exponents,
    resolve_precision,
)

# the one backend check every kernel wrapper shares (fft4step.auto_interpret)
_auto_interpret = auto_interpret


def _pad_lines(x, axis, mult):
    lines = x.shape[axis]
    pad = (-lines) % mult
    if pad == 0:
        return x, lines
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), lines


@functools.partial(
    jax.jit,
    static_argnames=(
        "axis", "fwd", "inv", "filter_mode", "block", "fft_impl",
        "karatsuba", "precision", "compute_dtype", "interpret", "n1", "n2",
        "n3", "batch_block",
    ),
)
def spectral_op(
    xr,
    xi,
    hr=None,
    hi=None,
    u=None,
    v=None,
    *,
    axis: int = 1,
    fwd: bool = True,
    inv: bool = True,
    filter_mode: str = FILTER_NONE,
    block: int = 8,
    fft_impl: str = "matmul",
    karatsuba: bool = False,
    precision: Optional[str] = None,
    compute_dtype: Optional[str] = None,
    interpret: Optional[bool] = None,
    n1: Optional[int] = None,
    n2: Optional[int] = None,
    n3: Optional[int] = None,
    batch_block: Optional[int] = None,
):
    """One fused dispatch: [FFT] -> [filter multiply] -> [IFFT] along `axis`.

    x: (lines, N) when axis=1, (N, lines) when axis=0 — or a batch of
    scenes, (B, lines, N) / (B, N, lines), fused into the same single
    dispatch (`axis` always names the scene axis, batch excluded).
    filter args by mode (unbatched; shared across any batch):
      shared: hr/hi (N,)       — e.g. the range matched filter
      full:   hr/hi one scene's shape
      outer:  u (lines,) or (lines, K), v (N,) or (N, K) —
              filter = exp(i * sum_k u[line,k] * v[sample,k])
    n1/n2/n3: optional mixed-radix factorization override (n = n1*n2[*n3],
    powers of two <= 128); default per fft4step.default_factorization.
    precision: matmul-operand Precision policy name (fft4step.PRECISIONS:
    f32 | bf16 | f16 | bs16 block-scaled f16). `compute_dtype` is the
    deprecated pre-policy spelling of the same knob.
    """
    precision = resolve_precision(precision or compute_dtype).name
    batched = xr.ndim == 3
    if not batched:
        xr = xr[None]
        xi = xi[None]
    b = xr.shape[0]
    line_axis = 1 if axis == 1 else 2
    n = xr.shape[axis + 1]
    xr, lines = _pad_lines(xr, line_axis, block)
    xi, _ = _pad_lines(xi, line_axis, block)

    outer_rank = 1
    if filter_mode in (FILTER_OUTER, FILTER_SHARED_OUTER):
        u = u.reshape(u.shape[0], -1)
        v = v.reshape(v.shape[0], -1)
        outer_rank = u.shape[1]

    spec = SpectralSpec(
        n=n, fwd=fwd, inv=inv, filter_mode=filter_mode, axis=axis,
        block=block, batch_block=batch_block, fft_impl=fft_impl,
        karatsuba=karatsuba, precision=precision, n1=n1, n2=n2,
        n3=n3, outer_rank=outer_rank,
    )
    call = build_spectral_call(spec, xr.shape[line_axis], batch=b,
                               interpret=_auto_interpret(interpret))

    filt_line_axis = 0 if axis == 1 else 1   # filters stay 2-D
    filter_args = []
    if filter_mode == FILTER_SHARED:
        fshape = (1, n) if axis == 1 else (n, 1)
        filter_args = [hr.reshape(fshape), hi.reshape(fshape)]
    elif filter_mode == FILTER_FULL:
        hr, _ = _pad_lines(hr, filt_line_axis, block)
        hi, _ = _pad_lines(hi, filt_line_axis, block)
        filter_args = [hr, hi]
    elif filter_mode in (FILTER_OUTER, FILTER_SHARED_OUTER):
        pad = (-lines) % block
        u = jnp.pad(u, ((0, pad), (0, 0)))      # (lines_padded, K)
        if axis == 1:
            filter_args = [u, v.T]              # (L, K), (K, N)
        else:
            filter_args = [u.T, v]              # (K, L), (N, K)
        if filter_mode == FILTER_SHARED_OUTER:
            fshape = (1, n) if axis == 1 else (n, 1)
            filter_args = [hr.reshape(fshape), hi.reshape(fshape)] + filter_args

    yr, yi = call(xr, xi, *filter_args)
    if line_axis == 1:
        yr, yi = yr[:, :lines], yi[:, :lines]
    else:
        yr, yi = yr[:, :, :lines], yi[:, :, :lines]
    if not batched:
        return yr[0], yi[0]
    return yr, yi


@functools.partial(
    jax.jit,
    static_argnames=(
        "segments", "residency", "batch_block", "phase_block",
        "buffer_depth", "fft_impl",
        "karatsuba", "precision", "interpret", "n1", "n2", "n3",
        "return_exp",
    ),
)
def mega_spectral_op(
    xr,
    xi,
    *filter_args,
    segments,
    residency: str = RESIDENT_VMEM,
    batch_block: Optional[int] = None,
    phase_block: int = 8,
    buffer_depth: int = 2,
    fft_impl: str = "matmul",
    karatsuba: bool = False,
    precision: Optional[str] = None,
    interpret: Optional[bool] = None,
    n1: Optional[int] = None,
    n2: Optional[int] = None,
    n3: Optional[int] = None,
    exp_in=None,
    return_exp: bool = False,
):
    """The single-dispatch 2-D megakernel: a whole multi-axis spectral
    pipeline — `fft? mul* ifft?` segments with in-kernel corner turns
    between them — as ONE fused dispatch.

    x: one scene (na, nr) or a batch (B, na, nr), split re/im float32 in
    scene layout (azimuth rows x range samples). ``segments`` is a static
    tuple of ``(axis, fwd, inv, filter_mode)`` records in execution order
    (axis 1 transforms the range axis, 0 the azimuth axis); a record may
    extend to ``(axis, fwd, inv, filter_mode, n1, n2, n3, karatsuba)`` to
    pin THAT segment's factorization and complex-product algorithm — the
    per-segment decisions a tuned ``repro.tuning.Schedule`` carries
    (``None`` fields defer to the global knobs below).
    ``filter_args`` follow in segment order, each segment contributing its
    mode's payload in SCENE coordinates (n = transformed-axis length,
    lines = the other axis):

      shared:       hr (n,), hi (n,)
      full:         hr (na, nr), hi (na, nr)
      outer:        u (lines,) or (lines, K); v (n,) or (n, K)
      shared_outer: hr, hi, u, v

    residency 'vmem' holds the whole (Bb, na, nr) slab on-chip (zero HBM
    intermediates — the paper's single-dispatch claim); 'staged' runs a
    phase-split grid with an HBM scratch corner-turn intermediate and
    ``buffer_depth``-slot DMA buffering (large scenes; depth 1 disables
    the copy/compute overlap). f32 results are bit-identical between the
    modes and to the equivalent per-axis dispatch chain.
    n1/n2/n3 override the RANGE-axis factorization (the azimuth axis uses
    the default split), matching ``compile_plan``'s ``fft_kw`` convention.

    ``exp_in`` / ``return_exp`` (block-scaled precisions only) chain the
    carried per-line exponents ACROSS megakernel dispatches — the sharded
    lowering's corner-turn contract. With ``return_exp=True`` the result
    comes back scaled, as ``(yr, yi, exp)``: ``exp`` holds the per-line
    exponents along the LAST segment's free axis — exactly what the next
    dispatch's prologue would extract — and the scaled slab is what rides
    the all_to_all wire. Passing that ``exp`` as the next call's
    ``exp_in`` (all_gathered to full length when the free axis is
    re-sharded) restores the values exactly, power-of-two scaling being
    bit-exact, so a chain of dispatches matches one fused dispatch bit
    for bit.
    """
    prec = resolve_precision(precision)
    precision = prec.name
    if (exp_in is not None or return_exp) and not prec.block_scaled:
        raise ValueError(
            "exp_in/return_exp carry block exponents and require a "
            f"block-scaled precision, got {precision!r}")
    batched = xr.ndim == 3
    if not batched:
        xr = xr[None]
        xi = xi[None]
    b, na, nr = xr.shape
    if exp_in is not None:
        # the previous dispatch's carried exponents: fold them back in
        # (exact) before the prologue re-extracts along this dispatch's
        # first free axis
        xr, xi = apply_exponents(xr, xi, exp_in)

    segs = []
    args = list(filter_args)
    prepared = []
    ai = 0
    for seg_rec in segments:
        if len(seg_rec) == 4:
            (axis, fwd, inv, fmode), seg_kw = seg_rec, {}
        elif len(seg_rec) == 8:
            axis, fwd, inv, fmode = seg_rec[:4]
            seg_kw = dict(zip(("n1", "n2", "n3", "karatsuba"), seg_rec[4:]))
        else:
            raise ValueError(
                f"segment record must have 4 fields (axis, fwd, inv, "
                f"filter_mode) or 8 (+ n1, n2, n3, karatsuba), got "
                f"{len(seg_rec)}")
        n = nr if axis == 1 else na
        rank = 1
        if fmode in (FILTER_SHARED, FILTER_FULL, FILTER_SHARED_OUTER):
            hr, hi = args[ai], args[ai + 1]
            ai += 2
            if fmode == FILTER_FULL:
                prepared += [hr, hi]
            else:
                shape = (1, n) if axis == 1 else (n, 1)
                prepared += [hr.reshape(shape), hi.reshape(shape)]
        if fmode in (FILTER_OUTER, FILTER_SHARED_OUTER):
            u, v = args[ai], args[ai + 1]
            ai += 2
            u = u.reshape(u.shape[0], -1)
            v = v.reshape(v.shape[0], -1)
            rank = u.shape[1]
            prepared += ([u, v.T] if axis == 1 else [u.T, v])
        segs.append(SegmentSpec(axis=axis, fwd=fwd, inv=inv,
                                filter_mode=fmode, outer_rank=rank,
                                **seg_kw))
    if ai != len(args):
        raise ValueError(
            f"got {len(args)} filter arrays but segments consume {ai}")

    spec = MegaSpec(
        na=na, nr=nr, segments=tuple(segs), residency=residency,
        batch_block=batch_block, phase_block=phase_block,
        buffer_depth=buffer_depth, n1=n1, n2=n2,
        n3=n3, fft_impl=fft_impl, karatsuba=karatsuba, precision=precision)
    call = build_mega_call(spec, batch=b,
                           interpret=_auto_interpret(interpret))
    yr, yi = call(xr, xi, *prepared)
    if return_exp:
        # hand the carry to the NEXT dispatch: re-extract along the last
        # segment's free axis (bit-identical to what its prologue would
        # compute) and return the slab scaled
        exp = line_exponents(yr, yi, segs[-1].axis)
        yr, yi = remove_exponents(yr, yi, exp)
        if not batched:
            return yr[0], yi[0], exp[0]
        return yr, yi, exp
    if not batched:
        return yr[0], yi[0]
    return yr, yi


# ---- Convenience entry points (named for the SAR pipeline steps) ----------

def fft_rows(xr, xi, **kw):
    """Batched forward FFT along the last axis of (B, N)."""
    return spectral_op(xr, xi, fwd=True, inv=False, axis=1, **kw)


def ifft_rows(xr, xi, **kw):
    return spectral_op(xr, xi, fwd=False, inv=True, axis=1, **kw)


def fft_cols(xr, xi, **kw):
    """Forward FFT along axis 0 of (N, C) — transpose-free column pipeline."""
    return spectral_op(xr, xi, fwd=True, inv=False, axis=0, **kw)


def ifft_cols(xr, xi, **kw):
    return spectral_op(xr, xi, fwd=False, inv=True, axis=0, **kw)


def fused_fft_mult_ifft_rows(xr, xi, hr, hi, **kw):
    """The paper's fused range-compression dispatch: FFT · H · IFFT per line."""
    return spectral_op(xr, xi, hr=hr, hi=hi, fwd=True, inv=True, axis=1,
                       filter_mode=FILTER_SHARED, **kw)


def fused_mult_ifft_cols(xr, xi, hr, hi, **kw):
    """The paper's fused azimuth-compression dispatch: H · IFFT per column
    (data already in the azimuth frequency domain). hr/hi is the full 2-D
    azimuth filter H_a(f_a, R0)."""
    return spectral_op(xr, xi, hr=hr, hi=hi, fwd=False, inv=True, axis=0,
                       filter_mode=FILTER_FULL, **kw)


def fused_rcmc_rows(xr, xi, shift, freqs, **kw):
    """Beyond-paper: exact RCMC as one fused dispatch per azimuth-frequency row:
    FFT -> exp(i * shift[row] * freqs[col]) -> IFFT (Fourier shift theorem),
    with the rank-1 phase synthesized in VMEM (FILTER_OUTER)."""
    return spectral_op(xr, xi, u=shift, v=freqs, fwd=True, inv=True, axis=1,
                       filter_mode=FILTER_OUTER, **kw)


def fused_mult_ifft_cols_outer(xr, xi, u, v, **kw):
    """Azimuth compression with on-the-fly rank-1 phase: H = exp(i u[col] v[row])
    — u is the per-column (range gate) 1/Ka term, v the per-row -pi f_a^2."""
    return spectral_op(xr, xi, u=u, v=v, fwd=False, inv=True, axis=0,
                       filter_mode=FILTER_OUTER, **kw)


def fused_rc_rcmc_rows(xr, xi, hr, hi, u, v, **kw):
    """Beyond-paper 3-dispatch RDA, middle dispatch: range compression AND
    exact RCMC in one pass (data already in the azimuth-frequency domain):
    FFT -> H_r[col] * exp(i shift[row] * freqs[col]) -> IFFT."""
    return spectral_op(xr, xi, hr=hr, hi=hi, u=u, v=v, fwd=True, inv=True,
                       axis=1, filter_mode=FILTER_SHARED_OUTER, **kw)
