"""Pallas TPU kernels for the fused spectral pipeline (paper's contribution).

fft4step.py — single-dispatch [FFT]·[filter]·[IFFT] kernel, matmul (MXU) and
              stockham (VPU) implementations, rows & columns pipelines.
ops.py      — jit'd public wrappers (padding, filter plumbing).
ref.py      — pure-jnp oracles (jnp.fft) every kernel is tested against.
transpose.py— tiled transpose for the paper-faithful pipeline variant.
"""
from repro.kernels.fft4step import (  # noqa: F401
    FILTER_FULL,
    FILTER_NONE,
    FILTER_OUTER,
    FILTER_SHARED,
    FILTER_SHARED_OUTER,
    PRECISIONS,
    RESIDENT_STAGED,
    RESIDENT_VMEM,
    MegaSpec,
    Precision,
    SegmentSpec,
    SpectralSpec,
    auto_interpret,
    build_mega_call,
    build_spectral_call,
    default_factorization,
    dft_constants,
    resolve_precision,
)
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.transpose import transpose  # noqa: F401
