"""Single-dispatch fused spectral-pipeline Pallas kernel (the paper's contribution).

The paper fuses FFT -> matched-filter multiply -> IFFT into one Metal dispatch,
holding a 4096-point complex line in 32 KiB of threadgroup memory, and feeds
Apple's 8x8 simdgroup MMA with a radix-8 DFT butterfly.

TPU adaptation (see DESIGN.md SS2):
  * on-chip tier   : 32 KiB threadgroup memory  ->  ~16 MiB VMEM. We block a
    *batch of lines* (row pipeline) or a whole (N x L) column slab (column
    pipeline) per grid step, instead of one line per threadgroup.
  * matrix unit    : 8x8 simdgroup MMA -> 128x128 MXU. The radix-8 butterfly
    becomes a *four-step FFT*: N = n1*n2, each stage a dense matmul against a
    DFT matrix (n1, n2 <= 128), twiddle as a pointwise multiply. Complex
    arithmetic is split re/im (4 real matmuls, or 3 with Karatsuba).
  * IFFT           : conj-FFT-conj with the 1/N scale folded into the final
    store — identical to the paper's SSII-C trick.
  * the paper's in-place constraint (Stockham needs 2x buffers > 32 KiB) does
    not bind in VMEM; we keep the numerically-identical out-of-place stages
    inside the kernel and spend the slack on line batching.

A 'stockham' VPU implementation (radix-4/radix-2, no matmuls) is provided as
the scalar baseline for the paper's Table I comparison.

Everything is validated in interpret mode against kernels/ref.py (pure jnp).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Filter (pointwise multiply) modes for the fused pipeline.
FILTER_NONE = "none"      # no multiply (pure FFT / pure IFFT dispatch)
FILTER_SHARED = "shared"  # one N-vector shared by every line (range matched filter)
FILTER_FULL = "full"      # full 2-D filter, same shape as the scene block
FILTER_OUTER = "outer"    # on-the-fly rank-K phase synthesis
                          # exp(i * sum_k u[line,k] * v[sample,k])
                          # (covers RCMC phase ramps and azimuth compression —
                          #  beyond-paper bandwidth optimization: O(N+L) filter
                          #  I/O instead of O(N*L))
FILTER_SHARED_OUTER = "shared_outer"  # H[sample] * exp(i sum_k u v): range
                          # matched filter and RCMC shift in ONE dispatch
                          # (the 3-dispatch RDA; beyond-paper)


def default_factorization(n: int) -> tuple[int, int]:
    """Split n = n1 * n2 with n1 >= n2, both powers of two <= 128 when possible."""
    if n & (n - 1):
        raise ValueError(f"FFT length must be a power of two, got {n}")
    p = n.bit_length() - 1
    n1 = 1 << ((p + 1) // 2)
    n2 = n // n1
    return n1, n2


@dataclasses.dataclass(frozen=True)
class SpectralSpec:
    """Static configuration of one fused spectral dispatch."""

    n: int                      # FFT length (the transformed axis)
    fwd: bool                   # forward FFT first?
    filter_mode: str            # FILTER_*
    inv: bool                   # inverse FFT last?
    axis: int = 1               # 1 = rows pipeline (last axis), 0 = columns
    block: int = 8              # lines (rows kernel) / columns (cols kernel) per grid step
    n1: Optional[int] = None    # four-step factorization (defaults to ~sqrt split)
    n2: Optional[int] = None
    fft_impl: str = "matmul"    # 'matmul' (MXU) | 'stockham' (VPU scalar baseline)
    karatsuba: bool = False     # 3-matmul complex product instead of 4
    compute_dtype: str = "f32"  # 'f32' | 'bf16' (bf16 inputs, f32 accumulation)
    fold_scale: bool = True     # fold the IFFT 1/N into the filter/final store
    outer_rank: int = 1         # K of the rank-K FILTER_OUTER phase

    def factors(self) -> tuple[int, int]:
        if self.n1 is not None:
            n1 = self.n1
            n2 = self.n2 if self.n2 is not None else self.n // n1
        else:
            n1, n2 = default_factorization(self.n)
        if n1 * n2 != self.n:
            raise ValueError(f"n1*n2 != n: {n1}*{n2} != {self.n}")
        return n1, n2


# ---------------------------------------------------------------------------
# DFT constants (host-side numpy; passed to the kernel as broadcast operands)
# ---------------------------------------------------------------------------

def dft_constants(n1: int, n2: int) -> tuple[np.ndarray, ...]:
    """F1 (n1,n1), F2 (n2,n2) DFT matrices and the (n1,n2) twiddle, split re/im."""
    def dft(n):
        k = np.arange(n)
        m = np.exp(-2j * np.pi * np.outer(k, k) / n)
        return m.real.astype(np.float32), m.imag.astype(np.float32)

    f1r, f1i = dft(n1)
    f2r, f2i = dft(n2)
    k1 = np.arange(n1)[:, None]
    m2 = np.arange(n2)[None, :]
    tw = np.exp(-2j * np.pi * k1 * m2 / (n1 * n2))
    return f1r, f1i, f2r, f2i, tw.real.astype(np.float32), tw.imag.astype(np.float32)


# ---------------------------------------------------------------------------
# In-kernel complex helpers (split re/im)
# ---------------------------------------------------------------------------

def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _cast(x, dtype_str):
    return x.astype(jnp.bfloat16) if dtype_str == "bf16" else x


def _cdot(fr, fi, xr, xi, dims, *, karatsuba: bool, compute_dtype: str):
    """Complex dot_general: (fr + i fi) . (xr + i xi) with contraction `dims`.

    4 real matmuls, or 3 with Karatsuba (P3 = (Fr+Fi)(Xr+Xi)). f32 accumulate.
    """
    dg = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=(dims, ((), ())),
        preferred_element_type=jnp.float32,
    )
    fr_, fi_ = _cast(fr, compute_dtype), _cast(fi, compute_dtype)
    xr_, xi_ = _cast(xr, compute_dtype), _cast(xi, compute_dtype)
    if karatsuba:
        p1 = dg(fr_, xr_)
        p2 = dg(fi_, xi_)
        p3 = dg(_cast(fr + fi, compute_dtype), _cast(xr + xi, compute_dtype))
        return p1 - p2, p3 - p1 - p2
    yr = dg(fr_, xr_) - dg(fi_, xi_)
    yi = dg(fr_, xi_) + dg(fi_, xr_)
    return yr, yi


def _cdot_rhs(xr, xi, fr, fi, dims, *, karatsuba: bool, compute_dtype: str):
    """Complex dot_general with the DFT matrix on the right: X . F."""
    dg = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=(dims, ((), ())),
        preferred_element_type=jnp.float32,
    )
    fr_, fi_ = _cast(fr, compute_dtype), _cast(fi, compute_dtype)
    xr_, xi_ = _cast(xr, compute_dtype), _cast(xi, compute_dtype)
    if karatsuba:
        p1 = dg(xr_, fr_)
        p2 = dg(xi_, fi_)
        p3 = dg(_cast(xr + xi, compute_dtype), _cast(fr + fi, compute_dtype))
        return p1 - p2, p3 - p1 - p2
    yr = dg(xr_, fr_) - dg(xi_, fi_)
    yi = dg(xi_, fr_) + dg(xr_, fi_)
    return yr, yi


# ---------------------------------------------------------------------------
# Four-step matmul FFT, in-kernel (rows: transform the last axis of (L, N))
# ---------------------------------------------------------------------------

def _fft_rows_matmul(xr, xi, consts, spec: SpectralSpec):
    f1r, f1i, f2r, f2i, twr, twi = consts
    n1, n2 = spec.factors()
    L = xr.shape[0]
    xr = xr.reshape(L, n1, n2)
    xi = xi.reshape(L, n1, n2)
    # Stage A: contract n1 with F1 -> (n1, L, n2)
    ar, ai = _cdot(f1r, f1i, xr, xi, ((1,), (1,)),
                   karatsuba=spec.karatsuba, compute_dtype=spec.compute_dtype)
    # Twiddle (n1, 1, n2)
    br, bi = _cmul(ar, ai, twr[:, None, :], twi[:, None, :])
    # Stage C: contract n2 with F2 -> (n1, L, n2)
    cr, ci = _cdot_rhs(br, bi, f2r, f2i, ((2,), (0,)),
                       karatsuba=spec.karatsuba, compute_dtype=spec.compute_dtype)
    # out[l, k2*n1 + k1] = C[k1, l, k2]
    cr = jnp.transpose(cr, (1, 2, 0)).reshape(L, spec.n)
    ci = jnp.transpose(ci, (1, 2, 0)).reshape(L, spec.n)
    return cr, ci


def _fft_cols_matmul(xr, xi, consts, spec: SpectralSpec):
    """Transform axis 0 of an (N, C) column slab — no global transpose needed."""
    f1r, f1i, f2r, f2i, twr, twi = consts
    n1, n2 = spec.factors()
    C = xr.shape[1]
    xr = xr.reshape(n1, n2, C)
    xi = xi.reshape(n1, n2, C)
    # Stage A: contract n1 with F1 -> (n1, n2, C)
    ar, ai = _cdot(f1r, f1i, xr, xi, ((1,), (0,)),
                   karatsuba=spec.karatsuba, compute_dtype=spec.compute_dtype)
    br, bi = _cmul(ar, ai, twr[:, :, None], twi[:, :, None])
    # Stage C: contract n2 with F2 -> (n1, C, n2)
    cr, ci = _cdot_rhs(br, bi, f2r, f2i, ((1,), (0,)),
                       karatsuba=spec.karatsuba, compute_dtype=spec.compute_dtype)
    # out[k2*n1 + k1, c] = C[k1, c, k2]
    cr = jnp.transpose(cr, (2, 0, 1)).reshape(spec.n, C)
    ci = jnp.transpose(ci, (2, 0, 1)).reshape(spec.n, C)
    return cr, ci


# ---------------------------------------------------------------------------
# Stockham VPU FFT, in-kernel (the paper's 'scalar' baseline, radix-4 + radix-2)
# ---------------------------------------------------------------------------

def _fft_stockham(xr, xi, spec: SpectralSpec, axis: int):
    """Self-sorting Stockham along `axis` of a 2-D block, pure vector ops."""
    if axis == 0:  # operate on (N, C): move to (C, N), reuse rows code, move back
        yr, yi = _fft_stockham(xr.T, xi.T, spec, 1)
        return yr.T, yi.T
    L, N = xr.shape
    yr = xr.reshape(L, N, 1)
    yi = xi.reshape(L, N, 1)
    n, s = N, 1
    while n > 1:
        if n % 4 == 0:
            m = n // 4
            k = jax.lax.broadcasted_iota(jnp.float32, (m, 1), 0)
            th = (-2.0 * math.pi / n) * k
            w1r, w1i = jnp.cos(th), jnp.sin(th)
            w2r, w2i = _cmul(w1r, w1i, w1r, w1i)
            w3r, w3i = _cmul(w2r, w2i, w1r, w1i)
            sl = lambda z, q: z[:, q * m:(q + 1) * m, :]
            a_r, a_i = sl(yr, 0), sl(yi, 0)
            b_r, b_i = sl(yr, 1), sl(yi, 1)
            c_r, c_i = sl(yr, 2), sl(yi, 2)
            d_r, d_i = sl(yr, 3), sl(yi, 3)
            apc_r, apc_i = a_r + c_r, a_i + c_i
            amc_r, amc_i = a_r - c_r, a_i - c_i
            bpd_r, bpd_i = b_r + d_r, b_i + d_i
            bmd_r, bmd_i = b_r - d_r, b_i - d_i
            t0r, t0i = apc_r + bpd_r, apc_i + bpd_i
            # (amc - i*bmd) * w1
            u1r, u1i = amc_r + bmd_i, amc_i - bmd_r
            t1r, t1i = _cmul(u1r, u1i, w1r, w1i)
            # (apc - bpd) * w2
            t2r, t2i = _cmul(apc_r - bpd_r, apc_i - bpd_i, w2r, w2i)
            # (amc + i*bmd) * w3
            u3r, u3i = amc_r - bmd_i, amc_i + bmd_r
            t3r, t3i = _cmul(u3r, u3i, w3r, w3i)
            yr = jnp.stack([t0r, t1r, t2r, t3r], axis=2).reshape(L, m, 4 * s)
            yi = jnp.stack([t0i, t1i, t2i, t3i], axis=2).reshape(L, m, 4 * s)
            n, s = m, 4 * s
        else:
            m = n // 2
            k = jax.lax.broadcasted_iota(jnp.float32, (m, 1), 0)
            th = (-2.0 * math.pi / n) * k
            wr, wi = jnp.cos(th), jnp.sin(th)
            a_r, a_i = yr[:, :m, :], yi[:, :m, :]
            b_r, b_i = yr[:, m:, :], yi[:, m:, :]
            t1r, t1i = _cmul(a_r - b_r, a_i - b_i, wr, wi)
            yr = jnp.stack([a_r + b_r, t1r], axis=2).reshape(L, m, 2 * s)
            yi = jnp.stack([a_i + b_i, t1i], axis=2).reshape(L, m, 2 * s)
            n, s = m, 2 * s
    return yr.reshape(L, N), yi.reshape(L, N)


# ---------------------------------------------------------------------------
# The fused kernel body: [FFT] -> [multiply] -> [IFFT], one dispatch
# ---------------------------------------------------------------------------

def _run_fft(xr, xi, consts, spec: SpectralSpec, inverse: bool):
    """Forward or inverse (conj-FFT-conj) transform along spec.axis."""
    if inverse:
        xi = -xi
    if spec.fft_impl == "matmul":
        fft = _fft_rows_matmul if spec.axis == 1 else _fft_cols_matmul
        yr, yi = fft(xr, xi, consts, spec)
    elif spec.fft_impl == "stockham":
        yr, yi = _fft_stockham(xr, xi, spec, spec.axis)
    else:
        raise ValueError(f"unknown fft_impl {spec.fft_impl}")
    if inverse:
        # conj + 1/N, folded into the final store (paper SSII-C)
        scale = 1.0 / spec.n
        return yr * scale, yi * (-scale)
    return yr, yi


def _spectral_kernel(spec: SpectralSpec, *refs):
    """Pallas kernel body. Ref layout (in order):

    xr, xi, [f1r,f1i,f2r,f2i,twr,twi if matmul], [filter refs...], or, oi
    """
    it = iter(refs)
    xr_ref, xi_ref = next(it), next(it)
    consts = None
    if spec.fft_impl == "matmul" and (spec.fwd or spec.inv):
        consts = tuple(next(it)[...] for _ in range(6))
    filt = ()
    if spec.filter_mode in (FILTER_SHARED, FILTER_FULL):
        filt = (next(it), next(it))          # hr, hi
    elif spec.filter_mode == FILTER_OUTER:
        filt = (next(it), next(it))          # u (per-line), v (per-sample)
    elif spec.filter_mode == FILTER_SHARED_OUTER:
        filt = (next(it), next(it), next(it), next(it))  # hr, hi, u, v
    or_ref, oi_ref = next(it), next(it)

    xr = xr_ref[...]
    xi = xi_ref[...]

    if spec.fwd:
        xr, xi = _run_fft(xr, xi, consts, spec, inverse=False)

    def _apply_outer(xr, xi, u_ref, v_ref):
        u = u_ref[...]      # rows: (L, K); cols: (K, C)  — per-line parameters
        v = v_ref[...]      # rows: (K, N); cols: (N, K)  — per-sample parameters
        # rank-K phase synthesized in VMEM (no 2-D filter I/O)
        if spec.axis == 1:
            phase = jax.lax.dot_general(
                u, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        else:
            phase = jax.lax.dot_general(
                v, u, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        return _cmul(xr, xi, jnp.cos(phase), jnp.sin(phase))

    if spec.filter_mode in (FILTER_SHARED, FILTER_FULL):
        # FILTER_SHARED blocks are (1, N) [rows] or (N, 1) [cols]: broadcast.
        xr, xi = _cmul(xr, xi, filt[0][...], filt[1][...])
    elif spec.filter_mode == FILTER_OUTER:
        xr, xi = _apply_outer(xr, xi, filt[0], filt[1])
    elif spec.filter_mode == FILTER_SHARED_OUTER:
        xr, xi = _cmul(xr, xi, filt[0][...], filt[1][...])
        xr, xi = _apply_outer(xr, xi, filt[2], filt[3])

    if spec.inv:
        xr, xi = _run_fft(xr, xi, consts, spec, inverse=True)

    or_ref[...] = xr
    oi_ref[...] = xi


# ---------------------------------------------------------------------------
# pallas_call builder
# ---------------------------------------------------------------------------

def _flops_per_line(spec: SpectralSpec) -> float:
    """Nominal 5 N log2 N per transform + 6N per complex multiply (for benches)."""
    n = spec.n
    f = 0.0
    if spec.fwd:
        f += 5.0 * n * math.log2(n)
    if spec.inv:
        f += 5.0 * n * math.log2(n)
    if spec.filter_mode != FILTER_NONE:
        f += 6.0 * n
    return f


def build_spectral_call(spec: SpectralSpec, lines: int, interpret: bool = False):
    """Returns fn(xr, xi, *filter_args) -> (yr, yi) as a single pallas_call.

    Rows pipeline: x is (lines, N), grid over line blocks.
    Cols pipeline: x is (N, lines), grid over column blocks.
    """
    n = spec.n
    L = spec.block
    if lines % L:
        raise ValueError(f"lines={lines} not divisible by block={L}")
    grid = (lines // L,)

    K = spec.outer_rank
    if spec.axis == 1:
        x_shape = (lines, n)
        x_spec = pl.BlockSpec((L, n), lambda i: (i, 0))
        shared_spec = pl.BlockSpec((1, n), lambda i: (0, 0))
        full_spec = x_spec
        u_spec = pl.BlockSpec((L, K), lambda i: (i, 0))   # (lines, K)
        v_spec = pl.BlockSpec((K, n), lambda i: (0, 0))   # (K, n)
    else:
        x_shape = (n, lines)
        x_spec = pl.BlockSpec((n, L), lambda i: (0, i))
        shared_spec = pl.BlockSpec((n, 1), lambda i: (0, 0))
        full_spec = x_spec
        u_spec = pl.BlockSpec((K, L), lambda i: (0, i))   # (K, lines)
        v_spec = pl.BlockSpec((n, K), lambda i: (0, 0))   # (n, K)

    in_specs = [x_spec, x_spec]
    extra_args: list[jnp.ndarray] = []

    needs_consts = spec.fft_impl == "matmul" and (spec.fwd or spec.inv)
    if needs_consts:
        n1, n2 = spec.factors()
        consts = dft_constants(n1, n2)
        const_specs = [
            pl.BlockSpec((n1, n1), lambda i: (0, 0)),
            pl.BlockSpec((n1, n1), lambda i: (0, 0)),
            pl.BlockSpec((n2, n2), lambda i: (0, 0)),
            pl.BlockSpec((n2, n2), lambda i: (0, 0)),
            pl.BlockSpec((n1, n2), lambda i: (0, 0)),
            pl.BlockSpec((n1, n2), lambda i: (0, 0)),
        ]
        in_specs += const_specs
        extra_args += [jnp.asarray(c) for c in consts]

    if spec.filter_mode == FILTER_SHARED:
        in_specs += [shared_spec, shared_spec]
    elif spec.filter_mode == FILTER_FULL:
        in_specs += [full_spec, full_spec]
    elif spec.filter_mode == FILTER_OUTER:
        in_specs += [u_spec, v_spec]
    elif spec.filter_mode == FILTER_SHARED_OUTER:
        in_specs += [shared_spec, shared_spec, u_spec, v_spec]

    out_specs = [x_spec, x_spec]
    out_shape = [
        jax.ShapeDtypeStruct(x_shape, jnp.float32),
        jax.ShapeDtypeStruct(x_shape, jnp.float32),
    ]

    kernel = functools.partial(_spectral_kernel, spec)
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )

    def fn(xr, xi, *filter_args):
        args = [xr, xi] + extra_args + list(filter_args)
        return call(*args)

    fn.flops = _flops_per_line(spec) * lines  # nominal, for benchmark CSV
    return fn
