"""Single-dispatch fused spectral-pipeline Pallas kernel (the paper's contribution).

The paper fuses FFT -> matched-filter multiply -> IFFT into one Metal dispatch,
holding a 4096-point complex line in 32 KiB of threadgroup memory, and feeds
Apple's 8x8 simdgroup MMA with a radix-8 DFT butterfly.

TPU adaptation (see DESIGN.md SS2):
  * on-chip tier   : 32 KiB threadgroup memory  ->  ~16 MiB VMEM. We block a
    *batch of lines* (row pipeline) or a whole (N x L) column slab (column
    pipeline) per grid step, instead of one line per threadgroup.
  * matrix unit    : 8x8 simdgroup MMA -> 128x128 MXU. The radix-8 butterfly
    becomes a *four-step FFT*: N = n1*n2, each stage a dense matmul against a
    DFT matrix (n1, n2 <= 128), twiddle as a pointwise multiply. Complex
    arithmetic is split re/im (4 real matmuls, or 3 with Karatsuba).
  * IFFT           : conj-FFT-conj with the 1/N scale folded into the final
    store — identical to the paper's SSII-C trick.
  * the paper's in-place constraint (Stockham needs 2x buffers > 32 KiB) does
    not bind in VMEM; we keep the numerically-identical out-of-place stages
    inside the kernel and spend the slack on line batching.

A 'stockham' VPU implementation (radix-4/radix-2, no matmuls) is provided as
the scalar baseline for the paper's Table I comparison.

Batched multi-scene dispatch (beyond-paper)
-------------------------------------------
Every kernel takes a leading batch dimension: x is (B, lines, N) for the
rows pipeline and (B, N, lines) for the columns pipeline. The Pallas grid
spans ``batch-blocks x line-blocks`` and each grid step holds a
(Bb, L, N) slab — the SAME line-block of Bb scenes — which the transform
folds into one (Bb*L, N) line batch. Scenes therefore share one dispatch,
one set of broadcast DFT-constant blocks per step, and larger (better
MXU-shaped) matmuls; none of that happens with a Python-level vmap, which
re-issues the whole dispatch per scene. Filters are batch-shared (one
(lines, N) filter / (N,) vector / rank-K phase for all B scenes), matching
multi-scene SAR where every scene uses the same SceneConfig. The unbatched
public API in kernels/ops.py is the B=1 special case (2-D inputs are
expanded and squeezed transparently).

Mixed-radix factorization rules
-------------------------------
``SpectralSpec.factors()`` returns a two- OR three-factor decomposition
``n = n1*n2[*n3]`` with every factor a power of two <= 128 (the MXU edge):

  * n <= 16384: the ~sqrt two-factor split (n1 >= n2), e.g. 4096 = 64*64,
    8192 = 128*64, 512 = 32*16.
  * 16384 < n <= 2^21: a three-factor split, e.g. 32768 = 32*32*32 —
    the four-step formulation applies recursively (stage-A matmul,
    twiddle, then a four-step FFT of the remaining length), so lengths
    beyond 128*128 still map onto dense MXU matmuls instead of erroring.

Explicit ``n1``/``n2``/``n3`` override the default (the repro.tuning
subsystem sweeps them per (B, n) together with ``block``, ``karatsuba``
and ``precision``, and caches the fastest config per device fingerprint;
``build_spectral_call`` also accepts a whole ``repro.tuning.KernelConfig``
via its ``config`` parameter).

Everything is validated in interpret mode against kernels/ref.py (pure jnp).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Filter (pointwise multiply) modes for the fused pipeline.
FILTER_NONE = "none"      # no multiply (pure FFT / pure IFFT dispatch)
FILTER_SHARED = "shared"  # one N-vector shared by every line (range matched filter)
FILTER_FULL = "full"      # full 2-D filter, same shape as the scene block
FILTER_OUTER = "outer"    # on-the-fly rank-K phase synthesis
                          # exp(i * sum_k u[line,k] * v[sample,k])
                          # (covers RCMC phase ramps and azimuth compression —
                          #  beyond-paper bandwidth optimization: O(N+L) filter
                          #  I/O instead of O(N*L))
FILTER_SHARED_OUTER = "shared_outer"  # H[sample] * exp(i sum_k u v): range
                          # matched filter and RCMC shift in ONE dispatch
                          # (the 3-dispatch RDA; beyond-paper)


MAX_FACTOR = 128  # MXU edge: every DFT matmul factor must be <= 128


# ---------------------------------------------------------------------------
# Precision policy
# ---------------------------------------------------------------------------
#
# Matmul-operand precision of the in-kernel DFT stages ("Range, Not
# Precision", arXiv 2605.28451: FFT inputs are range-limited, so narrow
# floats with a shared block exponent keep SAR image quality while doubling
# matrix-unit throughput). Accumulation is always float32
# (preferred_element_type); only the dot operands are narrowed.
#
#   f32   exact float32 operands (default)
#   bf16  bfloat16 operands — wide exponent, 8-bit mantissa
#   f16   float16 operands — 11-bit mantissa but narrow exponent (can
#         overflow past |x| ~ 6.5e4; prefer bs16)
#   bs16  block-scaled float16: the kernel prologue extracts one power-of-two
#         exponent per grid block (scale division is exact in f32), runs the
#         whole fused pipeline on the scaled data with f16 operands, and the
#         epilogue re-applies the exponent at the final store. Combines f16's
#         mantissa with an unbounded effective exponent range.

@dataclasses.dataclass(frozen=True)
class Precision:
    """One matmul-operand precision policy for the fused kernel."""

    name: str
    dtype: str            # operand dtype the DFT matmuls are cast to
    block_scaled: bool    # per-block exponent extraction in prologue/epilogue

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)


PRECISIONS: dict[str, Precision] = {
    "f32": Precision("f32", "float32", False),
    "bf16": Precision("bf16", "bfloat16", False),
    "f16": Precision("f16", "float16", False),
    "bs16": Precision("bs16", "float16", True),
}


def resolve_precision(p) -> Precision:
    """Accepts a Precision, a policy name, or None (-> f32)."""
    if p is None:
        return PRECISIONS["f32"]
    if isinstance(p, Precision):
        return p
    try:
        return PRECISIONS[p]
    except KeyError:
        raise ValueError(
            f"unknown precision {p!r}; one of {sorted(PRECISIONS)}") from None


def default_factorization(n: int) -> tuple[int, ...]:
    """Mixed-radix split of n into 2 or 3 power-of-two factors, each <= 128.

    n <= 128*128:  the ~sqrt two-factor split with n1 >= n2 (the paper's
                   regime: 4096 = 64*64; plus 8192 = 128*64, 512 = 32*16).
    n <= 128^3:    three factors f1 >= f2 >= f3 (e.g. 32768 = 32*32*32) —
                   the four-step recursion keeps every stage on the MXU.
    """
    if n & (n - 1):
        raise ValueError(f"FFT length must be a power of two, got {n}")
    p = n.bit_length() - 1
    if n <= MAX_FACTOR * MAX_FACTOR:
        n1 = 1 << ((p + 1) // 2)
        return n1, n // n1
    if n > MAX_FACTOR ** 3:
        raise ValueError(
            f"n={n} exceeds the three-factor limit {MAX_FACTOR ** 3}")
    p1 = (p + 2) // 3
    p2 = (p - p1 + 1) // 2
    return 1 << p1, 1 << p2, 1 << (p - p1 - p2)


@dataclasses.dataclass(frozen=True)
class SpectralSpec:
    """Static configuration of one fused spectral dispatch."""

    n: int                      # FFT length (the transformed axis)
    fwd: bool                   # forward FFT first?
    filter_mode: str            # FILTER_*
    inv: bool                   # inverse FFT last?
    axis: int = 1               # 1 = rows pipeline (last axis), 0 = columns
    block: int = 8              # lines (rows kernel) / columns (cols kernel) per grid step
    batch_block: Optional[int] = None  # scenes per grid step (None = all)
    n1: Optional[int] = None    # mixed-radix factorization (defaults to
    n2: Optional[int] = None    # default_factorization's 2- or 3-way split)
    n3: Optional[int] = None
    fft_impl: str = "matmul"    # 'matmul' (MXU) | 'stockham' (VPU scalar baseline)
    karatsuba: bool = False     # 3-matmul complex product instead of 4
    precision: str = "f32"      # PRECISIONS key (matmul operands; f32 accum)
    fold_scale: bool = True     # fold the IFFT 1/N into the filter/final store
    outer_rank: int = 1         # K of the rank-K FILTER_OUTER phase

    def factors(self) -> tuple[int, ...]:
        """The mixed-radix decomposition n = n1 * n2 [* n3], every factor
        a power of two <= 128 (see the module docstring for the rules)."""
        if self.n1 is not None:
            fs = [self.n1]
            if self.n2 is not None:
                fs.append(self.n2)
            if self.n3 is not None:
                fs.append(self.n3)
            if len(fs) == 1:
                fs.append(self.n // self.n1)
            fs = tuple(fs)
        else:
            fs = default_factorization(self.n)
        if int(np.prod(fs)) != self.n:
            raise ValueError(f"factors {fs} do not multiply to n={self.n}")
        for f in fs:
            if f < 1 or f & (f - 1):
                raise ValueError(f"factor {f} is not a power of two: {fs}")
            if f > MAX_FACTOR:
                raise ValueError(
                    f"factor {f} exceeds the MXU edge {MAX_FACTOR}: {fs}")
        return fs

    @property
    def num_dft_consts(self) -> int:
        """Operand count for the DFT constants: one (re, im) matrix pair per
        factor plus one (re, im) twiddle pair per inter-stage boundary."""
        k = len(self.factors())
        return 4 * k - 2


# ---------------------------------------------------------------------------
# DFT constants (host-side numpy; passed to the kernel as broadcast operands)
# ---------------------------------------------------------------------------

def dft_constants(*factors: int) -> tuple[np.ndarray, ...]:
    """DFT matrices and inter-stage twiddles for a mixed-radix factor list.

    Returns, split re/im and in order: one (f_i, f_i) DFT matrix per factor,
    then one (f_i, prod(f_{i+1:})) twiddle per non-final stage, where the
    stage-i twiddle is exp(-2j pi k_i j / prod(f_{i:})) — the classic
    four-step twiddle, applied recursively. For two factors this is exactly
    (F1, F2, tw(n1, n2)); three factors add F3 and a (f2, f3) twiddle.
    """
    def dft(n):
        k = np.arange(n)
        m = np.exp(-2j * np.pi * np.outer(k, k) / n)
        return m.real.astype(np.float32), m.imag.astype(np.float32)

    out: list[np.ndarray] = []
    for f in factors:
        out.extend(dft(f))
    for i in range(len(factors) - 1):
        rest = int(np.prod(factors[i + 1:]))
        k = np.arange(factors[i])[:, None]
        j = np.arange(rest)[None, :]
        tw = np.exp(-2j * np.pi * k * j / (factors[i] * rest))
        out.append(tw.real.astype(np.float32))
        out.append(tw.imag.astype(np.float32))
    return tuple(out)


# ---------------------------------------------------------------------------
# In-kernel complex helpers (split re/im)
# ---------------------------------------------------------------------------

def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _cast(x, precision: str):
    prec = PRECISIONS[precision]
    return x if prec.dtype == "float32" else x.astype(prec.jnp_dtype)


def _cdot(fr, fi, xr, xi, dims, *, karatsuba: bool, precision: str):
    """Complex dot_general: (fr + i fi) . (xr + i xi) with contraction `dims`.

    4 real matmuls, or 3 with Karatsuba (P3 = (Fr+Fi)(Xr+Xi)). f32 accumulate.
    """
    dg = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=(dims, ((), ())),
        preferred_element_type=jnp.float32,
    )
    fr_, fi_ = _cast(fr, precision), _cast(fi, precision)
    xr_, xi_ = _cast(xr, precision), _cast(xi, precision)
    if karatsuba:
        p1 = dg(fr_, xr_)
        p2 = dg(fi_, xi_)
        p3 = dg(_cast(fr + fi, precision), _cast(xr + xi, precision))
        return p1 - p2, p3 - p1 - p2
    yr = dg(fr_, xr_) - dg(fi_, xi_)
    yi = dg(fr_, xi_) + dg(fi_, xr_)
    return yr, yi


def _cdot_rhs(xr, xi, fr, fi, dims, *, karatsuba: bool, precision: str):
    """Complex dot_general with the DFT matrix on the right: X . F."""
    dg = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=(dims, ((), ())),
        preferred_element_type=jnp.float32,
    )
    fr_, fi_ = _cast(fr, precision), _cast(fi, precision)
    xr_, xi_ = _cast(xr, precision), _cast(xi, precision)
    if karatsuba:
        p1 = dg(xr_, fr_)
        p2 = dg(xi_, fi_)
        p3 = dg(_cast(xr + xi, precision), _cast(fr + fi, precision))
        return p1 - p2, p3 - p1 - p2
    yr = dg(xr_, fr_) - dg(xi_, fi_)
    yi = dg(xi_, fr_) + dg(xr_, fi_)
    return yr, yi


# ---------------------------------------------------------------------------
# Four-step matmul FFT, in-kernel (rows: transform the last axis of (L, N))
# ---------------------------------------------------------------------------

def _split_consts(consts, factors):
    """(per-stage DFT matrix pairs, per-boundary twiddle pairs)."""
    k = len(factors)
    mats = [(consts[2 * i], consts[2 * i + 1]) for i in range(k)]
    tws = [(consts[2 * k + 2 * i], consts[2 * k + 2 * i + 1])
           for i in range(k - 1)]
    return mats, tws


def _fft_rows_matmul(xr, xi, consts, spec: SpectralSpec):
    """Mixed-radix four-step FFT along the last axis of (L, N).

    Recursive Cooley-Tukey over spec.factors(): at stage i the length-m
    block (m = prod of the remaining factors) is reshaped to (f_i, m/f_i),
    contracted with the f_i-point DFT matrix on the MXU, twiddled, and the
    remainder transformed recursively. Two factors reproduce the classic
    four-step (stage A matmul, twiddle, stage C matmul) exactly.
    """
    factors = spec.factors()
    mats, tws = _split_consts(consts, factors)
    kw = dict(karatsuba=spec.karatsuba, precision=spec.precision)

    def rec(xr, xi, i):
        # xr/xi: (M, m) — transform the last axis, m = prod(factors[i:])
        M, m = xr.shape
        f = factors[i]
        fr, fi = mats[i]
        if i == len(factors) - 1:
            # base: one dense DFT matmul (DFT matrices are symmetric)
            return _cdot_rhs(xr, xi, fr, fi, ((1,), (0,)), **kw)
        rest = m // f
        x3r = xr.reshape(M, f, rest)
        x3i = xi.reshape(M, f, rest)
        # stage A: contract f with F_i -> (f, M, rest), index k_i first
        ar, ai = _cdot(fr, fi, x3r, x3i, ((1,), (1,)), **kw)
        twr, twi = tws[i]
        br, bi = _cmul(ar, ai, twr[:, None, :], twi[:, None, :])
        # recurse on the remaining length
        zr, zi = rec(br.reshape(f * M, rest), bi.reshape(f * M, rest), i + 1)
        zr = zr.reshape(f, M, rest)
        zi = zi.reshape(f, M, rest)
        # out[l, k_rest * f + k_i] = z[k_i, l, k_rest]
        return (jnp.transpose(zr, (1, 2, 0)).reshape(M, m),
                jnp.transpose(zi, (1, 2, 0)).reshape(M, m))

    return rec(xr, xi, 0)


def _fft_cols_matmul(xr, xi, consts, spec: SpectralSpec):
    """Mixed-radix four-step FFT along axis 0 of an (N, C) column slab —
    no global transpose needed (same recursion as rows, column layout)."""
    factors = spec.factors()
    mats, tws = _split_consts(consts, factors)
    kw = dict(karatsuba=spec.karatsuba, precision=spec.precision)

    def rec(xr, xi, i):
        # xr/xi: (m, C) — transform axis 0, m = prod(factors[i:])
        m, C = xr.shape
        f = factors[i]
        fr, fi = mats[i]
        if i == len(factors) - 1:
            return _cdot(fr, fi, xr, xi, ((1,), (0,)), **kw)
        rest = m // f
        x3r = xr.reshape(f, rest, C)
        x3i = xi.reshape(f, rest, C)
        # stage A: contract f with F_i -> (f, rest, C)
        ar, ai = _cdot(fr, fi, x3r, x3i, ((1,), (0,)), **kw)
        twr, twi = tws[i]
        br, bi = _cmul(ar, ai, twr[:, :, None], twi[:, :, None])
        # recurse along the remaining length: (rest, f*C)
        cr = jnp.transpose(br, (1, 0, 2)).reshape(rest, f * C)
        ci = jnp.transpose(bi, (1, 0, 2)).reshape(rest, f * C)
        zr, zi = rec(cr, ci, i + 1)
        # out[k_rest * f + k_i, c] = z[k_rest, k_i, c] — a plain reshape
        return zr.reshape(m, C), zi.reshape(m, C)

    return rec(xr, xi, 0)


# ---------------------------------------------------------------------------
# Stockham VPU FFT, in-kernel (the paper's 'scalar' baseline, radix-4 + radix-2)
# ---------------------------------------------------------------------------

def _fft_stockham(xr, xi, spec: SpectralSpec, axis: int):
    """Self-sorting Stockham along `axis` of a 2-D block, pure vector ops."""
    if axis == 0:  # operate on (N, C): move to (C, N), reuse rows code, move back
        yr, yi = _fft_stockham(xr.T, xi.T, spec, 1)
        return yr.T, yi.T
    L, N = xr.shape
    yr = xr.reshape(L, N, 1)
    yi = xi.reshape(L, N, 1)
    n, s = N, 1
    while n > 1:
        if n % 4 == 0:
            m = n // 4
            k = jax.lax.broadcasted_iota(jnp.float32, (m, 1), 0)
            th = (-2.0 * math.pi / n) * k
            w1r, w1i = jnp.cos(th), jnp.sin(th)
            w2r, w2i = _cmul(w1r, w1i, w1r, w1i)
            w3r, w3i = _cmul(w2r, w2i, w1r, w1i)
            sl = lambda z, q: z[:, q * m:(q + 1) * m, :]
            a_r, a_i = sl(yr, 0), sl(yi, 0)
            b_r, b_i = sl(yr, 1), sl(yi, 1)
            c_r, c_i = sl(yr, 2), sl(yi, 2)
            d_r, d_i = sl(yr, 3), sl(yi, 3)
            apc_r, apc_i = a_r + c_r, a_i + c_i
            amc_r, amc_i = a_r - c_r, a_i - c_i
            bpd_r, bpd_i = b_r + d_r, b_i + d_i
            bmd_r, bmd_i = b_r - d_r, b_i - d_i
            t0r, t0i = apc_r + bpd_r, apc_i + bpd_i
            # (amc - i*bmd) * w1
            u1r, u1i = amc_r + bmd_i, amc_i - bmd_r
            t1r, t1i = _cmul(u1r, u1i, w1r, w1i)
            # (apc - bpd) * w2
            t2r, t2i = _cmul(apc_r - bpd_r, apc_i - bpd_i, w2r, w2i)
            # (amc + i*bmd) * w3
            u3r, u3i = amc_r - bmd_i, amc_i + bmd_r
            t3r, t3i = _cmul(u3r, u3i, w3r, w3i)
            yr = jnp.stack([t0r, t1r, t2r, t3r], axis=2).reshape(L, m, 4 * s)
            yi = jnp.stack([t0i, t1i, t2i, t3i], axis=2).reshape(L, m, 4 * s)
            n, s = m, 4 * s
        else:
            m = n // 2
            k = jax.lax.broadcasted_iota(jnp.float32, (m, 1), 0)
            th = (-2.0 * math.pi / n) * k
            wr, wi = jnp.cos(th), jnp.sin(th)
            a_r, a_i = yr[:, :m, :], yi[:, :m, :]
            b_r, b_i = yr[:, m:, :], yi[:, m:, :]
            t1r, t1i = _cmul(a_r - b_r, a_i - b_i, wr, wi)
            yr = jnp.stack([a_r + b_r, t1r], axis=2).reshape(L, m, 2 * s)
            yi = jnp.stack([a_i + b_i, t1i], axis=2).reshape(L, m, 2 * s)
            n, s = m, 2 * s
    return yr.reshape(L, N), yi.reshape(L, N)


# ---------------------------------------------------------------------------
# The fused kernel body: [FFT] -> [multiply] -> [IFFT], one dispatch
# ---------------------------------------------------------------------------

def _run_fft(xr, xi, consts, spec: SpectralSpec, inverse: bool):
    """Forward or inverse (conj-FFT-conj) transform along spec.axis.

    x is a (Bb, L, n) / (Bb, n, L) batch block: the batch dim folds into
    the line dim for the transform (scenes are independent lines), so one
    grid step's matmuls span Bb * L lines — THE amortization: DFT constants
    are loaded once per step and shared by every scene in the block.
    """
    bb = xr.shape[0]
    if spec.axis == 1:
        # (Bb, L, n) -> (Bb*L, n): contiguous, a free reshape
        xr2 = xr.reshape(bb * xr.shape[1], xr.shape[2])
        xi2 = xi.reshape(bb * xi.shape[1], xi.shape[2])
    else:
        # (Bb, n, L) -> (n, Bb*L): the scene axis must stay leading
        xr2 = jnp.moveaxis(xr, 0, 1).reshape(xr.shape[1], bb * xr.shape[2])
        xi2 = jnp.moveaxis(xi, 0, 1).reshape(xi.shape[1], bb * xi.shape[2])
    if inverse:
        xi2 = -xi2
    if spec.fft_impl == "matmul":
        fft = _fft_rows_matmul if spec.axis == 1 else _fft_cols_matmul
        yr, yi = fft(xr2, xi2, consts, spec)
    elif spec.fft_impl == "stockham":
        yr, yi = _fft_stockham(xr2, xi2, spec, spec.axis)
    else:
        raise ValueError(f"unknown fft_impl {spec.fft_impl}")
    if inverse:
        # conj + 1/N, folded into the final store (paper SSII-C)
        scale = 1.0 / spec.n
        yr, yi = yr * scale, yi * (-scale)
    if spec.axis == 1:
        return yr.reshape(xr.shape), yi.reshape(xi.shape)
    yr = jnp.moveaxis(yr.reshape(xr.shape[1], bb, xr.shape[2]), 1, 0)
    yi = jnp.moveaxis(yi.reshape(xi.shape[1], bb, xi.shape[2]), 1, 0)
    return yr, yi


def _spectral_kernel(spec: SpectralSpec, *refs):
    """Pallas kernel body. Ref layout (in order):

    xr, xi, [DFT matrices + twiddles if matmul], [filter refs...], or, oi

    The x/output refs are (Bb, L, n) rows / (Bb, n, L) cols batch blocks:
    each grid step holds the SAME line-block of every scene in the batch
    block, so the DFT constants and filters are shared across scenes (the
    2-D filters broadcast right-aligned over the leading batch dim).
    """
    it = iter(refs)
    xr_ref, xi_ref = next(it), next(it)
    consts = None
    if spec.fft_impl == "matmul" and (spec.fwd or spec.inv):
        consts = tuple(next(it)[...] for _ in range(spec.num_dft_consts))
    filt = ()
    if spec.filter_mode in (FILTER_SHARED, FILTER_FULL):
        filt = (next(it), next(it))          # hr, hi
    elif spec.filter_mode == FILTER_OUTER:
        filt = (next(it), next(it))          # u (per-line), v (per-sample)
    elif spec.filter_mode == FILTER_SHARED_OUTER:
        filt = (next(it), next(it), next(it), next(it))  # hr, hi, u, v
    or_ref, oi_ref = next(it), next(it)

    xr = xr_ref[...]
    xi = xi_ref[...]

    # bs16 prologue: extract one power-of-two exponent per grid block so the
    # f16 matmul operands stay in range. The whole fused pipeline (FFT,
    # filter, IFFT) is linear in x, so one scale factored out here and
    # re-applied in the epilogue is exact up to f32 rounding — and since the
    # scale is a power of two, the scaling itself is bit-exact.
    scale = None
    if PRECISIONS[spec.precision].block_scaled:
        amax = jnp.maximum(jnp.max(jnp.abs(xr)), jnp.max(jnp.abs(xi)))
        exp = jnp.ceil(jnp.log2(jnp.maximum(amax, jnp.float32(1e-37))))
        scale = jnp.exp2(exp)
        inv_scale = jnp.exp2(-exp)
        xr = xr * inv_scale
        xi = xi * inv_scale

    if spec.fwd:
        xr, xi = _run_fft(xr, xi, consts, spec, inverse=False)

    def _apply_outer(xr, xi, u_ref, v_ref):
        u = u_ref[...]      # rows: (L, K); cols: (K, C)  — per-line parameters
        v = v_ref[...]      # rows: (K, N); cols: (N, K)  — per-sample parameters
        # rank-K phase synthesized in VMEM (no 2-D filter I/O); the 2-D
        # phase broadcasts across the leading batch-block dim
        if spec.axis == 1:
            phase = jax.lax.dot_general(
                u, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        else:
            phase = jax.lax.dot_general(
                v, u, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        return _cmul(xr, xi, jnp.cos(phase), jnp.sin(phase))

    if spec.filter_mode in (FILTER_SHARED, FILTER_FULL):
        # FILTER_SHARED blocks are (1, N) [rows] or (N, 1) [cols]: broadcast.
        xr, xi = _cmul(xr, xi, filt[0][...], filt[1][...])
    elif spec.filter_mode == FILTER_OUTER:
        xr, xi = _apply_outer(xr, xi, filt[0], filt[1])
    elif spec.filter_mode == FILTER_SHARED_OUTER:
        xr, xi = _cmul(xr, xi, filt[0][...], filt[1][...])
        xr, xi = _apply_outer(xr, xi, filt[2], filt[3])

    if spec.inv:
        xr, xi = _run_fft(xr, xi, consts, spec, inverse=True)

    if scale is not None:
        # bs16 epilogue: fold the block exponent back into the final store
        xr = xr * scale
        xi = xi * scale

    or_ref[...] = xr.reshape(or_ref.shape)
    oi_ref[...] = xi.reshape(oi_ref.shape)


# ---------------------------------------------------------------------------
# pallas_call builder
# ---------------------------------------------------------------------------

def _flops_per_line(spec: SpectralSpec) -> float:
    """Nominal 5 N log2 N per transform + 6N per complex multiply (for benches)."""
    n = spec.n
    f = 0.0
    if spec.fwd:
        f += 5.0 * n * math.log2(n)
    if spec.inv:
        f += 5.0 * n * math.log2(n)
    if spec.filter_mode != FILTER_NONE:
        f += 6.0 * n
    return f


def build_spectral_call(spec: SpectralSpec, lines: int, batch: int = 1,
                        interpret: bool = False, config=None):
    """Returns fn(xr, xi, *filter_args) -> (yr, yi) as a single pallas_call.

    ``config`` is an optional :class:`repro.tuning.KernelConfig`: its
    non-None knobs (block, n1/n2/n3, karatsuba, precision) are applied on
    top of ``spec`` before the call is built — the one config path from
    the tuning subsystem into the kernel layer. (Duck-typed through
    ``config.apply(spec)``; kernels do not import repro.tuning.)

    Rows pipeline: x is (B, lines, N), cols pipeline: x is (B, N, lines).
    The grid runs over (batch-blocks, line-blocks) with each grid step
    holding a (Bb, L, N) slab — the same line-block of Bb scenes at once —
    so the DFT-constant loads and the per-step dispatch overhead amortize
    across the batch (spec.batch_block defaults to the whole batch; cap it
    when Bb * L * N would overflow VMEM). Filters are 2-D and batch-shared
    (every scene uses the same SceneConfig filters).
    """
    if config is not None:
        spec = config.apply(spec)
    n = spec.n
    L = spec.block
    if lines % L:
        raise ValueError(f"lines={lines} not divisible by block={L}")
    Bb = spec.batch_block or batch
    if batch % Bb:
        raise ValueError(f"batch={batch} not divisible by batch_block={Bb}")
    grid = (batch // Bb, lines // L)

    K = spec.outer_rank
    if spec.axis == 1:
        x_shape = (batch, lines, n)
        x_spec = pl.BlockSpec((Bb, L, n), lambda b, i: (b, i, 0))
        shared_spec = pl.BlockSpec((1, n), lambda b, i: (0, 0))
        full_spec = pl.BlockSpec((L, n), lambda b, i: (i, 0))
        u_spec = pl.BlockSpec((L, K), lambda b, i: (i, 0))   # (lines, K)
        v_spec = pl.BlockSpec((K, n), lambda b, i: (0, 0))   # (K, n)
    else:
        x_shape = (batch, n, lines)
        x_spec = pl.BlockSpec((Bb, n, L), lambda b, i: (b, 0, i))
        shared_spec = pl.BlockSpec((n, 1), lambda b, i: (0, 0))
        full_spec = pl.BlockSpec((n, L), lambda b, i: (0, i))
        u_spec = pl.BlockSpec((K, L), lambda b, i: (0, i))   # (K, lines)
        v_spec = pl.BlockSpec((n, K), lambda b, i: (0, 0))   # (n, K)

    in_specs = [x_spec, x_spec]
    extra_args: list[jnp.ndarray] = []

    needs_consts = spec.fft_impl == "matmul" and (spec.fwd or spec.inv)
    if needs_consts:
        consts = dft_constants(*spec.factors())
        in_specs += [pl.BlockSpec(c.shape, lambda b, i: (0, 0))
                     for c in consts]
        extra_args += [jnp.asarray(c) for c in consts]

    if spec.filter_mode == FILTER_SHARED:
        in_specs += [shared_spec, shared_spec]
    elif spec.filter_mode == FILTER_FULL:
        in_specs += [full_spec, full_spec]
    elif spec.filter_mode == FILTER_OUTER:
        in_specs += [u_spec, v_spec]
    elif spec.filter_mode == FILTER_SHARED_OUTER:
        in_specs += [shared_spec, shared_spec, u_spec, v_spec]

    out_specs = [x_spec, x_spec]
    out_shape = [
        jax.ShapeDtypeStruct(x_shape, jnp.float32),
        jax.ShapeDtypeStruct(x_shape, jnp.float32),
    ]

    kernel = functools.partial(_spectral_kernel, spec)
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )

    def fn(xr, xi, *filter_args):
        args = [xr, xi] + extra_args + list(filter_args)
        return call(*args)

    fn.flops = _flops_per_line(spec) * lines * batch  # nominal, for benches
    return fn
