"""Single-dispatch fused spectral-pipeline Pallas kernel (the paper's contribution).

The paper fuses FFT -> matched-filter multiply -> IFFT into one Metal dispatch,
holding a 4096-point complex line in 32 KiB of threadgroup memory, and feeds
Apple's 8x8 simdgroup MMA with a radix-8 DFT butterfly.

TPU adaptation (see DESIGN.md SS2):
  * on-chip tier   : 32 KiB threadgroup memory  ->  ~16 MiB VMEM. We block a
    *batch of lines* (row pipeline) or a whole (N x L) column slab (column
    pipeline) per grid step, instead of one line per threadgroup.
  * matrix unit    : 8x8 simdgroup MMA -> 128x128 MXU. The radix-8 butterfly
    becomes a *four-step FFT*: N = n1*n2, each stage a dense matmul against a
    DFT matrix (n1, n2 <= 128), twiddle as a pointwise multiply. Complex
    arithmetic is split re/im (4 real matmuls, or 3 with Karatsuba).
  * IFFT           : conj-FFT-conj with the 1/N scale folded into the final
    store — identical to the paper's SSII-C trick.
  * the paper's in-place constraint (Stockham needs 2x buffers > 32 KiB) does
    not bind in VMEM; we keep the numerically-identical out-of-place stages
    inside the kernel and spend the slack on line batching.

A 'stockham' VPU implementation (radix-4/radix-2, no matmuls) is provided as
the scalar baseline for the paper's Table I comparison.

Batched multi-scene dispatch (beyond-paper)
-------------------------------------------
Every kernel takes a leading batch dimension: x is (B, lines, N) for the
rows pipeline and (B, N, lines) for the columns pipeline. The Pallas grid
spans ``batch-blocks x line-blocks`` and each grid step holds a
(Bb, L, N) slab — the SAME line-block of Bb scenes — which the transform
folds into one (Bb*L, N) line batch. Scenes therefore share one dispatch,
one set of broadcast DFT-constant blocks per step, and larger (better
MXU-shaped) matmuls; none of that happens with a Python-level vmap, which
re-issues the whole dispatch per scene. Filters are batch-shared (one
(lines, N) filter / (N,) vector / rank-K phase for all B scenes), matching
multi-scene SAR where every scene uses the same SceneConfig. The unbatched
public API in kernels/ops.py is the B=1 special case (2-D inputs are
expanded and squeezed transparently).

Mixed-radix factorization rules
-------------------------------
``SpectralSpec.factors()`` returns a two- OR three-factor decomposition
``n = n1*n2[*n3]`` with every factor a power of two <= 128 (the MXU edge):

  * n <= 16384: the ~sqrt two-factor split (n1 >= n2), e.g. 4096 = 64*64,
    8192 = 128*64, 512 = 32*16.
  * 16384 < n <= 2^21: a three-factor split, e.g. 32768 = 32*32*32 —
    the four-step formulation applies recursively (stage-A matmul,
    twiddle, then a four-step FFT of the remaining length), so lengths
    beyond 128*128 still map onto dense MXU matmuls instead of erroring.

Explicit ``n1``/``n2``/``n3`` override the default (the repro.tuning
subsystem sweeps them per (B, n) together with ``block``, ``karatsuba``
and ``precision``, and caches the fastest config per device fingerprint;
``build_spectral_call`` also accepts a whole ``repro.tuning.KernelConfig``
via its ``config`` parameter).

Everything is validated in interpret mode against kernels/ref.py (pure jnp).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Filter (pointwise multiply) modes for the fused pipeline.
FILTER_NONE = "none"      # no multiply (pure FFT / pure IFFT dispatch)
FILTER_SHARED = "shared"  # one N-vector shared by every line (range matched filter)
FILTER_FULL = "full"      # full 2-D filter, same shape as the scene block
FILTER_OUTER = "outer"    # on-the-fly rank-K phase synthesis
                          # exp(i * sum_k u[line,k] * v[sample,k])
                          # (covers RCMC phase ramps and azimuth compression —
                          #  beyond-paper bandwidth optimization: O(N+L) filter
                          #  I/O instead of O(N*L))
FILTER_SHARED_OUTER = "shared_outer"  # H[sample] * exp(i sum_k u v): range
                          # matched filter and RCMC shift in ONE dispatch
                          # (the 3-dispatch RDA; beyond-paper)


MAX_FACTOR = 128  # MXU edge: every DFT matmul factor must be <= 128

# Residency modes of the single-dispatch 2-D megakernel (build_mega_call).
RESIDENT_VMEM = "vmem"      # whole (Bb, na, nr) slab on-chip per grid step
RESIDENT_STAGED = "staged"  # phase-split grid + HBM scratch, DMA-staged


def auto_interpret(interpret: Optional[bool]) -> bool:
    """Resolve the tri-state ``interpret`` flag every kernel wrapper takes:
    None auto-selects interpret mode off-TPU (this container is CPU-only;
    on a real TPU fleet the same code lowers to Mosaic)."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Precision policy
# ---------------------------------------------------------------------------
#
# Matmul-operand precision of the in-kernel DFT stages ("Range, Not
# Precision", arXiv 2605.28451: FFT inputs are range-limited, so narrow
# floats with a shared block exponent keep SAR image quality while doubling
# matrix-unit throughput). Accumulation is always float32
# (preferred_element_type); only the dot operands are narrowed.
#
#   f32   exact float32 operands (default)
#   bf16  bfloat16 operands — wide exponent, 8-bit mantissa
#   f16   float16 operands — 11-bit mantissa but narrow exponent (can
#         overflow past |x| ~ 6.5e4; prefer bs16)
#   bs16  block-scaled float16: the kernel prologue extracts one power-of-two
#         exponent PER LINE along the segment's free axis (scale division is
#         exact in f32), runs the fused pipeline on the scaled data with f16
#         operands, and the epilogue re-applies the exponents at the final
#         store. Combines f16's mantissa with an unbounded effective exponent
#         range. Per-line granularity makes the policy invariant to every
#         grid blocking (line blocks, batch blocks, staged phase blocks,
#         device sharding): any block of lines sees exactly the exponents its
#         lines would get in any other partitioning, so bs16 results are
#         bit-identical across the per-axis, megakernel, and sharded routes.
#         Between segments the megakernels RE-BLOCK: apply the carried
#         exponents (exact), re-extract along the new segment's free axis,
#         rescale — matching the per-dispatch extraction of the multi-
#         dispatch pipeline bit for bit.

@dataclasses.dataclass(frozen=True)
class Precision:
    """One matmul-operand precision policy for the fused kernel."""

    name: str
    dtype: str            # operand dtype the DFT matmuls are cast to
    block_scaled: bool    # per-line exponent extraction in prologue/epilogue

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)


PRECISIONS: dict[str, Precision] = {
    "f32": Precision("f32", "float32", False),
    "bf16": Precision("bf16", "bfloat16", False),
    "f16": Precision("f16", "float16", False),
    "bs16": Precision("bs16", "float16", True),
}


def resolve_precision(p) -> Precision:
    """Accepts a Precision, a policy name, or None (-> f32)."""
    if p is None:
        return PRECISIONS["f32"]
    if isinstance(p, Precision):
        return p
    try:
        return PRECISIONS[p]
    except KeyError:
        raise ValueError(
            f"unknown precision {p!r}; one of {sorted(PRECISIONS)}") from None


def default_factorization(n: int) -> tuple[int, ...]:
    """Mixed-radix split of n into 2 or 3 power-of-two factors, each <= 128.

    n <= 128*128:  the ~sqrt two-factor split with n1 >= n2 (the paper's
                   regime: 4096 = 64*64; plus 8192 = 128*64, 512 = 32*16).
    n <= 128^3:    three factors f1 >= f2 >= f3 (e.g. 32768 = 32*32*32) —
                   the four-step recursion keeps every stage on the MXU.
    """
    if n & (n - 1):
        raise ValueError(f"FFT length must be a power of two, got {n}")
    p = n.bit_length() - 1
    if n <= MAX_FACTOR * MAX_FACTOR:
        n1 = 1 << ((p + 1) // 2)
        return n1, n // n1
    if n > MAX_FACTOR ** 3:
        raise ValueError(
            f"n={n} exceeds the three-factor limit {MAX_FACTOR ** 3}")
    p1 = (p + 2) // 3
    p2 = (p - p1 + 1) // 2
    return 1 << p1, 1 << p2, 1 << (p - p1 - p2)


@dataclasses.dataclass(frozen=True)
class SpectralSpec:
    """Static configuration of one fused spectral dispatch."""

    n: int                      # FFT length (the transformed axis)
    fwd: bool                   # forward FFT first?
    filter_mode: str            # FILTER_*
    inv: bool                   # inverse FFT last?
    axis: int = 1               # 1 = rows pipeline (last axis), 0 = columns
    block: int = 8              # lines (rows kernel) / columns (cols kernel) per grid step
    batch_block: Optional[int] = None  # scenes per grid step (None = all)
    n1: Optional[int] = None    # mixed-radix factorization (defaults to
    n2: Optional[int] = None    # default_factorization's 2- or 3-way split)
    n3: Optional[int] = None
    fft_impl: str = "matmul"    # 'matmul' (MXU) | 'stockham' (VPU scalar baseline)
    karatsuba: bool = False     # 3-matmul complex product instead of 4
    precision: str = "f32"      # PRECISIONS key (matmul operands; f32 accum)
    fold_scale: bool = True     # fold the IFFT 1/N into the filter/final store
    outer_rank: int = 1         # K of the rank-K FILTER_OUTER phase

    def factors(self) -> tuple[int, ...]:
        """The mixed-radix decomposition n = n1 * n2 [* n3], every factor
        a power of two <= 128 (see the module docstring for the rules)."""
        if self.n1 is not None:
            fs = [self.n1]
            if self.n2 is not None:
                fs.append(self.n2)
            if self.n3 is not None:
                fs.append(self.n3)
            if len(fs) == 1:
                fs.append(self.n // self.n1)
            fs = tuple(fs)
        else:
            fs = default_factorization(self.n)
        if int(np.prod(fs)) != self.n:
            raise ValueError(f"factors {fs} do not multiply to n={self.n}")
        for f in fs:
            if f < 1 or f & (f - 1):
                raise ValueError(f"factor {f} is not a power of two: {fs}")
            if f > MAX_FACTOR:
                raise ValueError(
                    f"factor {f} exceeds the MXU edge {MAX_FACTOR}: {fs}")
        return fs

    @property
    def num_dft_consts(self) -> int:
        """Operand count for the DFT constants: one (re, im) matrix pair per
        factor plus one (re, im) twiddle pair per inter-stage boundary."""
        k = len(self.factors())
        return 4 * k - 2


# ---------------------------------------------------------------------------
# DFT constants (host-side numpy; passed to the kernel as broadcast operands)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def dft_constants(*factors: int) -> tuple[np.ndarray, ...]:
    """DFT matrices and inter-stage twiddles for a mixed-radix factor list.

    Returns, split re/im and in order: one (f_i, f_i) DFT matrix per factor,
    then one (f_i, prod(f_{i+1:})) twiddle per non-final stage, where the
    stage-i twiddle is exp(-2j pi k_i j / prod(f_{i:})) — the classic
    four-step twiddle, applied recursively. For two factors this is exactly
    (F1, F2, tw(n1, n2)); three factors add F3 and a (f2, f3) twiddle.

    Memoized per factorization (the key is the factor tuple itself):
    ``build_spectral_call`` and every jit re-trace would otherwise rebuild
    the same numpy matrices — an O(n·f) host cost per trace that is pure
    waste, since the constants depend on nothing but the factors. The
    cached arrays are marked read-only so no caller can mutate the shared
    copies (``dft_constants.cache_info()`` is asserted in tests).
    """
    def dft(n):
        k = np.arange(n)
        m = np.exp(-2j * np.pi * np.outer(k, k) / n)
        return m.real.astype(np.float32), m.imag.astype(np.float32)

    out: list[np.ndarray] = []
    for f in factors:
        out.extend(dft(f))
    for i in range(len(factors) - 1):
        rest = int(np.prod(factors[i + 1:]))
        k = np.arange(factors[i])[:, None]
        j = np.arange(rest)[None, :]
        tw = np.exp(-2j * np.pi * k * j / (factors[i] * rest))
        out.append(tw.real.astype(np.float32))
        out.append(tw.imag.astype(np.float32))
    for a in out:
        a.setflags(write=False)
    return tuple(out)


# ---------------------------------------------------------------------------
# In-kernel complex helpers (split re/im)
# ---------------------------------------------------------------------------

def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _cast(x, precision: str):
    prec = PRECISIONS[precision]
    return x if prec.dtype == "float32" else x.astype(prec.jnp_dtype)


def _cdot(fr, fi, xr, xi, dims, *, karatsuba: bool, precision: str):
    """Complex dot_general: (fr + i fi) . (xr + i xi) with contraction `dims`.

    4 real matmuls, or 3 with Karatsuba (P3 = (Fr+Fi)(Xr+Xi)). f32 accumulate.
    """
    dg = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=(dims, ((), ())),
        preferred_element_type=jnp.float32,
    )
    fr_, fi_ = _cast(fr, precision), _cast(fi, precision)
    xr_, xi_ = _cast(xr, precision), _cast(xi, precision)
    if karatsuba:
        p1 = dg(fr_, xr_)
        p2 = dg(fi_, xi_)
        p3 = dg(_cast(fr + fi, precision), _cast(xr + xi, precision))
        return p1 - p2, p3 - p1 - p2
    yr = dg(fr_, xr_) - dg(fi_, xi_)
    yi = dg(fr_, xi_) + dg(fi_, xr_)
    return yr, yi


def _cdot_rhs(xr, xi, fr, fi, dims, *, karatsuba: bool, precision: str):
    """Complex dot_general with the DFT matrix on the right: X . F."""
    dg = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=(dims, ((), ())),
        preferred_element_type=jnp.float32,
    )
    fr_, fi_ = _cast(fr, precision), _cast(fi, precision)
    xr_, xi_ = _cast(xr, precision), _cast(xi, precision)
    if karatsuba:
        p1 = dg(xr_, fr_)
        p2 = dg(xi_, fi_)
        p3 = dg(_cast(xr + xi, precision), _cast(fr + fi, precision))
        return p1 - p2, p3 - p1 - p2
    yr = dg(xr_, fr_) - dg(xi_, fi_)
    yi = dg(xi_, fr_) + dg(xr_, fi_)
    return yr, yi


# ---------------------------------------------------------------------------
# Four-step matmul FFT, in-kernel (rows: transform the last axis of (L, N))
# ---------------------------------------------------------------------------

def _split_consts(consts, factors):
    """(per-stage DFT matrix pairs, per-boundary twiddle pairs)."""
    k = len(factors)
    mats = [(consts[2 * i], consts[2 * i + 1]) for i in range(k)]
    tws = [(consts[2 * k + 2 * i], consts[2 * k + 2 * i + 1])
           for i in range(k - 1)]
    return mats, tws


def _fft_rows_matmul(xr, xi, consts, spec: SpectralSpec):
    """Mixed-radix four-step FFT along the last axis of (L, N).

    Recursive Cooley-Tukey over spec.factors(): at stage i the length-m
    block (m = prod of the remaining factors) is reshaped to (f_i, m/f_i),
    contracted with the f_i-point DFT matrix on the MXU, twiddled, and the
    remainder transformed recursively. Two factors reproduce the classic
    four-step (stage A matmul, twiddle, stage C matmul) exactly.
    """
    factors = spec.factors()
    mats, tws = _split_consts(consts, factors)
    kw = dict(karatsuba=spec.karatsuba, precision=spec.precision)

    def rec(xr, xi, i):
        # xr/xi: (M, m) — transform the last axis, m = prod(factors[i:])
        M, m = xr.shape
        f = factors[i]
        fr, fi = mats[i]
        if i == len(factors) - 1:
            # base: one dense DFT matmul (DFT matrices are symmetric)
            return _cdot_rhs(xr, xi, fr, fi, ((1,), (0,)), **kw)
        rest = m // f
        x3r = xr.reshape(M, f, rest)
        x3i = xi.reshape(M, f, rest)
        # stage A: contract f with F_i -> (f, M, rest), index k_i first
        ar, ai = _cdot(fr, fi, x3r, x3i, ((1,), (1,)), **kw)
        twr, twi = tws[i]
        br, bi = _cmul(ar, ai, twr[:, None, :], twi[:, None, :])
        # recurse on the remaining length
        zr, zi = rec(br.reshape(f * M, rest), bi.reshape(f * M, rest), i + 1)
        zr = zr.reshape(f, M, rest)
        zi = zi.reshape(f, M, rest)
        # out[l, k_rest * f + k_i] = z[k_i, l, k_rest]
        return (jnp.transpose(zr, (1, 2, 0)).reshape(M, m),
                jnp.transpose(zi, (1, 2, 0)).reshape(M, m))

    return rec(xr, xi, 0)


def _fft_cols_matmul(xr, xi, consts, spec: SpectralSpec):
    """Mixed-radix four-step FFT along axis 0 of an (N, C) column slab —
    no global transpose needed (same recursion as rows, column layout)."""
    factors = spec.factors()
    mats, tws = _split_consts(consts, factors)
    kw = dict(karatsuba=spec.karatsuba, precision=spec.precision)

    def rec(xr, xi, i):
        # xr/xi: (m, C) — transform axis 0, m = prod(factors[i:])
        m, C = xr.shape
        f = factors[i]
        fr, fi = mats[i]
        if i == len(factors) - 1:
            return _cdot(fr, fi, xr, xi, ((1,), (0,)), **kw)
        rest = m // f
        x3r = xr.reshape(f, rest, C)
        x3i = xi.reshape(f, rest, C)
        # stage A: contract f with F_i -> (f, rest, C)
        ar, ai = _cdot(fr, fi, x3r, x3i, ((1,), (0,)), **kw)
        twr, twi = tws[i]
        br, bi = _cmul(ar, ai, twr[:, :, None], twi[:, :, None])
        # recurse along the remaining length: (rest, f*C)
        cr = jnp.transpose(br, (1, 0, 2)).reshape(rest, f * C)
        ci = jnp.transpose(bi, (1, 0, 2)).reshape(rest, f * C)
        zr, zi = rec(cr, ci, i + 1)
        # out[k_rest * f + k_i, c] = z[k_rest, k_i, c] — a plain reshape
        return zr.reshape(m, C), zi.reshape(m, C)

    return rec(xr, xi, 0)


# ---------------------------------------------------------------------------
# Stockham VPU FFT, in-kernel (the paper's 'scalar' baseline, radix-4 + radix-2)
# ---------------------------------------------------------------------------

def _fft_stockham(xr, xi, spec: SpectralSpec, axis: int):
    """Self-sorting Stockham along `axis` of a 2-D block, pure vector ops."""
    if axis == 0:  # operate on (N, C): move to (C, N), reuse rows code, move back
        yr, yi = _fft_stockham(xr.T, xi.T, spec, 1)
        return yr.T, yi.T
    L, N = xr.shape
    yr = xr.reshape(L, N, 1)
    yi = xi.reshape(L, N, 1)
    n, s = N, 1
    while n > 1:
        if n % 4 == 0:
            m = n // 4
            k = jax.lax.broadcasted_iota(jnp.float32, (m, 1), 0)
            th = (-2.0 * math.pi / n) * k
            w1r, w1i = jnp.cos(th), jnp.sin(th)
            w2r, w2i = _cmul(w1r, w1i, w1r, w1i)
            w3r, w3i = _cmul(w2r, w2i, w1r, w1i)
            sl = lambda z, q: z[:, q * m:(q + 1) * m, :]
            a_r, a_i = sl(yr, 0), sl(yi, 0)
            b_r, b_i = sl(yr, 1), sl(yi, 1)
            c_r, c_i = sl(yr, 2), sl(yi, 2)
            d_r, d_i = sl(yr, 3), sl(yi, 3)
            apc_r, apc_i = a_r + c_r, a_i + c_i
            amc_r, amc_i = a_r - c_r, a_i - c_i
            bpd_r, bpd_i = b_r + d_r, b_i + d_i
            bmd_r, bmd_i = b_r - d_r, b_i - d_i
            t0r, t0i = apc_r + bpd_r, apc_i + bpd_i
            # (amc - i*bmd) * w1
            u1r, u1i = amc_r + bmd_i, amc_i - bmd_r
            t1r, t1i = _cmul(u1r, u1i, w1r, w1i)
            # (apc - bpd) * w2
            t2r, t2i = _cmul(apc_r - bpd_r, apc_i - bpd_i, w2r, w2i)
            # (amc + i*bmd) * w3
            u3r, u3i = amc_r - bmd_i, amc_i + bmd_r
            t3r, t3i = _cmul(u3r, u3i, w3r, w3i)
            yr = jnp.stack([t0r, t1r, t2r, t3r], axis=2).reshape(L, m, 4 * s)
            yi = jnp.stack([t0i, t1i, t2i, t3i], axis=2).reshape(L, m, 4 * s)
            n, s = m, 4 * s
        else:
            m = n // 2
            k = jax.lax.broadcasted_iota(jnp.float32, (m, 1), 0)
            th = (-2.0 * math.pi / n) * k
            wr, wi = jnp.cos(th), jnp.sin(th)
            a_r, a_i = yr[:, :m, :], yi[:, :m, :]
            b_r, b_i = yr[:, m:, :], yi[:, m:, :]
            t1r, t1i = _cmul(a_r - b_r, a_i - b_i, wr, wi)
            yr = jnp.stack([a_r + b_r, t1r], axis=2).reshape(L, m, 2 * s)
            yi = jnp.stack([a_i + b_i, t1i], axis=2).reshape(L, m, 2 * s)
            n, s = m, 2 * s
    return yr.reshape(L, N), yi.reshape(L, N)


# ---------------------------------------------------------------------------
# The fused kernel body: [FFT] -> [multiply] -> [IFFT], one dispatch
# ---------------------------------------------------------------------------

def _run_fft(xr, xi, consts, spec: SpectralSpec, inverse: bool):
    """Forward or inverse (conj-FFT-conj) transform along spec.axis.

    x is a (Bb, L, n) / (Bb, n, L) batch block: the batch dim folds into
    the line dim for the transform (scenes are independent lines), so one
    grid step's matmuls span Bb * L lines — THE amortization: DFT constants
    are loaded once per step and shared by every scene in the block.
    """
    bb = xr.shape[0]
    if spec.axis == 1:
        # (Bb, L, n) -> (Bb*L, n): contiguous, a free reshape
        xr2 = xr.reshape(bb * xr.shape[1], xr.shape[2])
        xi2 = xi.reshape(bb * xi.shape[1], xi.shape[2])
    else:
        # (Bb, n, L) -> (n, Bb*L): the scene axis must stay leading
        xr2 = jnp.moveaxis(xr, 0, 1).reshape(xr.shape[1], bb * xr.shape[2])
        xi2 = jnp.moveaxis(xi, 0, 1).reshape(xi.shape[1], bb * xi.shape[2])
    if inverse:
        xi2 = -xi2
    if spec.fft_impl == "matmul":
        fft = _fft_rows_matmul if spec.axis == 1 else _fft_cols_matmul
        yr, yi = fft(xr2, xi2, consts, spec)
    elif spec.fft_impl == "stockham":
        yr, yi = _fft_stockham(xr2, xi2, spec, spec.axis)
    else:
        raise ValueError(f"unknown fft_impl {spec.fft_impl}")
    if inverse:
        # conj + 1/N, folded into the final store (paper SSII-C)
        scale = 1.0 / spec.n
        yr, yi = yr * scale, yi * (-scale)
    if spec.axis == 1:
        return yr.reshape(xr.shape), yi.reshape(xi.shape)
    yr = jnp.moveaxis(yr.reshape(xr.shape[1], bb, xr.shape[2]), 1, 0)
    yi = jnp.moveaxis(yi.reshape(xi.shape[1], bb, xi.shape[2]), 1, 0)
    return yr, yi


def _filter_ref_count(filter_mode: str) -> int:
    """Operand count of one kernel filter payload, by mode."""
    return {FILTER_NONE: 0, FILTER_SHARED: 2, FILTER_FULL: 2,
            FILTER_OUTER: 2, FILTER_SHARED_OUTER: 4}[filter_mode]


def _apply_filters(xr, xi, axis: int, filter_mode: str, filt):
    """Apply one composed kernel filter to an (..., L, n) / (..., n, L)
    block. ``filt`` holds the mode's refs or arrays (hr/hi, u/v, or both);
    2-D payloads broadcast right-aligned over any leading batch dim."""

    def _apply_outer(xr, xi, u_ref, v_ref):
        u = u_ref[...]      # rows: (L, K); cols: (K, C)  — per-line parameters
        v = v_ref[...]      # rows: (K, N); cols: (N, K)  — per-sample parameters
        # rank-K phase synthesized in VMEM (no 2-D filter I/O); the 2-D
        # phase broadcasts across the leading batch-block dim
        if axis == 1:
            phase = jax.lax.dot_general(
                u, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        else:
            phase = jax.lax.dot_general(
                v, u, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        return _cmul(xr, xi, jnp.cos(phase), jnp.sin(phase))

    if filter_mode in (FILTER_SHARED, FILTER_FULL):
        # FILTER_SHARED blocks are (1, N) [rows] or (N, 1) [cols]: broadcast.
        xr, xi = _cmul(xr, xi, filt[0][...], filt[1][...])
    elif filter_mode == FILTER_OUTER:
        xr, xi = _apply_outer(xr, xi, filt[0], filt[1])
    elif filter_mode == FILTER_SHARED_OUTER:
        xr, xi = _cmul(xr, xi, filt[0][...], filt[1][...])
        xr, xi = _apply_outer(xr, xi, filt[2], filt[3])
    return xr, xi


def line_exponents(xr, xi, axis: int):
    """bs16 codec, extract half: one power-of-two exponent per line along
    the free axis of `axis`-oriented data, reduced over the transform axis
    (the last dim when axis=1, the second-to-last when axis=0; any leading
    dims are batch). Each segment is linear per line, so scales factored
    out per line and re-applied in the epilogue are exact up to f32
    rounding — and power-of-two scaling is itself bit-exact.

    Per-line granularity is the route-invisibility property: the exponent
    of a line depends only on that line's values, never on how the grid
    blocked lines/batches/phases or how devices sharded the free axis, so
    every route quantizes identically (asserted across fused3 / fused1
    vmem+staged / 8-device sharded in tests/test_quality_regression.py).
    The 1e-37 floor keeps all-zero (e.g. padded) lines at a finite
    exponent; zero stays exactly zero through scale and unscale. The
    clamp to [-126, 126] keeps `_pow2` exact for BOTH exp and -exp
    (an amax past 2^126 would have overflowed the FFT long before)."""
    red = xr.ndim - 1 if axis == 1 else xr.ndim - 2
    amax = jnp.maximum(jnp.max(jnp.abs(xr), axis=red, keepdims=True),
                       jnp.max(jnp.abs(xi), axis=red, keepdims=True))
    exp = jnp.ceil(jnp.log2(jnp.maximum(amax, jnp.float32(1e-37))))
    return jnp.clip(exp, jnp.float32(-126.0), jnp.float32(126.0))


def _pow2(exp):
    """Exactly 2^exp for integer-valued f32 exp in [-126, 126], built by
    placing exp straight into the f32 exponent bits. `jnp.exp2` is NOT
    exact on every backend (CPU lowers it through exp(x·ln2), so e.g.
    exp2(17) != 131072), and an inexact scale would break the codec's
    round-trip identity (tests/test_kernels.py::test_bs16_codec_round_trip)."""
    bits = (exp.astype(jnp.int32) + 127) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def apply_exponents(xr, xi, exp):
    """bs16 codec, apply half: fold per-line exponents back in (exact)."""
    scale = _pow2(exp)
    return xr * scale, xi * scale


def remove_exponents(xr, xi, exp):
    """Scale per-line exponents out (exact): x -> x * 2^-exp."""
    inv = _pow2(-exp)
    return xr * inv, xi * inv


def _spectral_kernel(spec: SpectralSpec, *refs):
    """Pallas kernel body. Ref layout (in order):

    xr, xi, [DFT matrices + twiddles if matmul], [filter refs...], or, oi

    The x/output refs are (Bb, L, n) rows / (Bb, n, L) cols batch blocks:
    each grid step holds the SAME line-block of every scene in the batch
    block, so the DFT constants and filters are shared across scenes (the
    2-D filters broadcast right-aligned over the leading batch dim).
    """
    it = iter(refs)
    xr_ref, xi_ref = next(it), next(it)
    consts = None
    if spec.fft_impl == "matmul" and (spec.fwd or spec.inv):
        consts = tuple(next(it)[...] for _ in range(spec.num_dft_consts))
    filt = tuple(next(it) for _ in range(_filter_ref_count(spec.filter_mode)))
    or_ref, oi_ref = next(it), next(it)

    xr = xr_ref[...]
    xi = xi_ref[...]

    exp = None
    if PRECISIONS[spec.precision].block_scaled:
        exp = line_exponents(xr, xi, spec.axis)
        xr, xi = remove_exponents(xr, xi, exp)

    if spec.fwd:
        xr, xi = _run_fft(xr, xi, consts, spec, inverse=False)

    xr, xi = _apply_filters(xr, xi, spec.axis, spec.filter_mode, filt)

    if spec.inv:
        xr, xi = _run_fft(xr, xi, consts, spec, inverse=True)

    if exp is not None:
        # bs16 epilogue: fold the per-line exponents back into the store
        xr, xi = apply_exponents(xr, xi, exp)

    or_ref[...] = xr.reshape(or_ref.shape)
    oi_ref[...] = xi.reshape(oi_ref.shape)


# ---------------------------------------------------------------------------
# pallas_call builder
# ---------------------------------------------------------------------------

def _flops_per_line(spec: SpectralSpec) -> float:
    """Nominal 5 N log2 N per transform + 6N per complex multiply (for benches)."""
    n = spec.n
    f = 0.0
    if spec.fwd:
        f += 5.0 * n * math.log2(n)
    if spec.inv:
        f += 5.0 * n * math.log2(n)
    if spec.filter_mode != FILTER_NONE:
        f += 6.0 * n
    return f


def build_spectral_call(spec: SpectralSpec, lines: int, batch: int = 1,
                        interpret: bool = False, config=None):
    """Returns fn(xr, xi, *filter_args) -> (yr, yi) as a single pallas_call.

    ``config`` is an optional :class:`repro.tuning.KernelConfig`: its
    non-None knobs (block, n1/n2/n3, karatsuba, precision) are applied on
    top of ``spec`` before the call is built — the one config path from
    the tuning subsystem into the kernel layer. (Duck-typed through
    ``config.apply(spec)``; kernels do not import repro.tuning.)

    Rows pipeline: x is (B, lines, N), cols pipeline: x is (B, N, lines).
    The grid runs over (batch-blocks, line-blocks) with each grid step
    holding a (Bb, L, N) slab — the same line-block of Bb scenes at once —
    so the DFT-constant loads and the per-step dispatch overhead amortize
    across the batch (spec.batch_block defaults to the whole batch; cap it
    when Bb * L * N would overflow VMEM). Filters are 2-D and batch-shared
    (every scene uses the same SceneConfig filters).
    """
    if config is not None:
        spec = config.apply(spec)
    n = spec.n
    L = spec.block
    if lines % L:
        raise ValueError(f"lines={lines} not divisible by block={L}")
    Bb = spec.batch_block or batch
    if batch % Bb:
        raise ValueError(f"batch={batch} not divisible by batch_block={Bb}")
    grid = (batch // Bb, lines // L)

    K = spec.outer_rank
    if spec.axis == 1:
        x_shape = (batch, lines, n)
        x_spec = pl.BlockSpec((Bb, L, n), lambda b, i: (b, i, 0))
        shared_spec = pl.BlockSpec((1, n), lambda b, i: (0, 0))
        full_spec = pl.BlockSpec((L, n), lambda b, i: (i, 0))
        u_spec = pl.BlockSpec((L, K), lambda b, i: (i, 0))   # (lines, K)
        v_spec = pl.BlockSpec((K, n), lambda b, i: (0, 0))   # (K, n)
    else:
        x_shape = (batch, n, lines)
        x_spec = pl.BlockSpec((Bb, n, L), lambda b, i: (b, 0, i))
        shared_spec = pl.BlockSpec((n, 1), lambda b, i: (0, 0))
        full_spec = pl.BlockSpec((n, L), lambda b, i: (0, i))
        u_spec = pl.BlockSpec((K, L), lambda b, i: (0, i))   # (K, lines)
        v_spec = pl.BlockSpec((n, K), lambda b, i: (0, 0))   # (n, K)

    in_specs = [x_spec, x_spec]
    extra_args: list[jnp.ndarray] = []

    needs_consts = spec.fft_impl == "matmul" and (spec.fwd or spec.inv)
    if needs_consts:
        consts = dft_constants(*spec.factors())
        in_specs += [pl.BlockSpec(c.shape, lambda b, i: (0, 0))
                     for c in consts]
        extra_args += [jnp.asarray(c) for c in consts]

    if spec.filter_mode == FILTER_SHARED:
        in_specs += [shared_spec, shared_spec]
    elif spec.filter_mode == FILTER_FULL:
        in_specs += [full_spec, full_spec]
    elif spec.filter_mode == FILTER_OUTER:
        in_specs += [u_spec, v_spec]
    elif spec.filter_mode == FILTER_SHARED_OUTER:
        in_specs += [shared_spec, shared_spec, u_spec, v_spec]

    out_specs = [x_spec, x_spec]
    out_shape = [
        jax.ShapeDtypeStruct(x_shape, jnp.float32),
        jax.ShapeDtypeStruct(x_shape, jnp.float32),
    ]

    kernel = functools.partial(_spectral_kernel, spec)
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )

    def fn(xr, xi, *filter_args):
        args = [xr, xi] + extra_args + list(filter_args)
        return call(*args)

    fn.flops = _flops_per_line(spec) * lines * batch  # nominal, for benches
    return fn


# ---------------------------------------------------------------------------
# The single-dispatch 2-D megakernel: fft? mul* ifft? (turn fft? mul* ifft?)*
# ---------------------------------------------------------------------------
#
# The paper's headline is ONE dispatch for the whole imaging chain with every
# intermediate on-chip. The per-axis kernel above still forces one dispatch
# per transform axis because the range->azimuth corner turn is a fusion
# barrier. The megakernel removes it: a single pallas_call runs an arbitrary
# sequence of per-axis spectral *segments* (each `fft? mul* ifft?`, composed
# filters included) with the corner turns INSIDE the kernel, in one of two
# residency modes:
#
# RESIDENT_VMEM   The whole (Bb, na, nr) slab lives in VMEM for the entire
#                 grid step; a "turn" is purely logical (the cols transform
#                 contracts axis 0 of the same slab — no data movement).
#                 Zero HBM intermediates: the paper's claim realized on TPU,
#                 for scenes whose slab fits the ~16 MiB budget. The TPU
#                 analogue of the Radix-8 Stockham two-tier register/
#                 threadgroup decomposition (arXiv 2603.27569) — VMEM plays
#                 the register tier.
# RESIDENT_STAGED Large scenes: one dispatch whose grid is split into one
#                 phase per segment. Each phase strips its free axis in
#                 `phase_block`-line blocks, manually DMA-staged between an
#                 HBM scratch buffer (the corner-turned intermediate) and
#                 double-buffered VMEM slabs, so the corner-turn DMA of
#                 block j+1 overlaps the DFT matmuls of block j. Bergach et
#                 al. (arXiv 1505.08067) show the global transpose, not the
#                 butterflies, dominates radar FFT pipelines — this schedule
#                 hides it behind compute instead of spending a dispatch +
#                 full HBM round-trip per axis change.
#
# Numerics: both modes run the exact same per-segment math as the per-axis
# kernel (same _run_fft, same filter application, same constants), and every
# segment treats its line blocks independently — so f32 results are
# bit-identical between the two modes AND to the equivalent multi-dispatch
# pipeline (asserted in tests/test_fused1.py). bs16 carries PER-LINE block
# exponents through the in-kernel corner turns: each segment boundary
# re-blocks (apply the carried exponents — exact power-of-two scaling —
# then re-extract along the new segment's free axis), which reproduces the
# multi-dispatch pipeline's per-dispatch extraction bit for bit. Because a
# line's exponent never depends on the grid blocking, bs16 is bit-identical
# across both residency modes, the per-axis chain, and the sharded lowering
# (tests/test_quality_regression.py, tests/test_service.py).


@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    """One per-axis `fft? mul* ifft?` run inside a megakernel dispatch.

    The per-segment scheduling fields (``n1/n2/n3``, ``karatsuba``) let a
    tuned Schedule give EACH segment its own factorization and complex-
    product algorithm — the part of the schedule space a single global
    MegaSpec knob cannot express. ``None`` defers to the MegaSpec-level
    value (and from there to the library default), so legacy specs are
    unchanged."""

    axis: int                      # scene axis: 1 = range/rows, 0 = azimuth/cols
    fwd: bool = False
    inv: bool = False
    filter_mode: str = FILTER_NONE
    outer_rank: int = 1
    n1: Optional[int] = None       # per-segment factorization override
    n2: Optional[int] = None
    n3: Optional[int] = None
    karatsuba: Optional[bool] = None   # tri-state: None defers to MegaSpec


@dataclasses.dataclass(frozen=True)
class MegaSpec:
    """Static configuration of one single-dispatch 2-D megakernel."""

    na: int                        # azimuth lines (axis-0 FFT length)
    nr: int                        # range samples (axis-1 FFT length)
    segments: tuple[SegmentSpec, ...]
    residency: str = RESIDENT_VMEM
    batch_block: Optional[int] = None  # scenes per grid step, vmem mode
                                       # (None = 1: one scene slab per step
                                       # keeps the VMEM cut batch-invariant;
                                       # constants stay resident across
                                       # steps — their block never moves)
    phase_block: int = 8           # lines per staged-phase grid step
    buffer_depth: int = 2          # staged DMA slots (1 = no overlap)
    n1: Optional[int] = None       # range-axis factorization override
    n2: Optional[int] = None       #   (azimuth uses default_factorization;
    n3: Optional[int] = None       #    same convention as compile_plan's fft_kw)
    fft_impl: str = "matmul"
    karatsuba: bool = False
    precision: str = "f32"

    def __post_init__(self):
        if not self.segments:
            raise ValueError("MegaSpec needs at least one segment")
        if self.residency not in (RESIDENT_VMEM, RESIDENT_STAGED):
            raise ValueError(f"unknown residency {self.residency!r}")
        if self.buffer_depth < 1:
            raise ValueError(
                f"buffer_depth must be >= 1, got {self.buffer_depth}")
        for s in self.segments:
            if s.axis not in (0, 1):
                raise ValueError(f"segment axis must be 0 or 1, got {s.axis}")
            if not (s.fwd or s.inv or s.filter_mode != FILTER_NONE):
                raise ValueError("empty megakernel segment")
        resolve_precision(self.precision)

    def seg_spec(self, seg: SegmentSpec) -> SpectralSpec:
        """The per-axis SpectralSpec view of one segment (drives _run_fft
        and the DFT-constant layout — numerics identical to the per-axis
        kernel by construction). Factorization precedence: the segment's
        own override > the MegaSpec range-axis knobs (axis 1 only, the
        compile_plan fft_kw convention) > library default; karatsuba:
        segment override > MegaSpec global."""
        kw = {}
        if seg.axis == 1:
            kw = dict(n1=self.n1, n2=self.n2, n3=self.n3)
        if seg.n1 is not None:
            kw = dict(n1=seg.n1, n2=seg.n2, n3=seg.n3)
        kara = self.karatsuba if seg.karatsuba is None else seg.karatsuba
        return SpectralSpec(
            n=self.nr if seg.axis == 1 else self.na,
            fwd=seg.fwd, inv=seg.inv, filter_mode=seg.filter_mode,
            axis=seg.axis, fft_impl=self.fft_impl, karatsuba=kara,
            precision=self.precision, outer_rank=seg.outer_rank, **kw)

    @property
    def turns(self) -> int:
        """In-kernel corner turns (axis changes between segments)."""
        return sum(1 for a, b in zip(self.segments, self.segments[1:])
                   if a.axis != b.axis)


def _seg_const_key(spec: MegaSpec, seg: SegmentSpec) -> tuple:
    """The constants-sharing key of one segment: (axis, factorization).
    Segments on one axis share one broadcast-operand set ONLY while they
    agree on the factorization — a schedule that gives two same-axis
    segments different radix splits gets one set each."""
    return (seg.axis, spec.seg_spec(seg).factors())


def _mega_const_plan(spec: MegaSpec) -> list[tuple[tuple, tuple]]:
    """((axis, factors), dft_constants) per distinct transformed
    (axis, factorization), in first-use order — one set of broadcast
    operands shared by every segment (and every scene in the batch block)
    that transforms that axis with those factors."""
    out: list[tuple[tuple, tuple]] = []
    if spec.fft_impl != "matmul":
        return out
    seen = set()
    for seg in spec.segments:
        key = _seg_const_key(spec, seg)
        if (seg.fwd or seg.inv) and key not in seen:
            seen.add(key)
            out.append((key, dft_constants(*key[1])))
    return out


def _seg_filter_shapes(spec: MegaSpec, seg: SegmentSpec) -> list[tuple]:
    """Kernel-layout shapes of one segment's filter operands (whole-scene
    blocks; the megakernel never line-blocks its filters)."""
    na, nr, K = spec.na, spec.nr, seg.outer_rank
    if seg.axis == 1:
        shared, full = (1, nr), (na, nr)
        u, v = (na, K), (K, nr)
    else:
        shared, full = (na, 1), (na, nr)
        u, v = (K, nr), (na, K)
    return {
        FILTER_NONE: [],
        FILTER_SHARED: [shared, shared],
        FILTER_FULL: [full, full],
        FILTER_OUTER: [u, v],
        FILTER_SHARED_OUTER: [shared, shared, u, v],
    }[seg.filter_mode]


def _run_segment(xr, xi, consts, sspec: SpectralSpec, seg: SegmentSpec, filt):
    """One segment on a (Bb, na, nr) slab — the (Bb, L, n) rows layout and
    the (Bb, n, L) cols layout are BOTH the scene layout, so the corner
    turn between segments is purely logical."""
    if seg.fwd:
        xr, xi = _run_fft(xr, xi, consts, sspec, inverse=False)
    xr, xi = _apply_filters(xr, xi, seg.axis, seg.filter_mode, filt)
    if seg.inv:
        xr, xi = _run_fft(xr, xi, consts, sspec, inverse=True)
    return xr, xi


def _mega_kernel_resident(spec: MegaSpec, *refs):
    """VMEM-resident megakernel body. Ref order: xr, xi, [per-axis DFT
    constants], [per-segment filter refs], or, oi. The grid step holds a
    whole (Bb, na, nr) slab; every intermediate stays in VMEM."""
    it = iter(refs)
    xr_ref, xi_ref = next(it), next(it)
    const_plan = _mega_const_plan(spec)
    consts = {key: tuple(next(it)[...] for _ in range(len(cs)))
              for key, cs in const_plan}
    seg_filts = [tuple(next(it)
                       for _ in range(_filter_ref_count(s.filter_mode)))
                 for s in spec.segments]
    or_ref, oi_ref = next(it), next(it)

    xr = xr_ref[...]
    xi = xi_ref[...]
    block_scaled = PRECISIONS[spec.precision].block_scaled
    exp = None
    for i, (seg, filt) in enumerate(zip(spec.segments, seg_filts)):
        if block_scaled:
            if i == 0:
                # prologue: extract per-line exponents once per grid step
                exp = line_exponents(xr, xi, seg.axis)
            else:
                # corner turn (or same-axis boundary): re-block the carried
                # exponents alongside the data — apply exactly, re-extract
                # along the new free axis. Re-blocking at EVERY boundary
                # (not just axis changes) mirrors the multi-dispatch
                # pipeline's per-dispatch extraction, keeping the fused
                # route bit-identical to it.
                xr, xi = apply_exponents(xr, xi, exp)
                exp = line_exponents(xr, xi, seg.axis)
            xr, xi = remove_exponents(xr, xi, exp)
        xr, xi = _run_segment(xr, xi, consts.get(_seg_const_key(spec, seg)),
                              spec.seg_spec(seg), seg, filt)
    if exp is not None:
        # epilogue: the carried exponents land once, at the final store
        xr, xi = apply_exponents(xr, xi, exp)
    or_ref[...] = xr.reshape(or_ref.shape)
    oi_ref[...] = xi.reshape(oi_ref.shape)


def _staged_phases(spec: MegaSpec) -> tuple[list[dict], int]:
    """Static phase schedule of the scratch-staged megakernel: one phase
    per segment, stripping its free axis in `phase_block`-line blocks.
    Returns (phases, total grid steps). Phase p reads from the raw input
    (p=0) or the HBM scratch, and writes to the output (last p) or back
    to the scratch — in-place when the axis repeats, corner-turned when
    it flips (col-blocks written, row-blocks read, or vice versa)."""
    phases: list[dict] = []
    off = 0
    last = len(spec.segments) - 1
    for i, seg in enumerate(spec.segments):
        lines = spec.na if seg.axis == 1 else spec.nr
        pb = min(spec.phase_block, lines)
        if lines % pb:
            raise ValueError(
                f"phase_block={pb} does not divide the free axis "
                f"({lines} lines) of segment {i}")
        phases.append(dict(
            seg=seg, idx=i, axis=seg.axis, pb=pb, nblocks=lines // pb,
            offset=off, src="x" if i == 0 else "scratch",
            dst="out" if i == last else "scratch"))
        off += lines // pb
    return phases, off


# DMA semaphore channels of the staged kernel, per double-buffer slot.
_SEM_IN_R, _SEM_IN_I, _SEM_F_R, _SEM_F_I, _SEM_OUT_R, _SEM_OUT_I = range(6)


def _mega_kernel_staged(spec: MegaSpec, *refs):
    """Scratch-staged megakernel body — grid (B, total_steps).

    Ref order: xr, xi (ANY), [per-axis DFT constants (VMEM)],
    [per-segment filters: FULL pairs in ANY (DMA-sliced with the line
    block), everything else resident in VMEM], or, oi (ANY), then
    scratch: sr, si (ANY — the HBM corner-turn intermediate), the
    double-buffered VMEM line slabs (rows and/or cols orientation, plus
    FULL-filter slabs where needed), the bs16 per-line exponent-state
    vectors er (na, 1) / ec (1, nr) when the precision is block-scaled,
    and the DMA semaphores (2 slots x 6
    channels). Each step waits for its own slot's input DMA, immediately
    starts the NEXT block's input DMA into the other slot, then runs the
    segment's DFT matmuls — the copy/compute overlap the dispatch count
    alone cannot buy.
    """
    phases, _ = _staged_phases(spec)
    it = iter(refs)
    xr_ref, xi_ref = next(it), next(it)
    const_plan = _mega_const_plan(spec)
    consts = {key: tuple(next(it)[...] for _ in range(len(cs)))
              for key, cs in const_plan}
    seg_filts = [tuple(next(it)
                       for _ in range(_filter_ref_count(s.filter_mode)))
                 for s in spec.segments]
    or_ref, oi_ref = next(it), next(it)
    sr_ref, si_ref = next(it), next(it)
    bufs = {}
    if any(p["axis"] == 1 for p in phases):
        bufs[1] = next(it)
    if any(p["axis"] == 0 for p in phases):
        bufs[0] = next(it)
    fbufs = {}
    if any(p["axis"] == 1 and p["seg"].filter_mode == FILTER_FULL
           for p in phases):
        fbufs[1] = next(it)
    if any(p["axis"] == 0 and p["seg"].filter_mode == FILTER_FULL
           for p in phases):
        fbufs[0] = next(it)
    block_scaled = PRECISIONS[spec.precision].block_scaled
    er_ref = ec_ref = None
    if block_scaled:
        # carried per-line exponent state (bs16): the row-axis and
        # col-axis exponent vectors persist in VMEM across the sequential
        # phase steps (the same cross-step scratch persistence the
        # double-buffer prefetch relies on), so the HBM scratch holds
        # SCALED data end to end and the exponents ride the corner turn
        # in these vectors instead of being re-derived from scratch reads.
        er_ref = next(it)              # (na, 1): axis-1 (row) exponents
        ec_ref = next(it)              # (1, nr): axis-0 (col) exponents
    sems = next(it)

    b = pl.program_id(0)
    s = pl.program_id(1)

    def _sliced(ref, axis: int, lo, pb: int, batched: bool):
        """A (pb, nr) row / (na, pb) col slab slice of a scene ref."""
        if axis == 1:
            return ref.at[b, pl.ds(lo, pb), :] if batched \
                else ref.at[pl.ds(lo, pb), :]
        return ref.at[b, :, pl.ds(lo, pb)] if batched \
            else ref.at[:, pl.ds(lo, pb)]

    for p in phases:
        seg, axis, pb = p["seg"], p["axis"], p["pb"]
        off, nb = p["offset"], p["nblocks"]
        prev_axis = phases[p["idx"] - 1]["axis"] if p["idx"] else None
        buf = bufs[axis]
        fbuf = fbufs.get(axis)
        sspec = spec.seg_spec(seg)
        filt_refs = seg_filts[p["idx"]]
        has_full = seg.filter_mode == FILTER_FULL
        src_r, src_i = ((xr_ref, xi_ref) if p["src"] == "x"
                        else (sr_ref, si_ref))
        dst_r, dst_i = ((or_ref, oi_ref) if p["dst"] == "out"
                        else (sr_ref, si_ref))
        src_batched = p["src"] == "x"
        dst_batched = p["dst"] == "out"

        def in_copies(j, slot, seg=seg, axis=axis, pb=pb, buf=buf, fbuf=fbuf,
                      src_r=src_r, src_i=src_i, src_batched=src_batched,
                      filt_refs=filt_refs, has_full=has_full):
            lo = j * pb
            cps = [
                pltpu.make_async_copy(
                    _sliced(src_r, axis, lo, pb, src_batched),
                    buf.at[slot, 0], sems.at[slot, _SEM_IN_R]),
                pltpu.make_async_copy(
                    _sliced(src_i, axis, lo, pb, src_batched),
                    buf.at[slot, 1], sems.at[slot, _SEM_IN_I]),
            ]
            if has_full:
                cps += [
                    pltpu.make_async_copy(
                        _sliced(filt_refs[0], axis, lo, pb, False),
                        fbuf.at[slot, 0], sems.at[slot, _SEM_F_R]),
                    pltpu.make_async_copy(
                        _sliced(filt_refs[1], axis, lo, pb, False),
                        fbuf.at[slot, 1], sems.at[slot, _SEM_F_I]),
                ]
            return cps

        @pl.when((s >= off) & (s < off + nb))
        def _(p=p, seg=seg, axis=axis, pb=pb, off=off, nb=nb, buf=buf,
              fbuf=fbuf, sspec=sspec, filt_refs=filt_refs,
              has_full=has_full, dst_r=dst_r, dst_i=dst_i,
              dst_batched=dst_batched, in_copies=in_copies,
              prev_axis=prev_axis):
            j = s - off
            depth = spec.buffer_depth
            if depth == 1:
                # single slot: no copy/compute overlap — fetch, wait, run
                slot = 0
                for cp in in_copies(j, 0):
                    cp.start()
                for cp in in_copies(j, 0):
                    cp.wait()
            else:
                slot = jax.lax.rem(j, depth)

                @pl.when(j == 0)
                def _():                   # phase start: blocking first fetch
                    for cp in in_copies(0, 0):
                        cp.start()
                for cp in in_copies(j, slot):
                    cp.wait()
                @pl.when(j + 1 < nb)
                def _():                   # prefetch overlaps the matmuls
                    for cp in in_copies(j + 1, jax.lax.rem(j + 1, depth)):
                        cp.start()

            xr = buf[slot, 0][None]
            xi = buf[slot, 1][None]
            lo = j * pb
            exp = None
            if block_scaled:
                if p["src"] != "x":
                    # the scratch slab is scaled: unscale this block with
                    # the exponent state the previous phase wrote — its
                    # own lines' slice when the axis repeats, the whole
                    # other-axis vector across a corner turn (every
                    # element of a turned block crosses every prior line)
                    if prev_axis == 1:
                        old = (er_ref[pl.ds(lo, pb), :] if axis == 1
                               else er_ref[...])
                    else:
                        old = (ec_ref[:, pl.ds(lo, pb)] if axis == 0
                               else ec_ref[...])
                    xr, xi = apply_exponents(xr, xi, old[None])
                # re-block: per-line exponents along THIS phase's free
                # axis — identical to the per-dispatch extraction of the
                # multi-dispatch pipeline, hence route-invisible
                exp = line_exponents(xr, xi, axis)
                xr, xi = remove_exponents(xr, xi, exp)
                if axis == 1:
                    er_ref[pl.ds(lo, pb), :] = exp[0]
                else:
                    ec_ref[:, pl.ds(lo, pb)] = exp[0]
            if seg.filter_mode == FILTER_NONE:
                filt = ()
            elif has_full:
                filt = (fbuf[slot, 0], fbuf[slot, 1])
            elif seg.filter_mode == FILTER_SHARED:
                filt = (filt_refs[0][...], filt_refs[1][...])
            else:
                # OUTER / SHARED_OUTER: the per-line u factor is sliced to
                # the block in VMEM; shared vectors and v ride whole.
                if axis == 1:
                    u = filt_refs[-2][pl.ds(lo, pb), :]
                    v = filt_refs[-1][...]
                else:
                    u = filt_refs[-2][:, pl.ds(lo, pb)]
                    v = filt_refs[-1][...]
                if seg.filter_mode == FILTER_SHARED_OUTER:
                    filt = (filt_refs[0][...], filt_refs[1][...], u, v)
                else:
                    filt = (u, v)
            xr, xi = _run_segment(xr, xi, consts.get(_seg_const_key(spec, seg)),
                                  sspec, seg, filt)
            if exp is not None and p["dst"] == "out":
                # epilogue: the exponents land once, at the final store;
                # scratch-bound intermediates stay scaled (the carried
                # state rides er/ec through the corner turn instead)
                xr, xi = apply_exponents(xr, xi, exp)
            buf[slot, 0] = xr[0]
            buf[slot, 1] = xi[0]
            out_r = pltpu.make_async_copy(
                buf.at[slot, 0], _sliced(dst_r, axis, lo, pb, dst_batched),
                sems.at[slot, _SEM_OUT_R])
            out_i = pltpu.make_async_copy(
                buf.at[slot, 1], _sliced(dst_i, axis, lo, pb, dst_batched),
                sems.at[slot, _SEM_OUT_I])
            out_r.start()
            out_i.start()
            out_r.wait()
            out_i.wait()


def _mega_flops(spec: MegaSpec) -> float:
    """Nominal algorithmic FLOPs of one scene through every segment."""
    total = 0.0
    for seg in spec.segments:
        lines = spec.na if seg.axis == 1 else spec.nr
        total += _flops_per_line(spec.seg_spec(seg)) * lines
    return total


def build_mega_call(spec: MegaSpec, batch: int = 1,
                    interpret: bool = False):
    """Returns fn(xr, xi, *filter_args) -> (yr, yi): the WHOLE multi-axis
    spectral pipeline as one pallas_call.

    x is a (batch, na, nr) split re/im float32 scene batch; filter_args
    are the per-segment payloads in segment order, each in kernel layout
    (see :func:`_seg_filter_shapes` — the `ops.mega_spectral_op` wrapper
    handles scene-coordinate reshapes and batching sugar).

    residency RESIDENT_VMEM  : grid over batch blocks, whole (Bb, na, nr)
      slab in VMEM per step, zero HBM intermediates.
    residency RESIDENT_STAGED: grid (batch, phase steps), manual
      double-buffered DMA against an HBM scratch intermediate (see
      :func:`_mega_kernel_staged`).
    """
    na, nr = spec.na, spec.nr
    const_plan = _mega_const_plan(spec)
    const_arrays = [jnp.asarray(c) for _, cs in const_plan for c in cs]
    x_shape = (batch, na, nr)
    out_shape = [
        jax.ShapeDtypeStruct(x_shape, jnp.float32),
        jax.ShapeDtypeStruct(x_shape, jnp.float32),
    ]

    if spec.residency == RESIDENT_VMEM:
        bb = spec.batch_block or 1
        if batch % bb:
            raise ValueError(
                f"batch={batch} not divisible by batch_block={bb}")
        x_spec = pl.BlockSpec((bb, na, nr), lambda b: (b, 0, 0))
        in_specs = [x_spec, x_spec]
        in_specs += [pl.BlockSpec(c.shape, lambda b: (0, 0))
                     for c in const_arrays]
        for seg in spec.segments:
            in_specs += [pl.BlockSpec(shape, lambda b: (0, 0))
                         for shape in _seg_filter_shapes(spec, seg)]
        call = pl.pallas_call(
            functools.partial(_mega_kernel_resident, spec),
            grid=(batch // bb,),
            in_specs=in_specs,
            out_specs=[x_spec, x_spec],
            out_shape=out_shape,
            interpret=interpret,
        )
    else:
        phases, steps = _staged_phases(spec)
        any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        in_specs = [any_spec, any_spec]
        in_specs += [pl.BlockSpec(c.shape, lambda b, s: (0, 0))
                     for c in const_arrays]
        for seg in spec.segments:
            if seg.filter_mode == FILTER_FULL:
                in_specs += [any_spec, any_spec]
            else:
                in_specs += [pl.BlockSpec(shape, lambda b, s: (0, 0))
                             for shape in _seg_filter_shapes(spec, seg)]
        pb_r = next((p["pb"] for p in phases if p["axis"] == 1), None)
        pb_c = next((p["pb"] for p in phases if p["axis"] == 0), None)
        depth = spec.buffer_depth
        scratch = [pltpu.ANY((na, nr), jnp.float32),
                   pltpu.ANY((na, nr), jnp.float32)]
        if pb_r is not None:
            scratch.append(pltpu.VMEM((depth, 2, pb_r, nr), jnp.float32))
        if pb_c is not None:
            scratch.append(pltpu.VMEM((depth, 2, na, pb_c), jnp.float32))
        if any(p["axis"] == 1 and p["seg"].filter_mode == FILTER_FULL
               for p in phases):
            scratch.append(pltpu.VMEM((depth, 2, pb_r, nr), jnp.float32))
        if any(p["axis"] == 0 and p["seg"].filter_mode == FILTER_FULL
               for p in phases):
            scratch.append(pltpu.VMEM((depth, 2, na, pb_c), jnp.float32))
        if PRECISIONS[spec.precision].block_scaled:
            # bs16 carried-exponent state: per-row and per-col exponent
            # vectors persisting across the sequential phase steps, so
            # the HBM scratch stays scaled end to end (_mega_kernel_staged)
            scratch.append(pltpu.VMEM((na, 1), jnp.float32))
            scratch.append(pltpu.VMEM((1, nr), jnp.float32))
        scratch.append(pltpu.SemaphoreType.DMA((depth, 6)))
        call = pl.pallas_call(
            functools.partial(_mega_kernel_staged, spec),
            grid=(batch, steps),
            in_specs=in_specs,
            out_specs=[any_spec, any_spec],
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
        )

    def fn(xr, xi, *filter_args):
        return call(xr, xi, *const_arrays, *filter_args)

    fn.flops = _mega_flops(spec) * batch  # nominal, for benches
    return fn
