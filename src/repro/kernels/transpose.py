"""Tiled VMEM transpose kernel (used by the paper-faithful pipeline variant).

The paper's azimuth steps spend 80% of runtime on global transposes; our
production pipeline eliminates them with column-slab kernels (fft4step.py,
axis=0), but the paper-faithful variant keeps them so the reproduction and
the beyond-paper win can be measured separately (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def _transpose_kernel_b(x_ref, o_ref):
    o_ref[...] = jnp.swapaxes(x_ref[...], -1, -2)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def transpose(x, *, tile: int = 256, interpret: Optional[bool] = None):
    """Tiled (R, C) -> (C, R) transpose; (B, R, C) -> (B, C, R) batched
    (one dispatch, grid over B x row-tiles x col-tiles). Tile must divide
    both scene dims."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    *lead, r, c = x.shape
    t = min(tile, r, c)
    if r % t or c % t:
        # fall back to XLA for ragged shapes (tests exercise the tiled path)
        return jnp.swapaxes(x, -1, -2)
    if not lead:
        return pl.pallas_call(
            _transpose_kernel,
            grid=(r // t, c // t),
            in_specs=[pl.BlockSpec((t, t), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((t, t), lambda i, j: (j, i)),
            out_shape=jax.ShapeDtypeStruct((c, r), x.dtype),
            interpret=interpret,
        )(x)
    b = lead[0]
    return pl.pallas_call(
        _transpose_kernel_b,
        grid=(b, r // t, c // t),
        in_specs=[pl.BlockSpec((1, t, t), lambda k, i, j: (k, i, j))],
        out_specs=pl.BlockSpec((1, t, t), lambda k, i, j: (k, j, i)),
        out_shape=jax.ShapeDtypeStruct((b, c, r), x.dtype),
        interpret=interpret,
    )(x)
