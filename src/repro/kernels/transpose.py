"""Tiled VMEM transpose kernel (used by the paper-faithful pipeline variant).

The paper's azimuth steps spend 80% of runtime on global transposes; our
production pipeline eliminates them with column-slab kernels (fft4step.py,
axis=0), but the paper-faithful variant keeps them so the reproduction and
the beyond-paper win can be measured separately (EXPERIMENTS.md §Perf).

Ragged shapes (scene dims not divisible by the tile) stay on the Pallas
path: the input is zero-padded up to the tile grid, transposed tiled, and
the result sliced back — the paper-faithful variant is measured through
the same kernel regardless of shape, instead of silently falling back to
an XLA transpose that would corrupt the comparison.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fft4step import auto_interpret


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def _transpose_kernel_b(x_ref, o_ref):
    o_ref[...] = jnp.swapaxes(x_ref[...], -1, -2)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def transpose(x, *, tile: int = 256, interpret: Optional[bool] = None):
    """Tiled (R, C) -> (C, R) transpose; (B, R, C) -> (B, C, R) batched
    (one dispatch, grid over B x row-tiles x col-tiles). Ragged dims are
    padded to the tile grid and sliced after — always the Pallas kernel,
    never an XLA fallback."""
    interpret = auto_interpret(interpret)
    *lead, r, c = x.shape
    t = min(tile, r, c)
    pr, pc = (-r) % t, (-c) % t
    if pr or pc:
        widths = [(0, 0)] * len(lead) + [(0, pr), (0, pc)]
        x = jnp.pad(x, widths)
    rp, cp = r + pr, c + pc
    if not lead:
        y = pl.pallas_call(
            _transpose_kernel,
            grid=(rp // t, cp // t),
            in_specs=[pl.BlockSpec((t, t), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((t, t), lambda i, j: (j, i)),
            out_shape=jax.ShapeDtypeStruct((cp, rp), x.dtype),
            interpret=interpret,
        )(x)
        return y[:c, :r] if (pr or pc) else y
    b = lead[0]
    y = pl.pallas_call(
        _transpose_kernel_b,
        grid=(b, rp // t, cp // t),
        in_specs=[pl.BlockSpec((1, t, t), lambda k, i, j: (k, i, j))],
        out_specs=pl.BlockSpec((1, t, t), lambda k, i, j: (k, j, i)),
        out_shape=jax.ShapeDtypeStruct((b, cp, rp), x.dtype),
        interpret=interpret,
    )(x)
    return y[:, :c, :r] if (pr or pc) else y
