"""Pure-jnp oracles for every Pallas kernel (complex64 via jnp.fft).

These define the numerical ground truth the kernels are tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import jax.numpy as jnp


def to_complex(xr, xi):
    return xr.astype(jnp.complex64) + 1j * xi.astype(jnp.complex64)


def from_complex(x):
    return jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)


def fft_ref(xr, xi, axis: int):
    return from_complex(jnp.fft.fft(to_complex(xr, xi), axis=axis))


def ifft_ref(xr, xi, axis: int):
    return from_complex(jnp.fft.ifft(to_complex(xr, xi), axis=axis))


def spectral_ref(xr, xi, *, axis: int, fwd: bool, inv: bool,
                 hr=None, hi=None, u=None, v=None):
    """Oracle for the fused pipeline: [FFT] -> [pointwise filter] -> [IFFT].

    hr/hi: explicit filter (broadcastable to x). u/v: rank-K phase filter
    exp(i * sum_k u[line,k] v[sample,k]) matching FILTER_OUTER
    (u: (lines,) or (lines, K); v: (n,) or (n, K)).

    Batched oracles: pass x with a leading batch dim and axis=-1/-2 — the
    2-D filter/phase broadcasts across the batch like the kernels do."""
    x = to_complex(xr, xi)
    if fwd:
        x = jnp.fft.fft(x, axis=axis)
    if hr is not None:
        x = x * to_complex(hr, hi)
    if u is not None:
        u2 = u.reshape(u.shape[0], -1)
        v2 = v.reshape(v.shape[0], -1)
        phase = jnp.einsum("lk,sk->ls", u2, v2)   # (lines, samples)
        if axis in (0, -2):
            phase = phase.T
        x = x * jnp.exp(1j * phase.astype(jnp.complex64))
    if inv:
        x = jnp.fft.ifft(x, axis=axis)
    return from_complex(x)


def transpose_ref(x):
    return x.T
