"""SpectralPlan IR — the SAR focusing chain lifted into data.

The paper's observation is that a whole imaging pipeline is a sequence of
fused ``[FFT] · H · [IFFT]`` stages. This module makes that sequence a
first-class value: a :class:`SpectralPlan` is a tuple of declarative
:class:`Stage` records (axis, fwd/inv, named filter refs, precision), and a
small compiler turns it into executable single-dispatch Pallas calls. RDA,
CSA and ω-K (core/sar/{rda,csa,omegak}.py) are *only* plans — no algorithm
owns an executor loop — so a new algorithm, precision policy, or schedule
is a data change, not a code change (cf. Bergach et al., arXiv 1505.08067,
on modeling the radar stage graph explicitly).

Compiler/executor responsibilities:

* **Fusion** — adjacent compatible stages collapse into one
  ``ops.spectral_op`` dispatch. Stages are flattened to atoms
  (``fft`` / ``mul`` / ``ifft`` / ``transpose`` / custom) and greedily
  regrouped under the kernel grammar ``fft? mul* ifft?`` (same transform
  axis; transposes and custom atoms are barriers). Multiple fused ``mul``
  atoms compose into one kernel filter: shared×shared → shared,
  shared×full → full, outer×outer → rank-(K₁+K₂) outer,
  shared×outer → shared_outer, full×outer → full. ``fuse=FUSE_MEGA``
  additionally fuses ACROSS transform-axis changes — the grammar gains
  in-kernel corner turns, ``fft? mul* ifft? (turn fft? mul* ifft?)*`` —
  collapsing a whole transpose-free plan into ONE megakernel dispatch
  (``ops.mega_spectral_op``; the fused1 pipeline family).
* **Tuning** — per-dispatch :class:`repro.tuning.KernelConfig` records are
  pulled from the repro.tuning cache at compile time (device-fingerprinted,
  batch-bucketed; never re-swept here — ``tune="off"`` skips the lookup
  entirely).
* **Filter caching** — materialized+composed filter tensors are cached per
  ``(SceneConfig, plan, fuse, backend)``, and the underlying host-side
  float64 filter math per ``(SceneConfig, params, filter_name)``, so
  repeated ``focus()`` calls on new scenes skip all host filter work.
* **Streaming** — :meth:`Pipeline.run_streamed` executes the compiled plan
  over strips of a host-resident scene too large for one device buffer:
  each dispatch is re-issued per strip along its free (line) axis with the
  line-indexed filter payloads sliced to match, keeping ≤2 strips in
  flight so strip transfer overlaps compute (jax async dispatch). Because
  the kernel processes line blocks independently, the streamed image is
  bit-identical to the in-memory path.

Filter tensors are *named and lazy*: plans reference filters by string,
the registry maps names to host-side builders, and nothing is materialized
until a plan that uses the name is compiled against a concrete scene.

Plans serialize to/from JSON (``plan_to_json`` / ``plan_from_json``) so a
pipeline definition can be shipped, diffed, and round-tripped.
"""
from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Any, Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.fft4step import (
    FILTER_FULL,
    FILTER_NONE,
    FILTER_OUTER,
    FILTER_SHARED,
    FILTER_SHARED_OUTER,
    resolve_precision,
)
from repro.kernels.transpose import transpose as tiled_transpose
from repro.tuning import KernelConfig, Schedule, SegmentConfig, cached_config

BACKEND_PALLAS = "pallas"   # fused single-dispatch Pallas kernels
BACKEND_XLA = "xla"         # one jnp op per atom (the unfused oracle)

# Fusion levels accepted by compile_plan/plan_dispatch_count's ``fuse``:
#   False      one dispatch per atom (the unfused oracle grouping)
#   True       per-axis fusion: fft? mul* ifft? on ONE transform axis
#   FUSE_MEGA  cross-axis fusion: fft? mul* ifft? (turn fft? mul* ifft?)*
#              — axis changes become IN-KERNEL corner turns and a whole
#              transpose-free plan collapses to a single megakernel
#              dispatch (kernels/fft4step.build_mega_call)
FUSE_MEGA = "mega"


def split(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    return jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)


def unsplit(xr: jnp.ndarray, xi: jnp.ndarray) -> jnp.ndarray:
    return xr.astype(jnp.complex64) + 1j * xi.astype(jnp.complex64)


# ---------------------------------------------------------------------------
# The IR
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Stage:
    """One declarative pipeline stage.

    kind "spectral": ``[FFT if fwd] · filters · [IFFT if inv]`` along
    ``axis`` in scene coordinates (1 = range/rows, 0 = azimuth/columns).
    ``filters`` are registry names (see :func:`register_filter`), applied
    in order; at compile time adjacent filters compose into ONE kernel
    payload (see :func:`_compose_group_filters`). ``precision`` overrides
    the matmul-operand policy for this stage (None defers to the
    compile-time ``precision`` override, then the autotuned config, then
    the library default f32).

    kind "transpose": a global corner turn (fusion barrier). The compiler
    tracks orientation, so stages after a transpose still name their axis
    in scene coordinates.

    Other kinds dispatch to :func:`register_stage_impl` implementations
    (e.g. the sinc-interpolation RCMC), with ``opts`` passed through as a
    plain dict. ``opts`` is stored as a tuple of (key, value) pairs so the
    Stage stays hashable (plans are cache keys).
    """

    name: str
    kind: str = "spectral"
    axis: int = 1
    fwd: bool = False
    inv: bool = False
    filters: tuple[str, ...] = ()
    precision: Optional[str] = None
    opts: tuple[tuple[str, Any], ...] = ()

    def opt_dict(self) -> dict:
        return dict(self.opts)


@dataclasses.dataclass(frozen=True)
class SpectralPlan:
    """A named, hashable sequence of :class:`Stage` records plus static
    plan parameters (e.g. CSA's reference range) that filter builders may
    consume via their ``params`` dict.

    A plan is pure data: it references filters by registry name and never
    holds arrays, so it can be hashed (it keys the compile-time payload
    cache), serialized to JSON (:func:`plan_to_json`), diffed, and
    shipped between processes. Materialization happens only when the plan
    is compiled against a concrete :class:`~repro.core.sar.SceneConfig`
    by :func:`compile_plan`.
    """

    name: str
    stages: tuple[Stage, ...]
    params: tuple[tuple[str, Any], ...] = ()

    def param_dict(self) -> dict:
        return dict(self.params)


# ---------------------------------------------------------------------------
# Serialization
# ---------------------------------------------------------------------------

def plan_to_dict(plan: SpectralPlan) -> dict:
    return {
        "name": plan.name,
        "params": [list(p) for p in plan.params],
        "stages": [
            {
                "name": s.name, "kind": s.kind, "axis": s.axis,
                "fwd": s.fwd, "inv": s.inv, "filters": list(s.filters),
                "precision": s.precision, "opts": [list(o) for o in s.opts],
            }
            for s in plan.stages
        ],
    }


def plan_from_dict(d: dict) -> SpectralPlan:
    stages = tuple(
        Stage(
            name=s["name"], kind=s.get("kind", "spectral"),
            axis=s.get("axis", 1), fwd=s.get("fwd", False),
            inv=s.get("inv", False), filters=tuple(s.get("filters", ())),
            precision=s.get("precision"),
            opts=tuple((k, v) for k, v in s.get("opts", ())),
        )
        for s in d["stages"]
    )
    params = tuple((k, v) for k, v in d.get("params", ()))
    return SpectralPlan(name=d["name"], stages=stages, params=params)


def plan_to_json(plan: SpectralPlan, **kw) -> str:
    return json.dumps(plan_to_dict(plan), **kw)


def plan_from_json(s: str) -> SpectralPlan:
    return plan_from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Filter registry — named, lazily-materialized filter tensors
# ---------------------------------------------------------------------------
#
# Builders run host-side (numpy, float64 where it matters) and return, per
# mode and in scene coordinates (n = transformed-axis length, lines = the
# other axis):
#   shared: complex vector (n,)
#   full:   complex matrix (na, nr)
#   outer:  (u (lines, K) float32, v (n, K) float32) — phase exp(i Σ u v)

@dataclasses.dataclass(frozen=True)
class FilterDef:
    name: str
    mode: str                      # FILTER_SHARED | FILTER_FULL | FILTER_OUTER
    build: Callable                # (cfg, params: dict) -> arrays


_FILTERS: dict[str, FilterDef] = {}


def register_filter(name: str, mode: str, build: Callable) -> None:
    if mode not in (FILTER_SHARED, FILTER_FULL, FILTER_OUTER):
        raise ValueError(f"unsupported filter mode {mode!r}")
    _FILTERS[name] = FilterDef(name, mode, build)


def filter_names() -> tuple[str, ...]:
    return tuple(sorted(_FILTERS))


# host-side filter-math cache: (cfg, params, name) -> built arrays.
# Bounded FIFO: full 2-D filters are O(scene) host bytes, so a server
# focusing many distinct geometries must not accumulate them forever.
_BUILD_CACHE: dict = {}
_BUILD_CACHE_MAX = 64
_BUILD_STATS = {"hits": 0, "misses": 0}


def _fifo_put(cache: dict, key, value, limit: int) -> None:
    while len(cache) >= limit:
        cache.pop(next(iter(cache)))
    cache[key] = value


def _built(name: str, cfg, params: tuple) -> tuple[str, Any]:
    fd = _FILTERS.get(name)
    if fd is None:
        raise KeyError(f"unknown filter {name!r}; registered: {filter_names()}")
    key = (cfg, params, name)
    if key in _BUILD_CACHE:
        _BUILD_STATS["hits"] += 1
    else:
        _BUILD_STATS["misses"] += 1
        _fifo_put(_BUILD_CACHE, key, fd.build(cfg, dict(params)),
                  _BUILD_CACHE_MAX)
    return fd.mode, _BUILD_CACHE[key]


def filter_cache_stats() -> dict:
    return dict(_BUILD_STATS)


def clear_filter_caches() -> None:
    _BUILD_CACHE.clear()
    _PAYLOAD_CACHE.clear()
    _BUILD_STATS.update(hits=0, misses=0)


# ---------------------------------------------------------------------------
# Custom stage implementations (non-spectral kinds)
# ---------------------------------------------------------------------------
#
# impl(x, cfg, opts, lo, hi) -> x: complex in/out, batch-polymorphic.
# lo/hi select a row range for the streaming executor (None = whole scene);
# stream_axis names the scene axis the stage can be stripped along.

_STAGE_IMPLS: dict[str, tuple[Callable, Optional[int]]] = {}


def register_stage_impl(kind: str, impl: Callable,
                        stream_axis: Optional[int] = 0) -> None:
    _STAGE_IMPLS[kind] = (impl, stream_axis)


# ---------------------------------------------------------------------------
# Stage flattening + fusion grouping
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Atom:
    kind: str                 # "fft" | "ifft" | "mul" | "transpose" | custom
    axis: int                 # scene-coordinate transform/orientation axis
    filter: Optional[str]     # for "mul"
    stage: Stage


def _flatten(plan: SpectralPlan) -> list[_Atom]:
    atoms: list[_Atom] = []
    for s in plan.stages:
        if s.kind == "spectral":
            if s.fwd:
                atoms.append(_Atom("fft", s.axis, None, s))
            for f in s.filters:
                atoms.append(_Atom("mul", s.axis, f, s))
            if s.inv:
                atoms.append(_Atom("ifft", s.axis, None, s))
            if not (s.fwd or s.inv or s.filters):
                raise ValueError(f"empty spectral stage {s.name!r}")
        else:
            atoms.append(_Atom(s.kind, s.axis, None, s))
    return atoms


def _fusable(group: list[_Atom], atom: _Atom, mega: bool = False) -> bool:
    """May `atom` join `group` under the kernel grammar?

    Per-axis (mega=False): fft? mul* ifft? on ONE transform axis —
    transposes and custom kinds never fuse, an ifft closes the group, a
    forward fft only opens one. Cross-axis (mega=True): the grammar gains
    in-kernel corner turns, `fft? mul* ifft? (turn fft? mul* ifft?)*` —
    an axis change always starts a fresh segment (any atom kind may open
    it), while WITHIN the trailing same-axis segment the per-axis rules
    still hold."""
    if atom.kind not in ("fft", "ifft", "mul"):
        return False
    if not group:
        return True
    if group[0].kind not in ("fft", "ifft", "mul"):
        return False
    if atom.axis != group[-1].axis:
        return mega                        # a turn: only the megakernel fuses
    seg = []
    for a in reversed(group):              # the trailing same-axis segment
        if a.axis != atom.axis:
            break
        seg.append(a)
    if any(a.kind == "ifft" for a in seg):
        return False                       # the inverse transform closes a segment
    if atom.kind == "fft":
        return False                       # a forward FFT only opens a segment
    return True


def _group_atoms(atoms: list[_Atom], fuse) -> list[list[_Atom]]:
    if not fuse:
        return [[a] for a in atoms]
    mega = fuse == FUSE_MEGA
    groups: list[list[_Atom]] = []
    cur: list[_Atom] = []
    for a in atoms:
        if cur and _fusable(cur, a, mega):
            cur.append(a)
        else:
            if cur:
                groups.append(cur)
            cur = [a]
    if cur:
        groups.append(cur)
    return groups


def _split_segments(group: list[_Atom]) -> list[list[_Atom]]:
    """A fused group as its per-axis segments (consecutive same-axis
    runs) — one entry for per-axis groups, several for mega groups."""
    segs: list[list[_Atom]] = []
    for a in group:
        if segs and segs[-1][0].axis == a.axis:
            segs[-1].append(a)
        else:
            segs.append([a])
    return segs


def plan_dispatch_count(plan: SpectralPlan, fuse=True) -> int:
    """Dispatches the compiler will emit — the fusion-legality invariant
    tests assert this equals each variant's documented count. ``fuse``
    accepts False / True / :data:`FUSE_MEGA`."""
    return len(_group_atoms(_flatten(plan), fuse))


# ---------------------------------------------------------------------------
# Filter composition (host side, scene coordinates)
# ---------------------------------------------------------------------------

def _compose_group_filters(group: list[_Atom], cfg, params: tuple,
                           axis: int) -> tuple[str, tuple]:
    """Compose the group's mul atoms into ONE kernel filter payload.

    Returns (filter_mode, arrays) in scene coordinates:
      shared       -> (h complex (n,),)
      full         -> (h complex (na, nr),)
      outer        -> (u (lines, K) f32, v (n, K) f32)
      shared_outer -> (h (n,), u, v)
    """
    muls = [a for a in group if a.kind == "mul"]
    if not muls:
        return FILTER_NONE, ()
    shared = None
    full = None
    us, vs = [], []
    for a in muls:
        mode, arrs = _built(a.filter, cfg, params)
        if mode == FILTER_SHARED:
            h = np.asarray(arrs)
            shared = h if shared is None else shared * h
        elif mode == FILTER_FULL:
            h = np.asarray(arrs)
            full = h if full is None else full * h
        else:  # outer
            u, v = arrs
            us.append(np.asarray(u, np.float32).reshape(u.shape[0], -1))
            vs.append(np.asarray(v, np.float32).reshape(v.shape[0], -1))
    if full is not None:
        if shared is not None:
            full = full * (shared[None, :] if axis == 1 else shared[:, None])
        if us:
            u = np.concatenate(us, axis=1)
            v = np.concatenate(vs, axis=1)
            # fold the rank-K phase into the explicit filter (float32 phase,
            # matching the kernel's in-VMEM synthesis)
            phase = (u @ v.T).astype(np.float32) if axis == 1 \
                else (v @ u.T).astype(np.float32)
            full = full * np.exp(1j * phase.astype(np.float64)).astype(
                full.dtype)
        return FILTER_FULL, (full,)
    if us:
        u = np.concatenate(us, axis=1)
        v = np.concatenate(vs, axis=1)
        if shared is not None:
            return FILTER_SHARED_OUTER, (shared, u, v)
        return FILTER_OUTER, (u, v)
    return FILTER_SHARED, (shared,)


# composed per-dispatch payload cache: (cfg, plan, fuse, backend) -> payloads
# (bounded like _BUILD_CACHE — composed full filters are scene-sized too)
_PAYLOAD_CACHE: dict = {}
_PAYLOAD_CACHE_MAX = 64


# payload marker for a cross-axis (megakernel) group: the arrays slot
# holds one (axis, mode, arrays) record per in-kernel segment
MEGA = "mega"


def _group_payloads(plan: SpectralPlan, cfg, fuse,
                    backend: str) -> list:
    key = (cfg, plan, fuse, backend)
    if key not in _PAYLOAD_CACHE:
        atoms = _flatten(plan)
        groups = _group_atoms(atoms, fuse)
        payloads = []
        for g in groups:
            if g[0].kind not in ("fft", "ifft", "mul"):
                payloads.append((FILTER_NONE, ()))
                continue
            segs = _split_segments(g)
            if len(segs) == 1:
                payloads.append(
                    _compose_group_filters(g, cfg, plan.params, g[0].axis))
            else:
                payloads.append((MEGA, tuple(
                    (s[0].axis,
                     *_compose_group_filters(s, cfg, plan.params, s[0].axis))
                    for s in segs)))
        _fifo_put(_PAYLOAD_CACHE, key, (groups, payloads),
                  _PAYLOAD_CACHE_MAX)
    return _PAYLOAD_CACHE[key]


# ---------------------------------------------------------------------------
# Compiled pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Step:
    """One compiled dispatch (or one oracle op in the xla backend).

    Besides the executable ``fn``, a step carries a declarative record of
    the dispatch it performs (``kind``, ``phys_axis``, ``filter_mode``,
    ``filter_kw``, ``kernel_kw``) so a compiled pipeline can be
    *re-lowered* to another execution substrate without recompiling the
    plan — e.g. :func:`repro.core.sar.distributed.lower_pipeline` replays
    spectral steps on shard_map slabs, re-issuing ``ops.spectral_op`` per
    device with the line-indexed filter payloads sharded alongside the
    data (the multi-device analogue of ``strip_fn``'s host strips).
    """

    name: str
    fn: Callable[[jnp.ndarray], jnp.ndarray]
    dispatches: int
    hbm_roundtrips: int
    fused: bool
    stream_axis: Optional[int] = None     # data axis strips run along
    strip_fn: Optional[Callable] = None   # fn(x_strip, lo, hi)
    kind: str = "spectral"                # "spectral" | "transpose" | custom
    phys_axis: Optional[int] = None       # physical transform axis
    filter_mode: str = FILTER_NONE        # composed kernel filter mode
    filter_kw: Optional[dict] = None      # device filter payloads (line-indexed)
    kernel_kw: Optional[dict] = None      # ops.spectral_op config kwargs
    # mega steps only: per-segment scene-coordinate filter payloads,
    # aligned with kernel_kw["segments"] — one tuple of device arrays per
    # segment record, in the flat order ops.mega_spectral_op consumes.
    # This is what lets lower_sharded split the in-kernel segment chain at
    # corner-turn boundaries and re-shard each group's filters per device.
    seg_filter_args: Optional[tuple] = None


@dataclasses.dataclass
class Pipeline:
    """A compiled plan: a named sequence of dispatch steps.

    Execution surfaces (all share the same compiled steps):

    * :meth:`run` — in-memory, blocking per jax's usual async dispatch.
    * :meth:`jitted` — the same step sequence traced into ONE XLA
      computation (the serving hot path; amortizes per-step dispatch).
    * :meth:`run_streamed` — strip-wise over a host-resident scene that
      exceeds device memory.
    * :meth:`lower_sharded` — re-lower to multi-device shard_map slabs
      with corner-turn collectives (transpose-free spectral plans and
      mega plans; in a mega step the in-kernel corner turns become the
      all_to_alls).

    A Pipeline holds materialized device filter payloads for one
    ``(SceneConfig, plan)`` pair; the payloads come from the bounded
    module-level caches, so building the same pipeline twice skips all
    host filter math (see :func:`filter_cache_stats`). For a process that
    serves many geometries, prefer :func:`cached_pipeline`, which also
    reuses the compiled Pipeline object itself.
    """

    name: str
    cfg: Any
    steps: list[Step]
    plan: Optional[SpectralPlan] = None

    @property
    def dispatches(self) -> int:
        return sum(s.dispatches for s in self.steps)

    @property
    def hbm_roundtrips(self) -> int:
        return sum(s.hbm_roundtrips for s in self.steps)

    def run(self, raw: jnp.ndarray) -> jnp.ndarray:
        """Execute the compiled steps on one scene ``(na, nr)`` or a
        batch ``(B, na, nr)`` sharing the SceneConfig, complex64 in/out.

        A batched input runs each stage as a SINGLE dispatch whose grid
        spans ``B × line-blocks`` — batching is a grid extension, not a
        python loop, so the batched image equals the per-scene image
        bit-for-bit (asserted in tests/test_service.py). Steps execute
        eagerly; wrap with :meth:`jitted` to fuse the inter-step glue.
        """
        x = raw
        for s in self.steps:
            x = s.fn(x)
        return x

    def jitted(self) -> Callable[[jnp.ndarray], jnp.ndarray]:
        """One jax.jit callable for the whole step sequence. Retraces per
        distinct input shape (each batch size B is one trace); the
        focusing service pre-traces its micro-batch sizes at warm-up."""
        @jax.jit
        def f(raw):
            return self.run(raw)
        return f

    def lower_sharded(self, mesh, axes=("data",), **kw):
        """Lower this compiled pipeline onto a device mesh: every
        spectral step runs on slabs sharded along its free (line) axis,
        with an all_to_all corner turn inserted wherever consecutive
        steps transform different axes. A mega step is split at its
        in-kernel turn boundaries into per-device segment groups — one
        staged megakernel dispatch per device per group, the turns
        between groups becoming the collectives. Transpose/custom stages
        do not lower. See
        :func:`repro.core.sar.distributed.lower_pipeline` for the
        collective-bytes story; returns ``fn(raw) -> image``."""
        from repro.core.sar import distributed
        return distributed.lower_pipeline(self, mesh, axes=axes, **kw)

    def run_streamed(self, raw, strips: int = 4,
                     inflight: int = 2) -> np.ndarray:
        """Execute over host memory in `strips` tiles per stage.

        Each dispatch runs strip-by-strip along its free (line) axis with
        the line-indexed filter payloads sliced to the strip, so a scene
        that cannot fit in one device buffer still flows through the same
        compiled stages. Up to `inflight` strips are kept un-synchronized
        so jax's async dispatch overlaps the next strip's host->device
        transfer with the current strip's compute. Output is bit-identical
        to `run` (the kernel treats line blocks independently).
        """
        x = np.ascontiguousarray(np.asarray(raw))
        if x.ndim != 2:
            raise ValueError("run_streamed expects one (na, nr) scene")
        for step in self.steps:
            if step.stream_axis is None or step.strip_fn is None:
                raise ValueError(
                    f"step {step.name!r} does not support streaming "
                    "(global transposes need the whole scene; cross-axis "
                    "megakernel steps have no single free axis to strip "
                    "— use a per-axis variant like fused3)")
            ax = step.stream_axis
            n = x.shape[ax]
            sizes = [n // strips + (1 if i < n % strips else 0)
                     for i in range(strips)]
            out = np.empty(x.shape, x.dtype)
            pending: deque = deque()
            lo = 0
            for size in sizes:
                if size == 0:
                    continue
                hi = lo + size
                sl = ((slice(lo, hi), slice(None)) if ax == 0
                      else (slice(None), slice(lo, hi)))
                xs = jax.device_put(x[sl])
                pending.append((sl, step.strip_fn(xs, lo, hi)))
                while len(pending) >= max(1, inflight):
                    s2, y2 = pending.popleft()
                    out[s2] = np.asarray(y2)   # blocks; later strips overlap
                lo = hi
            while pending:
                s2, y2 = pending.popleft()
                out[s2] = np.asarray(y2)
            x = out
        return x


# ---------------------------------------------------------------------------
# The compiler
# ---------------------------------------------------------------------------

def _tuned_config(n: int, batch: int) -> KernelConfig:
    """Best-known kernel config for (n, batch) from the repro.tuning
    cache (device-fingerprinted; batch normalized to its serving bucket).
    Never triggers a sweep — compile time is lookup-only; an empty
    KernelConfig (all defaults) on a miss."""
    return cached_config(n, batch) or KernelConfig()


def _schedule_segments(opts, count: int) -> tuple:
    """Consume ``count`` per-segment configs from the compile-wide
    schedule cursor. Spectral steps take one, a mega-fused group one per
    in-kernel segment, so a Schedule's segments map onto the plan's
    spectral segments in compile order. Empty configs when compiling
    without a schedule; a schedule shorter than the plan pads with empty
    configs too (``Schedule.segment`` past-the-end behaviour)."""
    sched = opts["schedule"]
    if sched is None:
        return (SegmentConfig(),) * count
    lo = opts["_seg_cursor"][0]
    opts["_seg_cursor"][0] = lo + count
    return tuple(sched.segment(lo + i) for i in range(count))


def _schedule_globals(tuned: KernelConfig, opts) -> KernelConfig:
    """The schedule's dispatch-global knobs applied over the tuned-cache
    config. Runs BEFORE the explicit fft_kw merge, so the resolution
    order stays: explicit compile args > schedule > tuned cache >
    library defaults."""
    sched = opts["schedule"]
    if sched is None:
        return tuned
    knobs = dict(block=sched.block, col_block=sched.col_block,
                 precision=sched.precision, residency=sched.residency,
                 phase_block=sched.phase_block,
                 buffer_depth=sched.buffer_depth)
    return tuned.merge_overrides(
        {k: v for k, v in knobs.items() if v is not None})


def _payload_to_device(mode: str, arrays: tuple, axis: int,
                       transposed: bool) -> dict:
    """Scene-coordinate payload -> ops.spectral_op kwargs in the physical
    orientation (full filters transpose with the data; shared vectors and
    outer u/v are orientation-invariant given the physical axis)."""
    if mode == FILTER_NONE:
        return {}
    if mode in (FILTER_SHARED, FILTER_FULL):
        h = arrays[0]
        if mode == FILTER_FULL and transposed:
            h = np.ascontiguousarray(h.T)
        return {"hr": jnp.asarray(h.real.astype(np.float32)),
                "hi": jnp.asarray(h.imag.astype(np.float32))}
    if mode == FILTER_OUTER:
        u, v = arrays
        return {"u": jnp.asarray(u), "v": jnp.asarray(v)}
    h, u, v = arrays
    return {"hr": jnp.asarray(h.real.astype(np.float32)),
            "hi": jnp.asarray(h.imag.astype(np.float32)),
            "u": jnp.asarray(u), "v": jnp.asarray(v)}


def _slice_filter_kwargs(kw: dict, mode: str, phys_axis: int, lo: int,
                         hi: int) -> dict:
    """Slice the line-indexed filter payloads to a [lo, hi) line strip."""
    out = dict(kw)
    if mode == FILTER_FULL:
        out["hr"] = kw["hr"][lo:hi] if phys_axis == 1 else kw["hr"][:, lo:hi]
        out["hi"] = kw["hi"][lo:hi] if phys_axis == 1 else kw["hi"][:, lo:hi]
    if mode in (FILTER_OUTER, FILTER_SHARED_OUTER):
        out["u"] = kw["u"][lo:hi]
    return out


def _make_spectral_step(group, mode, arrays, *, cfg, transposed, backend,
                        opts) -> Step:
    axis = group[0].axis                       # logical (scene) axis
    phys_axis = (1 - axis) if transposed else axis
    fwd = any(a.kind == "fft" for a in group)
    inv = any(a.kind == "ifft" for a in group)
    n = cfg.nr if axis == 1 else cfg.na
    name = group[0].stage.name

    # per-dispatch kernel config: explicit compile args > stage precision >
    # schedule > tuned cache entry > library defaults
    tuned = _tuned_config(n, opts["batch"]) if (
        backend == BACKEND_PALLAS and opts["tune"] != "off") else \
        KernelConfig()
    tuned = _schedule_globals(tuned, opts)
    seg = _schedule_segments(opts, 1)[0]
    if seg.n1 is not None:
        tuned = tuned.merge_overrides(dict(n1=seg.n1, n2=seg.n2, n3=seg.n3))
    if seg.karatsuba is not None:
        tuned = tuned.merge_overrides(dict(karatsuba=seg.karatsuba))
    fkw = opts["fft_kw"] if axis == 1 else None
    if fkw:
        tuned = tuned.merge_overrides(fkw)
    if phys_axis == 1:
        block = opts["block"] or tuned.block or 8
    else:
        block = opts["col_block"] or 128
    stage_prec = next((a.stage.precision for a in group
                       if a.stage.precision is not None), None)
    precision = resolve_precision(
        opts["precision"] or stage_prec or tuned.precision).name

    kernel_kw = dict(
        axis=phys_axis, fwd=fwd, inv=inv, filter_mode=mode, block=block,
        fft_impl=opts["fft_impl"], interpret=opts["interpret"],
        precision=precision, n1=tuned.n1, n2=tuned.n2,
        n3=tuned.n3, karatsuba=bool(tuned.karatsuba),
    )
    filter_kw = _payload_to_device(mode, arrays, axis, transposed)

    if backend == BACKEND_PALLAS:
        def fn(x, _fk=filter_kw):
            xr, xi = split(x)
            yr, yi = ops.spectral_op(xr, xi, **_fk, **kernel_kw)
            return unsplit(yr, yi)
    else:
        # the unfused oracle: same math, one jnp op per piece
        def fn(x, _fk=filter_kw):
            return _xla_apply(x, fwd, inv, mode, _fk, phys_axis)

    # streaming: strips run along the physical line axis; the scene must be
    # in its natural orientation for host strips to be meaningful
    stream_axis = None
    strip_fn = None
    if not transposed:
        stream_axis = 0 if phys_axis == 1 else 1

        def strip_fn(xs, lo, hi, _fk=filter_kw):
            fk = _slice_filter_kwargs(_fk, mode, phys_axis, lo, hi)
            if backend == BACKEND_PALLAS:
                xr, xi = split(xs)
                yr, yi = ops.spectral_op(xr, xi, **fk, **kernel_kw)
                return unsplit(yr, yi)
            return _xla_apply(xs, fwd, inv, mode, fk, phys_axis)

    fused = backend == BACKEND_PALLAS and len(group) > 1
    return Step(name, fn, 1, 1, fused, stream_axis, strip_fn,
                kind="spectral", phys_axis=phys_axis, filter_mode=mode,
                filter_kw=filter_kw, kernel_kw=kernel_kw)


def _seg_device_args(mode: str, arrays: tuple) -> list:
    """One segment's scene-coordinate payload as the flat device-array
    list `ops.mega_spectral_op` consumes (hr/hi pairs split re/im)."""
    if mode == FILTER_NONE:
        return []
    if mode in (FILTER_SHARED, FILTER_FULL):
        h = arrays[0]
        return [jnp.asarray(h.real.astype(np.float32)),
                jnp.asarray(h.imag.astype(np.float32))]
    if mode == FILTER_OUTER:
        u, v = arrays
        return [jnp.asarray(u), jnp.asarray(v)]
    h, u, v = arrays
    return [jnp.asarray(h.real.astype(np.float32)),
            jnp.asarray(h.imag.astype(np.float32)),
            jnp.asarray(u), jnp.asarray(v)]


def _make_mega_step(group, seg_payloads, *, cfg, backend, opts) -> Step:
    """One cross-axis fused group -> ONE megakernel dispatch (or the
    per-segment jnp oracle chain in the xla backend).

    The whole pipeline is a single `pallas_call`: per-axis segments run
    back-to-back with the corner turns inside the kernel, in the
    residency mode resolved here — explicit compile option > tuned cache
    entry > VMEM-feasibility auto-cut (repro.tuning.cost.mega_residency).

    Every precision fuses, including block-scaled bs16: the megakernel
    carries per-line block exponents through its in-kernel corner turns
    (re-blocking at each segment boundary — see fft4step.line_exponents),
    so the fused dispatch is bit-identical to the per-axis chain it
    replaces and the fused1 reroute/sharded lowering stay invisible.
    """
    segs = _split_segments(group)
    name = "+".join(dict.fromkeys(a.stage.name for a in group))

    segments = []
    filter_args: list = []
    seg_args: list = []                   # per-segment device payloads
    seg_fk: list = []                     # per-segment oracle payloads
    for atoms, (axis, mode, arrays) in zip(segs, seg_payloads):
        fwd = any(a.kind == "fft" for a in atoms)
        inv = any(a.kind == "ifft" for a in atoms)
        segments.append((axis, fwd, inv, mode))
        dev = _seg_device_args(mode, arrays)
        filter_args += dev
        seg_args.append(tuple(dev))
        fk = {}
        if mode in (FILTER_SHARED, FILTER_FULL, FILTER_SHARED_OUTER):
            fk["hr"], fk["hi"] = dev[0], dev[1]
        if mode in (FILTER_OUTER, FILTER_SHARED_OUTER):
            fk["u"], fk["v"] = dev[-2], dev[-1]
            fk["u"] = fk["u"].reshape(fk["u"].shape[0], -1)
            fk["v"] = fk["v"].reshape(fk["v"].shape[0], -1)
        seg_fk.append((axis, fwd, inv, mode, fk))
    segments = tuple(segments)

    tuned = _tuned_config(cfg.nr, opts["batch"]) if (
        backend == BACKEND_PALLAS and opts["tune"] != "off") else \
        KernelConfig()
    tuned = _schedule_globals(tuned, opts)
    seg_cfgs = _schedule_segments(opts, len(segs))
    if opts["fft_kw"]:
        tuned = tuned.merge_overrides(opts["fft_kw"])
    stage_prec = next((a.stage.precision for a in group
                       if a.stage.precision is not None), None)
    precision = resolve_precision(
        opts["precision"] or stage_prec or tuned.precision).name

    residency = opts["residency"] or tuned.residency
    if residency is None:
        from repro import tuning
        residency = tuning.cost.mega_residency(
            cfg.na, cfg.nr, precision=precision,
            filter_bytes=sum(int(a.size) * 4 for a in filter_args))
    phase_block = opts["phase_block"] or tuned.phase_block or 8

    # per-segment schedule decisions ride as extended 8-field segment
    # records (axis, fwd, inv, mode, n1, n2, n3, karatsuba) — the kernel
    # resolves each against the dispatch-global factorization/karatsuba
    if any(sc != SegmentConfig() for sc in seg_cfgs):
        segments = tuple(
            rec + (sc.n1, sc.n2, sc.n3, sc.karatsuba)
            for rec, sc in zip(segments, seg_cfgs))

    kernel_kw = dict(
        segments=segments, residency=residency, phase_block=phase_block,
        fft_impl=opts["fft_impl"], interpret=opts["interpret"],
        precision=precision, n1=tuned.n1, n2=tuned.n2, n3=tuned.n3,
        karatsuba=bool(tuned.karatsuba),
    )
    if tuned.buffer_depth is not None:
        kernel_kw["buffer_depth"] = tuned.buffer_depth

    if backend == BACKEND_PALLAS:
        def fn(x, _fa=tuple(filter_args)):
            xr, xi = split(x)
            yr, yi = ops.mega_spectral_op(xr, xi, *_fa, **kernel_kw)
            return unsplit(yr, yi)
    else:
        # the unfused oracle: the same segment chain, one jnp op per piece
        def fn(x, _sf=tuple(seg_fk)):
            for axis, fwd, inv, mode, fk in _sf:
                x = _xla_apply(x, fwd, inv, mode, fk, axis)
            return x

    fused = backend == BACKEND_PALLAS
    # stream_axis/strip_fn stay None: a cross-axis stage has no single
    # free axis to strip a host scene along, so run_streamed must reject
    # it — use a per-axis variant (fused3 & friends) there. lower_sharded
    # DOES accept this step: seg_filter_args below carries the
    # per-segment payloads it needs to split the in-kernel segment chain
    # at corner-turn boundaries into per-device groups.
    #
    # hbm_roundtrips=1 counts DISPATCH-BOUNDARY materializations of the
    # working scene (raw in, image out), the metric every step reports.
    # The staged residency additionally moves the scene through its HBM
    # scratch once per in-kernel turn — but that traffic never crosses a
    # dispatch boundary and is double-buffered behind the DFT matmuls,
    # which is precisely the difference this step exists to exploit
    # (bench rows carry residency=... so the distinction stays visible).
    return Step(name, fn, 1, 1, fused, None, None, kind="mega",
                phys_axis=None, filter_mode=MEGA, filter_kw=None,
                kernel_kw=kernel_kw, seg_filter_args=tuple(seg_args))


def _xla_apply(x, fwd, inv, mode, fk, phys_axis):
    ax = -1 if phys_axis == 1 else -2
    if fwd:
        x = jnp.fft.fft(x, axis=ax)
    if mode != FILTER_NONE:
        if mode in (FILTER_SHARED, FILTER_FULL, FILTER_SHARED_OUTER):
            h = unsplit(fk["hr"], fk["hi"])
            if mode == FILTER_SHARED or (mode == FILTER_SHARED_OUTER
                                         and h.ndim == 1):
                h = h[None, :] if phys_axis == 1 else h[:, None]
            x = x * h
        if mode in (FILTER_OUTER, FILTER_SHARED_OUTER):
            phase = jnp.einsum("lk,sk->ls", fk["u"], fk["v"])
            if phys_axis == 0:
                phase = phase.T
            x = x * jnp.exp(1j * phase.astype(jnp.complex64))
    if inv:
        x = jnp.fft.ifft(x, axis=ax)
    return x


def _make_transpose_step(stage: Stage, backend: str, interpret) -> Step:
    if backend == BACKEND_PALLAS:
        def fn(x):
            return unsplit(tiled_transpose(jnp.real(x).astype(jnp.float32),
                                           interpret=interpret),
                           tiled_transpose(jnp.imag(x).astype(jnp.float32),
                                           interpret=interpret))
    else:
        def fn(x):
            return jnp.swapaxes(x, -1, -2)
    return Step(stage.name, fn, 1, 1, False, None, None, kind="transpose")


def _make_custom_step(stage: Stage, cfg) -> Step:
    if stage.kind not in _STAGE_IMPLS:
        raise KeyError(f"no implementation registered for stage kind "
                       f"{stage.kind!r}")
    impl, stream_axis = _STAGE_IMPLS[stage.kind]
    opts = stage.opt_dict()

    def fn(x):
        return impl(x, cfg, opts, None, None)

    strip_fn = None
    if stream_axis is not None:
        def strip_fn(xs, lo, hi):
            return impl(xs, cfg, opts, lo, hi)
    return Step(stage.name, fn, 1, 1, False, stream_axis, strip_fn,
                kind=stage.kind)


def compile_plan(
    plan: SpectralPlan,
    cfg,
    *,
    backend: str = BACKEND_PALLAS,
    fuse=True,
    batch: int = 1,
    interpret: Optional[bool] = None,
    block: Optional[int] = None,
    col_block: Optional[int] = None,
    fft_impl: str = "matmul",
    precision: Optional[str] = None,
    tune: str = "cached",
    fft_kw: Optional[dict] = None,
    residency: Optional[str] = None,
    phase_block: Optional[int] = None,
    schedule: Optional[Schedule] = None,
) -> Pipeline:
    """Compile a plan against a concrete scene into a :class:`Pipeline`.

    cfg is a :class:`~repro.core.sar.SceneConfig`; the compiled pipeline
    accepts one ``(cfg.na, cfg.nr)`` complex64 scene or any batch
    ``(B, na, nr)`` of scenes sharing that geometry (B is a runtime shape,
    not a compile parameter — see ``batch`` below).

    backend: 'pallas' (fused dispatches) or 'xla' (jnp oracle ops).
    fuse: merge adjacent compatible atoms into single dispatches. ``True``
      fuses per transform axis; :data:`FUSE_MEGA` ("mega") additionally
      fuses ACROSS axis changes into single-dispatch megakernel steps
      (in-kernel corner turns — the fused1 pipeline family).
    residency: megakernel execution mode for mega-fused steps — 'vmem'
      (whole slab on-chip) or 'staged' (HBM scratch + double-buffered
      DMA); None auto-selects by the repro.tuning VMEM-feasibility cut.
    phase_block: lines per staged-phase grid step (None = tuned or 8).
    batch: scene-batch size the tuned configs are *looked up* for
      (normalized to the serving power-of-two bucket by repro.tuning);
      it does not restrict the shapes the pipeline accepts.
    block/col_block: line blocks for rows/columns dispatches (None = the
      autotuned or library default).
    precision: global matmul-operand policy override for every spectral
      stage (see fft4step.PRECISIONS); per-stage ``Stage.precision`` wins
      over the autotune cache but not over this.
    tune: 'cached' pulls per-dispatch kernel configs from the
      repro.tuning cache; 'off' uses library defaults.
    fft_kw: explicit config for range-axis (axis=1) dispatches — e.g. a
      just-measured factorization from a repro.tuning search.
    schedule: a :class:`repro.tuning.Schedule` (the schedule-graph search
      winner) to compile through. Its dispatch-global knobs override the
      tuned-cache entry and its per-segment factorization/karatsuba
      decisions map onto the plan's spectral segments in compile order —
      a mega-fused group consumes one per in-kernel segment, reaching
      the kernel as extended segment records; other spectral steps one
      each. Explicit per-knob compile args (block, precision, fft_kw,
      residency, ...) still win over the schedule.

    Cache behaviour: composed filter payloads are served from the bounded
    ``(cfg, plan, fuse, backend)`` payload cache and the underlying host
    filter math from the ``(cfg, params, name)`` build cache, so
    recompiling the same (scene, plan) pair does no host filter work.
    The Pipeline object itself is rebuilt each call — use
    :func:`cached_pipeline` to also reuse compiled pipelines (and their
    jit traces) across calls, e.g. from the focusing service.
    """
    if backend not in (BACKEND_PALLAS, BACKEND_XLA):
        raise ValueError(f"unknown backend {backend!r}")
    groups, payloads = _group_payloads(plan, cfg, fuse, backend)
    opts = dict(batch=batch, tune=tune, fft_kw=fft_kw or {}, block=block,
                col_block=col_block, fft_impl=fft_impl,
                interpret=interpret, precision=precision,
                residency=residency, phase_block=phase_block,
                schedule=schedule, _seg_cursor=[0])
    steps: list[Step] = []
    transposed = False
    for group, (mode, arrays) in zip(groups, payloads):
        kind = group[0].kind
        if mode == MEGA:
            if transposed:
                raise ValueError(
                    f"mega step {group[0].stage.name!r} inside a "
                    "transposed section is not supported")
            steps.append(_make_mega_step(
                group, arrays, cfg=cfg, backend=backend, opts=opts))
        elif kind in ("fft", "ifft", "mul"):
            steps.append(_make_spectral_step(
                group, mode, arrays, cfg=cfg, transposed=transposed,
                backend=backend, opts=opts))
        elif kind == "transpose":
            steps.append(_make_transpose_step(group[0].stage, backend,
                                              interpret))
            transposed = not transposed
        else:
            if transposed:
                raise ValueError(
                    f"custom stage {group[0].stage.name!r} inside a "
                    "transposed section is not supported")
            steps.append(_make_custom_step(group[0].stage, cfg))
    if transposed:
        raise ValueError(f"plan {plan.name!r} ends in transposed orientation")
    return Pipeline(plan.name, cfg, steps, plan)


# ---------------------------------------------------------------------------
# Variant registry — named plans + their compile defaults
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Variant:
    """A registered pipeline variant: a plan factory, how to compile it,
    and its documented dispatch count (the fusion-legality invariant)."""

    name: str
    plan_fn: Callable[..., SpectralPlan]
    compile_defaults: tuple[tuple[str, Any], ...] = ()
    plan_kw: tuple[str, ...] = ()       # build kwargs routed to plan_fn
    dispatches: int = 0                 # documented compiled dispatch count


_VARIANTS: dict[str, Variant] = {}


def register_variant(name: str, plan_fn, *, compile_defaults=(),
                     plan_kw=(), dispatches=0) -> None:
    _VARIANTS[name] = Variant(name, plan_fn, tuple(compile_defaults),
                              tuple(plan_kw), dispatches)


def get_variant(name: str) -> Variant:
    if name not in _VARIANTS:
        raise KeyError(f"unknown pipeline variant {name!r}; "
                       f"registered: {sorted(_VARIANTS)}")
    return _VARIANTS[name]


def variant_names() -> tuple[str, ...]:
    return tuple(sorted(_VARIANTS))


def build_variant(cfg, name: str, **kw) -> Pipeline:
    """Build + compile a registered variant. Plan-level kwargs (declared in
    the variant's plan_kw) route to the plan factory; the rest override the
    variant's compile defaults and go to compile_plan."""
    var = get_variant(name)
    plan_args = {k: kw.pop(k) for k in list(kw) if k in var.plan_kw}
    compile_args = dict(var.compile_defaults)
    compile_args.update(kw)
    return compile_plan(var.plan_fn(**plan_args), cfg, **compile_args)


# ---------------------------------------------------------------------------
# Compiled-pipeline cache — the serving hot path
# ---------------------------------------------------------------------------
#
# compile_plan is cheap-ish (payloads are cached) but not free, and a fresh
# Pipeline means fresh jit traces. A server coalescing requests into
# micro-batches wants ONE warm Pipeline per (scene geometry, variant,
# compile options) so every request after the first reuses both the
# compiled steps and their XLA executables. Bounded FIFO like the filter
# caches: pipelines hold scene-sized device filter payloads.

_PIPELINE_CACHE: dict = {}
_PIPELINE_CACHE_MAX = 32


def _freeze(v):
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    return v


def cached_pipeline(cfg, variant: str, **kw) -> Pipeline:
    """`build_variant` behind a bounded cache keyed on
    ``(cfg, variant, compile kwargs)``. Repeated calls return the SAME
    Pipeline object, so jit traces, device filter payloads, and autotune
    lookups are all warm. Unhashable kwarg values (dicts/lists, e.g.
    ``fft_kw``) are frozen to tuples for the key."""
    key = (cfg, variant, _freeze(kw))
    if key not in _PIPELINE_CACHE:
        import repro.core.sar  # noqa: F401  (registers the shipped variants)
        _fifo_put(_PIPELINE_CACHE, key, build_variant(cfg, variant, **kw),
                  _PIPELINE_CACHE_MAX)
    return _PIPELINE_CACHE[key]


def clear_pipeline_cache() -> None:
    _PIPELINE_CACHE.clear()
