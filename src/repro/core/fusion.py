"""SpectralPipeline — the paper's contribution as a composable JAX op.

One fused dispatch computing  [FFT] -> pointwise filter -> [IFFT]  along rows
or columns of a 2-D block, with the intermediate spectrum never leaving
on-chip memory. Backend 'pallas' lowers to the single pl.pallas_call kernel
(kernels/fft4step.py, MXU matmul FFT); backend 'xla' is the unfused oracle
(jnp.fft per stage) used for baselines and CPU-exact references.

Also exposes `fft_conv`, a fused long-convolution primitive (FFT * K * IFFT
in one dispatch) — the building block of the FFTConvMixer LM layer that
demonstrates the paper's kernel inside a Hyena/S4-style language model.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.fft4step import (
    FILTER_FULL,
    FILTER_NONE,
    FILTER_OUTER,
    FILTER_SHARED,
    FILTER_SHARED_OUTER,
)

BACKEND_PALLAS = "pallas"
BACKEND_XLA = "xla"


@dataclasses.dataclass(frozen=True)
class SpectralPipeline:
    """A reusable fused [FFT] * H * [IFFT] stage.

    axis: 1 = transform rows of (lines, n); 0 = columns of (n, lines).
    filter_mode: one of kernels.FILTER_* ('none'|'shared'|'full'|'outer'|
                 'shared_outer').
    backend: 'pallas' (fused single dispatch) or 'xla' (unfused jnp.fft).
    """

    fwd: bool = True
    inv: bool = True
    filter_mode: str = FILTER_NONE
    axis: int = 1
    backend: str = BACKEND_PALLAS
    block: int = 8
    fft_impl: str = "matmul"
    precision: Optional[str] = None   # fft4step.PRECISIONS policy name
    compute_dtype: Optional[str] = None  # deprecated alias for `precision`
    karatsuba: bool = False
    interpret: Optional[bool] = None

    def __call__(self, xr, xi, hr=None, hi=None, u=None, v=None):
        if self.backend == BACKEND_XLA:
            h = dict(hr=hr, hi=hi) if hr is not None else {}
            o = dict(u=u, v=v) if u is not None else {}
            if self.filter_mode == FILTER_SHARED and hr is not None:
                # broadcast the shared vector along the line axis
                shape = (1, -1) if self.axis == 1 else (-1, 1)
                h = dict(hr=hr.reshape(shape), hi=hi.reshape(shape))
            return ref.spectral_ref(xr, xi, axis=self.axis, fwd=self.fwd,
                                    inv=self.inv, **h, **o)
        return ops.spectral_op(
            xr, xi, hr=hr, hi=hi, u=u, v=v, axis=self.axis, fwd=self.fwd,
            inv=self.inv, filter_mode=self.filter_mode, block=self.block,
            fft_impl=self.fft_impl, karatsuba=self.karatsuba,
            precision=self.precision or self.compute_dtype,
            interpret=self.interpret)


def fft_conv(x: jnp.ndarray, k_fft_r: jnp.ndarray, k_fft_i: jnp.ndarray,
             backend: str = BACKEND_PALLAS, block: int = 8,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused circular convolution: real input (B, N), precomputed filter
    spectrum (N,) split re/im -> real output (B, N). ONE dispatch.

    Callers wanting causal/linear convolution zero-pad x and the kernel to
    2N before calling (standard FFT-conv practice)."""
    zeros = jnp.zeros_like(x)
    pipe = SpectralPipeline(fwd=True, inv=True, filter_mode=FILTER_SHARED,
                            backend=backend, block=block, interpret=interpret)
    yr, _ = pipe(x, zeros, hr=k_fft_r, hi=k_fft_i)
    return yr
