"""Matched filters and RCMC terms for the Range Doppler Algorithm.

All filters are returned as split re/im float32 (the kernel's native layout);
``*_c`` variants return complex64 for the jnp baseline. Phases are computed
with the bulk carrier term removed (exp(i*4*pi*fc*r0/c) is constant per range
gate and does not affect focusing) so float32 trigonometry stays accurate.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.sar.geometry import C, SceneConfig


# ---------------------------------------------------------------------------
# Axes
# ---------------------------------------------------------------------------

def range_freqs(cfg: SceneConfig) -> np.ndarray:
    """Range (fast-time) frequency axis, FFT ordering (Hz)."""
    return np.fft.fftfreq(cfg.nr, d=1.0 / cfg.fs)


def azimuth_freqs(cfg: SceneConfig) -> np.ndarray:
    """Azimuth (Doppler) frequency axis, FFT ordering (Hz). Broadside
    geometry => Doppler centroid 0, no fftshift needed."""
    return np.fft.fftfreq(cfg.na, d=1.0 / cfg.prf)


def migration_factor(cfg: SceneConfig) -> np.ndarray:
    """D(f_a) = sqrt(1 - (lambda f_a / 2 v)^2), (na,) float64."""
    fa = azimuth_freqs(cfg)
    s = (cfg.wavelength * fa / (2.0 * cfg.v)) ** 2
    return np.sqrt(np.maximum(1.0 - s, 1e-12))


def range_gates(cfg: SceneConfig) -> np.ndarray:
    """Closest-approach range r0(col) of each range gate (m), (nr,) float64."""
    return cfg.r0 + (np.arange(cfg.nr) - cfg.nr / 2) * cfg.dr


# ---------------------------------------------------------------------------
# Range matched filter (step 1 of the RDA)
# ---------------------------------------------------------------------------

def range_matched_filter(cfg: SceneConfig) -> tuple[np.ndarray, np.ndarray]:
    """H_r(f) = conj(FFT(chirp replica)), split re/im float32, (nr,).

    The replica is the transmitted chirp placed at fast-time offset 0, so the
    compressed peak lands at the echo's start column.
    """
    n = cfg.pulse_samples
    t = np.arange(n, dtype=np.float64) / cfg.fs
    replica = np.zeros(cfg.nr, np.complex128)
    replica[:n] = np.exp(1j * np.pi * cfg.kr * t**2)
    h = np.conj(np.fft.fft(replica))
    return h.real.astype(np.float32), h.imag.astype(np.float32)


def range_matched_filter_c(cfg: SceneConfig) -> np.ndarray:
    hr, hi = range_matched_filter(cfg)
    return (hr + 1j * hi).astype(np.complex64)


# ---------------------------------------------------------------------------
# RCMC (step 3)
# ---------------------------------------------------------------------------

def rcmc_shift_samples(cfg: SceneConfig) -> np.ndarray:
    """Range-invariant RCMC shift (in range samples) per Doppler row, (na,).

    delta_R(f_a) = r0 (1/D - 1), evaluated at the scene-center range (the
    paper's narrow-swath approximation).
    """
    d = migration_factor(cfg)
    return (cfg.r0 * (1.0 / d - 1.0) / cfg.dr).astype(np.float64)


def rcmc_shift_samples_variant(cfg: SceneConfig) -> np.ndarray:
    """Range-VARIANT shift (na, nr): delta_R(f_a, r) = r0(r)(1/D - 1)/dr."""
    d = migration_factor(cfg)[:, None]
    r = range_gates(cfg)[None, :]
    return r * (1.0 / d - 1.0) / cfg.dr


def rcmc_phase_uv(cfg: SceneConfig) -> tuple[np.ndarray, np.ndarray]:
    """Rank-1 phase parameters for the fused Fourier-shift RCMC.

    After a range FFT of the range-Doppler data, multiplying row f_a by
    exp(+i 2 pi k s(f_a) / nr) (k = FFT bin index, signed) shifts its content
    by -s samples, i.e. x_corr[col] = x[col + s]. Returns (u (na,), v (nr,))
    with phase = u[row] * v[col].
    """
    u = rcmc_shift_samples(cfg).astype(np.float32)
    v = (2.0 * np.pi * np.fft.fftfreq(cfg.nr)).astype(np.float32)
    return u, v


def sinc_interp_weights(frac: np.ndarray, taps: int = 8) -> np.ndarray:
    """Windowed-sinc interpolation weights, (len(frac), taps).

    Tap k (k = 0..taps-1) samples position floor(s) + k - taps//2 + 1; the
    weight is sinc(k - taps//2 + 1 - frac) * hamming window (the paper's
    8-tap sinc interpolator)."""
    offs = np.arange(taps) - taps // 2 + 1
    x = offs[None, :] - frac[:, None]
    w = np.sinc(x)
    ham = 0.54 + 0.46 * np.cos(np.pi * x / (taps // 2))
    w = w * np.where(np.abs(x) <= taps // 2, ham, 0.0)
    # normalize so DC gain is exactly 1
    return (w / np.sum(w, axis=1, keepdims=True)).astype(np.float32)


# ---------------------------------------------------------------------------
# Azimuth matched filter (step 4)
# ---------------------------------------------------------------------------

def azimuth_phase_uv(cfg: SceneConfig) -> tuple[np.ndarray, np.ndarray]:
    """Rank-1 azimuth-compression phase: H_a = exp(i u[col] v[row]).

    Exact hyperbolic filter with the bulk carrier removed:
      phase(f_a, r) = (4 pi fc / c) * r0(r) * (D(f_a) - 1)
    which factors as u[r] = r0(r) (meters), v[f_a] = 4 pi fc (D-1) / c.
    """
    u = range_gates(cfg).astype(np.float32)
    v = (4.0 * np.pi * cfg.fc * (migration_factor(cfg) - 1.0) / C).astype(np.float32)
    return u, v


def azimuth_phase_uv2(cfg: SceneConfig) -> tuple[np.ndarray, np.ndarray]:
    """Rank-2, float32-safe factorization of the azimuth-compression phase.

    The raw rank-1 product r0(r) * v(f_a) reaches ~10^3..10^4 radians, where
    float32 cos/sin loses ~1e-4 of phase. Splitting off the scene-center bulk
    term and wrapping it mod 2 pi in float64 keeps every float32 factor small:

      phase(f_a, r) = (r0(r) - r_ref) * v(f_a)  +  wrap(r_ref * v(f_a))

    Returns u (nr, 2), v (na, 2) for the FILTER_OUTER rank-K kernel
    (phase = sum_k u[col,k] * v[row,k])."""
    d = migration_factor(cfg)
    v1 = 4.0 * np.pi * cfg.fc * (d - 1.0) / C                  # (na,) f64
    rg = range_gates(cfg)                                       # (nr,) f64
    u = np.stack([rg - cfg.r0, np.ones_like(rg)], axis=1)
    wrapped = np.angle(np.exp(1j * (cfg.r0 * v1)))              # mod 2pi, f64
    v = np.stack([v1, wrapped], axis=1)
    return u.astype(np.float32), v.astype(np.float32)


def azimuth_matched_filter_c(cfg: SceneConfig) -> np.ndarray:
    """Full 2-D azimuth filter H_a(f_a, r), complex64 (na, nr) — the unfused
    baseline's explicit filter (and the fused FILTER_FULL variant's input)."""
    u, v = azimuth_phase_uv(cfg)
    phase = v[:, None].astype(np.float64) * u[None, :].astype(np.float64)
    return np.exp(1j * phase).astype(np.complex64)


def azimuth_matched_filter_split(cfg: SceneConfig) -> tuple[np.ndarray, np.ndarray]:
    h = azimuth_matched_filter_c(cfg)
    return h.real.astype(np.float32), h.imag.astype(np.float32)


# ---------------------------------------------------------------------------
# ω-K (range migration) terms
# ---------------------------------------------------------------------------

def range_freqs_unwrapped(cfg: SceneConfig) -> np.ndarray:
    """Range frequency axis unwrapped to [0, fs), (nr,) float64.

    The demodulated chirp is one-sided (instantaneous frequency sweeps
    0..B with B possibly beyond fs/2), so DFT bin b physically carries
    frequency (b/nr)·fs — NOT the signed fftfreq value. The ω-K dispersion
    sqrt((fc+f_r)² − …) must be evaluated on this unwrapped axis to
    compensate the right physical frequency per bin."""
    return np.arange(cfg.nr, dtype=np.float64) / cfg.nr * cfg.fs


def omegak_kmap(cfg: SceneConfig) -> np.ndarray:
    """K(f_a, f_r) = sqrt((fc+f_r)² − (c f_a / 2v)²), (na, nr) float64 —
    the 2-D wavenumber the ω-K reference function is built from."""
    fr = range_freqs_unwrapped(cfg)[None, :]
    fa = azimuth_freqs(cfg)[:, None]
    arg = (cfg.fc + fr) ** 2 - (C * fa / (2.0 * cfg.v)) ** 2
    return np.sqrt(np.maximum(arg, 1.0))


def omegak_stolt_phase(cfg: SceneConfig, r_ref: Optional[float] = None) -> np.ndarray:
    """Differential ω-K reference-function phase, complex64 (na, nr):

        H(f_a, f_r) = exp(+i 4π r_ref/c · (K(f_a,f_r) − fc − f_r))

    K − fc − f_r vanishes identically at f_a = 0, so this filter is exactly
    the *migration* part of the reference function: multiplied with the
    range matched filter it compensates bulk RCM and azimuth hyperbolic
    phase at r_ref through ALL orders of f_r (the paper-fused RDA only
    corrects the f_r-linear shift). Its own f_r-linear content is the
    fused Fourier-shift stage of the Stolt map — the first-order Stolt
    interpolation exp(i 2π f_r Δt(f_a)) applied in the same dispatch as
    the range FFT/IFFT pair, leaving only the range-variant residual
    (r − r_ref)(1/D − 1) that the RDA narrow-swath approximation also
    accepts. Computed float64, wrapped mod 2π, stored complex64."""
    r_ref = cfg.r0 if r_ref is None else r_ref
    fr = range_freqs_unwrapped(cfg)[None, :]
    k = omegak_kmap(cfg)
    phase = (4.0 * np.pi * r_ref / C) * (k - cfg.fc - fr)
    return np.exp(1j * np.mod(phase, 2.0 * np.pi)).astype(np.complex64)


def stolt_azimuth_uv(cfg: SceneConfig, r_ref: Optional[float] = None
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Residual ω-K azimuth compression, rank-1 phase for FILTER_OUTER:

        phase(f_a, r) = 4π fc (D(f_a) − 1) (r0(r) − r_ref) / c

    The bulk term at r_ref is already inside omegak_stolt_phase, so unlike
    the RDA rank-2 filter no wrapped-bulk column is needed; the residual
    factors are small enough for float32. u: (nr,) per range gate,
    v: (na,) per Doppler bin."""
    r_ref = cfg.r0 if r_ref is None else r_ref
    u = (range_gates(cfg) - r_ref).astype(np.float32)
    v = (4.0 * np.pi * cfg.fc * (migration_factor(cfg) - 1.0) / C
         ).astype(np.float32)
    return u, v


# ---------------------------------------------------------------------------
# SpectralPlan filter registry — the names plans reference
# ---------------------------------------------------------------------------

def _register_plan_filters() -> None:
    from repro.core import plan
    from repro.kernels.fft4step import FILTER_FULL, FILTER_OUTER, FILTER_SHARED

    plan.register_filter(
        "range_mf", FILTER_SHARED,
        lambda cfg, p: range_matched_filter_c(cfg))
    plan.register_filter(
        "azimuth_mf", FILTER_FULL,
        lambda cfg, p: azimuth_matched_filter_c(cfg))
    plan.register_filter(
        "azimuth_mf_outer", FILTER_OUTER,
        lambda cfg, p: azimuth_phase_uv2(cfg))
    plan.register_filter(
        "rcmc_shift", FILTER_OUTER,
        lambda cfg, p: rcmc_phase_uv(cfg))
    plan.register_filter(
        "omegak_stolt", FILTER_FULL,
        lambda cfg, p: omegak_stolt_phase(cfg, p.get("r_ref")))
    plan.register_filter(
        "stolt_az", FILTER_OUTER,
        lambda cfg, p: stolt_azimuth_uv(cfg, p.get("r_ref")))


_register_plan_filters()
