"""Chirp-signal point-target raw-echo simulator (paper Sec. V-A).

Generates the demodulated baseband echo matrix (na x nr, complex64) for a set
of point targets under the hyperbolic range equation

    R_k(eta) = sqrt(r0_k^2 + v^2 (eta - eta_k)^2),

with a linear-FM transmitted chirp and rectangular range/azimuth windows, plus
additive circular Gaussian noise at the configured raw SNR (paper: 20 dB).

Pure jnp; vectorized over the full (na, nr) grid per target so the simulator
itself runs on-device and is jit-able.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import enable_x64
from repro.core.sar.geometry import C, PointTarget, SceneConfig


def time_axes(cfg: SceneConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(slow_time (na,), fast_time (nr,)) centered on the scene center."""
    eta = (jnp.arange(cfg.na, dtype=jnp.float64) - cfg.na / 2) / cfg.prf
    # fast time window centered on the scene-center two-way delay
    t0 = 2.0 * cfg.r0 / C
    t = t0 + (jnp.arange(cfg.nr, dtype=jnp.float64) - cfg.nr / 2) / cfg.fs
    return eta, t


def _target_echo(cfg: SceneConfig, eta, t, tgt: PointTarget) -> jnp.ndarray:
    """Echo of one point target on the (na, nr) grid, complex64."""
    r0k = cfg.r0 + tgt.range_offset
    etak = tgt.azimuth_offset / cfg.v
    # instantaneous slant range, (na, 1)
    rk = jnp.sqrt(r0k**2 + (cfg.v * (eta - etak)) ** 2)[:, None]
    tau = 2.0 * rk / C                       # two-way delay
    dt = t[None, :] - tau                    # fast time relative to echo start
    # windows
    w_r = (jnp.abs(dt - cfg.tp / 2) <= cfg.tp / 2).astype(jnp.float64)
    w_a = (jnp.abs(eta - etak) <= cfg.aperture_time / 2).astype(jnp.float64)[:, None]
    # carrier phase + chirp phase (float64 host math keeps 2*pi*fc*tau exact
    # enough; the stored echo is complex64 like the paper's FP32 data)
    phase = -2.0 * jnp.pi * cfg.fc * tau + jnp.pi * cfg.kr * dt**2
    echo = tgt.sigma * w_r * w_a * jnp.exp(1j * phase)
    return echo.astype(jnp.complex64)


def simulate(cfg: SceneConfig, targets: list[PointTarget],
             add_noise: bool = True) -> jnp.ndarray:
    """Raw echo matrix (na, nr) complex64 for all targets (+ noise)."""
    cfg.validate()
    with enable_x64(True):
        eta, t = time_axes(cfg)
        acc = jnp.zeros((cfg.na, cfg.nr), jnp.complex64)
        for tgt in targets:
            acc = acc + _target_echo(cfg, eta, t, tgt)
    if add_noise and cfg.noise_db is not None:
        # raw per-sample echo power within the support is sigma^2; scale noise
        # for the configured raw SNR
        snr_lin = 10.0 ** (cfg.noise_db / 10.0)
        sigma_n = float(np.sqrt(1.0 / (2.0 * snr_lin)))
        key = jax.random.PRNGKey(cfg.seed)
        k1, k2 = jax.random.split(key)
        noise = (jax.random.normal(k1, acc.shape, jnp.float32) +
                 1j * jax.random.normal(k2, acc.shape, jnp.float32)) * sigma_n
        acc = acc + noise.astype(jnp.complex64)
    return acc


@functools.lru_cache(maxsize=4)
def _cached_scene_np(cfg: SceneConfig, targets: tuple[PointTarget, ...],
                     add_noise: bool) -> np.ndarray:
    return np.asarray(simulate(cfg, list(targets), add_noise))


def simulate_cached(cfg: SceneConfig, targets: list[PointTarget],
                    add_noise: bool = True) -> np.ndarray:
    """Host-cached simulator (tests reuse the same scene repeatedly)."""
    return _cached_scene_np(cfg, tuple(targets), add_noise)
