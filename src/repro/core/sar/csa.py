"""Chirp Scaling Algorithm as a SpectralPlan (Raney et al. 1994; C&W ch. 7).

The embedded-GPU systems the paper compares against in Table V run CSA, so
we implement it as a baseline: it trades RCMC interpolation for three phase
multiplies (chirp scaling -> bulk RCMC + range compression in the 2-D
spectrum -> azimuth compression + residual phase), i.e. it is
FFT-and-multiply only.

That structure makes CSA *entirely* expressible as a plan — ONE stage list
serves both baselines: compiled with the XLA backend unfused it is the
7-dispatch textbook CSA; compiled with the Pallas backend the fusion pass
collapses it to 3 single-dispatch stages

  1. cols: FFT_az -> * H1                      (fused, FILTER_FULL)
  2. rows: FFT_r  -> * H2 -> IFFT_r            (the paper's kernel verbatim)
  3. cols:        -> * H3 -> IFFT_az           (fused, FILTER_FULL)

with no transposes — a beyond-paper demonstration that the fusion idea
covers the competitor algorithm too.

Like the RDA plans, the compiled pipeline accepts one scene (na, nr) or a
batch (B, na, nr) sharing the SceneConfig; the phase screens are computed
once (and cached per (cfg, plan)) and broadcast across the batch.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import plan as planlib
from repro.core.plan import Pipeline, SpectralPlan, Stage
from repro.core.sar import filters
from repro.core.sar.geometry import C, SceneConfig
from repro.kernels.fft4step import FILTER_FULL


def _csa_terms(cfg: SceneConfig, r_ref: Optional[float] = None):
    """Host-side (float64) CSA phase terms.

    Returns dict with, all in FFT ordering:
      cs      (na,)  curvature factor Cs(f_a) = 1/D - 1
      km      (na,)  range FM rate modified by range-azimuth coupling
      tau_ref (na,)  reference delay 2 R_ref / (c D)
      tau     (nr,)  absolute fast-time axis
      fr      (nr,)  range frequency axis
    """
    r_ref = cfg.r0 if r_ref is None else r_ref
    d = filters.migration_factor(cfg)                      # (na,)
    cs = 1.0 / d - 1.0
    fa = filters.azimuth_freqs(cfg)
    km = cfg.kr / (1.0 - cfg.kr * C * r_ref * fa**2 /
                   (2.0 * cfg.v**2 * cfg.fc**3 * d**3))
    tau_ref = 2.0 * r_ref / (C * d)
    t0 = 2.0 * cfg.r0 / C
    tau = t0 + (np.arange(cfg.nr) - cfg.nr / 2) / cfg.fs
    fr = filters.range_freqs(cfg)
    return dict(r_ref=r_ref, d=d, cs=cs, km=km, tau_ref=tau_ref, tau=tau,
                fr=fr, fa=fa)


def csa_phases(cfg: SceneConfig, r_ref: Optional[float] = None):
    """The three CSA phase screens, complex64 (computed in float64, wrapped).

    h1 (na, nr): chirp scaling           exp(+i pi Km Cs (tau - tau_ref)^2)
    h2 (na, nr): range compression + bulk RCMC over (f_a, f_r):
                 exp(+i pi D f_r^2 / Km) * exp(+i 4 pi f_r R_ref Cs / c)
    h3 (na, nr): azimuth MF (bulk-removed) + residual phase:
                 exp(+i 4 pi fc r0 (D-1) / c) * exp(-i 4 pi Km (1+Cs) Cs
                                                     (r0 - R_ref)^2 / c^2)
    """
    t = _csa_terms(cfg, r_ref)
    cs, km, tau_ref = t["cs"][:, None], t["km"][:, None], t["tau_ref"][:, None]
    d = t["d"][:, None]
    tau, fr = t["tau"][None, :], t["fr"][None, :]

    ph1 = np.pi * km * cs * (tau - tau_ref) ** 2
    h1 = np.exp(1j * np.mod(ph1, 2 * np.pi)).astype(np.complex64)

    ph2 = np.pi * d * fr**2 / km + 4.0 * np.pi * fr * t["r_ref"] * cs / C
    h2 = np.exp(1j * np.mod(ph2, 2 * np.pi)).astype(np.complex64)

    r0_gate = filters.range_gates(cfg)[None, :]
    ph3 = (4.0 * np.pi * cfg.fc * (d - 1.0) / C) * r0_gate \
        - 4.0 * np.pi * km * (1.0 + cs) * cs * (r0_gate - t["r_ref"]) ** 2 / C**2
    h3 = np.exp(1j * np.mod(ph3, 2 * np.pi)).astype(np.complex64)
    return h1, h2, h3


planlib.register_filter(
    "csa_h1", FILTER_FULL,
    lambda cfg, p: csa_phases(cfg, p.get("r_ref"))[0])
planlib.register_filter(
    "csa_h2", FILTER_FULL,
    lambda cfg, p: csa_phases(cfg, p.get("r_ref"))[1])
planlib.register_filter(
    "csa_h3", FILTER_FULL,
    lambda cfg, p: csa_phases(cfg, p.get("r_ref"))[2])


def plan_csa(r_ref: Optional[float] = None) -> SpectralPlan:
    """One stage list for both CSA baselines (see module docstring)."""
    params = () if r_ref is None else (("r_ref", float(r_ref)),)
    return SpectralPlan("csa", (
        Stage("azimuth_fft", axis=0, fwd=True),
        Stage("chirp_scaling", axis=0, filters=("csa_h1",)),
        Stage("range_comp_rcmc", axis=1, fwd=True, inv=True,
              filters=("csa_h2",)),
        Stage("azimuth_compression", axis=0, inv=True, filters=("csa_h3",)),
    ), params=params)


planlib.register_variant(
    "csa", plan_csa,
    compile_defaults=(("backend", planlib.BACKEND_XLA), ("fuse", False)),
    plan_kw=("r_ref",), dispatches=7)
planlib.register_variant(
    "csa_fused", plan_csa, plan_kw=("r_ref",), dispatches=3)
# The competitor algorithm through the megakernel: the SAME stage list
# under the cross-axis grammar is ONE dispatch — the 2-D phase screens
# ride along as FULL filters (DMA-sliced per line block in staged mode).
planlib.register_variant(
    "csa_fused1", plan_csa,
    compile_defaults=(("fuse", planlib.FUSE_MEGA),),
    plan_kw=("r_ref",), dispatches=1)


def build_csa(cfg: SceneConfig, r_ref: Optional[float] = None,
              **kw) -> Pipeline:
    """Unfused CSA: 4 FFT stages + 3 phase multiplies, one XLA op each."""
    return planlib.build_variant(cfg, "csa", r_ref=r_ref, **kw)


def build_csa_fused(cfg: SceneConfig, r_ref: Optional[float] = None,
                    **kw) -> Pipeline:
    """The competitor algorithm through the paper's fused kernel:
    3 single-dispatch stages, no transposes."""
    return planlib.build_variant(cfg, "csa_fused", r_ref=r_ref, **kw)
