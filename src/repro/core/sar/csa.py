"""Chirp Scaling Algorithm baseline (Raney et al. 1994; Cumming & Wong ch. 7).

The embedded-GPU systems the paper compares against in Table V run CSA, so we
implement it as a baseline: it trades RCMC interpolation for three phase
multiplies (chirp scaling -> bulk RCMC + range compression in the 2-D spectrum
-> azimuth compression + residual phase), i.e. it is FFT-and-multiply only.

That structure makes CSA *entirely* expressible with the paper's fused
spectral kernel — every step is [FFT] * phase * [IFFT]; `build_csa_fused`
runs it in 4 fused dispatches (a beyond-paper demonstration that the fusion
idea covers the competitor algorithm too).

Like the RDA pipelines, both builders accept one scene (na, nr) or a batch
(B, na, nr) sharing the SceneConfig; the phase screens are computed once
and broadcast across the batch, and the fused variant runs each stage as a
single batched Pallas dispatch.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.sar import filters
from repro.core.sar.geometry import C, SceneConfig
from repro.core.sar.rda import Pipeline, Step, split, unsplit
from repro.kernels import ops


def _csa_terms(cfg: SceneConfig, r_ref: Optional[float] = None):
    """Host-side (float64) CSA phase terms.

    Returns dict with, all in FFT ordering:
      cs      (na,)  curvature factor Cs(f_a) = 1/D - 1
      km      (na,)  range FM rate modified by range-azimuth coupling
      tau_ref (na,)  reference delay 2 R_ref / (c D)
      tau     (nr,)  absolute fast-time axis
      fr      (nr,)  range frequency axis
    """
    r_ref = cfg.r0 if r_ref is None else r_ref
    d = filters.migration_factor(cfg)                      # (na,)
    cs = 1.0 / d - 1.0
    fa = filters.azimuth_freqs(cfg)
    km = cfg.kr / (1.0 - cfg.kr * C * r_ref * fa**2 /
                   (2.0 * cfg.v**2 * cfg.fc**3 * d**3))
    tau_ref = 2.0 * r_ref / (C * d)
    t0 = 2.0 * cfg.r0 / C
    tau = t0 + (np.arange(cfg.nr) - cfg.nr / 2) / cfg.fs
    fr = filters.range_freqs(cfg)
    return dict(r_ref=r_ref, d=d, cs=cs, km=km, tau_ref=tau_ref, tau=tau,
                fr=fr, fa=fa)


def csa_phases(cfg: SceneConfig, r_ref: Optional[float] = None):
    """The three CSA phase screens, complex64 (computed in float64, wrapped).

    h1 (na, nr): chirp scaling           exp(+i pi Km Cs (tau - tau_ref)^2)
    h2 (na, nr): range compression + bulk RCMC over (f_a, f_r):
                 exp(+i pi D f_r^2 / Km) * exp(+i 4 pi f_r R_ref Cs / c)
    h3 (na, nr): azimuth MF (bulk-removed) + residual phase:
                 exp(+i 4 pi fc r0 (D-1) / c) * exp(-i 4 pi Km (1+Cs) Cs
                                                     (r0 - R_ref)^2 / c^2)
    """
    t = _csa_terms(cfg, r_ref)
    cs, km, tau_ref = t["cs"][:, None], t["km"][:, None], t["tau_ref"][:, None]
    d = t["d"][:, None]
    tau, fr = t["tau"][None, :], t["fr"][None, :]

    ph1 = np.pi * km * cs * (tau - tau_ref) ** 2
    h1 = np.exp(1j * np.mod(ph1, 2 * np.pi)).astype(np.complex64)

    ph2 = np.pi * d * fr**2 / km + 4.0 * np.pi * fr * t["r_ref"] * cs / C
    h2 = np.exp(1j * np.mod(ph2, 2 * np.pi)).astype(np.complex64)

    r0_gate = filters.range_gates(cfg)[None, :]
    ph3 = (4.0 * np.pi * cfg.fc * (d - 1.0) / C) * r0_gate \
        - 4.0 * np.pi * km * (1.0 + cs) * cs * (r0_gate - t["r_ref"]) ** 2 / C**2
    h3 = np.exp(1j * np.mod(ph3, 2 * np.pi)).astype(np.complex64)
    return h1, h2, h3


def build_csa(cfg: SceneConfig, r_ref: Optional[float] = None) -> Pipeline:
    """Unfused CSA: 4 FFT stages + 3 phase multiplies, one XLA op each."""
    h1, h2, h3 = (jnp.asarray(h) for h in csa_phases(cfg, r_ref))

    def az_fft(x):
        return jnp.fft.fft(x, axis=-2)

    def chirp_scale(x):
        return x * h1

    def range_fft_mult_ifft(x):
        return jnp.fft.ifft(jnp.fft.fft(x, axis=-1) * h2, axis=-1)

    def az_compress(x):
        return jnp.fft.ifft(x * h3, axis=-2)

    return Pipeline("csa", cfg, [
        Step("azimuth_fft", az_fft, 1, 1, False),
        Step("chirp_scaling", chirp_scale, 1, 1, False),
        Step("range_comp_rcmc", range_fft_mult_ifft, 3, 3, False),
        Step("azimuth_compression", az_compress, 2, 2, False),
    ])


def build_csa_fused(cfg: SceneConfig, r_ref: Optional[float] = None,
                    interpret: Optional[bool] = None, block: int = 8,
                    col_block: int = 128, fft_impl: str = "matmul") -> Pipeline:
    """Beyond-paper: the competitor algorithm run through the paper's fused
    kernel — 3 single-dispatch stages, no transposes:

      1. cols: FFT_az -> * H1                      (fused, FILTER_FULL)
      2. rows: FFT_r  -> * H2 -> IFFT_r            (the paper's kernel verbatim)
      3. cols:        -> * H3 -> IFFT_az           (fused, FILTER_FULL)
    """
    h1, h2, h3 = csa_phases(cfg, r_ref)
    h1r, h1i = jnp.asarray(h1.real), jnp.asarray(h1.imag)
    h2r, h2i = jnp.asarray(h2.real), jnp.asarray(h2.imag)
    h3r, h3i = jnp.asarray(h3.real), jnp.asarray(h3.imag)
    rkw = dict(interpret=interpret, block=block, fft_impl=fft_impl)
    ckw = dict(interpret=interpret, block=col_block, fft_impl=fft_impl)

    def az_fft_scale(x):
        xr, xi = split(x)
        yr, yi = ops.spectral_op(xr, xi, hr=h1r, hi=h1i, fwd=True, inv=False,
                                 axis=0, filter_mode="full", **ckw)
        return unsplit(yr, yi)

    def range_fused(x):
        xr, xi = split(x)
        yr, yi = ops.spectral_op(xr, xi, hr=h2r, hi=h2i, fwd=True, inv=True,
                                 axis=1, filter_mode="full", **rkw)
        return unsplit(yr, yi)

    def az_compress(x):
        xr, xi = split(x)
        yr, yi = ops.spectral_op(xr, xi, hr=h3r, hi=h3i, fwd=False, inv=True,
                                 axis=0, filter_mode="full", **ckw)
        return unsplit(yr, yi)

    return Pipeline("csa_fused", cfg, [
        Step("az_fft_chirp_scale", az_fft_scale, 1, 1, True),
        Step("range_comp_rcmc", range_fused, 1, 1, True),
        Step("azimuth_compression", az_compress, 1, 1, True),
    ])
