"""ω-K / range-migration algorithm — proof the SpectralPlan IR generalizes.

The third focusing algorithm in this repo, and the reason the plan IR
exists: ω-K is *only* a plan. There is no bespoke executor code here — the
whole algorithm is three declarative stages handed to the shared compiler
(core/plan.py), which fuses them into 3 single-dispatch Pallas stages with
zero transposes, exactly like the reordered RDA and the fused CSA.

Algorithm (Cumming & Wong ch. 8, first-order Stolt):

  1. azimuth FFT                                       (cols dispatch)
  2. range FFT -> H_mf(f_r) * H_stolt(f_a, f_r) -> range IFFT
                                                       (rows dispatch)
  3. residual azimuth compression * azimuth IFFT       (cols dispatch)

Stage 2 is where ω-K differs from the RDA: the 2-D spectrum of a point
target at range r is exp(-i 4π r/c · K(f_a, f_r)) with
K = sqrt((fc+f_r)² − (c f_a/2v)²), and the reference-function multiply
H_stolt = exp(+i 4π r_ref/c (K − fc − f_r)) compensates range-azimuth
coupling at r_ref through ALL orders of f_r. The exact Stolt mapping would
then resample f_r so K becomes linear for every range; its first-order
(shift) term exp(i 2π f_r Δt(f_a)) is already inside H_stolt and is
applied as a fused Fourier-shift in the same dispatch as the range
FFT/IFFT pair — the plan compiler composes the shared range matched
filter with the full 2-D Stolt phase into ONE kernel filter. The
neglected warp term leaves the residual RCM (r − r_ref)(1/D − 1), the
same narrow-swath remainder the RDA's range-invariant RCMC accepts, so
ω-K peak positions match the RDA reference to within a pixel (asserted
in tests/test_plan.py).

Because H_stolt ≡ 1 at f_a = 0, stage 2 degenerates to the paper's exact
range compression there — peaks land on the same range columns as the RDA.
Stage 3 removes the azimuth phase left for r ≠ r_ref,
exp(+i 4π fc (D−1)(r − r_ref)/c), a float32-safe rank-1 FILTER_OUTER
phase synthesized in VMEM (no 2-D filter I/O).

Usage::

    from repro.core.sar import focus
    image = focus(raw, cfg, variant="omegak")            # 3 fused dispatches
"""
from __future__ import annotations

from typing import Optional

from repro.core import plan as planlib
from repro.core.plan import SpectralPlan, Stage


def plan_omegak(r_ref: Optional[float] = None) -> SpectralPlan:
    """The ω-K plan. r_ref: Stolt reference range (default scene center)."""
    params = () if r_ref is None else (("r_ref", float(r_ref)),)
    return SpectralPlan("omegak", (
        Stage("azimuth_fft", axis=0, fwd=True),
        Stage("range_rfm_stolt", axis=1, fwd=True, inv=True,
              filters=("range_mf", "omegak_stolt")),
        Stage("azimuth_compression", axis=0, inv=True, filters=("stolt_az",)),
    ), params=params)


planlib.register_variant(
    "omegak", plan_omegak, plan_kw=("r_ref",), dispatches=3)
# ω-K through the cross-axis megakernel grammar: the same three stages as
# ONE single-dispatch step (in-kernel corner turns; the full 2-D Stolt
# screen is a FULL filter, DMA-sliced per block in staged mode).
planlib.register_variant(
    "omegak_fused1", plan_omegak,
    compile_defaults=(("fuse", planlib.FUSE_MEGA),),
    plan_kw=("r_ref",), dispatches=1)
