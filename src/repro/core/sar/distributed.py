"""Multi-device SAR: shard_map RDA with corner-turn collectives.

A SAR scene alternates between row-local (range) and column-local (azimuth)
stages, so the classic multi-node schedule is a "corner turn" — an all-to-all
that re-shards the matrix from azimuth-sharded to range-sharded. Two
schedules are provided (the collective-bytes trade-off is a §Perf experiment):

``corner2``  The 3-dispatch RDA (rda.build_fused3) distributed directly:
             azimuth stages run on column slabs, the fused range stage on row
             slabs, with a corner turn before and after it. 2 all-to-alls,
             every compute stage a single fused Pallas dispatch.

``halo``     The paper-ordered pipeline with ONE corner turn: range
             compression is row-local on the natural (azimuth-sharded) raw
             layout; after one corner turn the azimuth FFT + azimuth
             compression are column-local, and RCMC (which gathers at most
             `halo` range cells across the cut) uses a halo exchange with the
             two ring neighbours (collective_permute) instead of a second
             all-to-all. all_to_all bytes halve; permute bytes are
             O(halo/nr_local) of a corner turn.

Both return the focused image range-sharded (na, nr/P). Ingest layouts differ
(each matches a physically sensible way to distribute arriving pulses):
  corner2: raw sharded P(None, axes) — each pulse scattered across devices
           (range-sharded ingest; azimuth stages are then immediately local)
  halo:    raw sharded P(axes, None) — pulses round-robined across devices
           (pulse-sharded ingest; range compression is immediately local)
  output image (na, nr) sharded P(None, axes) — range columns distributed
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.sar import filters
from repro.core.sar.geometry import SceneConfig
from repro.core.sar.rda import split, unsplit
from repro.kernels import ops


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


# ---------------------------------------------------------------------------
# Schedule 1: two corner turns around the fused range stage
# ---------------------------------------------------------------------------

def build_corner2(cfg: SceneConfig, mesh: Mesh, axes=("data",),
                  interpret: Optional[bool] = None, block: int = 8,
                  col_block: int = 8, fft_impl: str = "matmul",
                  turn_dtype=None):
    """Returns jit-able fn(raw (na, nr) complex64) -> image, both sharded.

    turn_dtype: optional dtype for the corner-turn payload (e.g.
    jnp.bfloat16) — halves the dominant collective term; quality impact is
    measured in tests (§Perf-SAR iteration 3)."""
    p = _axis_size(mesh, axes)
    if cfg.nr % p or cfg.na % p:
        raise ValueError(f"scene {cfg.na}x{cfg.nr} not divisible by {p} devices")

    hr_r, hr_i = (jnp.asarray(a) for a in filters.range_matched_filter(cfg))
    rc_u, rc_v = (jnp.asarray(a) for a in filters.rcmc_phase_uv(cfg))
    az_u2, az_v2 = (jnp.asarray(a) for a in filters.azimuth_phase_uv2(cfg))
    rkw = dict(interpret=interpret, block=block, fft_impl=fft_impl)
    ckw = dict(interpret=interpret, block=col_block, fft_impl=fft_impl)

    def turn(x, split_axis, concat_axis):
        dt = x.dtype
        if turn_dtype is not None:
            # bf16 wire format for the turn: the FFT magnitudes are
            # O(sqrt(N)) and bf16's 8-bit mantissa costs ~2e-3 relative —
            # validated acceptable for imaging (SNR delta < 0.01 dB). The
            # optimization_barrier pins the converts to the collective's two
            # sides so XLA cannot re-widen the payload.
            x = jax.lax.optimization_barrier(x.astype(turn_dtype))
        x = jax.lax.all_to_all(x, axes, split_axis, concat_axis, tiled=True)
        if turn_dtype is not None:
            x = jax.lax.optimization_barrier(x)
        return x.astype(dt)

    def local(xr, xi, rc_u_blk, az_u2_blk):
        # in: (na, nr/P) column slab; azimuth lines complete per column.
        xr, xi = ops.fft_cols(xr, xi, **ckw)                 # dispatch 1
        # corner turn -> (na/P, nr) row slab (rows = azimuth freq)
        xr = turn(xr, 0, 1)
        xi = turn(xi, 0, 1)
        xr, xi = ops.fused_rc_rcmc_rows(
            xr, xi, hr_r, hr_i, rc_u_blk, rc_v, **rkw)       # dispatch 2
        # corner turn back -> (na, nr/P)
        xr = turn(xr, 1, 0)
        xi = turn(xi, 1, 0)
        xr, xi = ops.fused_mult_ifft_cols_outer(
            xr, xi, az_u2_blk, az_v2, **ckw)                 # dispatch 3
        return xr, xi

    shard = functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, axes), P(None, axes), P(axes), P(axes, None)),
        out_specs=(P(None, axes), P(None, axes)), check_vma=False)

    @jax.jit
    def run(raw):
        xr, xi = split(raw)
        # rc_u is per azimuth-frequency row -> sharded with the row slabs;
        # az_u2 is per range gate -> sharded with the column slabs.
        yr, yi = shard(local)(xr, xi, rc_u, az_u2)
        return unsplit(yr, yi)

    return run


# ---------------------------------------------------------------------------
# Schedule 2: one corner turn + halo-exchange RCMC
# ---------------------------------------------------------------------------

def _halo_rcmc(xr, xi, cfg: SceneConfig, axes, halo: int, p: int,
               taps: int = 8):
    """Sinc-interp RCMC on an (na, nr/P) column slab with ring halo exchange.

    Every row's shift is <= halo - taps//2 cells, so each device only needs
    `halo` columns from its right neighbour (shifts are non-negative: the
    migration curve always moves content to larger range).
    """
    s = jnp.asarray(filters.rcmc_shift_samples(cfg), jnp.float32)[:, None]
    base = jnp.floor(s)
    frac = s - base
    offs = np.arange(taps) - taps // 2 + 1
    xk = offs[None, None, :] - frac[..., None]
    w = jnp.sinc(xk) * jnp.where(
        jnp.abs(xk) <= taps // 2,
        0.54 + 0.46 * jnp.cos(np.pi * xk / (taps // 2)), 0.0)
    w = w / jnp.sum(w, axis=-1, keepdims=True)

    # halo exchange with both ring neighbours (the shift is non-negative, but
    # the sinc taps reach taps//2 - 1 cells to the left). p is the static
    # device count along `axes` (jax.lax.axis_size is newer-jax-only).
    lh = taps // 2
    perm_r = [((i + 1) % p, i) for i in range(p)]  # right neighbour -> me
    perm_l = [((i - 1) % p, i) for i in range(p)]  # left neighbour -> me

    def with_halo(x):
        from_right = jax.lax.ppermute(x[:, :halo], axes, perm_r)
        from_left = jax.lax.ppermute(x[:, -lh:], axes, perm_l)
        return jnp.concatenate([from_left, x, from_right], axis=1)

    hxr, hxi = with_halo(xr), with_halo(xi)
    nr_loc = xr.shape[1]
    cols = jnp.arange(nr_loc, dtype=jnp.int32)[None, :]
    yr = jnp.zeros_like(xr)
    yi = jnp.zeros_like(xi)
    for k in range(taps):
        idx = jnp.clip(cols + lh + base.astype(jnp.int32) + offs[k], 0,
                       nr_loc + lh + halo - 1)
        wk = w[..., k]
        yr = yr + jnp.take_along_axis(hxr, jnp.broadcast_to(idx, xr.shape), 1) * wk
        yi = yi + jnp.take_along_axis(hxi, jnp.broadcast_to(idx, xi.shape), 1) * wk
    return yr, yi


def build_halo(cfg: SceneConfig, mesh: Mesh, axes=("data",),
               interpret: Optional[bool] = None, block: int = 8,
               col_block: int = 8, fft_impl: str = "matmul",
               halo: Optional[int] = None):
    p = _axis_size(mesh, axes)
    if cfg.nr % p or cfg.na % p:
        raise ValueError(f"scene {cfg.na}x{cfg.nr} not divisible by {p} devices")
    max_shift = float(np.max(filters.rcmc_shift_samples(cfg)))
    halo = halo or int(np.ceil(max_shift)) + 8
    if halo > cfg.nr // p:
        # the halo premise (halo << nr/P) fails: each device would need more
        # than its whole neighbour slab, i.e. the exchange degenerates to a
        # corner turn. Applicability bound recorded in EXPERIMENTS.md §Perf.
        raise ValueError("halo exceeds local slab width; use corner2")

    hr_r, hr_i = (jnp.asarray(a) for a in filters.range_matched_filter(cfg))
    az_u2, az_v2 = (jnp.asarray(a) for a in filters.azimuth_phase_uv2(cfg))
    rkw = dict(interpret=interpret, block=block, fft_impl=fft_impl)
    ckw = dict(interpret=interpret, block=col_block, fft_impl=fft_impl)

    def local(xr, xi, az_u2_blk):
        # in: (na/P, nr) row slab — the raw data's natural layout.
        xr, xi = ops.fused_fft_mult_ifft_rows(xr, xi, hr_r, hr_i, **rkw)  # 1
        # the single corner turn -> (na, nr/P)
        xr = jax.lax.all_to_all(xr, axes, 1, 0, tiled=True)
        xi = jax.lax.all_to_all(xi, axes, 1, 0, tiled=True)
        xr, xi = ops.fft_cols(xr, xi, **ckw)                              # 2
        xr, xi = _halo_rcmc(xr, xi, cfg, axes, halo, p)                   # 3
        xr, xi = ops.fused_mult_ifft_cols_outer(
            xr, xi, az_u2_blk, az_v2, **ckw)                              # 4
        return xr, xi

    shard = functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axes, None), P(axes, None), P(axes)),
        out_specs=(P(None, axes), P(None, axes)), check_vma=False)

    @jax.jit
    def run(raw):
        xr, xi = split(raw)
        yr, yi = shard(local)(xr, xi, az_u2)
        return unsplit(yr, yi)

    return run


SCHEDULES = {"corner2": build_corner2, "halo": build_halo}


def distributed_focus(raw, cfg: SceneConfig, mesh: Mesh, axes=("data",),
                      schedule: str = "corner2", **kw):
    return SCHEDULES[schedule](cfg, mesh, axes, **kw)(raw)
