"""Multi-device SAR: shard_map RDA with corner-turn collectives.

A SAR scene alternates between row-local (range) and column-local (azimuth)
stages, so the classic multi-node schedule is a "corner turn" — an all-to-all
that re-shards the matrix from azimuth-sharded to range-sharded. Two
schedules are provided (the collective-bytes trade-off is a §Perf experiment):

``corner2``  The 3-dispatch RDA (rda.build_fused3) distributed directly:
             azimuth stages run on column slabs, the fused range stage on row
             slabs, with a corner turn before and after it. 2 all-to-alls,
             every compute stage a single fused Pallas dispatch.

``halo``     The paper-ordered pipeline with ONE corner turn: range
             compression is row-local on the natural (azimuth-sharded) raw
             layout; after one corner turn the azimuth FFT + azimuth
             compression are column-local, and RCMC (which gathers at most
             `halo` range cells across the cut) uses a halo exchange with the
             two ring neighbours (collective_permute) instead of a second
             all-to-all. all_to_all bytes halve; permute bytes are
             O(halo/nr_local) of a corner turn.

Beyond the two hand-written schedules, `lower_pipeline` lowers ANY
transpose-free compiled plan — including the single-dispatch megakernel
family (fused1 / csa_fused1 / omegak_fused1): a mega step splits at its
in-kernel corner-turn boundaries into per-device segment groups, one
megakernel dispatch per device per group, with the turns between groups
becoming the all_to_alls (docs/distributed.md §Mega lowering).

Both return the focused image range-sharded (na, nr/P). Ingest layouts differ
(each matches a physically sensible way to distribute arriving pulses):
  corner2: raw sharded P(None, axes) — each pulse scattered across devices
           (range-sharded ingest; azimuth stages are then immediately local)
  halo:    raw sharded P(axes, None) — pulses round-robined across devices
           (pulse-sharded ingest; range compression is immediately local)
  output image (na, nr) sharded P(None, axes) — range columns distributed
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.sar import filters
from repro.kernels.fft4step import (
    FILTER_FULL,
    FILTER_NONE,
    FILTER_OUTER,
    FILTER_SHARED,
    FILTER_SHARED_OUTER,
    resolve_precision,
)
from repro.core.sar.geometry import SceneConfig
from repro.core.sar.rda import split, unsplit
from repro.kernels import ops


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def make_sar_mesh(axes=("data",), devices=None) -> Mesh:
    """A corner-turn-friendly mesh over every visible device, multi-host
    capable.

    Devices sort by ``(process_index, id)`` so each host owns a CONTIGUOUS
    block of the sharded axis (the corner2 layout): a corner-turn
    all_to_all then moves the bulk of its (P-1)/P payload between
    neighbouring slabs on the same host's links, and only the slab
    fraction crossing a host boundary rides the network. With two axis
    names the mesh is processes x local-devices (e.g. ``("pod", "data")``
    for per-host sharding with a pod axis for data parallelism); with one
    it is the flat 1-D mesh every single-host path uses today.
    """
    if isinstance(axes, str):
        axes = (axes,)
    if devices is None:
        devices = sorted(jax.devices(),
                         key=lambda d: (d.process_index, d.id))
    devs = np.asarray(devices, dtype=object)
    if len(axes) == 1:
        return Mesh(devs, axes)
    if len(axes) == 2:
        nproc = len({d.process_index for d in devices})
        if nproc == 0 or len(devices) % nproc:
            raise ValueError(
                f"{len(devices)} devices do not tile {nproc} processes")
        return Mesh(devs.reshape(nproc, -1), axes)
    raise ValueError(f"make_sar_mesh supports 1 or 2 axis names, got "
                     f"{axes!r}")


# ---------------------------------------------------------------------------
# Schedule 1: two corner turns around the fused range stage
# ---------------------------------------------------------------------------

def build_corner2(cfg: SceneConfig, mesh: Mesh, axes=("data",),
                  interpret: Optional[bool] = None, block: int = 8,
                  col_block: int = 8, fft_impl: str = "matmul",
                  turn_dtype=None):
    """Returns jit-able fn(raw (na, nr) complex64) -> image, both sharded.

    turn_dtype: optional dtype for the corner-turn payload (e.g.
    jnp.bfloat16) — halves the dominant collective term; quality impact is
    measured in tests (§Perf-SAR iteration 3)."""
    p = _axis_size(mesh, axes)
    if cfg.nr % p or cfg.na % p:
        raise ValueError(f"scene {cfg.na}x{cfg.nr} not divisible by {p} devices")

    hr_r, hr_i = (jnp.asarray(a) for a in filters.range_matched_filter(cfg))
    rc_u, rc_v = (jnp.asarray(a) for a in filters.rcmc_phase_uv(cfg))
    az_u2, az_v2 = (jnp.asarray(a) for a in filters.azimuth_phase_uv2(cfg))
    rkw = dict(interpret=interpret, block=block, fft_impl=fft_impl)
    ckw = dict(interpret=interpret, block=col_block, fft_impl=fft_impl)

    def turn(x, split_axis, concat_axis):
        dt = x.dtype
        if turn_dtype is not None:
            # bf16 wire format for the turn: the FFT magnitudes are
            # O(sqrt(N)) and bf16's 8-bit mantissa costs ~2e-3 relative —
            # validated acceptable for imaging (SNR delta < 0.01 dB). The
            # optimization_barrier pins the converts to the collective's two
            # sides so XLA cannot re-widen the payload.
            x = jax.lax.optimization_barrier(x.astype(turn_dtype))
        x = jax.lax.all_to_all(x, axes, split_axis, concat_axis, tiled=True)
        if turn_dtype is not None:
            x = jax.lax.optimization_barrier(x)
        return x.astype(dt)

    def local(xr, xi, rc_u_blk, az_u2_blk):
        # in: (na, nr/P) column slab; azimuth lines complete per column.
        xr, xi = ops.fft_cols(xr, xi, **ckw)                 # dispatch 1
        # corner turn -> (na/P, nr) row slab (rows = azimuth freq)
        xr = turn(xr, 0, 1)
        xi = turn(xi, 0, 1)
        xr, xi = ops.fused_rc_rcmc_rows(
            xr, xi, hr_r, hr_i, rc_u_blk, rc_v, **rkw)       # dispatch 2
        # corner turn back -> (na, nr/P)
        xr = turn(xr, 1, 0)
        xi = turn(xi, 1, 0)
        xr, xi = ops.fused_mult_ifft_cols_outer(
            xr, xi, az_u2_blk, az_v2, **ckw)                 # dispatch 3
        return xr, xi

    shard = functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, axes), P(None, axes), P(axes), P(axes, None)),
        out_specs=(P(None, axes), P(None, axes)), check_vma=False)

    @jax.jit
    def run(raw):
        xr, xi = split(raw)
        # rc_u is per azimuth-frequency row -> sharded with the row slabs;
        # az_u2 is per range gate -> sharded with the column slabs.
        yr, yi = shard(local)(xr, xi, rc_u, az_u2)
        return unsplit(yr, yi)

    return run


# ---------------------------------------------------------------------------
# Schedule 2: one corner turn + halo-exchange RCMC
# ---------------------------------------------------------------------------

def _halo_rcmc(xr, xi, cfg: SceneConfig, axes, halo: int, p: int,
               taps: int = 8):
    """Sinc-interp RCMC on an (na, nr/P) column slab with ring halo exchange.

    Every row's shift is <= halo - taps//2 cells, so each device only needs
    `halo` columns from its right neighbour (shifts are non-negative: the
    migration curve always moves content to larger range).
    """
    s = jnp.asarray(filters.rcmc_shift_samples(cfg), jnp.float32)[:, None]
    base = jnp.floor(s)
    frac = s - base
    offs = np.arange(taps) - taps // 2 + 1
    xk = offs[None, None, :] - frac[..., None]
    w = jnp.sinc(xk) * jnp.where(
        jnp.abs(xk) <= taps // 2,
        0.54 + 0.46 * jnp.cos(np.pi * xk / (taps // 2)), 0.0)
    w = w / jnp.sum(w, axis=-1, keepdims=True)

    # halo exchange with both ring neighbours (the shift is non-negative, but
    # the sinc taps reach taps//2 - 1 cells to the left). p is the static
    # device count along `axes` (jax.lax.axis_size is newer-jax-only).
    lh = taps // 2
    perm_r = [((i + 1) % p, i) for i in range(p)]  # right neighbour -> me
    perm_l = [((i - 1) % p, i) for i in range(p)]  # left neighbour -> me

    def with_halo(x):
        from_right = jax.lax.ppermute(x[:, :halo], axes, perm_r)
        from_left = jax.lax.ppermute(x[:, -lh:], axes, perm_l)
        return jnp.concatenate([from_left, x, from_right], axis=1)

    hxr, hxi = with_halo(xr), with_halo(xi)
    nr_loc = xr.shape[1]
    cols = jnp.arange(nr_loc, dtype=jnp.int32)[None, :]
    yr = jnp.zeros_like(xr)
    yi = jnp.zeros_like(xi)
    for k in range(taps):
        idx = jnp.clip(cols + lh + base.astype(jnp.int32) + offs[k], 0,
                       nr_loc + lh + halo - 1)
        wk = w[..., k]
        yr = yr + jnp.take_along_axis(hxr, jnp.broadcast_to(idx, xr.shape), 1) * wk
        yi = yi + jnp.take_along_axis(hxi, jnp.broadcast_to(idx, xi.shape), 1) * wk
    return yr, yi


def build_halo(cfg: SceneConfig, mesh: Mesh, axes=("data",),
               interpret: Optional[bool] = None, block: int = 8,
               col_block: int = 8, fft_impl: str = "matmul",
               halo: Optional[int] = None):
    p = _axis_size(mesh, axes)
    if cfg.nr % p or cfg.na % p:
        raise ValueError(f"scene {cfg.na}x{cfg.nr} not divisible by {p} devices")
    max_shift = float(np.max(filters.rcmc_shift_samples(cfg)))
    halo = halo or int(np.ceil(max_shift)) + 8
    if halo > cfg.nr // p:
        # the halo premise (halo << nr/P) fails: each device would need more
        # than its whole neighbour slab, i.e. the exchange degenerates to a
        # corner turn. Applicability bound recorded in EXPERIMENTS.md §Perf.
        raise ValueError("halo exceeds local slab width; use corner2")

    hr_r, hr_i = (jnp.asarray(a) for a in filters.range_matched_filter(cfg))
    az_u2, az_v2 = (jnp.asarray(a) for a in filters.azimuth_phase_uv2(cfg))
    rkw = dict(interpret=interpret, block=block, fft_impl=fft_impl)
    ckw = dict(interpret=interpret, block=col_block, fft_impl=fft_impl)

    def local(xr, xi, az_u2_blk):
        # in: (na/P, nr) row slab — the raw data's natural layout.
        xr, xi = ops.fused_fft_mult_ifft_rows(xr, xi, hr_r, hr_i, **rkw)  # 1
        # the single corner turn -> (na, nr/P)
        xr = jax.lax.all_to_all(xr, axes, 1, 0, tiled=True)
        xi = jax.lax.all_to_all(xi, axes, 1, 0, tiled=True)
        xr, xi = ops.fft_cols(xr, xi, **ckw)                              # 2
        xr, xi = _halo_rcmc(xr, xi, cfg, axes, halo, p)                   # 3
        xr, xi = ops.fused_mult_ifft_cols_outer(
            xr, xi, az_u2_blk, az_v2, **ckw)                              # 4
        return xr, xi

    shard = functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axes, None), P(axes, None), P(axes)),
        out_specs=(P(None, axes), P(None, axes)), check_vma=False)

    @jax.jit
    def run(raw):
        xr, xi = split(raw)
        yr, yi = shard(local)(xr, xi, az_u2)
        return unsplit(yr, yi)

    return run


# ---------------------------------------------------------------------------
# Generic corner-turn lowering of a compiled SpectralPlan pipeline
# ---------------------------------------------------------------------------
#
# Every fused spectral dispatch processes line blocks independently — that
# is what lets the streaming executor strip a scene through host memory.
# The same property lets a compiled pipeline shard: each step runs on the
# slab sharded along its free (line) axis, and wherever two consecutive
# steps transform different axes the lowering inserts a corner turn
# (all_to_all). Line-indexed filter payloads (FULL matrices, OUTER u
# vectors) enter shard_map with the matching PartitionSpec so every device
# sees exactly its slab's slice; shared vectors and outer v factors ride
# along replicated. For the 3-dispatch RDA this reproduces the
# hand-written `corner2` schedule bit-for-bit (tests/test_distributed.py).

def _spec_for_filter(name: str, arr, mode: str, stream_axis: int, axes):
    """PartitionSpec for one filter operand in scene orientation."""
    if name in ("hr", "hi"):
        if mode == FILTER_FULL and arr.ndim == 2:
            return P(axes, None) if stream_axis == 0 else P(None, axes)
        return P(None)                     # shared (n,) vector: replicated
    if name == "u":                        # (lines, K): lines = stream axis
        return P(axes, None)
    return P(*([None] * arr.ndim))         # v (n, K): replicated


def _lowerable_steps(pipe) -> list:
    steps = list(pipe.steps)
    if not steps:
        raise ValueError(f"pipeline {pipe.name!r} has no steps")
    for s in steps:
        if s.kind == "mega":
            if s.kernel_kw is None or s.seg_filter_args is None:
                raise ValueError(
                    f"mega step {s.name!r} carries no per-segment filter "
                    "payloads (seg_filter_args) — it was compiled by a "
                    "pre-sharding build; recompile the plan (e.g. "
                    "core.plan.compile_plan / cached_pipeline) and lower "
                    "the fresh pipeline")
            continue
        if (s.kind != "spectral" or s.stream_axis is None
                or s.kernel_kw is None):
            raise ValueError(
                f"step {s.name!r} (kind {s.kind!r}) cannot lower to "
                "shard_map slabs: a transpose/custom stage reorders the "
                "whole scene, which no per-device slab can do locally. "
                "Compile a transpose-free per-axis variant (fused3 / "
                "csa_fused / omegak), or their single-dispatch megakernel "
                "twins (fused1 / csa_fused1 / omegak_fused1, "
                "fuse=FUSE_MEGA) whose in-kernel corner turns lower to "
                "all_to_all collectives; transposing variants run locally "
                "via Pipeline.run / run_streamed instead")
    return steps


def _clamped_block(kernel_kw: dict, lines_local: int) -> dict:
    """The per-dispatch line block must fit (and divide) the local slab."""
    kw = dict(kernel_kw)
    blk = min(int(kw.get("block") or 8), lines_local)
    while lines_local % blk:
        blk -= 1
    kw["block"] = max(1, blk)
    return kw


def _divisor_block(want: int, lines: int) -> int:
    """Largest block <= want that divides lines (>= 1)."""
    blk = min(int(want), int(lines))
    while lines % blk:
        blk -= 1
    return max(1, blk)


def _mega_groups(step):
    """Split a mega step's in-kernel segment chain at its corner-turn
    boundaries: consecutive same-axis segment records (with their
    scene-coordinate filter payloads) form one per-device group — one
    staged megakernel dispatch per device, the turns BETWEEN groups
    becoming all_to_all collectives. Returns
    ``[(axis, [records], [per-seg farg tuples]), ...]``."""
    recs = step.kernel_kw["segments"]
    fargs = step.seg_filter_args
    if len(recs) != len(fargs):
        raise ValueError(
            f"mega step {step.name!r}: {len(recs)} segment records but "
            f"{len(fargs)} per-segment filter payloads")
    groups: list = []
    for rec, fa in zip(recs, fargs):
        axis = rec[0]
        if groups and groups[-1][0] == axis:
            groups[-1][1].append(rec)
            groups[-1][2].append(tuple(fa))
        else:
            groups.append((axis, [rec], [tuple(fa)]))
    return groups


def _mega_filter_specs(mode: str, arrays, stream_axis: int, axes) -> list:
    """PartitionSpecs for one mega segment's scene-coordinate payload.

    The free (line) axis is the sharded one: FULL 2-D filters and OUTER
    ``u`` factors slice with the slab; SHARED vectors (the complete
    transform axis) and OUTER ``v`` factors replicate."""
    def line_sharded(a):
        if a.ndim != 2:
            return P(None)
        return P(axes, None) if stream_axis == 0 else P(None, axes)

    specs: list = []
    arrays = list(arrays)
    if mode in (FILTER_SHARED, FILTER_FULL, FILTER_SHARED_OUTER):
        hr, hi = arrays[0], arrays[1]
        for a in (hr, hi):
            # SHARED payloads are 1-D (whole transform axis, replicated);
            # a 2-D payload is a FULL scene-shaped filter, sliced like x
            specs.append(line_sharded(a) if mode != FILTER_SHARED
                         else P(None))
    if mode in (FILTER_OUTER, FILTER_SHARED_OUTER):
        u, v = arrays[-2], arrays[-1]
        # u is (lines, K) — lines IS the sharded free axis; v is (n, K)
        # on the complete transform axis
        specs.append(P(axes, *([None] * (u.ndim - 1))))
        specs.append(P(*([None] * v.ndim)))
    if mode == FILTER_NONE and arrays:
        raise ValueError("filter-less segment carries payload arrays")
    return specs


# kernel knobs a mega step's kernel_kw shares with every per-device group
_MEGA_GROUP_KW = ("fft_impl", "interpret", "precision", "karatsuba",
                  "buffer_depth")


def _group_mega_kw(src: dict, recs, stream_axis: int, lines_local: int,
                   na_local: int, nr_local: int, filter_bytes: int,
                   residency: Optional[str]) -> dict:
    """The `ops.mega_spectral_op` kwargs for ONE per-device segment
    group: the parent dispatch's global knobs, the group's own segment
    records, a phase_block clamped to divide the LOCAL free-axis lines,
    and the residency re-resolved for the 1/P slab (unless pinned)."""
    kw = {k: src[k] for k in _MEGA_GROUP_KW if k in src}
    kw["segments"] = tuple(recs)
    if stream_axis == 0:
        # row slab (na/P, nr): the global n1/n2/n3 range-axis override
        # still factors this slab's full-width range axis. Column slabs
        # slice the range axis, so a full-width factorization would no
        # longer multiply out — axis-0 groups fall back to the default
        # split (per-segment 8-field records stay valid either way: they
        # factor the transform axis, which sharding never slices).
        for k in ("n1", "n2", "n3"):
            kw[k] = src.get(k)
    kw["phase_block"] = _divisor_block(src.get("phase_block") or 8,
                                       lines_local)
    if residency is None:
        from repro import tuning
        residency = tuning.cost.mega_residency(
            na_local, nr_local, precision=src.get("precision"),
            filter_bytes=filter_bytes)
    kw["residency"] = residency
    return kw


def lower_pipeline(pipe, mesh: Mesh, axes=("data",), turn_dtype=None,
                   residency: Optional[str] = None):
    """Lower a compiled :class:`~repro.core.plan.Pipeline` onto `mesh`.

    Returns a jit-ed ``fn(raw) -> image`` accepting one scene ``(na, nr)``
    or a batch ``(B, na, nr)``, complex64. The input arrives sharded along
    the FIRST unit's line axis and the image leaves sharded along the
    LAST unit's line axis (for the RDA family both are
    ``P(None, axes)`` — range columns distributed, matching `corner2`).

    Spectral steps lower one-to-one: each runs `ops.spectral_op` on the
    slab sharded along its free (line) axis. A MEGA step is split at its
    in-kernel corner-turn boundaries into per-device segment groups
    (range segments on range-sharded ``(na/P, nr)`` slabs, azimuth
    segments on ``(na, nr/P)``): each group is ONE
    `ops.mega_spectral_op` megakernel dispatch per device — zero HBM
    intermediates within the group — and the in-kernel turns between
    groups become the all_to_alls. ``residency`` pins every group's mode
    ('vmem' | 'staged'); the default re-resolves per group on the 1/P
    local slab (`repro.tuning.cost.mega_residency`), so a 4096² scene
    that must stage locally can run VMEM-resident per device.

    Collective cost: one all_to_all of the full scene per axis change
    (2 · 8 · na · nr · (P−1)/P bytes each for split float32 re/im, halved
    by ``turn_dtype=jnp.bfloat16``; `tuning.cost.collective_turn_bytes` /
    `turn_seconds` price exactly this). Block-scaled (bs16) mega chains
    keep the slab SCALED on the wire and all_gather the carried per-line
    exponent vector alongside it (4 · lines · (P−1)/P bytes per turn —
    the same cost functions price it via their ``precision`` argument),
    then unscale after the turn: since power-of-two scaling is exact, the
    sharded bs16 image is bit-identical to the local megakernel's (the
    exponent of a line never depends on how the free axis was sharded).
    A K-unit lowering has at most
    K−1 turns; fused3/csa_fused/omegak AND the fused1 megakernel family
    all have exactly 2 — the `corner2` schedule generalized to any plan
    the compiler accepts.

    The returned runner carries the lowering's shape as attributes:
    ``devices``, ``dispatches_per_device`` (units), ``turns``
    (collective corner turns), and ``unit_info`` (name / stream axis /
    kind / residency per unit) — the compiler dispatch-count invariant
    benchmarks and tests assert.
    """
    p = _axis_size(mesh, axes)
    cfg = pipe.cfg
    steps = _lowerable_steps(pipe)

    # ---- flatten steps into UNITS: one shard_map-local dispatch each ----
    farg_arrays: list = []
    farg_specs: list = []
    units: list = []   # (stream_axis, label, kind, residency, carry, apply)

    def add_spectral(s):
        names = sorted((s.filter_kw or {}).keys())
        start = len(farg_arrays)
        for name in names:
            arr = s.filter_kw[name]
            farg_arrays.append(arr)
            farg_specs.append(_spec_for_filter(name, arr, s.filter_mode,
                                               s.stream_axis, axes))
        lines_local = (cfg.na if s.stream_axis == 0 else cfg.nr) // p
        kw = _clamped_block(s.kernel_kw, lines_local)

        def apply(xr, xi, fargs, _names=tuple(names), _kw=kw, _i=start):
            fk = {n: fargs[_i + j] for j, n in enumerate(_names)}
            return ops.spectral_op(xr, xi, **fk, **_kw)

        units.append((s.stream_axis, s.name, "spectral", None, False,
                      apply))

    def add_mega(s):
        for gi, (axis, recs, seg_fargs) in enumerate(_mega_groups(s)):
            stream = 1 - axis
            lines_local = (cfg.na if stream == 0 else cfg.nr) // p
            start = len(farg_arrays)
            fbytes = 0
            for rec, fa in zip(recs, seg_fargs):
                mode = rec[3]
                specs = _mega_filter_specs(mode, fa, stream, axes)
                if len(specs) != len(fa):
                    raise ValueError(
                        f"mega step {s.name!r} group {gi}: segment mode "
                        f"{mode!r} expects {len(specs)} payload arrays, "
                        f"got {len(fa)}")
                farg_arrays.extend(fa)
                farg_specs.extend(specs)
                fbytes += sum(int(np.prod(a.shape)) * 4 // p for a in fa)
            count = len(farg_arrays) - start
            na_l = cfg.na // p if stream == 0 else cfg.na
            nr_l = cfg.nr if stream == 0 else cfg.nr // p
            kw = _group_mega_kw(s.kernel_kw, recs, stream, lines_local,
                                na_l, nr_l, fbytes, residency)
            # block-scaled groups chain their carried per-line exponents
            # through the turns (ops.mega_spectral_op exp_in/return_exp)
            carry = resolve_precision(kw.get("precision")).block_scaled

            def apply(xr, xi, fargs, exp_in=None, return_exp=False,
                      _kw=kw, _i=start, _c=count):
                return ops.mega_spectral_op(
                    xr, xi, *fargs[_i:_i + _c], exp_in=exp_in,
                    return_exp=return_exp, **_kw)

            units.append((stream, f"{s.name}[g{gi}]", "mega",
                          kw["residency"], carry, apply))

    for s in steps:
        (add_mega if s.kind == "mega" else add_spectral)(s)

    for stream, label, _kind, _res, _carry, _apply in units:
        lines = cfg.na if stream == 0 else cfg.nr
        if lines % p:
            raise ValueError(
                f"unit {label!r}: {lines} lines not divisible by {p} "
                "devices")

    n_turns = sum(1 for a, b in zip(units, units[1:]) if a[0] != b[0])

    def _turn(x, from_axis: int, bpre: int):
        # re-shard: sharded rows -> sharded cols (or back). split/concat in
        # local coordinates, offset past any batch dims.
        split_axis = bpre + (1 - from_axis)
        concat_axis = bpre + from_axis
        dt = x.dtype
        if turn_dtype is not None:
            # narrow wire format; barriers pin the converts to the
            # collective (see build_corner2.turn)
            x = jax.lax.optimization_barrier(x.astype(turn_dtype))
        x = jax.lax.all_to_all(x, axes, split_axis, concat_axis, tiled=True)
        if turn_dtype is not None:
            x = jax.lax.optimization_barrier(x)
        return x.astype(dt)

    def _build(ndim: int):
        bpre = ndim - 2

        def dspec(stream_axis: int):
            scene = ((axes, None) if stream_axis == 0 else (None, axes))
            return P(*([None] * bpre), *scene)

        def local(xr, xi, *fargs):
            cur = units[0][0]
            exp = None
            for i, (stream, _label, _kind, _res, carry, apply) \
                    in enumerate(units):
                if stream != cur:
                    xr = _turn(xr, cur, bpre)
                    xi = _turn(xi, cur, bpre)
                    if exp is not None:
                        # the carried per-line exponents ride the corner
                        # turn with the (still scaled) slab: they are
                        # sharded along their own line axis — the
                        # PREVIOUS group's stream axis — and after the
                        # turn every device's re-sharded slab spans all
                        # of those lines, so an all_gather restores the
                        # full vector (priced with the turn in
                        # tuning.cost.collective_turn_bytes)
                        exp = jax.lax.all_gather(
                            exp, axes, axis=bpre + cur, tiled=True)
                    cur = stream
                if carry:
                    chain = i + 1 < len(units) and units[i + 1][4]
                    if chain:
                        xr, xi, exp = apply(xr, xi, fargs, exp_in=exp,
                                            return_exp=True)
                    else:
                        xr, xi = apply(xr, xi, fargs, exp_in=exp)
                        exp = None
                else:
                    xr, xi = apply(xr, xi, fargs)
            return xr, xi

        shard = functools.partial(
            shard_map, mesh=mesh,
            in_specs=(dspec(units[0][0]), dspec(units[0][0]), *farg_specs),
            out_specs=(dspec(units[-1][0]), dspec(units[-1][0])),
            check_vma=False)

        @jax.jit
        def run(raw):
            xr, xi = split(raw)
            yr, yi = shard(local)(xr, xi, *farg_arrays)
            return unsplit(yr, yi)

        return run

    runners: dict[int, callable] = {}

    def run(raw):
        nd = jnp.ndim(raw)
        if nd not in (2, 3):
            raise ValueError("expected (na, nr) or (B, na, nr)")
        if nd not in runners:
            runners[nd] = _build(nd)
        return runners[nd](raw)

    # the lowering's shape, for dispatch-count invariants and BENCH rows
    run.devices = p
    run.dispatches_per_device = len(units)
    run.turns = n_turns
    run.unit_info = tuple(
        {"name": label, "stream_axis": stream, "kind": kind,
         "residency": res, "carries_exponents": carry}
        for stream, label, kind, res, carry, _apply in units)
    return run


def build_sharded(cfg: SceneConfig, variant: str = "fused3",
                  mesh: Optional[Mesh] = None, axes=("data",),
                  schedule: str = "corner2", turn_dtype=None, **compile_kw):
    """Compile `variant` for `cfg` and return a multi-device runner.

    schedule 'corner2': the generic plan lowering (`lower_pipeline`) — an
    all_to_all corner turn at every transform-axis change; works for any
    transpose-free spectral plan and reproduces the hand-written corner2
    schedule exactly on the 3-dispatch RDA. compile_kw (precision, block,
    fft_kw, ...) route to the plan compiler.

    schedule 'halo': the hand-written single-turn RDA schedule
    (`build_halo`) — range compression on the natural pulse-sharded
    layout, ONE corner turn, ring halo-exchange RCMC. RDA only; the
    `variant` argument selects nothing beyond asserting RDA semantics.

    This is the focusing service's `sharded` execution backend
    (repro.service.backends.ShardedBackend).
    """
    if mesh is None:
        mesh = make_sar_mesh(axes)
    if schedule == "halo":
        if variant not in ("fused3", "fused_tfree", "fused", "unfused"):
            raise ValueError(
                f"schedule 'halo' implements the RDA; variant {variant!r} "
                "is not an RDA pipeline (use schedule='corner2')")
        supported = ("interpret", "block", "col_block", "fft_impl", "halo")
        ignored = sorted(set(compile_kw) - set(supported))
        if ignored or turn_dtype is not None:
            # refuse rather than silently run f32/full-width: a client
            # that asked for precision='bf16' must not get an unlabelled
            # f32 result back
            bad = ignored + (["turn_dtype"] if turn_dtype is not None
                             else [])
            raise ValueError(
                f"schedule 'halo' does not support option(s) {bad}; "
                "use schedule='corner2' for precision/turn_dtype")
        return build_halo(cfg, mesh, axes, **compile_kw)
    if schedule != "corner2":
        raise ValueError(f"unknown schedule {schedule!r}; "
                         f"known: corner2, halo")
    from repro.core.sar.rda import build_pipeline
    pipe = build_pipeline(cfg, variant, **compile_kw)
    return lower_pipeline(pipe, mesh, axes=axes, turn_dtype=turn_dtype)


SCHEDULES = {"corner2": build_corner2, "halo": build_halo}


def distributed_focus(raw, cfg: SceneConfig, mesh: Mesh, axes=("data",),
                      schedule: str = "corner2", **kw):
    return SCHEDULES[schedule](cfg, mesh, axes, **kw)(raw)
