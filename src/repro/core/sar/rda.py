"""Range Doppler Algorithm — every variant is a SpectralPlan (paper Sec. IV).

The pipeline variants are *data*: declarative `SpectralPlan` stage lists
(core/plan.py) compiled by the shared plan compiler into fused
`ops.spectral_op` dispatches. No variant owns an executor loop — adding a
pipeline is writing a plan, not code (see core/sar/omegak.py for the
third algorithm added exactly this way).

Data layout: (na, nr) = (azimuth, range), complex64 at the public boundary,
split re/im float32 inside the fused dispatches. Every compiled pipeline
accepts one scene (na, nr) or a batch (B, na, nr) sharing the SceneConfig;
batched inputs run each stage as a SINGLE Pallas dispatch whose grid spans
B x line-blocks. `Pipeline.run_streamed` additionally executes any
transpose-free plan over azimuth strips of a host-resident scene,
overlapping strip transfer with compute (bit-identical to `run`).

Kernel tuning: the compiler pulls per-dispatch `(block, n1, n2, n3,
karatsuba, precision)` configs from the repro.tuning cache at
compile time (device-fingerprinted, batch-bucketed); pass `fft_kw=...` to pin the range-axis config explicitly or
`precision="bf16"|"bs16"` to override the matmul-operand policy globally.

Variants
--------
``unfused``      The paper's baseline: one XLA op per atom (jnp.fft FFT,
                 multiply, jnp.fft IFFT, ...), every op an HBM round-trip.
                 7 logical dispatches.
``fused``        Paper-faithful fusion: range compression as ONE dispatch
                 (FFT * H_r * IFFT), azimuth FFT via transpose + row FFT +
                 transpose (paper keeps it unfused), RCMC as a separate
                 sinc-interpolation dispatch, azimuth compression as
                 transpose + fused(multiply * IFFT) + transpose. 8 dispatches.
``fused_tfree``  Beyond-paper: column-pipeline kernels transform azimuth
                 in place, RCMC becomes a fused Fourier-shift dispatch
                 (exact sinc interpolation via the shift theorem), azimuth
                 compression a fused column dispatch. 4 dispatches, zero
                 global transposes.
``fused3``       Beyond-paper minimum per-axis fusion: range compression
                 commutes with the azimuth FFT, so the plan reorders to
                 azimuth FFT -> [range FFT * H_r * RCMC-shift * IFFT] ->
                 [H_a * azimuth IFFT]. 3 dispatches (the distributed
                 schedule's local compute, see core/sar/distributed.py).
``fused1``       The paper's claim fully realized: the same three stages
                 fused ACROSS the axis changes into ONE megakernel
                 dispatch (fuse="mega"), corner turns in-kernel —
                 VMEM-resident for fitting scenes (zero HBM
                 intermediates) or scratch-staged with double-buffered
                 DMA beyond the budget. f32 bit-identical to fused3.

Plus, registered by their own modules: ``csa``/``csa_fused``
(core/sar/csa.py) and ``omegak`` (core/sar/omegak.py).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import plan as planlib
from repro.core.plan import (  # noqa: F401  (re-exported legacy names)
    Pipeline,
    SpectralPlan,
    Stage,
    Step,
    split,
    unsplit,
)
from repro.core.sar import filters
from repro.core.sar.geometry import SceneConfig


# ---------------------------------------------------------------------------
# Sinc-interpolation RCMC (the one non-spectral stage kind the RDA uses)
# ---------------------------------------------------------------------------

def rcmc_sinc(x: jnp.ndarray, cfg: SceneConfig, taps: int = 8,
              range_variant: bool = False, lo: Optional[int] = None,
              hi: Optional[int] = None) -> jnp.ndarray:
    """8-tap windowed-sinc RCMC in the range-Doppler domain (paper step 3).

    x: (na, nr) or (B, na, nr) complex, rows = Doppler bins. Row f_a is
    shifted by -s(f_a) samples, i.e. y[..., row, col] = x[..., row, col + s]
    interpolated (the shift table broadcasts across any batch dim).
    lo/hi restrict the shift table to a row strip (streaming executor).
    """
    if range_variant:
        s = jnp.asarray(filters.rcmc_shift_samples_variant(cfg), jnp.float32)
    else:
        s = jnp.asarray(filters.rcmc_shift_samples(cfg), jnp.float32)[:, None]
    if lo is not None:
        s = s[lo:hi]
    base = jnp.floor(s)
    frac = (s - base)  # in [0, 1)
    cols = jnp.arange(cfg.nr, dtype=jnp.int32)[None, :]
    y = jnp.zeros_like(x)
    offs = np.arange(taps) - taps // 2 + 1
    # weights: sinc(k - frac) * hamming, normalized (matches filters.sinc_…)
    xk = offs[None, None, :] - frac[..., None]
    w = jnp.sinc(xk) * jnp.where(
        jnp.abs(xk) <= taps // 2,
        0.54 + 0.46 * jnp.cos(jnp.pi * xk / (taps // 2)), 0.0)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    for k in range(taps):
        idx = jnp.mod(cols + base.astype(jnp.int32) + offs[k], cfg.nr)
        gathered = jnp.take_along_axis(x, jnp.broadcast_to(idx, x.shape),
                                       axis=-1)
        y = y + gathered * w[..., k].astype(x.dtype)
    return y


def _sinc_rcmc_impl(x, cfg, opts, lo, hi):
    return rcmc_sinc(x, cfg, taps=opts.get("taps", 8),
                     range_variant=opts.get("range_variant", False),
                     lo=lo, hi=hi)


planlib.register_stage_impl("sinc_rcmc", _sinc_rcmc_impl, stream_axis=0)


# ---------------------------------------------------------------------------
# The RDA plans
# ---------------------------------------------------------------------------

def plan_unfused(rcmc_mode: str = "sinc") -> SpectralPlan:
    """The textbook 4-step RDA. rcmc_mode 'sinc' uses the 8-tap windowed
    sinc interpolator; 'fourier' the exact shift-theorem correction."""
    if rcmc_mode == "sinc":
        rcmc = Stage("rcmc", kind="sinc_rcmc")
    elif rcmc_mode == "fourier":
        rcmc = Stage("rcmc", axis=1, fwd=True, inv=True,
                     filters=("rcmc_shift",))
    else:
        raise ValueError(f"unknown rcmc_mode {rcmc_mode!r}")
    return SpectralPlan("unfused", (
        Stage("range_compression", axis=1, fwd=True, inv=True,
              filters=("range_mf",)),
        Stage("azimuth_fft", axis=0, fwd=True),
        rcmc,
        Stage("azimuth_compression", axis=0, inv=True,
              filters=("azimuth_mf",)),
    ))


def plan_fused() -> SpectralPlan:
    """The paper's pipeline (Sec. IV-A): steps 1 & 4 fused, the azimuth
    transform via global transposes, RCMC a separate sinc dispatch."""
    return SpectralPlan("fused", (
        Stage("range_compression", axis=1, fwd=True, inv=True,
              filters=("range_mf",)),
        Stage("azimuth_fft_turn_in", kind="transpose"),
        Stage("azimuth_fft", axis=0, fwd=True),
        Stage("azimuth_fft_turn_out", kind="transpose"),
        Stage("rcmc", kind="sinc_rcmc"),
        Stage("azimuth_compression_turn_in", kind="transpose"),
        Stage("azimuth_compression", axis=0, inv=True,
              filters=("azimuth_mf",)),
        Stage("azimuth_compression_turn_out", kind="transpose"),
    ))


def plan_fused_tfree(synth_phase: bool = False) -> SpectralPlan:
    """4 dispatches, no global transposes, RCMC fused via the shift theorem.

    synth_phase=False reads the exact precomputed 2-D azimuth filter
    (FILTER_FULL; bit-compatible with the unfused baseline); True
    synthesizes it in VMEM as a float32-safe rank-2 phase (FILTER_OUTER),
    removing the filter's HBM read entirely."""
    az = "azimuth_mf_outer" if synth_phase else "azimuth_mf"
    return SpectralPlan("fused_tfree", (
        Stage("range_compression", axis=1, fwd=True, inv=True,
              filters=("range_mf",)),
        Stage("azimuth_fft", axis=0, fwd=True),
        Stage("rcmc", axis=1, fwd=True, inv=True, filters=("rcmc_shift",)),
        Stage("azimuth_compression", axis=0, inv=True, filters=(az,)),
    ))


def plan_fused3(synth_phase: bool = True) -> SpectralPlan:
    """The minimum-dispatch RDA: range compression commutes with the
    azimuth FFT (an identical per-row linear operator), so the plan
    reorders to  azimuth FFT -> [range FFT * H_r * RCMC-shift * IFFT] ->
    [H_a * azimuth IFFT]. The compiler fuses H_r (shared) with the
    RCMC rank-1 phase (outer) into ONE shared_outer dispatch."""
    az = "azimuth_mf_outer" if synth_phase else "azimuth_mf"
    return SpectralPlan("fused3", (
        Stage("azimuth_fft", axis=0, fwd=True),
        Stage("range_comp_rcmc", axis=1, fwd=True, inv=True,
              filters=("range_mf", "rcmc_shift")),
        Stage("azimuth_compression", axis=0, inv=True, filters=(az,)),
    ))


def plan_fused1(synth_phase: bool = True) -> SpectralPlan:
    """The single-dispatch RDA: the SAME stage list as ``fused3``, fused
    under the cross-axis megakernel grammar (``fuse="mega"``) — the
    azimuth FFT, the fused range stage, and the azimuth compression
    become per-axis segments of ONE dispatch with the corner turns inside
    the kernel (kernels/fft4step.build_mega_call). The paper's headline
    claim — the whole imaging chain in one dispatch, intermediates never
    leaving on-chip memory — realized on TPU for VMEM-fitting scenes, and
    kept at one dispatch via the scratch-staged mode beyond that."""
    az = "azimuth_mf_outer" if synth_phase else "azimuth_mf"
    return SpectralPlan("fused1", (
        Stage("azimuth_fft", axis=0, fwd=True),
        Stage("range_comp_rcmc", axis=1, fwd=True, inv=True,
              filters=("range_mf", "rcmc_shift")),
        Stage("azimuth_compression", axis=0, inv=True, filters=(az,)),
    ))


planlib.register_variant(
    "unfused", plan_unfused,
    compile_defaults=(("backend", planlib.BACKEND_XLA), ("fuse", False)),
    plan_kw=("rcmc_mode",), dispatches=7)
planlib.register_variant(
    "fused", plan_fused, dispatches=8)
planlib.register_variant(
    "fused_tfree", plan_fused_tfree, plan_kw=("synth_phase",), dispatches=4)
planlib.register_variant(
    "fused3", plan_fused3, plan_kw=("synth_phase",), dispatches=3)
planlib.register_variant(
    "fused1", plan_fused1,
    compile_defaults=(("fuse", planlib.FUSE_MEGA),),
    plan_kw=("synth_phase",), dispatches=1)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def _ensure_variants() -> None:
    # importing the sibling algorithm modules registers their plans
    from repro.core.sar import csa, omegak  # noqa: F401


def build_pipeline(cfg: SceneConfig, variant: str, **kw) -> Pipeline:
    """Compile a registered pipeline variant for one scene geometry.

    kw: plan kwargs (rcmc_mode / synth_phase / r_ref, per variant) plus any
    compile_plan option (block, col_block, interpret, fft_impl, fft_kw,
    precision, tune, backend, fuse, batch)."""
    _ensure_variants()
    return planlib.build_variant(cfg, variant, **kw)


def focus(raw: jnp.ndarray, cfg: SceneConfig, variant: str = "fused_tfree",
          **kw) -> jnp.ndarray:
    """One-call focusing: raw echo (na, nr) — or a batch (B, na, nr) of
    scenes sharing `cfg` — complex64 -> focused image(s) of the same
    shape. Compiled filters are cached per (cfg, plan), so repeated calls
    on new scenes skip the host-side filter math."""
    return build_pipeline(cfg, variant, **kw).run(raw)


def documented_dispatches(variant: str) -> int:
    """The variant's documented compiled dispatch count (tests assert the
    fusion compiler reproduces it exactly)."""
    _ensure_variants()
    return planlib.get_variant(variant).dispatches


def variant_names() -> tuple[str, ...]:
    _ensure_variants()
    return planlib.variant_names()


def _build(variant: str, cfg: SceneConfig, **kw) -> Pipeline:
    return build_pipeline(cfg, variant, **kw)


BUILDERS: dict[str, Callable[..., Pipeline]] = {
    v: functools.partial(_build, v)
    for v in ("unfused", "fused", "fused_tfree", "fused3", "fused1")
}
