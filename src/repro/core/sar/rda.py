"""Range Doppler Algorithm — three pipeline variants (paper Sec. IV).

Data layout: (na, nr) = (azimuth, range), complex64 at the public boundary,
split re/im float32 inside the fused paths (the Pallas kernels' layout).

Batched multi-scene focusing (beyond-paper): every pipeline accepts either
one scene (na, nr) or a batch (B, na, nr) sharing the same SceneConfig.
The fused variants process the whole batch per stage as a SINGLE Pallas
dispatch whose grid spans B x line-blocks (kernels/ops.py), so dispatch
overhead and the broadcast DFT-constant loads amortize across scenes —
`focus(raw_batch, cfg)` is the one-call entry, `examples/batch_scenes.py`
the demo, and benchmarks/bench_rda.py (table_2b) the amortization
measurement. Filters are computed once from cfg and shared by every scene.

Kernel tuning: the pipeline builders' `block`/`col_block` kwargs and the
kernels' mixed-radix factorization (n = n1*n2[*n3], factors <= 128; see
kernels/fft4step.py) are swept per (batch, FFT length) by
benchmarks/autotune.py — `autotune.best_config(n, B)` returns the cached
fastest `(block, n1, n2, n3, karatsuba)` config, and
`autotune.spectral_kwargs(cfg)` turns it into ops.spectral_op kwargs.

Variants
--------
``unfused``      The paper's baseline: one XLA op per stage (jnp.fft FFT,
                 multiply, jnp.fft IFFT, ...), every stage a separate
                 HBM round-trip. 9 logical dispatches.
``fused``        Paper-faithful fusion: range compression as ONE dispatch
                 (FFT * H_r * IFFT), azimuth FFT via transpose + row FFT +
                 transpose (paper keeps it unfused), RCMC as a separate
                 sinc-interpolation dispatch, azimuth compression as
                 transpose + fused(multiply * IFFT) + transpose. 8 dispatches.
``fused_tfree``  Beyond-paper: column-pipeline kernels transform azimuth
                 in place (VMEM holds a full column slab), RCMC becomes a
                 fused Fourier-shift dispatch (exact sinc interpolation via
                 the shift theorem), azimuth compression a fused rank-1-phase
                 column dispatch. 4 dispatches, zero global transposes.

Every variant exposes per-step callables so benchmarks can reproduce the
paper's Table III breakdown.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sar import filters
from repro.core.sar.geometry import SceneConfig
from repro.kernels import ops
from repro.kernels.transpose import transpose


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------

def split(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    return jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)


def unsplit(xr: jnp.ndarray, xi: jnp.ndarray) -> jnp.ndarray:
    return xr.astype(jnp.complex64) + 1j * xi.astype(jnp.complex64)


def rcmc_sinc(x: jnp.ndarray, cfg: SceneConfig, taps: int = 8,
              range_variant: bool = False) -> jnp.ndarray:
    """8-tap windowed-sinc RCMC in the range-Doppler domain (paper step 3).

    x: (na, nr) or (B, na, nr) complex, rows = Doppler bins. Row f_a is
    shifted by -s(f_a) samples, i.e. y[..., row, col] = x[..., row, col + s]
    interpolated (the shift table broadcasts across any batch dim).
    """
    if range_variant:
        s = jnp.asarray(filters.rcmc_shift_samples_variant(cfg), jnp.float32)
    else:
        s = jnp.asarray(filters.rcmc_shift_samples(cfg), jnp.float32)[:, None]
    base = jnp.floor(s)
    frac = (s - base)  # in [0, 1)
    cols = jnp.arange(cfg.nr, dtype=jnp.int32)[None, :]
    y = jnp.zeros_like(x)
    offs = np.arange(taps) - taps // 2 + 1
    # weights: sinc(k - frac) * hamming, normalized (matches filters.sinc_…)
    xk = offs[None, None, :] - frac[..., None]
    w = jnp.sinc(xk) * jnp.where(
        jnp.abs(xk) <= taps // 2,
        0.54 + 0.46 * jnp.cos(jnp.pi * xk / (taps // 2)), 0.0)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    for k in range(taps):
        idx = jnp.mod(cols + base.astype(jnp.int32) + offs[k], cfg.nr)
        gathered = jnp.take_along_axis(x, jnp.broadcast_to(idx, x.shape),
                                       axis=-1)
        y = y + gathered * w[..., k].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Step builders — each returns fn(state) -> state on complex64 (na, nr)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Step:
    name: str
    fn: Callable[[jnp.ndarray], jnp.ndarray]
    dispatches: int          # logical GPU dispatches this step models
    hbm_roundtrips: int      # full-array device-memory round trips (R+W pairs)
    fused: bool


@dataclasses.dataclass
class Pipeline:
    """A named sequence of steps. `run` jits the whole chain."""
    name: str
    cfg: SceneConfig
    steps: list[Step]

    @property
    def dispatches(self) -> int:
        return sum(s.dispatches for s in self.steps)

    @property
    def hbm_roundtrips(self) -> int:
        return sum(s.hbm_roundtrips for s in self.steps)

    def run(self, raw: jnp.ndarray) -> jnp.ndarray:
        x = raw
        for s in self.steps:
            x = s.fn(x)
        return x

    def jitted(self) -> Callable[[jnp.ndarray], jnp.ndarray]:
        @jax.jit
        def f(raw):
            return self.run(raw)
        return f


# -- unfused baseline --------------------------------------------------------

def build_unfused(cfg: SceneConfig, rcmc_mode: str = "sinc") -> Pipeline:
    hr_c = jnp.asarray(filters.range_matched_filter_c(cfg))
    ha_c = jnp.asarray(filters.azimuth_matched_filter_c(cfg))

    def range_compress(x):
        # 3 separate dispatches: FFT, multiply, IFFT (each an HBM round trip)
        xf = jnp.fft.fft(x, axis=-1)
        xf = xf * hr_c
        return jnp.fft.ifft(xf, axis=-1)

    def azimuth_fft(x):
        return jnp.fft.fft(x, axis=-2)

    def rcmc(x):
        if rcmc_mode == "sinc":
            return rcmc_sinc(x, cfg)
        u, v = filters.rcmc_phase_uv(cfg)
        ph = jnp.asarray(u)[:, None] * jnp.asarray(v)[None, :]
        return jnp.fft.ifft(jnp.fft.fft(x, axis=-1) * jnp.exp(1j * ph),
                            axis=-1)

    def azimuth_compress(x):
        return jnp.fft.ifft(x * ha_c, axis=-2)

    return Pipeline("unfused", cfg, [
        Step("range_compression", range_compress, 3, 3, False),
        Step("azimuth_fft", azimuth_fft, 1, 1, False),
        Step("rcmc", rcmc, 1, 1, False),
        Step("azimuth_compression", azimuth_compress, 2, 2, False),
    ])


# -- paper-faithful fused -----------------------------------------------------

def build_fused(cfg: SceneConfig, interpret: Optional[bool] = None,
                block: int = 8, fft_impl: str = "matmul",
                fft_kw: Optional[dict] = None) -> Pipeline:
    """The paper's pipeline: steps 1 & 4 fused, steps 2-3 unfused (Sec. IV-A).

    fft_kw: extra ops.spectral_op kwargs applied to the row-pipeline
    dispatches — typically the autotuned (n1, n2, n3, karatsuba) from
    benchmarks/autotune.py (factorizations are per FFT length, so they
    apply to the range axis; column dispatches keep the default split).
    """
    hr_r, hr_i = filters.range_matched_filter(cfg)
    hr_r, hr_i = jnp.asarray(hr_r), jnp.asarray(hr_i)
    ha_r, ha_i = filters.azimuth_matched_filter_split(cfg)
    # azimuth compression operates on the TRANSPOSED matrix (nr, na): filter^T
    ha_rT, ha_iT = jnp.asarray(ha_r.T).copy(), jnp.asarray(ha_i.T).copy()
    # fft_kw carries the length-nr factorization: range dispatches only.
    # The azimuth steps row-FFT the TRANSPOSED matrix (length na), so they
    # keep the default factorization for their own length.
    rkw = dict(interpret=interpret, block=block, fft_impl=fft_impl,
               **(fft_kw or {}))
    akw = dict(interpret=interpret, block=block, fft_impl=fft_impl)

    def range_compress(x):
        xr, xi = split(x)
        yr, yi = ops.fused_fft_mult_ifft_rows(xr, xi, hr_r, hr_i, **rkw)
        return unsplit(yr, yi)

    def azimuth_fft(x):
        # transpose -> row FFT -> transpose (paper keeps this unfused)
        xr, xi = split(x)
        xr, xi = transpose(xr, interpret=interpret), transpose(xi, interpret=interpret)
        yr, yi = ops.fft_rows(xr, xi, **akw)
        yr, yi = transpose(yr, interpret=interpret), transpose(yi, interpret=interpret)
        return unsplit(yr, yi)

    def rcmc(x):
        return rcmc_sinc(x, cfg)

    def azimuth_compress(x):
        xr, xi = split(x)
        xr, xi = transpose(xr, interpret=interpret), transpose(xi, interpret=interpret)
        yr, yi = ops.spectral_op(xr, xi, hr=ha_rT, hi=ha_iT, fwd=False, inv=True,
                                 axis=1, filter_mode="full", **akw)
        yr, yi = transpose(yr, interpret=interpret), transpose(yi, interpret=interpret)
        return unsplit(yr, yi)

    return Pipeline("fused", cfg, [
        Step("range_compression", range_compress, 1, 1, True),
        Step("azimuth_fft", azimuth_fft, 3, 3, False),
        Step("rcmc", rcmc, 1, 1, False),
        Step("azimuth_compression", azimuth_compress, 3, 3, True),
    ])


# -- beyond-paper: fused + transpose-free ------------------------------------

def build_fused_tfree(cfg: SceneConfig, interpret: Optional[bool] = None,
                      block: int = 8, col_block: int = 128,
                      fft_impl: str = "matmul",
                      synth_phase: bool = False,
                      fft_kw: Optional[dict] = None) -> Pipeline:
    """4 dispatches, no global transposes, RCMC fused via the shift theorem.

    synth_phase=False reads the exact precomputed 2-D azimuth filter
    (FILTER_FULL; bit-compatible with the unfused baseline); synth_phase=True
    synthesizes it in VMEM as a float32-safe rank-2 phase (FILTER_OUTER),
    removing the filter's HBM read entirely (the §Perf bandwidth hillclimb).
    """
    hr_r, hr_i = filters.range_matched_filter(cfg)
    hr_r, hr_i = jnp.asarray(hr_r), jnp.asarray(hr_i)
    rc_u, rc_v = filters.rcmc_phase_uv(cfg)
    rc_u, rc_v = jnp.asarray(rc_u), jnp.asarray(rc_v)
    az_u2, az_v2 = filters.azimuth_phase_uv2(cfg)
    az_u2, az_v2 = jnp.asarray(az_u2), jnp.asarray(az_v2)
    ha_r, ha_i = filters.azimuth_matched_filter_split(cfg)
    ha_r, ha_i = jnp.asarray(ha_r), jnp.asarray(ha_i)
    rkw = dict(interpret=interpret, block=block, fft_impl=fft_impl,
               **(fft_kw or {}))
    ckw = dict(interpret=interpret, block=col_block, fft_impl=fft_impl)

    def range_compress(x):
        xr, xi = split(x)
        yr, yi = ops.fused_fft_mult_ifft_rows(xr, xi, hr_r, hr_i, **rkw)
        return unsplit(yr, yi)

    def azimuth_fft(x):
        xr, xi = split(x)
        yr, yi = ops.fft_cols(xr, xi, **ckw)
        return unsplit(yr, yi)

    def rcmc(x):
        # ONE dispatch: range FFT -> rank-1 shift phase -> range IFFT
        xr, xi = split(x)
        yr, yi = ops.fused_rcmc_rows(xr, xi, rc_u, rc_v, **rkw)
        return unsplit(yr, yi)

    def azimuth_compress(x):
        # ONE dispatch: phase multiply -> column IFFT
        xr, xi = split(x)
        if synth_phase:
            yr, yi = ops.fused_mult_ifft_cols_outer(xr, xi, az_u2, az_v2, **ckw)
        else:
            yr, yi = ops.fused_mult_ifft_cols(xr, xi, ha_r, ha_i, **ckw)
        return unsplit(yr, yi)

    return Pipeline("fused_tfree", cfg, [
        Step("range_compression", range_compress, 1, 1, True),
        Step("azimuth_fft", azimuth_fft, 1, 1, True),
        Step("rcmc", rcmc, 1, 1, True),
        Step("azimuth_compression", azimuth_compress, 1, 1, True),
    ])


# -- beyond-paper: 3-dispatch RDA ---------------------------------------------

def build_fused3(cfg: SceneConfig, interpret: Optional[bool] = None,
                 block: int = 8, col_block: int = 128,
                 fft_impl: str = "matmul", synth_phase: bool = True,
                 fft_kw: Optional[dict] = None) -> Pipeline:
    """The minimum-dispatch RDA. Range compression commutes with the azimuth
    FFT (it is an identical per-row linear operator), so the pipeline reorders
    to  azimuth FFT -> [range FFT * H_r * RCMC-shift * range IFFT] ->
    [H_a * azimuth IFFT]  — THREE fused dispatches, 3 HBM round-trips total
    (vs 8 dispatches in the paper's fused pipeline). RCMC uses the exact
    Fourier-shift interpolator folded into the range dispatch.

    This is also the distributed schedule's local compute: each stage works on
    whole rows or whole columns only, so one corner-turn all_to_all between
    stages 2 and 3 suffices (see core/sar/distributed.py).
    """
    hr_r, hr_i = filters.range_matched_filter(cfg)
    hr_r, hr_i = jnp.asarray(hr_r), jnp.asarray(hr_i)
    rc_u, rc_v = filters.rcmc_phase_uv(cfg)
    rc_u, rc_v = jnp.asarray(rc_u), jnp.asarray(rc_v)
    az_u2, az_v2 = filters.azimuth_phase_uv2(cfg)
    az_u2, az_v2 = jnp.asarray(az_u2), jnp.asarray(az_v2)
    ha_r, ha_i = filters.azimuth_matched_filter_split(cfg)
    ha_r, ha_i = jnp.asarray(ha_r), jnp.asarray(ha_i)
    rkw = dict(interpret=interpret, block=block, fft_impl=fft_impl,
               **(fft_kw or {}))
    ckw = dict(interpret=interpret, block=col_block, fft_impl=fft_impl)

    def azimuth_fft(x):
        xr, xi = split(x)
        yr, yi = ops.fft_cols(xr, xi, **ckw)
        return unsplit(yr, yi)

    def range_compress_rcmc(x):
        xr, xi = split(x)
        yr, yi = ops.fused_rc_rcmc_rows(xr, xi, hr_r, hr_i, rc_u, rc_v, **rkw)
        return unsplit(yr, yi)

    def azimuth_compress(x):
        xr, xi = split(x)
        if synth_phase:
            yr, yi = ops.fused_mult_ifft_cols_outer(xr, xi, az_u2, az_v2, **ckw)
        else:
            yr, yi = ops.fused_mult_ifft_cols(xr, xi, ha_r, ha_i, **ckw)
        return unsplit(yr, yi)

    return Pipeline("fused3", cfg, [
        Step("azimuth_fft", azimuth_fft, 1, 1, True),
        Step("range_comp_rcmc", range_compress_rcmc, 1, 1, True),
        Step("azimuth_compression", azimuth_compress, 1, 1, True),
    ])


BUILDERS: dict[str, Callable[..., Pipeline]] = {
    "unfused": build_unfused,
    "fused": build_fused,
    "fused_tfree": build_fused_tfree,
    "fused3": build_fused3,
}


def build_pipeline(cfg: SceneConfig, variant: str, **kw) -> Pipeline:
    return BUILDERS[variant](cfg, **kw)


def focus(raw: jnp.ndarray, cfg: SceneConfig, variant: str = "fused_tfree",
          **kw) -> jnp.ndarray:
    """One-call RDA: raw echo (na, nr) — or a batch (B, na, nr) of scenes
    sharing `cfg` — complex64 -> focused image(s) of the same shape."""
    return build_pipeline(cfg, variant, **kw).run(raw)
