"""SAR scene geometry and derived radar quantities.

Side-looking strip-map geometry per Cumming & Wong [1]: a platform moving at
velocity ``v`` along azimuth, transmitting linear-FM chirps (bandwidth ``B``,
duration ``tp``, carrier ``fc``) toward a scene at closest-approach range
``r0``. The paper's scene is 4096 x 4096 complex samples (azimuth x range),
X-band (fc = 10 GHz), B = 100 MHz, v = 100 m/s, r0 = 20 km, 20 dB noise.
"""
from __future__ import annotations

import dataclasses
import math

C = 299_792_458.0  # speed of light, m/s


@dataclasses.dataclass(frozen=True)
class SceneConfig:
    """Static description of one SAR acquisition + simulation grid."""

    na: int = 4096            # azimuth lines
    nr: int = 4096            # range samples per line
    fc: float = 10.0e9        # carrier frequency (Hz)  — X band
    bandwidth: float = 100.0e6  # chirp bandwidth (Hz)
    tp: float = 10.0e-6       # pulse duration (s)
    fs: float = 120.0e6       # range sampling rate (Hz), 1.2x oversampled
    prf: float = 400.0        # pulse repetition frequency (Hz)
    v: float = 100.0          # platform velocity (m/s)
    r0: float = 20_000.0      # closest-approach range of scene center (m)
    aperture_time: float = 4.0  # synthetic aperture (beam dwell) time (s)
    noise_db: float = 20.0    # raw-data SNR in dB (paper: 20 dB additive noise)
    seed: int = 1234

    # ---- derived quantities -------------------------------------------------
    @property
    def wavelength(self) -> float:
        return C / self.fc

    @property
    def kr(self) -> float:
        """Range chirp FM rate (Hz/s)."""
        return self.bandwidth / self.tp

    @property
    def ka(self) -> float:
        """Azimuth FM rate at scene center (Hz/s), hyperbolic approximation."""
        return 2.0 * self.v**2 / (self.wavelength * self.r0)

    @property
    def doppler_bandwidth(self) -> float:
        return self.ka * self.aperture_time

    @property
    def range_res(self) -> float:
        """Slant-range resolution c/2B (m)."""
        return C / (2.0 * self.bandwidth)

    @property
    def azimuth_res(self) -> float:
        return self.v / self.doppler_bandwidth

    @property
    def dr(self) -> float:
        """Range sample spacing (m)."""
        return C / (2.0 * self.fs)

    @property
    def da(self) -> float:
        """Azimuth sample spacing (m)."""
        return self.v / self.prf

    @property
    def pulse_samples(self) -> int:
        return int(round(self.tp * self.fs))

    @property
    def aperture_samples(self) -> int:
        return int(round(self.aperture_time * self.prf))

    def validate(self) -> None:
        if self.doppler_bandwidth >= self.prf:
            raise ValueError(
                f"azimuth aliasing: doppler bandwidth {self.doppler_bandwidth:.1f} Hz"
                f" >= PRF {self.prf:.1f} Hz")
        if self.bandwidth > self.fs:
            raise ValueError("range aliasing: bandwidth > fs")
        if self.pulse_samples >= self.nr:
            raise ValueError("pulse longer than range window")
        if self.aperture_samples >= self.na:
            raise ValueError("aperture longer than azimuth window")


@dataclasses.dataclass(frozen=True)
class PointTarget:
    """A point scatterer at (range_offset_m, azimuth_offset_m) from scene
    center, with complex reflectivity magnitude ``sigma``."""

    range_offset: float = 0.0     # m, + = farther
    azimuth_offset: float = 0.0   # m, + = later
    sigma: float = 1.0


def paper_scene(na: int = 4096, nr: int = 4096) -> SceneConfig:
    """The paper's experimental setup (Sec. V-A)."""
    return SceneConfig(na=na, nr=nr)


def paper_targets(cfg: SceneConfig) -> list[PointTarget]:
    """Five point targets at various range/azimuth offsets (paper Table IV)."""
    rs = cfg.dr * cfg.nr / 8          # range extent unit
    az = cfg.da * cfg.na / 8          # azimuth extent unit
    return [
        PointTarget(0.0, 0.0),                      # target 0: center
        PointTarget(rs, 0.0),                       # target 1: range offset
        PointTarget(0.0, az),                       # target 2: azimuth offset
        PointTarget(-rs, -az),                      # target 3: diagonal offset
        PointTarget(2 * rs, 1.5 * az),              # target 4: far offset
    ]


def test_scene(n: int = 512) -> SceneConfig:
    """A reduced scene with the same qualitative regime (for CPU tests).

    Parameters are rescaled so the pulse fills ~1/4 of the range window and
    the aperture ~5/8 of the azimuth window, with visible range migration.
    """
    fs = 120.0e6
    prf = 400.0
    return SceneConfig(
        na=n,
        nr=n,
        fs=fs,
        prf=prf,
        tp=(n // 4) / fs,
        aperture_time=(n * 5 // 8) / prf,
        r0=5_000.0,
        noise_db=20.0,
    )
