"""Point-target quality metrics: PSLR, ISLR, SNR (paper Table IV).

All metrics are computed host-side with numpy on the magnitude image — they
are validation instruments, not part of the compute pipeline.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sar.geometry import PointTarget, SceneConfig


@dataclasses.dataclass
class TargetReport:
    row: int                 # measured peak position (azimuth)
    col: int                 # measured peak position (range)
    peak: float              # |peak|
    snr_db: float            # 20 log10(|peak| / noise RMS)
    pslr_range_db: float     # peak sidelobe ratio along the range cut
    pslr_azimuth_db: float
    islr_range_db: float     # integrated sidelobe ratio along the range cut
    islr_azimuth_db: float


def expected_pixel(cfg: SceneConfig, tgt: PointTarget) -> tuple[int, int]:
    """Predicted (row, col) of a focused target.

    Range: the echo starts at fast time 2R/c; the matched filter (replica at
    offset 0) compresses to the start sample. Azimuth: closest approach time.
    """
    col = cfg.nr / 2 + tgt.range_offset / cfg.dr
    row = cfg.na / 2 + tgt.azimuth_offset / cfg.da
    return int(round(row)) % cfg.na, int(round(col)) % cfg.nr


def _find_peak(mag: np.ndarray, row: int, col: int, search: int = 8):
    """Local peak within +-search of the predicted position (wrapped)."""
    na, nr = mag.shape
    rows = (np.arange(row - search, row + search + 1)) % na
    cols = (np.arange(col - search, col + search + 1)) % nr
    win = mag[np.ix_(rows, cols)]
    i, j = np.unravel_index(np.argmax(win), win.shape)
    return int(rows[i]), int(cols[j])


def _cut_metrics(cut: np.ndarray, peak_idx: int, mainlobe_halfwidth: int,
                 window: int):
    """PSLR and ISLR along a 1-D cut around peak_idx."""
    n = len(cut)
    idx = (np.arange(peak_idx - window, peak_idx + window + 1)) % n
    seg = np.abs(cut[idx]) ** 2
    center = window  # peak position within seg
    main = np.zeros(len(seg), bool)
    main[center - mainlobe_halfwidth:center + mainlobe_halfwidth + 1] = True
    p_main = float(seg[main].sum())
    p_side = float(seg[~main].sum())
    peak_side = float(seg[~main].max()) if (~main).any() else 0.0
    peak_main = float(seg[center])
    pslr = 10.0 * np.log10(max(peak_side, 1e-30) / peak_main)
    islr = 10.0 * np.log10(max(p_side, 1e-30) / max(p_main, 1e-30))
    return pslr, islr


def noise_rms(image: np.ndarray, cfg: SceneConfig,
              targets: list[PointTarget], guard: int = 64) -> float:
    """RMS magnitude outside guard windows around every target."""
    mag = np.abs(image)
    mask = np.ones_like(mag, bool)
    for t in targets:
        r, c = expected_pixel(cfg, t)
        rows = (np.arange(r - guard, r + guard + 1)) % cfg.na
        cols = (np.arange(c - guard, c + guard + 1)) % cfg.nr
        mask[np.ix_(rows, cols)] = False
    vals = mag[mask]
    return float(np.sqrt(np.mean(vals**2))) if vals.size else 0.0


def analyze_target(image: np.ndarray, cfg: SceneConfig, tgt: PointTarget,
                   noise: float, mainlobe_cells: float = 1.5,
                   window: int = 32) -> TargetReport:
    mag = np.abs(image)
    r0, c0 = expected_pixel(cfg, tgt)
    r, c = _find_peak(mag, r0, c0)
    # mainlobe halfwidth in samples from the theoretical resolutions
    ml_r = max(2, int(round(mainlobe_cells * cfg.range_res / cfg.dr)))
    ml_a = max(2, int(round(mainlobe_cells * cfg.azimuth_res / cfg.da)))
    rng_cut = image[r, :]
    azi_cut = image[:, c]
    pslr_r, islr_r = _cut_metrics(rng_cut, c, ml_r, window)
    pslr_a, islr_a = _cut_metrics(azi_cut, r, ml_a, window)
    peak = float(mag[r, c])
    snr = 20.0 * np.log10(peak / max(noise, 1e-30))
    return TargetReport(r, c, peak, snr, pslr_r, pslr_a, islr_r, islr_a)


def analyze_scene(image: np.ndarray, cfg: SceneConfig,
                  targets: list[PointTarget]) -> list[TargetReport]:
    noise = noise_rms(image, cfg, targets)
    return [analyze_target(image, cfg, t, noise) for t in targets]


# ---------------------------------------------------------------------------
# Pipeline-vs-pipeline comparisons (paper Table IV top rows)
# ---------------------------------------------------------------------------

def l2_relative_error(a: np.ndarray, b: np.ndarray) -> float:
    return float(np.linalg.norm((a - b).ravel()) /
                 max(np.linalg.norm(b.ravel()), 1e-30))


def max_abs_error(a: np.ndarray, b: np.ndarray) -> float:
    return float(np.max(np.abs(a - b)))


def compare_pipelines(img_a: np.ndarray, img_b: np.ndarray, cfg: SceneConfig,
                      targets: list[PointTarget]) -> dict:
    """The paper's Table IV: L2 rel error, max abs error, per-target SNR
    for both images and the per-target SNR delta."""
    rep_a = analyze_scene(img_a, cfg, targets)
    rep_b = analyze_scene(img_b, cfg, targets)
    return {
        "l2_relative_error": l2_relative_error(img_a, img_b),
        "max_abs_error": max_abs_error(img_a, img_b),
        "snr_a_db": [r.snr_db for r in rep_a],
        "snr_b_db": [r.snr_db for r in rep_b],
        "snr_delta_db": [abs(x.snr_db - y.snr_db)
                         for x, y in zip(rep_a, rep_b)],
        "reports_a": rep_a,
        "reports_b": rep_b,
    }
