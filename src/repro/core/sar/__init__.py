"""SAR substrate: geometry, simulator, filters, plan-compiled RDA / CSA /
ω-K pipelines, metrics."""
from repro.core.sar.geometry import (  # noqa: F401
    C,
    PointTarget,
    SceneConfig,
    paper_scene,
    paper_targets,
    test_scene,
)
from repro.core.sar.simulate import simulate, simulate_cached  # noqa: F401
from repro.core.sar.rda import (  # noqa: F401
    BUILDERS,
    Pipeline,
    Step,
    build_pipeline,
    documented_dispatches,
    focus,
    variant_names,
)
from repro.core.sar import csa, filters, metrics, omegak  # noqa: F401
