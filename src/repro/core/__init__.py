"""Core: the paper's fused spectral pipeline + the SAR system built on it."""
from repro.core.fusion import (  # noqa: F401
    BACKEND_PALLAS,
    BACKEND_XLA,
    SpectralPipeline,
    fft_conv,
)
from repro.core import sar  # noqa: F401
