"""Three-term roofline from compiled dry-run artifacts (TPU v5e targets).

  compute term    = HLO_FLOPs / (chips x 197e12 FLOP/s bf16)
  memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
  collective term = per-device link bytes / 50e9 B/s per ICI link

Collective bytes come from the post-SPMD HLO text: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op's tensor
bytes, scaled by the ring-transfer factor on its replica-group size g:
  all-reduce      2 (g-1)/g        (reduce-scatter + all-gather phases)
  all-gather      (g-1)/g          (on the gathered output bytes)
  reduce-scatter  (g-1)/g          (on the scattered input bytes)
  all-to-all      (g-1)/g
  collective-permute  1
HLO_FLOPs / HLO_bytes from compiled.cost_analysis() are for the per-device
SPMD program, so terms are per-chip step latencies directly (the `chips x`
division is already reflected in the partitioned shapes).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:\([^)]*\)|[a-z0-9\[\],{} ]+?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_FACTORS = {
    "all-reduce": lambda g: 2.0 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota form [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_op: dict
    link_bytes: float      # ring-model per-device bytes over the slowest link

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    counts: dict = {}
    bytes_by_op: dict = {}
    link = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(3).lower()
        if "-done" in line.split("=")[1][:40]:
            continue  # async done ops carry no new bytes
        nbytes = _shape_bytes(m.group(2))  # output shape (tuple-safe)
        if nbytes == 0:
            continue
        g = _group_size(line, n_devices)
        counts[op] = counts.get(op, 0) + 1
        bytes_by_op[op] = bytes_by_op.get(op, 0) + nbytes
        link += _FACTORS[op](max(g, 1)) * nbytes
    return CollectiveStats(counts, bytes_by_op, link)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collectives: CollectiveStats
    model_flops: Optional[float] = None   # analytic 6*N*D (or 6*N_active*D)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collectives.link_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound(self) -> float:
        """Roofline step-time lower bound (max of the three terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> Optional[float]:
        if not self.model_flops or not self.flops:
            return None
        return self.model_flops / self.flops

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_counts": self.collectives.counts,
            "collective_bytes_by_op": self.collectives.bytes_by_op,
            "collective_link_bytes": self.collectives.link_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_bound_s": self.bound,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
        }


def from_compiled(compiled, n_devices: int,
                  model_flops: Optional[float] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text(), n_devices)
    return Roofline(flops, nbytes, stats, model_flops)
