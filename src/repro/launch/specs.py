"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

Per the assignment, modality frontends are stubs: `input_specs` supplies
precomputed frame/patch embeddings alongside the token ids. Nothing here
allocates device memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.registry import ShapeSpec
from repro.models import Model
from repro.models.config import ModelConfig

N_PATCHES = 256  # vision stub: fixed patch count folded into the sequence


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, with_labels: bool) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    out = {"tokens": sds((b, s), i32)}
    if with_labels:
        out["labels"] = sds((b, s), i32)
    if cfg.frontend == "vision_stub":
        out["patch_embeds"] = sds((b, N_PATCHES, cfg.d_model), jnp.bfloat16)
        if cfg.mrope_sections is not None:
            out["positions"] = sds((b, s, len(cfg.mrope_sections)), i32)
    if cfg.is_encoder_decoder:
        out["frames"] = sds((b, cfg.encoder.n_frames, cfg.d_model),
                            jnp.bfloat16)
    return out


def decode_token_specs(shape: ShapeSpec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)


def cache_specs(model: Model, shape: ShapeSpec) -> dict:
    """Abstract decode cache (already at full length: the decode cells lower
    one serve_step against a seq_len-deep cache, per the assignment)."""
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))


def params_specs(model: Model) -> dict:
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
