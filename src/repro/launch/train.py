"""Training driver: config -> mesh -> sharded params -> fault-tolerant loop.

Runs anywhere: on this CPU container it trains reduced configs end-to-end
(examples/train_lm.py); on a fleet the same code paths run under the
production mesh. Integrates every substrate: deterministic data stream
(exact resume), AdamW, checkpoint manager (async, keep-k, atomic),
preemption handler, straggler watchdog, failure injection for tests.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.data import DataConfig, TokenStream
from repro.distributed import (
    FailureInjector,
    PreemptionHandler,
    SimulatedFailure,
    StragglerWatchdog,
)
from repro.launch import sharding as shd
from repro.launch import steps as steps_mod
from repro.launch.mesh import activation_rules, make_host_mesh
from repro.models import Model, use_mesh_rules
from repro.optim import AdamWConfig, adamw


@dataclasses.dataclass
class TrainRun:
    """Everything a (re)start needs."""
    params: dict
    opt_state: dict
    step: int


def build(arch: str, smoke: bool, batch: int, seq: int, mesh=None,
          opt_cfg: Optional[AdamWConfig] = None, accum: int = 1):
    cfg = registry.smoke(arch, seq=seq) if smoke else registry.get(arch)
    model = Model(cfg)
    mesh = mesh or make_host_mesh()
    rules = activation_rules(mesh)
    opt_cfg = opt_cfg or AdamWConfig(warmup_steps=10, decay_steps=1000)

    p_shape = specs_params = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    p_shard = shd.param_shardings(p_shape, cfg, mesh, rules)
    train_step = steps_mod.build_train_step(model, opt_cfg, accum)
    jitted = jax.jit(train_step, donate_argnums=(0, 1))

    data = TokenStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                  global_batch=batch))
    return model, cfg, mesh, rules, p_shard, jitted, data


def init_state(model, mesh, rules, p_shard, seed: int = 0) -> TrainRun:
    with use_mesh_rules(mesh, rules):
        params = jax.jit(model.init, out_shardings=p_shard)(
            jax.random.PRNGKey(seed))
    opt_state = adamw.init(params)
    return TrainRun(params, opt_state, 0)


def train_loop(run: TrainRun, jitted, data: TokenStream, mesh, rules,
               n_steps: int, ckpt: Optional[CheckpointManager] = None,
               ckpt_every: int = 50,
               injector: Optional[FailureInjector] = None,
               preempt: Optional[PreemptionHandler] = None,
               log_every: int = 10, async_ckpt: bool = True):
    """Returns (run, losses, watchdog). Raises SimulatedFailure through to the
    restart policy (distributed.run_with_restarts)."""
    watchdog = StragglerWatchdog()
    losses = []
    params, opt_state = run.params, run.opt_state
    step = run.step
    try:
        while step < n_steps:
            t0 = time.time()
            if injector is not None:
                injector.check(step)
            batch = data.batch(step)
            with use_mesh_rules(mesh, rules):
                params, opt_state, stats = jitted(params, opt_state, batch)
            loss = float(stats["loss"])
            losses.append(loss)
            step += 1
            dt = time.time() - t0
            if watchdog.record(step, dt):
                print(f"[watchdog] step {step} straggled: {dt:.2f}s")
            if log_every and step % log_every == 0:
                print(f"step {step}: loss={loss:.4f} "
                      f"gnorm={float(stats['grad_norm']):.3f} "
                      f"lr={float(stats['lr']):.2e} ({dt:.2f}s)", flush=True)
            if ckpt is not None and step % ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state},
                          blocking=not async_ckpt)
            if preempt is not None and preempt.should_stop:
                if ckpt is not None:
                    ckpt.save(step, {"params": params, "opt": opt_state},
                              blocking=True)
                break
    except SimulatedFailure:
        run.params, run.opt_state, run.step = params, opt_state, step
        raise
    if ckpt is not None:
        ckpt.wait()
    run.params, run.opt_state, run.step = params, opt_state, step
    return run, losses, watchdog


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    model, cfg, mesh, rules, p_shard, jitted, data = build(
        args.arch, args.smoke, args.batch, args.seq, accum=args.accum)
    print(f"arch={cfg.name} params~{cfg.param_count():,} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    run = init_state(model, mesh, rules, p_shard)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and ckpt is not None and ckpt.latest_step() is not None:
        tree, step = ckpt.restore({"params": run.params, "opt": run.opt_state})
        run = TrainRun(tree["params"], tree["opt"], step)
        print(f"resumed from step {step}")
    preempt = PreemptionHandler()
    run, losses, wd = train_loop(run, jitted, data, mesh, rules, args.steps,
                                 ckpt, args.ckpt_every, preempt=preempt)
    print(f"done: step={run.step} loss[first,last]="
          f"[{losses[0]:.3f}, {losses[-1]:.3f}] stragglers={len(wd.flagged)}")


if __name__ == "__main__":
    main()
