"""Launch layer: meshes, sharding rules, AOT dry-run, roofline, drivers."""
