"""Parameter sharding rules: FSDP over "data" x tensor/expert parallel over
"model", resolved per architecture.

Rules are path-regex -> logical axes; logical axes resolve to mesh axes
(launch.mesh.activation_rules) with divisibility checks — a dimension that
does not divide its mesh axis falls back to replicated rather than relying
on GSPMD padding (exceptions: see `_maybe`). MoE experts shard over "model"
when E divides it (expert parallelism); otherwise experts replicate and the
per-expert FFN is sharded over its hidden dim (granite's 40 experts on a
16-way axis; DESIGN.md §MoE-sharding).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# path-regex -> logical spec (leading scan axis handled automatically)
PARAM_RULES = [
    (r"\['embed'\]\['table'\]$", ("vocab", "embed")),
    (r"\['lm_head'\]\['table'\]$", ("vocab", "embed")),
    (r"\['(wq|wk|wv)'\]$", ("embed", "heads")),
    (r"\['wo'\]$", ("heads", "embed")),
    (r"\['(wi_gate|wi_up)'\]$", ("embed", "ff")),          # dense MLP (D, F)
    (r"\['ffn'\]\['router'\]$", ("embed", None)),
    (r"moe_wi", ("experts", "embed", "ff")),               # (E, D, F) placeholder
    (r"\['in_proj'\]$", ("embed", "ff")),                  # mamba (D, 2di)
    (r"\['x_proj'\]$", ("ff", None)),
    (r"\['dt_proj'\]\['w'\]$", (None, "ff")),
    (r"\['dt_proj'\]\['b'\]$", ("ff",)),
    (r"\['a_log'\]$", ("ff", None)),
    (r"\['d_skip'\]$", ("ff",)),
    (r"\['out_proj'\]$", ("ff", "embed")),                 # mamba/rglru out
    (r"\['(gate_proj|rec_proj)'\]$", ("embed", "ff")),     # rglru (D, W)
    (r"\['(wa|wx)'\]$", (None, "ff")),                     # rglru (W, W)
    (r"\['lambda'\]$", ("ff",)),
    (r"\['conv'\]\['w'\]$", (None, "ff")),
    (r"\['conv'\]\['b'\]$", ("ff",)),
    (r"\['scale'\]$", (None,)),                            # norms
]


def _logical_for(path: str, shape, cfg: ModelConfig, ep: bool):
    # MoE expert tensors are 3-D (E, D, F) / (E, F, D) — 4-D when
    # scan-stacked (the leading period axis is added by the caller).
    if re.search(r"\['ffn'\]\['(wi_gate|wi_up)'\]$", path) and len(shape) >= 3:
        return ("experts", "embed", None) if ep else (None, "embed", "ff")
    if re.search(r"\['ffn'\]\['wo'\]$", path) and len(shape) >= 3:
        return ("experts", None, "embed") if ep else (None, "ff", "embed")
    for pat, spec in PARAM_RULES:
        if re.search(pat, path):
            return spec
    return tuple(None for _ in shape)


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _maybe(mesh: Mesh, rules: dict, logical, dim: int) -> Optional[object]:
    """Resolve one logical name to a mesh axis iff the dim divides it."""
    axis = rules.get(logical) if logical else None
    if axis is None:
        return None
    if dim % _mesh_axis_size(mesh, axis) != 0:
        return None
    return axis


def param_shardings(params_shape, cfg: ModelConfig, mesh: Mesh, rules: dict):
    """Pytree of NamedSharding for a params (or eval_shape) pytree."""
    ep = (cfg.moe is not None
          and cfg.moe.n_experts % _mesh_axis_size(mesh, rules.get("experts")) == 0)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in flat:
        pstr = "".join(str(k) for k in path)
        shape = leaf.shape
        logical = _logical_for(pstr, shape, cfg, ep)
        # scan-stacked params carry a leading period axis -> replicated dim
        if "'scan'" in pstr and len(logical) == len(shape) - 1:
            logical = (None,) + tuple(logical)
        if len(logical) != len(shape):
            logical = tuple(None for _ in shape)
        spec = P(*[_maybe(mesh, rules, l, d) for l, d in zip(logical, shape)])
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def cache_shardings(cache_shape, cfg: ModelConfig, mesh: Mesh, rules: dict,
                    batch: int):
    """Decode-cache shardings. KV tensors (..., B, S, K, Dh): batch shards
    over the batch axes when divisible; otherwise the cache sequence shards
    over "data" (sequence-parallel flash-decoding for batch-1 long context).
    KV heads shard over "model" when divisible, else head_dim."""
    baxes = rules.get("batch")
    b_ok = batch % _mesh_axis_size(mesh, baxes) == 0 and batch > 1
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    out = []
    for path, leaf in flat:
        pstr = "".join(str(k) for k in path)
        shape = leaf.shape
        spec = P()
        if re.search(r"\['(k|v)'\]$", pstr) and len(shape) >= 4:
            lead = len(shape) - 4
            bdim, sdim, kdim, ddim = shape[-4:]
            b_ax = baxes if (b_ok and bdim % _mesh_axis_size(mesh, baxes) == 0) else None
            s_ax = None if b_ax is not None else _maybe(
                mesh, rules, "kv_seq", sdim)
            k_ax = _maybe(mesh, rules, "heads", kdim)
            d_ax = None if k_ax is not None else _maybe(
                mesh, rules, "heads", ddim)
            spec = P(*([None] * lead + [b_ax, s_ax, k_ax, d_ax]))
        elif re.search(r"\['pos'\]$", pstr) and len(shape) >= 2:
            lead = len(shape) - 2
            b_ax = baxes if (b_ok and shape[-2] % _mesh_axis_size(mesh, baxes) == 0) else None
            spec = P(*([None] * lead + [b_ax, None]))
        elif len(shape) >= 2:  # recurrent states (..., B, ...)
            lead = len(shape) - 2
            # state tensors: (P?, B, di, n) or (P?, B, w-1, di)
            dims = list(shape)
            axes = [None] * len(shape)
            # batch dim is the first after any scan lead for rec states
            bpos = 1 if len(shape) > 2 and "'scan'" in pstr else 0
            if b_ok and dims[bpos] % _mesh_axis_size(mesh, baxes) == 0:
                axes[bpos] = baxes
            # shard the channel dim over model if divisible
            ch = len(shape) - 1 if re.search(r"conv", pstr) else len(shape) - 2
            spec = P(*axes)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch_shape, mesh: Mesh, rules: dict):
    """Input batch: dim 0 over the batch axes (if divisible), rest replicated."""
    baxes = rules.get("batch")

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        ok = leaf.shape[0] % _mesh_axis_size(mesh, baxes) == 0
        return NamedSharding(
            mesh, P(*([baxes if ok else None] + [None] * (leaf.ndim - 1))))

    return jax.tree.map(one, batch_shape)


def attach(shapes, shardings):
    """ShapeDtypeStruct pytree + sharding pytree -> sharded SDS pytree
    (the AOT lowering inputs; no device allocation)."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
