"""Multi-pod dry-run: AOT lower + compile every (arch x shape) cell on the
production meshes, record memory/cost/collective analyses.

The first two statements below MUST precede any other import (jax locks the
device count on first init); this module is the only place the 512
placeholder devices exist — tests and benches see the host's real device
count.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
  python -m repro.launch.dryrun --arch sar-rda-4k --mesh multi   # the paper's
                                                                 # own workload
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch import roofline as rf
from repro.launch import sharding as shd
from repro.launch import specs, steps
from repro.launch.mesh import activation_rules, make_production_mesh
from repro.models import Model, use_mesh_rules
from repro.optim import adamw


def _flops_train(cfg, shape) -> float:
    """Analytic MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), D = tokens."""
    n = cfg.active_param_count()
    return 6.0 * n * shape.global_batch * shape.seq_len


def _flops_decode(cfg, shape) -> float:
    return 2.0 * cfg.active_param_count() * shape.global_batch


def _cell_lowered(cfg, shape, mesh, rules):
    """Build + lower the cell's step fn for `cfg`; returns (lowered, kind)."""
    model = Model(cfg)
    p_shape = specs.params_specs(model)
    p_shard = shd.param_shardings(p_shape, cfg, mesh, rules)
    p_sds = shd.attach(p_shape, p_shard)
    with use_mesh_rules(mesh, rules):
        if shape.kind == "train":
            opt_shape = jax.eval_shape(adamw.init, p_shape)
            opt_shard = {"mu": p_shard, "nu": p_shard,
                         "step": jax.sharding.NamedSharding(
                             mesh, jax.sharding.PartitionSpec())}
            opt_sds = shd.attach(opt_shape, opt_shard)
            b_shape = specs.batch_specs(cfg, shape, with_labels=True)
            b_sds = shd.attach(b_shape,
                               shd.batch_shardings(b_shape, mesh, rules))
            fn = steps.build_train_step(model)
            return jax.jit(fn, donate_argnums=(0, 1)).lower(
                p_sds, opt_sds, b_sds)
        if shape.kind == "prefill":
            b_shape = specs.batch_specs(cfg, shape, with_labels=False)
            b_sds = shd.attach(b_shape,
                               shd.batch_shardings(b_shape, mesh, rules))
            fn = steps.build_prefill(model, max_len=shape.seq_len)
            return jax.jit(fn).lower(p_sds, b_sds)
        c_shape = specs.cache_specs(model, shape)
        c_shard = shd.cache_shardings(c_shape, cfg, mesh, rules,
                                      shape.global_batch)
        c_sds = shd.attach(c_shape, c_shard)
        t_sds = specs.decode_token_specs(shape)
        fn = steps.build_decode(model)
        return jax.jit(fn, donate_argnums=(1,)).lower(p_sds, c_sds, t_sds)


def _hlo_flops(compiled) -> float:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return float(cost.get("flops", 0.0))


def scan_flops_correction(cfg, shape, mesh, rules) -> float:
    """XLA's cost_analysis counts a scan body ONCE regardless of trip count.
    Measure the per-period FLOPs by diffing two shallow *unrolled* lowerings
    at full width (1 vs 2 pattern periods) and add (trips - 1) x body."""
    import dataclasses as dc
    if not (cfg.scan_layers and cfg.n_periods > 1):
        return 0.0
    period = len(cfg.pattern)
    cfg1 = dc.replace(cfg, n_layers=period, scan_layers=False)
    cfg2 = dc.replace(cfg, n_layers=2 * period, scan_layers=False)
    f1 = _hlo_flops(_cell_lowered(cfg1, shape, mesh, rules).compile())
    f2 = _hlo_flops(_cell_lowered(cfg2, shape, mesh, rules).compile())
    body = max(f2 - f1, 0.0)
    return (cfg.n_periods - 1) * body


def _save_hlo(record: dict, compiled, out_dir, name: str):
    """Persist the post-SPMD HLO (gzipped) so roofline re-analysis never
    needs a recompile."""
    if not out_dir:
        return
    path = os.path.join(out_dir, name + ".hlo.gz")
    with gzip.open(path, "wt") as f:
        f.write(compiled.as_text())
    record["hlo"] = os.path.basename(path)


def lower_cell(arch: str, shape_name: str, mesh, out_dir=None, name=None,
               cached_correction=None) -> dict:
    """Lower + compile one cell; returns the record dict."""
    rules = activation_rules(mesh)
    n_dev = mesh.devices.size
    record = {"arch": arch, "shape": shape_name,
              "mesh": "x".join(str(s) for s in mesh.devices.shape),
              "devices": int(n_dev)}

    if arch.startswith("sar-rda"):
        return _lower_sar(record, mesh, out_dir, name)
    shape = registry.SHAPES[shape_name]

    cfg = registry.get(arch)
    if shape.kind == "train":
        record["model_flops"] = _flops_train(cfg, shape)
    elif shape.kind == "prefill":
        record["model_flops"] = (2.0 * cfg.active_param_count()
                                 * shape.global_batch * shape.seq_len)
    else:
        record["model_flops"] = _flops_decode(cfg, shape)

    t0 = time.time()
    lowered = _cell_lowered(cfg, shape, mesh, rules)
    record["t_lower_s"] = round(time.time() - t0, 2)

    t0 = time.time()
    compiled = lowered.compile()
    record["t_compile_s"] = round(time.time() - t0, 2)

    mem = compiled.memory_analysis()
    record["memory"] = {
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "peak_bytes_per_device": int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)),
    }
    _save_hlo(record, compiled, out_dir, name or f"{arch}__{shape_name}")
    t0 = time.time()
    # cost_analysis is for the per-device SPMD program; the correction is
    # measured on the same partitioning, so it is per-device too.
    if cached_correction is not None:
        correction = cached_correction
    else:
        correction = scan_flops_correction(cfg, shape, mesh, rules)
    record["t_correction_s"] = round(time.time() - t0, 2)
    # model_flops is global 6ND; divide by chips to compare per-device
    roof = rf.from_compiled(compiled, n_dev,
                            record["model_flops"] / n_dev)
    roof.flops += correction
    record["scan_flops_correction_per_device"] = correction
    record["roofline"] = roof.to_dict()
    return record


def _lower_sar(record: dict, mesh, out_dir=None, name=None) -> dict:
    """The paper's own workload on the production mesh: distributed RDA
    (corner-turn schedule), all mesh axes pooled. `sar-rda-8k` is the
    paper's future-work target (8K x 8K real-time processing; its Table V
    competitors also run 8K scenes)."""
    from repro.core.sar import paper_scene
    from repro.core.sar.distributed import build_corner2

    n = 8192 if "8k" in record["arch"] else 4096
    cfg = paper_scene(na=n, nr=n)
    axes = tuple(mesh.axis_names)
    # interpret=True: Mosaic kernels cannot compile for the CPU backend; the
    # interpreted kernel lowers to equivalent HLO, so the collective schedule
    # and memory accounting (what this cell proves) are unchanged.
    run = build_corner2(cfg, mesh, axes=axes, interpret=True,
                        block=8, col_block=8)
    raw_sds = jax.ShapeDtypeStruct((cfg.na, cfg.nr), jnp.complex64)
    t0 = time.time()
    lowered = jax.jit(lambda x: run(x)).lower(raw_sds)
    record["t_lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    record["t_compile_s"] = round(time.time() - t0, 2)
    _save_hlo(record, compiled, out_dir, name or record["arch"])
    mem = compiled.memory_analysis()
    record["memory"] = {
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "peak_bytes_per_device": int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)),
    }
    # 2 FFT-ish passes * 5 N log N per point + filters
    import math
    n_pts = cfg.na * cfg.nr
    record["model_flops"] = (
        2 * 5 * n_pts * math.log2(cfg.nr) + 2 * 5 * n_pts * math.log2(cfg.na)
        + 3 * 6 * n_pts)
    roof = rf.from_compiled(compiled, mesh.devices.size,
                            record["model_flops"] / mesh.devices.size)
    record["roofline"] = roof.to_dict()
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--refresh", action="store_true",
                    help="recompute existing cells (reusing their cached "
                         "scan-flops corrections)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}
    cells = []
    if args.all:
        cells = [(a, s) for a, s, skip in registry.cells() if skip is None]
        cells.append(("sar-rda-4k", "n/a"))
    else:
        assert args.arch, "--arch or --all required"
        if args.arch.startswith("sar"):
            cells = [(args.arch, "n/a")]
        else:
            cells = [(args.arch, args.shape or "train_4k")]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for multi in meshes[args.mesh]:
        mesh = make_production_mesh(multi_pod=multi)
        tag = "multi" if multi else "single"
        for arch, shape in cells:
            name = f"{arch}__{shape}__{tag}".replace("/", "_")
            path = os.path.join(args.out, name + ".json")
            cached = None
            if os.path.exists(path):
                old = json.load(open(path))
                if "roofline" in old and not args.refresh:
                    print(f"SKIP {name} (exists)")
                    continue
                cached = old.get("scan_flops_correction_per_device")
            try:
                rec = lower_cell(arch, shape, mesh, args.out, name, cached)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                print(f"OK   {name}: compile={rec['t_compile_s']}s "
                      f"mem={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                      f"t_comp={r['t_compute_s']*1e3:.2f}ms "
                      f"t_mem={r['t_memory_s']*1e3:.2f}ms "
                      f"t_coll={r['t_collective_s']*1e3:.2f}ms "
                      f"bound={r['bottleneck']}", flush=True)
            except Exception as e:
                failures += 1
                print(f"FAIL {name}: {e}", flush=True)
                traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
