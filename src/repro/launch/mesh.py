"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE first jax use.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — batch
shards over ("pod", "data"); parameters FSDP over "data" (intra-pod ICI),
replicated across pods (gradient all-reduce is the only cross-pod
collective, int8-compressible); tensor/expert parallel over "model".

``jax.sharding.AxisType`` / the ``axis_types=`` kwarg only exist on newer
jax; ``repro.compat.make_mesh`` drops them on 0.4.x where every mesh axis
is implicitly Auto anyway.
"""
from __future__ import annotations

import jax

from repro.compat import AXIS_TYPE_AUTO, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AXIS_TYPE_AUTO,) * len(axes))


def make_host_mesh(model: int = 1):
    """Whatever this host offers (tests / CPU examples)."""
    n = len(jax.devices())
    data = n // model
    return make_mesh((data, model), ("data", "model"),
                     axis_types=(AXIS_TYPE_AUTO, AXIS_TYPE_AUTO))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def activation_rules(mesh) -> dict:
    """Logical->mesh mapping for models.sharding.use_mesh_rules."""
    return {
        "batch": batch_axes(mesh),
        "seq": "model",       # Megatron-style sequence parallelism
        "heads": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "kv_seq": "data",     # sequence-parallel KV cache (long decode)
        "embed": "data",      # FSDP: parameters shard their d_model dim over
                              # "data" (gathered per layer, ZeRO-3 style)
    }
