"""Serving driver: prefill a batch of prompts, then decode with batched
single-token steps against the KV caches (full / ring / recurrent state).

CPU-runnable with reduced configs (examples/serve_lm.py); the decode step is
the same function the decode_32k / long_500k dry-run cells lower for the
production meshes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.launch.mesh import activation_rules, make_host_mesh
from repro.models import Model, use_mesh_rules


def generate(model: Model, params, prompts: jnp.ndarray, max_new: int,
             max_len: int, mesh=None, rules=None, temperature: float = 0.0,
             key=None):
    """prompts: (B, S) int32 -> (B, max_new) int32 greedy/sampled tokens."""
    cfg = model.cfg
    rules = rules or {}
    ctx = use_mesh_rules(mesh, rules) if mesh is not None else _null()
    batch = {"tokens": prompts}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.zeros(
            (prompts.shape[0], cfg.encoder.n_frames, cfg.d_model),
            jnp.float32)
    with ctx:
        prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
        decode = jax.jit(model.decode_step, donate_argnums=(1,))
        cache, logits = prefill(params, batch)
        outs = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for i in range(max_new):
            outs.append(tok)
            logits, cache = decode(params, cache, tok)
            if temperature > 0.0 and key is not None:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits / temperature, axis=-1)[:, None]
            else:
                tok = jnp.argmax(logits, axis=-1)[:, None]
            tok = tok.astype(jnp.int32)
    return jnp.concatenate(outs, axis=1)


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = registry.smoke(args.arch) if args.smoke else registry.get(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size, jnp.int32)
    t0 = time.time()
    toks = generate(model, params, prompts, args.max_new,
                    args.prompt_len + args.max_new)
    dt = time.time() - t0
    n = args.batch * args.max_new
    print(f"arch={cfg.name}: generated {n} tokens in {dt:.1f}s "
          f"({n / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(toks[0])[:16])


if __name__ == "__main__":
    main()
