"""The three lowered entry points per architecture: train_step, prefill,
decode_step — plus the SAR pipeline step for the paper's own workload."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.optim import AdamWConfig, adamw


def build_train_step(model: Model, opt_cfg: Optional[AdamWConfig] = None,
                     accum_steps: int = 1):
    opt_cfg = opt_cfg or AdamWConfig()
    return adamw.make_train_step(model.loss, opt_cfg, accum_steps)


def build_prefill(model: Model, max_len: int):
    def prefill(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill


def build_decode(model: Model):
    def decode(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return decode
