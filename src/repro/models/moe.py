"""GShard-style mixture-of-experts FFN: top-k routing with capacity,
einsum dispatch/combine (MXU- and GSPMD-friendly), optional shared expert.

Sharding: expert weights carry a leading E axis annotated "experts"; when E
divides the model axis the dispatched activations reshard g->e via an
all-to-all that GSPMD derives from the einsum (expert parallelism). When E
does not divide any axis (granite's 40 experts on a 16-way axis) the rules
map "experts" to None: experts stay replicated and the per-expert FFN is
tensor-parallel over "ff" instead (see DESIGN.md §MoE-sharding).

Tokens are processed in groups of `group_size` so the dense one-hot dispatch
tensor (G, Sg, E, C) stays bounded: its bytes are tokens * Sg * top_k * cf
regardless of E.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import MoEConfig
from repro.models.layers import ACTS, cast, truncated_normal
from repro.models.sharding import axis_size, shard


def init_moe(key, d: int, f: int, cfg: MoEConfig):
    ks = jax.random.split(key, 5)
    e = cfg.n_experts
    p = {
        "router": truncated_normal(ks[0], (d, e), d ** -0.5),
        "wi_gate": truncated_normal(ks[1], (e, d, f), d ** -0.5),
        "wi_up": truncated_normal(ks[2], (e, d, f), d ** -0.5),
        "wo": truncated_normal(ks[3], (e, f, d), f ** -0.5),
    }
    if cfg.shared_expert:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], d, f)
    return p


def _capacity(sg: int, cfg: MoEConfig) -> int:
    c = int(sg * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_ffn(p, x, cfg: MoEConfig, act: str = "silu", train: bool = True):
    """x: (B, S, D) -> (y, aux_loss). Group, route, dispatch, expert MLP,
    combine."""
    dt = x.dtype
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    sg = min(cfg.group_size, t)
    pad = (-t) % sg
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    g = (t + pad) // sg
    xg = tokens.reshape(g, sg, d)
    xg = shard(xg, "batch", None, None)

    logits = (xg @ cast(p["router"], dt)).astype(jnp.float32)  # (G,Sg,E)
    probs = jax.nn.softmax(logits, axis=-1)

    e, c = cfg.n_experts, _capacity(sg, cfg)
    # top-k selection -> positions within each expert's capacity buffer
    topv, topi = jax.lax.top_k(probs, cfg.top_k)               # (G,Sg,K)
    gates = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # choice-major priority (all 1st choices before any 2nd choice), token
    # order within a choice; per-choice loop keeps peak memory independent
    # of top_k.
    ep = cfg.n_experts % max(axis_size("experts"), 1) == 0
    e_ax = "experts" if ep else None
    f_ax = None if ep else "ff"
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)        # (G,Sg,K,E)
    counts = jnp.zeros((g, 1, e), jnp.float32)
    pos_k, within_k = [], []
    for k in range(cfg.top_k):
        oh = onehot[:, :, k, :]                                # (G,Sg,E)
        pos = counts + jnp.cumsum(oh, axis=1) - oh             # (G,Sg,E)
        pos_k.append((pos * oh).sum(-1))                       # (G,Sg) slot
        within_k.append(((pos < c) * oh).sum(-1))              # (G,Sg) kept?
        counts = counts + oh.sum(axis=1, keepdims=True)

    if cfg.dispatch == "gather":
        # ---- gather/scatter dispatch: ~zero FLOPs (the einsum one-hot
        # matmuls were 84% of granite's compiled FLOPs — §Perf iteration g1)
        garange = jnp.arange(g, dtype=jnp.int32)[:, None]
        sarange = jnp.broadcast_to(jnp.arange(sg, dtype=jnp.int32), (g, sg))
        buf = jnp.full((g, e, c), sg, jnp.int32)   # sentinel -> zero row
        for k in range(cfg.top_k):
            ek = topi[:, :, k]
            slot = jnp.clip(pos_k[k].astype(jnp.int32), 0, c - 1)
            keep = within_k[k] > 0
            # kept slots are unique per expert by construction; overflow
            # entries (clipped to slot c-1) carry the sentinel, and `min`
            # makes them no-ops even when they collide with a kept write
            buf = buf.at[garange, ek, slot].min(
                jnp.where(keep, sarange, sg))
        xg_pad = jnp.concatenate(
            [xg, jnp.zeros((g, 1, d), dt)], axis=1)            # (G,Sg+1,D)
        xe = jnp.take_along_axis(
            xg_pad, buf.reshape(g, e * c)[..., None], axis=1)
        xe = xe.reshape(g, e, c, d).transpose(1, 0, 2, 3)      # (E,G,C,D)
    else:
        # ---- GShard einsum dispatch (baseline; kept for ablation)
        disp = jnp.zeros((g, sg, e, c), jnp.float32)
        for k in range(cfg.top_k):
            slot_oh = jax.nn.one_hot(pos_k[k].astype(jnp.int32) *
                                     (within_k[k] > 0), c, dtype=jnp.float32)
            disp = disp + (within_k[k])[..., None, None] * \
                slot_oh[:, :, None, :] * onehot[:, :, k, :, None]
        xe = jnp.einsum("gsd,gsec->egcd", xg, disp.astype(dt))

    xe = shard(xe, e_ax, "batch", None, None)
    h = ACTS[act](jnp.einsum("egcd,edf->egcf", xe, cast(p["wi_gate"], dt)))
    h = h * jnp.einsum("egcd,edf->egcf", xe, cast(p["wi_up"], dt))
    h = shard(h, e_ax, "batch", None, f_ax)
    ye = jnp.einsum("egcf,efd->egcd", h, cast(p["wo"], dt))
    ye = shard(ye, e_ax, "batch", None, None)

    if cfg.dispatch == "gather":
        # combine: per (token, choice) gather from the expert outputs
        ye_flat = ye.transpose(1, 0, 2, 3).reshape(g, e * c, d)
        ye_flat = jnp.concatenate(
            [ye_flat, jnp.zeros((g, 1, d), ye.dtype)], axis=1)
        y = jnp.zeros((g, sg, d), dt)
        for k in range(cfg.top_k):
            ek = topi[:, :, k]
            slot = jnp.clip(pos_k[k].astype(jnp.int32), 0, c - 1)
            flat = jnp.where(within_k[k] > 0, ek * c + slot, e * c)
            yk = jnp.take_along_axis(ye_flat, flat[..., None], axis=1)
            y = y + yk * gates[:, :, k, None].astype(dt)
    else:
        combine = jnp.zeros((g, sg, e, c), jnp.float32)
        for k in range(cfg.top_k):
            slot_oh = jax.nn.one_hot(pos_k[k].astype(jnp.int32) *
                                     (within_k[k] > 0), c, dtype=jnp.float32)
            dk = (within_k[k])[..., None, None] * \
                slot_oh[:, :, None, :] * onehot[:, :, k, :, None]
            combine = combine + dk * gates[:, :, k, None, None]
        y = jnp.einsum("egcd,gsec->gsd", ye, combine.astype(dt))

    if cfg.shared_expert:
        from repro.models.layers import mlp
        y = y + mlp(p["shared"], xg, act)

    # load-balancing aux loss (Switch/GShard)
    me = probs.mean(axis=1)                                    # (G,E)
    kept = sum((within_k[k])[..., None] * onehot[:, :, k, :]
               for k in range(cfg.top_k))                      # (G,Sg,E)
    ce_frac = kept.mean(axis=1)                                # (G,E)
    aux = (me * ce_frac).sum(-1).mean() * e * cfg.aux_loss_weight
    y = y.reshape(-1, d)[:t] if pad else y.reshape(-1, d)
    return y.reshape(b, s, d), aux
