"""Shared LM building blocks: norms, MLP, RoPE/M-RoPE, embedding, chunked CE.

All parameters are plain dict pytrees of float32 arrays; activations are cast
to the config compute dtype at use. Sharding is expressed through
`repro.models.sharding.shard` logical-axis constraints (no-ops outside an
active mesh-rules context, so CPU tests run unchanged).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.sharding import gather_for_compute, shard


def cast(x, dtype: str, *keep):
    """Cast a parameter to the compute dtype at its use site.

    Every weight flows through here, so this is also where the ZeRO-3
    use-site gather lives: FSDP-sharded dims are un-sharded before the
    matmul (see sharding.gather_for_compute for why bf16 partial-sum
    contractions over the sharded "embed" dim would otherwise drift the
    loss). `keep` optionally names the logical axes of tensor-parallel
    output dims to leave sharded (e.g. cast(p["wq"], dt, None, "heads"))."""
    return gather_for_compute(x.astype(dtype), *keep)


def truncated_normal(key, shape, std, dtype="float32"):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(dt)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def init_mlp(key, d: int, f: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": truncated_normal(k1, (d, f), d ** -0.5),
        "wi_up": truncated_normal(k2, (d, f), d ** -0.5),
        "wo": truncated_normal(k3, (f, d), f ** -0.5),
    }


def mlp(p, x, act: str = "silu"):
    dt = x.dtype
    gate = ACTS[act](x @ cast(p["wi_gate"], dt, None, "ff"))
    up = x @ cast(p["wi_up"], dt, None, "ff")
    # intra-block: hidden dim over "model"; seq is unsharded here (Megatron
    # sequence parallelism applies to the residual stream between blocks)
    h = shard(gate * up, "batch", None, "ff")
    return h @ cast(p["wo"], dt)


# ---------------------------------------------------------------------------
# RoPE and M-RoPE
# ---------------------------------------------------------------------------

def rope_inv_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def rope_angles(positions, head_dim: int, theta: float,
                sections: Optional[tuple] = None) -> jnp.ndarray:
    """positions: (B, S) int or (B, S, C) for M-RoPE with len(sections)==C
    frequency groups. Returns angles (B, S, head_dim // 2) float32."""
    inv = rope_inv_freqs(head_dim, theta)
    if sections is None:
        return positions[..., None].astype(jnp.float32) * inv
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    parts, start = [], 0
    for c, sec in enumerate(sections):
        p = positions[..., c].astype(jnp.float32)
        parts.append(p[..., None] * inv[start:start + sec])
        start += sec
    return jnp.concatenate(parts, axis=-1)


def apply_rope(x, angles):
    """x: (B, S, H, Dh); angles: (B, S, Dh//2). Split-half rotation."""
    dt = x.dtype
    half = x.shape[-1] // 2
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# Embedding + chunked cross-entropy
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int):
    return {"table": truncated_normal(key, (vocab, d), 1.0)}


def embed(p, tokens, dtype: str):
    y = jnp.take(cast(p["table"], dtype, "vocab", None), tokens, axis=0)
    return shard(y, "batch", "seq", None)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    """Whisper-style absolute sinusoidal embeddings, (n, d) float32."""
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    t = jnp.arange(n, dtype=jnp.float32)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


def lm_loss_chunked(x, table, labels, mask=None, chunk: int = 512,
                    z_loss: float = 0.0):
    """Mean next-token CE without materializing (B, S, V) logits.

    x: (B, S, D) final hidden states; table: (V, D) (tied) output embedding;
    labels: (B, S) int32; mask: (B, S) 0/1. Scans sequence chunks; the chunk
    body is rematerialized in backward so only one chunk of logits is ever
    alive.
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    n_chunks = s // chunk
    rem = s - n_chunks * chunk
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    mask = mask.astype(jnp.float32)

    wt = cast(table, x.dtype, "vocab", None)

    @jax.checkpoint
    def chunk_nll(xc, yc, mc):
        logits = jax.lax.dot_general(                  # (B, c, V), f32 accum
            xc, wt, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * mc
        if z_loss:
            nll = nll + z_loss * (lse ** 2) * mc
        return nll.sum()

    def body(acc, inp):
        xc, yc, mc = inp
        return acc + chunk_nll(xc, yc, mc), None

    xs = (x[:, :n_chunks * chunk].reshape(b, n_chunks, chunk, d).swapaxes(0, 1),
          labels[:, :n_chunks * chunk].reshape(b, n_chunks, chunk).swapaxes(0, 1),
          mask[:, :n_chunks * chunk].reshape(b, n_chunks, chunk).swapaxes(0, 1))
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    if rem:
        total = total + chunk_nll(x[:, -rem:], labels[:, -rem:], mask[:, -rem:])
    return total / jnp.maximum(mask.sum(), 1.0)


def logits_last(x_last, table):
    """Decode-step logits: (B, D) @ (V, D)^T -> (B, V) float32."""
    return jax.lax.dot_general(
        x_last, table.astype(x_last.dtype), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
