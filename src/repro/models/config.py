"""Unified model configuration covering the 10 assigned architectures.

One ModelConfig describes: decoder-only dense/GQA transformers (global,
sliding-window, and patterned local:global attention), MoE FFNs (top-k,
optional shared expert), Mamba-1 SSM blocks, RG-LRU (Griffin) hybrid blocks,
encoder-decoder (whisper), and stubbed modality frontends (audio frames /
vision patches supplied as precomputed embeddings per the assignment).

Layer structure = `pattern` (a tuple of mixer kinds) cycled over `n_layers`;
layers whose index falls outside full pattern periods are appended verbatim.
Mixer kinds: 'global' | 'local' | 'rglru' | 'mamba'.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    shared_expert: bool = False      # llama4-style always-on shared expert
    group_size: int = 1024           # tokens per dispatch group
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    dispatch: str = "gather"         # 'gather' (take/scatter, ~0 dispatch
                                     # FLOPs) | 'einsum' (GShard one-hot
                                     # matmuls; the §Perf baseline)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None    # default ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or -(-d_model // 16)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    conv_width: int = 4
    c: float = 8.0                   # gate exponent constant (Griffin)
    lru_width: Optional[int] = None  # default d_model


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder consuming precomputed frame embeddings (stub)."""
    n_layers: int
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: tuple = ("global",)
    head_dim: Optional[int] = None
    window: int = 4096               # sliding window for 'local' mixers
    ffn: str = "mlp"                 # 'mlp' | 'moe' | 'none'
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    rope_theta: float = 10_000.0
    mrope_sections: Optional[tuple] = None   # e.g. (16, 24, 24) for M-RoPE
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    frontend: str = "none"           # 'none' | 'audio_stub' | 'vision_stub'
    # numerics / structure
    dtype: str = "bfloat16"          # activation compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    loss_chunk: int = 512            # sequence chunk for the vocab CE
    attn_q_chunk: Optional[int] = None  # online-softmax q chunking (None=auto)
    scan_layers: bool = True

    # ---- derived ------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def layer_kinds(self) -> tuple:
        """Per-layer mixer kind, pattern cycled across n_layers."""
        return tuple(self.pattern[i % len(self.pattern)]
                     for i in range(self.n_layers))

    @property
    def n_periods(self) -> int:
        """Full pattern periods (scanned); remainder layers are unrolled."""
        return self.n_layers // len(self.pattern)

    @property
    def remainder_kinds(self) -> tuple:
        r = self.n_layers % len(self.pattern)
        return tuple(self.pattern[:r])

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder is not None

    @property
    def attention_free(self) -> bool:
        return all(k in ("mamba", "rglru") for k in self.layer_kinds)

    @property
    def sub_quadratic(self) -> bool:
        """True if no mixer requires a full-length KV cache at decode
        (SSM / recurrent / bounded-window only)."""
        return all(k in ("mamba", "rglru", "local")
                   for k in self.layer_kinds)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for 6ND roofline."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, nh, nkv = self.resolved_head_dim, self.n_heads, self.n_kv_heads
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        for kind in self.layer_kinds:
            if kind in ("global", "local"):
                total += d * nh * hd + 2 * d * nkv * hd + nh * hd * d
            elif kind == "rglru":
                rg = self.rglru or RGLRUConfig()
                w = rg.lru_width or d
                total += 2 * d * w + w * d + rg.conv_width * w + 2 * w * w + 2 * w
            elif kind == "mamba":
                ssm = self.ssm or SSMConfig()
                di = ssm.expand * d
                dt = ssm.resolved_dt_rank(d)
                total += (2 * d * di + ssm.conv_width * di
                          + di * (dt + 2 * ssm.state_dim) + dt * di
                          + di * ssm.state_dim + di + di * d)
            # FFN
            if self.ffn == "mlp" and kind != "mamba":
                total += 3 * d * f
            elif self.ffn == "moe" and kind != "mamba":
                moe = self.moe
                total += moe.n_experts * 3 * d * f + d * moe.n_experts
                if moe.shared_expert:
                    total += 3 * d * f
            total += 2 * d  # the two norms
        if self.encoder is not None:
            for _ in range(self.encoder.n_layers):
                total += (d * nh * hd + 2 * d * nkv * hd + nh * hd * d
                          + 3 * d * f + 2 * d)
            # decoder cross-attention
            total += self.n_layers * (d * nh * hd + 2 * d * nkv * hd
                                      + nh * hd * d + d)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.ffn != "moe":
            return self.param_count()
        moe = self.moe
        dense_ffn = 3 * self.d_model * self.d_ff
        inactive = (moe.n_experts - moe.top_k) * dense_ffn
        return int(self.param_count() - self.n_layers * inactive)
