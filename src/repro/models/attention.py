"""GQA attention: global causal, sliding-window local, bidirectional
(encoder), cross-attention, with full and ring KV caches for decode.

Numerics: logits and softmax in float32 regardless of compute dtype.
Memory: optional query chunking (lax.scan with rematerialized chunk body)
keeps the (Sq, Skv) score matrix bounded at Sq_chunk * Skv — the pure-JAX
flash-attention pattern, adequate on TPU where XLA fuses mask+softmax.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, cast, rope_angles, truncated_normal
from repro.models.sharding import axis_size, shard


def _kv_spec(n_kv: int, head_dim: int) -> tuple:
    """KV tensors (B, S, K, Dh): shard heads over "model" only when K divides
    it (padded small-K shardings trigger involuntary SPMD remats); fall back
    to head_dim, then replicated."""
    m = axis_size("heads")
    if m > 1 and n_kv % m == 0:
        return (None, "heads", None)
    if m > 1 and head_dim % m == 0:
        return (None, None, "heads")
    return (None, None, None)

NEG_INF = -1e30


def init_attention(key, d: int, n_heads: int, n_kv: int, head_dim: int,
                   cross: bool = False):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": truncated_normal(k1, (d, n_heads * head_dim), d ** -0.5),
        "wk": truncated_normal(k2, (d, n_kv * head_dim), d ** -0.5),
        "wv": truncated_normal(k3, (d, n_kv * head_dim), d ** -0.5),
        "wo": truncated_normal(k4, (n_heads * head_dim, d),
                               (n_heads * head_dim) ** -0.5),
    }
    return p


def _split_heads(x, n, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n, head_dim)


def _score_mask(q_pos, k_pos, causal: bool, window: Optional[int],
                k_valid=None):
    """(B, Sq, Skv) bool mask of allowed attention edges.

    q_pos/k_pos: (B, Sq)/(B, Skv) int32 absolute positions.
    window W: only k in (q - W, q] (combined with causal).
    k_valid: (B, Skv) bool for cache slots that are populated.
    """
    d = q_pos[:, :, None] - k_pos[:, None, :]
    m = jnp.ones(d.shape, bool)
    if causal:
        m = m & (d >= 0)
    if window is not None:
        m = m & (d < window)
    if k_valid is not None:
        m = m & k_valid[:, None, :]
    return m


def sdpa(q, k, v, mask, q_chunk: Optional[int] = None):
    """q: (B,Sq,H,Dh), k/v: (B,Skv,K,Dh), mask: (B,Sq,Skv) -> (B,Sq,H,Dh).

    GQA: H = G*K query heads share K kv heads. float32 softmax.

    Train/prefill (Sq > 1): kv heads are expanded to H so the score tensor
    (B, H, Sq_chunk, Skv) is cleanly head-sharded over "model" — kv counts
    like yi's K=8 on a 16-way axis would otherwise leave the scores
    unsharded (56 GiB/device in the dry run). The expansion is cheap: kv
    projections are small and slice per-shard. Decode (Sq == 1) keeps the
    grouped einsum — expanding would multiply KV-cache HBM reads by G.
    """
    b, sq, h, dh = q.shape
    kheads = k.shape[2]
    g = h // kheads
    scale = dh ** -0.5

    if sq == 1:
        qg = q.reshape(b, sq, kheads, g, dh)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                            preferred_element_type=jnp.float32) * scale
        logits = jnp.where(mask[:, None, None], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
        return o.reshape(b, sq, h, dh)

    kf = jnp.repeat(k, g, axis=2) if g > 1 else k     # (B,Skv,H,Dh)
    vf = jnp.repeat(v, g, axis=2) if g > 1 else v
    kf = shard(kf, "batch", None, "heads", None)
    vf = shard(vf, "batch", None, "heads", None)

    def block(qc, mc):
        # qc: (B,c,H,Dh), mc: (B,c,Skv)
        logits = jnp.einsum("bqhd,bshd->bhqs", qc, kf,
                            preferred_element_type=jnp.float32) * scale
        logits = shard(logits, "batch", "heads", None, None)
        logits = jnp.where(mc[:, None], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqs,bshd->bqhd", w, vf)

    if q_chunk is None or sq <= q_chunk:
        return block(q, mask)

    n = sq // q_chunk
    rem = sq - n * q_chunk
    xs = (q[:, :n * q_chunk].reshape(b, n, q_chunk, h, dh).swapaxes(0, 1),
          mask[:, :n * q_chunk].reshape(b, n, q_chunk, -1).swapaxes(0, 1))
    _, ys = jax.lax.scan(
        lambda c, inp: (c, jax.checkpoint(block)(inp[0], inp[1])), None, xs)
    out = ys.swapaxes(0, 1).reshape(b, n * q_chunk, h, dh)
    if rem:
        out = jnp.concatenate([out, block(q[:, -rem:], mask[:, -rem:])], 1)
    return out


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

def init_full_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                    dtype) -> dict:
    shape = (batch, max_len, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_ring_cache(batch: int, window: int, n_kv: int, head_dim: int,
                    dtype) -> dict:
    shape = (batch, window, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.full((batch, window), -1, jnp.int32)}


# ---------------------------------------------------------------------------
# The attention block (train / prefill / decode)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv: int
    head_dim: int
    causal: bool = True
    window: Optional[int] = None          # None = global
    theta: float = 10_000.0
    sections: Optional[tuple] = None      # M-RoPE
    use_rope: bool = True
    q_chunk: Optional[int] = None


def attn_forward(p, spec: AttnSpec, x, positions, k_pos=None, xkv=None):
    """Training/prefill forward. x: (B,S,D). Returns (out, (k, v)) with k/v
    rotated (ready for caching)."""
    dt = x.dtype
    q = _split_heads(x @ cast(p["wq"], dt, None, "heads"), spec.n_heads, spec.head_dim)
    src = x if xkv is None else xkv
    k = _split_heads(src @ cast(p["wk"], dt, None, "heads"), spec.n_kv, spec.head_dim)
    v = _split_heads(src @ cast(p["wv"], dt, None, "heads"), spec.n_kv, spec.head_dim)
    kp = positions if k_pos is None else k_pos
    if spec.use_rope:
        q = apply_rope(q, rope_angles(positions, spec.head_dim, spec.theta,
                                      spec.sections))
        k = apply_rope(k, rope_angles(kp, spec.head_dim, spec.theta,
                                      spec.sections))
    q = shard(q, "batch", None, "heads", None)
    kvs = _kv_spec(spec.n_kv, spec.head_dim)
    k = shard(k, "batch", *kvs)
    v = shard(v, "batch", *kvs)
    mask = _score_mask(positions if positions.ndim == 2 else positions[..., 0],
                       kp if kp.ndim == 2 else kp[..., 0],
                       spec.causal, spec.window)
    o = sdpa(q, k, v, mask, spec.q_chunk)
    o = shard(o, "batch", None, "heads", None)
    out = o.reshape(*x.shape[:2], -1) @ cast(p["wo"], dt)
    return out, (k, v)


def attn_decode(p, spec: AttnSpec, x, cache: dict, pos):
    """One-token decode. x: (B,1,D); pos: scalar int32 (uniform batch).

    Full cache: k/v written at index pos; ring cache: at pos % window.
    Returns (out, new_cache)."""
    dt = x.dtype
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    if spec.sections is not None:
        positions = jnp.repeat(positions[..., None], len(spec.sections), -1)
    q = _split_heads(x @ cast(p["wq"], dt, None, "heads"), spec.n_heads, spec.head_dim)
    k = _split_heads(x @ cast(p["wk"], dt, None, "heads"), spec.n_kv, spec.head_dim)
    v = _split_heads(x @ cast(p["wv"], dt, None, "heads"), spec.n_kv, spec.head_dim)
    if spec.use_rope:
        ang = rope_angles(positions, spec.head_dim, spec.theta, spec.sections)
        q, k = apply_rope(q, ang), apply_rope(k, ang)

    ring = "pos" in cache
    slot = (pos % cache["k"].shape[1]) if ring else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    new_cache = dict(cache, k=ck, v=cv)
    if ring:
        cpos = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.full((b, 1), pos, jnp.int32), (0, slot))
        new_cache["pos"] = cpos
        k_pos, k_valid = cpos, cpos >= 0
    else:
        idx = jnp.arange(ck.shape[1], dtype=jnp.int32)
        k_pos = jnp.broadcast_to(idx, (b, ck.shape[1]))
        k_valid = k_pos <= pos
    qpos2 = positions if positions.ndim == 2 else positions[..., 0]
    mask = _score_mask(qpos2, k_pos, spec.causal, spec.window, k_valid)
    kvs = _kv_spec(spec.n_kv, spec.head_dim)
    if b == 1:
        # batch-1 long-context decode: sequence-parallel KV (flash-decoding;
        # the softmax reduction over shards is GSPMD's to all-reduce)
        ck_s = shard(ck.astype(dt), None, "kv_seq", *kvs[1:])
        cv_s = shard(cv.astype(dt), None, "kv_seq", *kvs[1:])
    else:
        ck_s = shard(ck.astype(dt), "batch", *kvs)
        cv_s = shard(cv.astype(dt), "batch", *kvs)
    o = sdpa(q, ck_s, cv_s, mask)
    out = o.reshape(b, 1, -1) @ cast(p["wo"], dt)
    return out, new_cache


def cross_decode(p, spec: AttnSpec, x, cache: dict):
    """Decoder cross-attention against a fixed encoder cache {k, v}."""
    dt = x.dtype
    b = x.shape[0]
    q = _split_heads(x @ cast(p["wq"], dt, None, "heads"), spec.n_heads, spec.head_dim)
    k, v = cache["k"].astype(dt), cache["v"].astype(dt)
    mask = jnp.ones((b, 1, k.shape[1]), bool)
    o = sdpa(q, k, v, mask)
    return o.reshape(b, 1, -1) @ cast(p["wo"], dt)
