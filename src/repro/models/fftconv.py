"""FFTConvMixer — the paper's fused spectral kernel inside an LM block.

A Hyena/S4-style long-convolution mixer: each channel is convolved with a
learned length-S causal kernel, computed as FFT -> pointwise spectral
multiply -> IFFT in ONE fused dispatch (core.fusion.fft_conv). This is the
demonstration layer promised in DESIGN.md §4: none of the assigned
architectures is LTI (so the technique does not apply to them), but an LTI
long-conv model is exactly the paper's dataflow per channel.

The learned kernel is parameterized in the time domain with exponential
decay (S4D-style), zero-padded to 2S for causal (linear, not circular)
convolution; its FFT is recomputed each call (cheap: one (C, 2S) FFT vs the
(B*C, 2S) data transforms).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fusion import fft_conv
from repro.models.layers import truncated_normal
from repro.models.sharding import shard


def init_fftconv(key, d: int, max_len: int):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": truncated_normal(k1, (d, d), d ** -0.5),
        "gate_proj": truncated_normal(k2, (d, d), d ** -0.5),
        "kernel": truncated_normal(k3, (d, max_len), 0.02),
        "decay": jnp.linspace(1.0, 6.0, d),     # per-channel log decay rate
        "out_proj": truncated_normal(k4, (d, d), d ** -0.5),
    }


def _conv_lines_oracle(lines, hr, hi):
    """real(IFFT(FFT(lines) * H)) — the unfused jnp path (also the VJP)."""
    h = hr.astype(jnp.complex64) + 1j * hi.astype(jnp.complex64)
    return jnp.real(jnp.fft.ifft(jnp.fft.fft(lines, axis=1) * h, axis=1)
                    ).astype(jnp.float32)


@jax.custom_vjp
def _conv_lines_fused(lines, hr, hi):
    """ONE fused Pallas dispatch: FFT -> per-line filter -> IFFT. The
    backward delegates to the mathematically identical jnp oracle
    (pallas_call defines no VJP); training still works, serving gets the
    fused kernel."""
    from repro.kernels import ops
    yr, _ = ops.spectral_op(
        lines, jnp.zeros_like(lines), hr=hr, hi=hi, fwd=True, inv=True,
        axis=1, filter_mode="full", block=8)
    return yr


def _conv_fwd(lines, hr, hi):
    return _conv_lines_fused(lines, hr, hi), (lines, hr, hi)


def _conv_bwd(res, g):
    lines, hr, hi = res
    _, vjp = jax.vjp(_conv_lines_oracle, lines, hr, hi)
    return vjp(g)


_conv_lines_fused.defvjp(_conv_fwd, _conv_bwd)


def fftconv_forward(p, x, backend: str = "pallas", interpret=None):
    """x: (B, S, D) float32 -> (B, S, D). One fused spectral dispatch for
    the whole (B*D, 2S) batch of lines."""
    b, s, d = x.shape
    dt = x.dtype
    u = x @ p["in_proj"].astype(dt)
    gate = jax.nn.silu(x @ p["gate_proj"].astype(dt))

    # causal kernel, decayed, zero-padded to 2S -> spectrum (2S,) per channel
    t = jnp.arange(s, dtype=jnp.float32)
    kern = p["kernel"][:, :s] * jnp.exp(-jnp.exp(p["decay"])[:, None]
                                        * t / s)              # (D, S)
    kf_full = jnp.fft.fft(jnp.pad(kern, ((0, 0), (0, s))), axis=1)

    # lines: (B*D, 2S) real signals, channel-major so each line's filter is
    # its channel spectrum (FILTER_FULL per line)
    lines = u.transpose(0, 2, 1).reshape(b * d, s)
    lines = jnp.pad(lines, ((0, 0), (0, s))).astype(jnp.float32)
    hr = jnp.tile(jnp.real(kf_full).astype(jnp.float32), (b, 1))
    hi = jnp.tile(jnp.imag(kf_full).astype(jnp.float32), (b, 1))

    yr = _conv_lines_fused(lines, hr, hi)
    y = yr[:, :s].reshape(b, d, s).transpose(0, 2, 1).astype(dt)
    y = shard(y, "batch", None, None)
    return (y * gate) @ p["out_proj"].astype(dt)


def fftconv_reference(p, x):
    """Oracle: per-channel causal convolution via jnp.fft (unfused)."""
    b, s, d = x.shape
    u = x @ p["in_proj"]
    gate = jax.nn.silu(x @ p["gate_proj"])
    t = jnp.arange(s, dtype=jnp.float32)
    kern = p["kernel"][:, :s] * jnp.exp(-jnp.exp(p["decay"])[:, None] * t / s)
    uf = jnp.fft.fft(jnp.pad(u.transpose(0, 2, 1), ((0, 0), (0, 0), (0, s))),
                     axis=2)
    kf = jnp.fft.fft(jnp.pad(kern, ((0, 0), (0, s))), axis=1)
    y = jnp.real(jnp.fft.ifft(uf * kf[None], axis=2))[:, :, :s]
    y = y.transpose(0, 2, 1)
    return (y * gate) @ p["out_proj"]
