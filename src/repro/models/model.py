"""Model assembly: init / train forward / prefill / decode for every
assigned architecture family.

Layers follow `cfg.pattern` cycled over n_layers. Full pattern periods are
stacked and executed with lax.scan (O(1) HLO size for 80-layer models);
remainder layers are unrolled. Each scanned period is rematerialized
(jax.checkpoint) so backward recomputes activations per period.

Caches:
  'global' mixers -> full KV cache (B, max_len, K, Dh)
  'local'  mixers -> ring KV cache (B, window, K, Dh) + slot positions
  'mamba'/'rglru' -> O(1) recurrent state
so sub-quadratic archs decode 500k-token contexts with bounded memory.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import recurrent as rec
from repro.models.config import ModelConfig, RGLRUConfig, SSMConfig
from repro.models.layers import (
    embed,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    lm_loss_chunked,
    logits_last,
    mlp,
    rmsnorm,
    sinusoidal_positions,
)
from repro.models.moe import init_moe, moe_ffn
from repro.models.sharding import shard

KINDS_ATTN = ("global", "local")
KINDS_REC = ("mamba", "rglru")

# parameters that must stay float32 regardless of compute dtype (recurrence
# decay rates, norm scales, dt bias — bf16 here visibly hurts numerics)
_NO_CAST = ("a_log", "lambda", "scale", "d_skip")


def cast_params_for_compute(params, dtype: str):
    """One upfront f32 -> compute-dtype cast of the big weights, so the FSDP
    all-gather moves bf16 (half the collective bytes and half the gathered
    buffer footprint vs gathering f32 and converting at use)."""
    if dtype in ("float32", "f32"):
        return params
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        pstr = "".join(str(k) for k in path)
        keep = any(f"'{n}'" in pstr for n in _NO_CAST) or \
            ("dt_proj" in pstr and pstr.endswith("['b']"))
        out.append(leaf if keep or leaf.dtype != jnp.float32
                   else leaf.astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def _attn_spec(cfg: ModelConfig, kind: str, q_chunk=None,
               encoder: bool = False, cross: bool = False) -> attn.AttnSpec:
    return attn.AttnSpec(
        n_heads=cfg.n_heads,
        n_kv=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        causal=not (encoder or cross),
        window=cfg.window if kind == "local" else None,
        theta=cfg.rope_theta,
        sections=cfg.mrope_sections,
        use_rope=cfg.encoder is None,     # whisper: absolute sinusoid instead
        q_chunk=q_chunk,
    )


def init_layer(key, cfg: ModelConfig, kind: str, cross: bool = False):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict[str, Any] = {"norm1": init_rmsnorm(d)}
    if kind in KINDS_ATTN:
        p["mixer"] = attn.init_attention(ks[0], d, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.resolved_head_dim)
    elif kind == "mamba":
        p["mixer"] = rec.init_mamba(ks[0], d, cfg.ssm or SSMConfig())
    elif kind == "rglru":
        p["mixer"] = rec.init_rglru(ks[0], d, cfg.rglru or RGLRUConfig())
    else:
        raise ValueError(kind)
    if cross:
        p["norm_x"] = init_rmsnorm(d)
        p["cross"] = attn.init_attention(ks[3], d, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.resolved_head_dim)
    if kind != "mamba":
        p["norm2"] = init_rmsnorm(d)
        if cfg.ffn == "mlp":
            p["ffn"] = init_mlp(ks[1], d, cfg.d_ff)
        elif cfg.ffn == "moe":
            p["ffn"] = init_moe(ks[1], d, cfg.d_ff, cfg.moe)
    return p


# ---------------------------------------------------------------------------
# Per-layer forward (training / prefill): returns (x, cache_entry, aux)
# ---------------------------------------------------------------------------

def layer_forward(p, cfg: ModelConfig, kind: str, x, positions,
                  q_chunk=None, enc_out=None, train: bool = True):
    spec = _attn_spec(cfg, kind, q_chunk)
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in KINDS_ATTN:
        y, entry = attn.attn_forward(p["mixer"], spec, h, positions)
    elif kind == "mamba":
        y, entry = rec.mamba_forward(p["mixer"], h, cfg.ssm or SSMConfig())
    else:  # rglru
        y, entry = rec.rglru_forward(p["mixer"], h, cfg.rglru or RGLRUConfig())
    # constrain the row-parallel projection output to the sequence-sharded
    # residual layout BEFORE the add: the model-axis partial-sum reduction
    # then lowers to reduce-scatter (half the ring bytes of all-reduce) —
    # §Perf iteration r2
    y = shard(y, "batch", "seq", None)
    x = x + y

    if "cross" in p and enc_out is not None:
        hq = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None],
            enc_out.shape[:2])
        cspec = dataclasses.replace(spec, causal=False, window=None,
                                    use_rope=False)
        yx, centry = attn.attn_forward(p["cross"], cspec, hq,
                                       positions, k_pos=enc_pos, xkv=enc_out)
        x = x + yx
    else:
        centry = None

    if kind != "mamba":
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if cfg.ffn == "moe":
            y2, aux = moe_ffn(p["ffn"], h2, cfg.moe, cfg.act, train)
        else:
            y2 = mlp(p["ffn"], h2, cfg.act)
        y2 = shard(y2, "batch", "seq", None)
        x = x + y2
    return x, entry, centry, aux


# ---------------------------------------------------------------------------
# Per-layer decode: returns (x, new_cache_entry)
# ---------------------------------------------------------------------------

def layer_decode(p, cfg: ModelConfig, kind: str, x, cache_entry, pos,
                 cross_cache=None):
    spec = _attn_spec(cfg, kind)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in KINDS_ATTN:
        y, new_entry = attn.attn_decode(p["mixer"], spec, h, cache_entry, pos)
    elif kind == "mamba":
        y, new_entry = rec.mamba_step(p["mixer"], h, cfg.ssm or SSMConfig(),
                                      cache_entry)
    else:
        y, new_entry = rec.rglru_step(p["mixer"], h,
                                      cfg.rglru or RGLRUConfig(), cache_entry)
    x = x + y
    if "cross" in p and cross_cache is not None:
        hq = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        cspec = dataclasses.replace(spec, causal=False, window=None,
                                    use_rope=False)
        x = x + attn.cross_decode(p["cross"], cspec, hq, cross_cache)
    if kind != "mamba":
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if cfg.ffn == "moe":
            y2, _ = moe_ffn(p["ffn"], h2, cfg.moe, cfg.act, train=False)
        else:
            y2 = mlp(p["ffn"], h2, cfg.act)
        x = x + y2
    return x, new_entry


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ---- init ---------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.n_layers + 8)
        params: dict[str, Any] = {
            "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model),
            "final_norm": init_rmsnorm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = init_embedding(keys[1], cfg.vocab_size,
                                               cfg.d_model)
        cross = cfg.is_encoder_decoder
        period = len(cfg.pattern)
        if cfg.scan_layers and cfg.n_periods > 1:
            subs = {}
            for j, kind in enumerate(cfg.pattern):
                stacked = [init_layer(keys[2 + i * period + j], cfg, kind,
                                      cross)
                           for i in range(cfg.n_periods)]
                subs[f"sub{j}"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *stacked)
            params["scan"] = subs
        else:
            params["layers"] = [
                init_layer(keys[2 + i], cfg, cfg.layer_kinds[i], cross)
                for i in range(cfg.n_periods * period)]
        params["rem"] = [
            init_layer(keys[2 + cfg.n_periods * period + r], cfg, kind, cross)
            for r, kind in enumerate(cfg.remainder_kinds)]
        if cfg.is_encoder_decoder:
            ek = jax.random.split(keys[-1], cfg.encoder.n_layers)
            params["encoder"] = {
                "layers": [init_layer(ek[i], cfg, "global", cross=False)
                           for i in range(cfg.encoder.n_layers)],
                "final_norm": init_rmsnorm(cfg.d_model),
            }
        return params

    # ---- encoder (whisper; frames are precomputed stub embeddings) ----------
    def encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(cfg.dtype)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
        pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None],
                               x.shape[:2])
        for p in params["encoder"]["layers"]:
            spec = _attn_spec(cfg, "global", encoder=True)
            h = rmsnorm(p["norm1"], x, cfg.norm_eps)
            y, _ = attn.attn_forward(p["mixer"], spec, h, pos)
            x = x + y
            h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
            x = x + mlp(p["ffn"], h2, cfg.act)
        return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)

    # ---- backbone forward ----------------------------------------------------
    def _inputs_to_x(self, params, batch):
        """tokens (+ stub frontend embeddings) -> initial hidden states."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens, cfg.dtype)
        if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(cfg.dtype)
            n = pe.shape[1]
            x = jnp.concatenate([x[:, :n] + pe, x[:, n:]], axis=1)
        if cfg.is_encoder_decoder:
            x = x + sinusoidal_positions(x.shape[1],
                                         cfg.d_model).astype(x.dtype)
        if "positions" in batch:
            positions = batch["positions"]
        else:
            positions = jnp.broadcast_to(
                jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
            if cfg.mrope_sections is not None:
                positions = jnp.repeat(positions[..., None],
                                       len(cfg.mrope_sections), -1)
        return x, positions

    def forward(self, params, batch, collect_cache: bool = False,
                train: bool = True):
        """Returns (final hidden states, aux_loss, cache_entries)."""
        cfg = self.cfg
        params = cast_params_for_compute(params, cfg.dtype)
        x, positions = self._inputs_to_x(params, batch)
        s = x.shape[1]
        q_chunk = cfg.attn_q_chunk or (1024 if s >= 4096 else None)
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = self.encode(params, batch["frames"])
        aux_total = jnp.zeros((), jnp.float32)
        entries: dict[str, Any] = {}

        def run_layer(p, kind, x):
            return layer_forward(p, cfg, kind, x, positions, q_chunk,
                                 enc_out, train)

        if "scan" in params:
            def body(x, period_params):
                ys = {}
                aux_p = jnp.zeros((), jnp.float32)
                for j, kind in enumerate(cfg.pattern):
                    x, e, ce, aux = run_layer(period_params[f"sub{j}"], kind, x)
                    ys[f"sub{j}"] = (e, ce) if collect_cache else 0.0
                    aux_p = aux_p + aux
                return x, (ys, aux_p)

            body = jax.checkpoint(body) if cfg.remat else body
            x, (ys, aux_s) = jax.lax.scan(body, x, params["scan"])
            aux_total = aux_total + aux_s.sum()
            if collect_cache:
                entries["scan"] = ys
        else:
            maybe_ckpt = jax.checkpoint if cfg.remat else (lambda f: f)
            for i, p in enumerate(params.get("layers", [])):
                kind = cfg.layer_kinds[i]
                x, e, ce, aux = maybe_ckpt(
                    functools.partial(run_layer, p, kind))(x)
                aux_total = aux_total + aux
                if collect_cache:
                    entries[f"layer{i}"] = (e, ce)
        for r, p in enumerate(params["rem"]):
            kind = cfg.remainder_kinds[r]
            x, e, ce, aux = run_layer(p, kind, x)
            aux_total = aux_total + aux
            if collect_cache:
                entries[f"rem{r}"] = (e, ce)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, aux_total, entries

    # ---- training loss --------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        x, aux, _ = self.forward(params, batch, train=True)
        table = (params["embed"]["table"] if cfg.tie_embeddings
                 else params["lm_head"]["table"])
        nll = lm_loss_chunked(x, table, batch["labels"],
                              batch.get("loss_mask"), cfg.loss_chunk)
        return nll + aux

    # ---- serving ---------------------------------------------------------------
    def _entry_to_cache(self, kind, entry, max_len, cache_dtype):
        """Convert a prefill (k, v) / state entry into a decode cache entry.
        Works on unstacked (B, S, ...) or scan-stacked (P, B, S, ...) trees."""
        cfg = self.cfg
        if kind in KINDS_REC:
            return entry  # (h_last, conv_buf) already the decode state
        k, v = entry
        lead = k.shape[:-4]
        b, s = k.shape[-4], k.shape[-3]
        if kind == "local":
            w = min(cfg.window, max_len)
            pos0 = jnp.arange(s, dtype=jnp.int32)
            if s >= w:
                # keep the last w positions; ring slot of position p is p % w,
                # so the contiguous tail is rolled by (s - w) % w.
                kk, vv = k[..., s - w:, :, :], v[..., s - w:, :, :]
                ppos = jnp.broadcast_to(pos0[s - w:], (*lead, b, w))
                shift = (s - w) % w
                kk = jnp.roll(kk, shift, axis=-3)
                vv = jnp.roll(vv, shift, axis=-3)
                ppos = jnp.roll(ppos, shift, axis=-1)
            else:
                pad = [(0, 0)] * (k.ndim - 3) + [(0, w - s), (0, 0), (0, 0)]
                kk, vv = jnp.pad(k, pad), jnp.pad(v, pad)
                ppos = jnp.concatenate(
                    [jnp.broadcast_to(pos0, (*lead, b, s)),
                     jnp.full((*lead, b, w - s), -1, jnp.int32)], -1)
            return {"k": kk.astype(cache_dtype), "v": vv.astype(cache_dtype),
                    "pos": ppos}
        # global: place [0:s] into a max_len buffer
        shape = (*lead, b, max_len, *k.shape[-2:])
        kk = jnp.zeros(shape, cache_dtype)
        vv = jnp.zeros(shape, cache_dtype)
        idx = (0,) * len(lead) + (0, 0, 0, 0)
        kk = jax.lax.dynamic_update_slice(kk, k.astype(cache_dtype), idx)
        vv = jax.lax.dynamic_update_slice(vv, v.astype(cache_dtype), idx)
        return {"k": kk, "v": vv}

    def prefill(self, params, batch, max_len: int):
        """Run the prompt; return (cache, last-position logits)."""
        cfg = self.cfg
        x, _, entries = self.forward(params, batch, collect_cache=True,
                                     train=False)
        cache: dict[str, Any] = {"step": jnp.asarray(
            batch["tokens"].shape[1], jnp.int32)}
        cdt = cfg.dtype
        if "scan" in entries:
            cache["scan"] = {
                f"sub{j}": self._entry_to_cache(
                    kind, entries["scan"][f"sub{j}"][0], max_len, cdt)
                for j, kind in enumerate(cfg.pattern)}
            if cfg.is_encoder_decoder:
                cache["scan_cross"] = {
                    f"sub{j}": {"k": entries["scan"][f"sub{j}"][1][0],
                                "v": entries["scan"][f"sub{j}"][1][1]}
                    for j in range(len(cfg.pattern))}
        for key in list(entries.keys()):
            if key.startswith(("layer", "rem")):
                i = int(key.replace("layer", "").replace("rem", ""))
                kind = (cfg.layer_kinds[i] if key.startswith("layer")
                        else cfg.remainder_kinds[i])
                cache[key] = self._entry_to_cache(kind, entries[key][0],
                                                  max_len, cdt)
                if cfg.is_encoder_decoder and entries[key][1] is not None:
                    cache[key + "_cross"] = {"k": entries[key][1][0],
                                             "v": entries[key][1][1]}
        table = (params["embed"]["table"] if cfg.tie_embeddings
                 else params["lm_head"]["table"])
        return cache, logits_last(x[:, -1], table)

    def init_cache(self, batch: int, max_len: int, dtype=None) -> dict:
        """Empty decode cache (the dry-run decode cells start here)."""
        cfg = self.cfg
        cdt = dtype or cfg.dtype
        hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads

        def one(kind):
            if kind == "global":
                return attn.init_full_cache(batch, max_len, nkv, hd, cdt)
            if kind == "local":
                return attn.init_ring_cache(batch, min(cfg.window, max_len),
                                            nkv, hd, cdt)
            if kind == "mamba":
                return rec.init_mamba_state(batch, cfg.d_model,
                                            cfg.ssm or SSMConfig())
            return rec.init_rglru_state(batch, cfg.d_model,
                                        cfg.rglru or RGLRUConfig())

        cache: dict[str, Any] = {"step": jnp.zeros((), jnp.int32)}
        if self.cfg.scan_layers and cfg.n_periods > 1:
            stack = lambda t: jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_periods, *x.shape)), t)
            cache["scan"] = {f"sub{j}": stack(one(kind))
                             for j, kind in enumerate(cfg.pattern)}
            if cfg.is_encoder_decoder:
                ne = cfg.encoder.n_frames
                cache["scan_cross"] = {
                    f"sub{j}": stack(attn.init_full_cache(batch, ne, nkv, hd,
                                                          cdt))
                    for j in range(len(cfg.pattern))}
        else:
            for i, kind in enumerate(cfg.layer_kinds[:cfg.n_periods *
                                                     len(cfg.pattern)]):
                cache[f"layer{i}"] = one(kind)
                if cfg.is_encoder_decoder:
                    cache[f"layer{i}_cross"] = attn.init_full_cache(
                        batch, cfg.encoder.n_frames, nkv, hd, cdt)
        for r, kind in enumerate(cfg.remainder_kinds):
            cache[f"rem{r}"] = one(kind)
            if cfg.is_encoder_decoder:
                cache[f"rem{r}_cross"] = attn.init_full_cache(
                    batch, cfg.encoder.n_frames, nkv, hd, cdt)
        return cache

    def decode_step(self, params, cache, tokens, pos=None):
        """One token for the whole batch. tokens: (B, 1). Returns
        (logits (B, V) f32, new cache)."""
        cfg = self.cfg
        pos = cache["step"] if pos is None else pos
        params = cast_params_for_compute(params, cfg.dtype)
        x = embed(params["embed"], tokens, cfg.dtype)
        if cfg.is_encoder_decoder:
            # absolute sinusoid at the runtime position
            x = x + _sinusoid_at(pos, cfg.d_model).astype(x.dtype)
        new_cache: dict[str, Any] = {"step": pos + 1}

        if "scan" in cache:
            def body(x, inp):
                period_params, entries, cross_entries = inp
                new_entries = {}
                for j, kind in enumerate(cfg.pattern):
                    cc = (cross_entries[f"sub{j}"]
                          if cross_entries is not None else None)
                    x, ne = layer_decode(period_params[f"sub{j}"], cfg, kind,
                                         x, entries[f"sub{j}"], pos, cc)
                    new_entries[f"sub{j}"] = ne
                return x, new_entries

            cross = cache.get("scan_cross")
            if cross is None:
                x, new_entries = jax.lax.scan(
                    lambda c, i: body(c, (i[0], i[1], None)),
                    x, (params["scan"], cache["scan"]))
            else:
                x, new_entries = jax.lax.scan(
                    lambda c, i: body(c, i),
                    x, (params["scan"], cache["scan"], cross))
                new_cache["scan_cross"] = cross
            new_cache["scan"] = new_entries
        else:
            for i, p in enumerate(params.get("layers", [])):
                kind = cfg.layer_kinds[i]
                cc = cache.get(f"layer{i}_cross")
                x, ne = layer_decode(p, cfg, kind, x, cache[f"layer{i}"],
                                     pos, cc)
                new_cache[f"layer{i}"] = ne
                if cc is not None:
                    new_cache[f"layer{i}_cross"] = cc
        for r, p in enumerate(params["rem"]):
            kind = cfg.remainder_kinds[r]
            cc = cache.get(f"rem{r}_cross")
            x, ne = layer_decode(p, cfg, kind, x, cache[f"rem{r}"], pos, cc)
            new_cache[f"rem{r}"] = ne
            if cc is not None:
                new_cache[f"rem{r}_cross"] = cc
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        table = (params["embed"]["table"] if cfg.tie_embeddings
                 else params["lm_head"]["table"])
        return logits_last(x[:, 0], table), new_cache


def _sinusoid_at(pos, d: int):
    """Single-position sinusoidal embedding at runtime index `pos`."""
    import math as _m
    half = d // 2
    freq = jnp.exp(-_m.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    t = pos.astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)])[None, None, :]
