"""LM model stack for the assigned architecture pool."""
from repro.models.config import (  # noqa: F401
    EncoderConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)
from repro.models.model import Model  # noqa: F401
from repro.models.sharding import (  # noqa: F401
    DEFAULT_RULES,
    shard,
    use_mesh_rules,
)
