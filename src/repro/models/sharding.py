"""Logical-axis sharding constraints for model code.

Model code annotates activations with *logical* axis names
(`shard(x, "batch", "seq", "heads")`). A launch-layer context maps logical
names to mesh axes; outside any context the calls are identity, so unit
tests and CPU smoke runs are unaffected.

Default production rules (see launch/mesh.py):
  batch   -> ("pod", "data")     data parallel
  seq     -> "model"             Megatron-style sequence parallelism for the
                                 residual stream between layers
  heads   -> "model"             tensor parallel attention
  ff      -> "model"             tensor parallel MLP
  vocab   -> "model"             vocab-parallel embedding/loss
  experts -> "model"             expert parallel (when E % axis == 0)
  kv_seq  -> "data"              sequence-parallel KV cache (long decode)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _active():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh, rules: dict):
    """Activate logical->mesh axis mapping for model sharding constraints."""
    prev = _active()
    _state.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _state.ctx = prev


def current_rules() -> Optional[tuple]:
    return _active()


def resolve_spec(rules: dict, *logical) -> P:
    return P(*[rules.get(name) if name else None for name in logical])


def axis_size(logical: str) -> int:
    """Mesh size behind a logical axis in the active context (1 if none)."""
    ctx = _active()
    if ctx is None:
        return 1
    mesh, rules = ctx
    axis = rules.get(logical)
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def shard(x, *logical):
    """Constrain x's sharding by logical axis names (None = replicated dim).

    Inside an active context: jax.lax.with_sharding_constraint with the
    resolved PartitionSpec. Outside: identity.
    """
    ctx = _active()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve_spec(rules, *logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": "model",
    "heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "kv_seq": "data",
    "embed": "data",
}
