"""Logical-axis sharding constraints for model code.

Model code annotates activations with *logical* axis names
(`shard(x, "batch", "seq", "heads")`). A launch-layer context maps logical
names to mesh axes; outside any context the calls are identity, so unit
tests and CPU smoke runs are unaffected.

Default production rules (see launch/mesh.py):
  batch   -> ("pod", "data")     data parallel
  seq     -> "model"             Megatron-style sequence parallelism for the
                                 residual stream between layers
  heads   -> "model"             tensor parallel attention
  ff      -> "model"             tensor parallel MLP
  vocab   -> "model"             vocab-parallel embedding/loss
  experts -> "model"             expert parallel (when E % axis == 0)
  kv_seq  -> "data"              sequence-parallel KV cache (long decode)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _active():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh, rules: dict):
    """Activate logical->mesh axis mapping for model sharding constraints."""
    prev = _active()
    _state.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _state.ctx = prev


def current_rules() -> Optional[tuple]:
    return _active()


def resolve_spec(rules: dict, *logical) -> P:
    return P(*[rules.get(name) if name else None for name in logical])


def axis_size(logical: str) -> int:
    """Mesh size behind a logical axis in the active context (1 if none)."""
    ctx = _active()
    if ctx is None:
        return 1
    mesh, rules = ctx
    axis = rules.get(logical)
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def shard(x, *logical):
    """Constrain x's sharding by logical axis names (None = replicated dim).

    Inside an active context: jax.lax.with_sharding_constraint with the
    resolved PartitionSpec. Outside: identity.
    """
    ctx = _active()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve_spec(rules, *logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def gather_for_compute(x, *keep):
    """ZeRO-3 use-site gather: un-shard a weight's FSDP dims inside jit,
    optionally keeping its tensor-parallel dims sharded.

    Without this constraint GSPMD is free to contract a matmul over the
    FSDP-sharded "embed" dimension as per-shard partial sums + all-reduce.
    That is numerically fine in f32 but NOT in bf16 compute: each partial
    product is rounded to bf16 before the reduce, drifting the loss by
    whole units vs the single-device run. Constraining the FSDP dims to
    replicated makes XLA all-gather the exact shards first (the gather is
    bit-exact), so sharded and unsharded training match to reduction-order
    error.

    `keep` (one logical name or None per dim) marks dims whose model-axis
    sharding is safe to preserve — the NON-contraction dims of
    column-parallel weights (wq/wk/wv output heads, MLP hidden, the vocab
    dim of the embedding/loss table), where keeping the shard costs no
    extra arithmetic rounding. Contraction dims must always gather
    (bf16 partial sums are exactly the drift this prevents), so pass
    nothing for row-parallel weights like wo. Dims that do not divide
    their mesh axis fall back to gathered. Identity outside an active
    mesh context.
    """
    ctx = _active()
    if ctx is None:
        return x
    mesh, rules = ctx
    if not keep:
        keep = (None,) * x.ndim
    spec = []
    for name, dim in zip(keep, x.shape):
        axis = rules.get(name) if name else None
        if axis is not None:
            sizes = [mesh.shape[a] for a in
                     (axis if isinstance(axis, tuple) else (axis,))]
            if dim % int(np.prod(sizes)) != 0:
                axis = None
        spec.append(axis)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": "model",
    "heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "kv_seq": "data",
    "embed": "data",
}
