"""Recurrent mixers: Mamba-1 selective SSM and RG-LRU (Griffin/RecurrentGemma).

TPU adaptation note (DESIGN.md §Arch-applicability): both recurrences are
input-gated (time-varying), so the FFT-convolution path of LTI SSMs — where
the paper's fused spectral kernel would apply — does NOT apply. The TPU-native
formulation is a log-depth `jax.lax.associative_scan` for training/prefill
and an O(1) state update for decode.

Memory: Mamba's hidden state is (d_inner, n_state) per position; the training
scan materializes it only per time-chunk (lax.scan over chunks carrying h),
the standard hardware-aware trade the CUDA kernel makes, expressed in JAX.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import RGLRUConfig, SSMConfig
from repro.models.layers import cast, truncated_normal
from repro.models.sharding import shard


# ---------------------------------------------------------------------------
# Depthwise causal conv1d (shared by both mixers)
# ---------------------------------------------------------------------------

def init_conv1d(key, width: int, channels: int):
    return {"w": truncated_normal(key, (width, channels), width ** -0.5),
            "b": jnp.zeros((channels,), jnp.float32)}


def conv1d(p, x):
    """Causal depthwise conv. x: (B, S, C) -> (B, S, C)."""
    dt = x.dtype
    w = cast(p["w"], dt)
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    return y + cast(p["b"], dt)


def conv1d_step(p, x, buf):
    """Single-step causal conv. x: (B, 1, C); buf: (B, width-1, C) holds the
    previous width-1 inputs. Returns (y, new_buf)."""
    dt = x.dtype
    w = cast(p["w"], dt)
    width = w.shape[0]
    xs = jnp.concatenate([buf.astype(dt), x], axis=1)      # (B, width, C)
    y = jnp.einsum("bwc,wc->bc", xs, w)[:, None, :] + cast(p["b"], dt)
    return y, xs[:, 1:, :].astype(buf.dtype)


# ---------------------------------------------------------------------------
# Linear recurrence h_t = a_t * h_{t-1} + b_t  (associative scan + chunking)
# ---------------------------------------------------------------------------

def _assoc(op_a, op_b):
    a1, b1 = op_a
    a2, b2 = op_b
    return a1 * a2, b1 * a2 + b2


def linear_scan(a, b, h0=None, axis: int = 1):
    """Solve h_t = a_t h_{t-1} + b_t along `axis`; a, b same shape.
    h0: initial state (shape = a with `axis` removed). Returns all h_t."""
    acc_a, acc_b = jax.lax.associative_scan(_assoc, (a, b), axis=axis)
    if h0 is not None:
        acc_b = acc_b + acc_a * jnp.expand_dims(h0, axis)
    return acc_b


def chunked_linear_scan(a, b, chunk: int, h0):
    """Scan over time chunks carrying the state; within a chunk use the
    log-depth associative scan. a, b: (B, S, ...); h0: (B, ...)."""
    bsz, s = a.shape[0], a.shape[1]
    if s <= chunk:
        h = linear_scan(a, b, h0)
        return h, h[:, -1]
    n = s // chunk
    assert s == n * chunk, "sequence not divisible by ssm chunk"
    ar = a.reshape(bsz, n, chunk, *a.shape[2:]).swapaxes(0, 1)
    br = b.reshape(bsz, n, chunk, *b.shape[2:]).swapaxes(0, 1)

    def body(h, inp):
        ac, bc = inp
        hc = linear_scan(ac, bc, h)
        return hc[:, -1], hc

    hlast, hs = jax.lax.scan(body, h0, (ar, br))
    h = hs.swapaxes(0, 1).reshape(bsz, s, *a.shape[2:])
    return h, hlast


# ---------------------------------------------------------------------------
# Mamba-1 block
# ---------------------------------------------------------------------------

def init_mamba(key, d: int, cfg: SSMConfig):
    di = cfg.expand * d
    dtr = cfg.resolved_dt_rank(d)
    ks = jax.random.split(key, 7)
    # S4D-real initialization for A
    a_init = jnp.tile(jnp.arange(1, cfg.state_dim + 1, dtype=jnp.float32),
                      (di, 1))
    return {
        "in_proj": truncated_normal(ks[0], (d, 2 * di), d ** -0.5),
        "conv": init_conv1d(ks[1], cfg.conv_width, di),
        "x_proj": truncated_normal(ks[2], (di, dtr + 2 * cfg.state_dim),
                                   di ** -0.5),
        "dt_proj": {"w": truncated_normal(ks[3], (dtr, di), dtr ** -0.5),
                    "b": jnp.log(jnp.expm1(  # softplus^-1 of U(1e-3, 1e-1)
                        jax.random.uniform(ks[4], (di,), jnp.float32,
                                           1e-3, 1e-1)))},
        "a_log": jnp.log(a_init),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": truncated_normal(ks[5], (di, d), di ** -0.5),
    }


def _mamba_terms(p, x, cfg: SSMConfig):
    """Input projection shared by scan/step: x -> (ssm-path input, gate)."""
    del cfg
    xz = x @ cast(p["in_proj"], x.dtype)
    xin, z = jnp.split(xz, 2, axis=-1)
    return xin, z


def _mamba_ssm_params(p, xc, cfg: SSMConfig):
    dt_ = xc.dtype
    dtr = p["dt_proj"]["w"].shape[0]
    n = cfg.state_dim
    proj = xc @ cast(p["x_proj"], dt_)
    dt_in, b_in, c_out = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus((dt_in @ cast(p["dt_proj"]["w"], dt_)
                          ).astype(jnp.float32) + p["dt_proj"]["b"])
    return dt, b_in.astype(jnp.float32), c_out.astype(jnp.float32)


def mamba_forward(p, x, cfg: SSMConfig, chunk: int = 128, h0=None):
    """x: (B, S, D) -> (y (B, S, D), (h_last, conv_buf)). Training/prefill.

    The (B, S, d_inner, n_state) hidden state is never materialized for the
    whole sequence: discretization, the associative scan, and the C-readout
    all happen inside a per-chunk lax.scan body (the Mamba CUDA kernel's
    memory trade, expressed in JAX); only the (B, S, d_inner) readout
    survives the chunk."""
    dt_ = x.dtype
    b, s, d = x.shape
    xin, z = _mamba_terms(p, x, cfg)
    xin = shard(xin, "batch", None, "ff")
    xc = jax.nn.silu(conv1d(p["conv"], xin))
    dt, b_in, c_out = _mamba_ssm_params(p, xc, cfg)
    a = -jnp.exp(p["a_log"])                                  # (di, n)
    if h0 is None:
        h0 = jnp.zeros((b, a.shape[0], cfg.state_dim), jnp.float32)

    xcf = xc.astype(jnp.float32)
    nc = max(1, s // chunk)
    assert s % nc == 0, (s, chunk)
    cs = s // nc
    resh = lambda t: t.reshape(b, nc, cs, *t.shape[2:]).swapaxes(0, 1)

    def body(h, inp):
        xck, dtk, bk, ck = inp                    # (B,cs,di), ..., (B,cs,n)
        abar = jnp.exp(dtk[..., None] * a)        # (B,cs,di,n) transient
        bx = (dtk * xck)[..., None] * bk[:, :, None, :]
        hc = linear_scan(abar, bx, h)
        yk = jnp.einsum("bsdn,bsn->bsd", hc, ck).astype(dt_)
        return hc[:, -1], yk

    hlast, ys = jax.lax.scan(body, h0, (resh(xcf), resh(dt), resh(b_in),
                                        resh(c_out)))
    y = ys.swapaxes(0, 1).reshape(b, s, -1)
    y = (y.astype(jnp.float32) + xcf * p["d_skip"]).astype(dt_)
    y = y * jax.nn.silu(z)
    out = y @ cast(p["out_proj"], dt_)
    conv_buf = xin[:, -(cfg.conv_width - 1):, :].astype(jnp.float32)
    return out, (hlast, conv_buf)


def mamba_step(p, x, cfg: SSMConfig, state):
    """Decode step. x: (B, 1, D); state = (h (B,di,n) f32, conv_buf)."""
    dt_ = x.dtype
    h, buf = state
    xin, z = _mamba_terms(p, x, cfg)
    xc_, new_buf = conv1d_step(p["conv"], xin, buf)
    xc = jax.nn.silu(xc_)
    dt, b_in, c_out = _mamba_ssm_params(p, xc, cfg)
    a = -jnp.exp(p["a_log"])
    abar = jnp.exp(dt[:, 0, :, None] * a)                     # (B,di,n)
    bx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] \
        * b_in[:, 0, None, :]
    h = abar * h + bx
    y = jnp.einsum("bdn,bn->bd", h, c_out[:, 0])
    y = (y + xc[:, 0].astype(jnp.float32) * p["d_skip"]).astype(dt_)
    y = (y * jax.nn.silu(z[:, 0]))[:, None, :]
    return y @ cast(p["out_proj"], dt_), (h, new_buf)


def init_mamba_state(batch: int, d: int, cfg: SSMConfig):
    di = cfg.expand * d
    return (jnp.zeros((batch, di, cfg.state_dim), jnp.float32),
            jnp.zeros((batch, cfg.conv_width - 1, di), jnp.float32))


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin / RecurrentGemma recurrent block)
# ---------------------------------------------------------------------------

def init_rglru(key, d: int, cfg: RGLRUConfig):
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    # Lambda init so that a = sigmoid(L)^c spreads over (0.9, 0.999)
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log((u ** (1.0 / cfg.c)) / (1.0 - u ** (1.0 / cfg.c)))
    return {
        "gate_proj": truncated_normal(ks[0], (d, w), d ** -0.5),   # gelu branch
        "rec_proj": truncated_normal(ks[1], (d, w), d ** -0.5),    # rec branch
        "conv": init_conv1d(ks[2], cfg.conv_width, w),
        "wa": truncated_normal(ks[3], (w, w), w ** -0.5),          # recur gate
        "wx": truncated_normal(ks[5], (w, w), w ** -0.5),          # input gate
        "lambda": lam,
        "out_proj": truncated_normal(jax.random.fold_in(key, 7), (w, d),
                                     w ** -0.5),
    }


def _rglru_core(p, xc, cfg: RGLRUConfig):
    """Gate computations shared by scan and step. xc: (B,S,W).

    The gate matmul outputs are constrained ff-sharded BEFORE the f32 cast:
    without this GSPMD partial-sums the (W,W) contraction and all-reduces
    the f32 (B,S,W) outputs — 68% of the train-step collective bytes in the
    baseline dry-run (EXPERIMENTS.md §Perf iteration r1). With the
    constraint it all-gathers the bf16 input once instead."""
    ra = shard(xc @ cast(p["wa"], xc.dtype), "batch", None, "ff")
    ia = shard(xc @ cast(p["wx"], xc.dtype), "batch", None, "ff")
    r = jax.nn.sigmoid(ra.astype(jnp.float32))
    i = jax.nn.sigmoid(ia.astype(jnp.float32))
    log_a = -cfg.c * r * jax.nn.softplus(p["lambda"])          # (B,S,W) f32
    a = jnp.exp(log_a)
    gated_x = i * xc.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    return a, b


def rglru_forward(p, x, cfg: RGLRUConfig, h0=None, chunk: int = 512):
    """x: (B,S,D) -> (y, (h_last, conv_buf))."""
    dt_ = x.dtype
    b_, s, d = x.shape
    gate = jax.nn.gelu(x @ cast(p["gate_proj"], dt_))
    xr = x @ cast(p["rec_proj"], dt_)
    gate = shard(gate, "batch", None, "ff")
    xr = shard(xr, "batch", None, "ff")
    xc = conv1d(p["conv"], xr)
    a, bterm = _rglru_core(p, xc, cfg)
    if h0 is None:
        h0 = jnp.zeros((b_, a.shape[-1]), jnp.float32)
    h, hlast = chunked_linear_scan(a, bterm, chunk, h0)
    y = (h.astype(dt_) * gate) @ cast(p["out_proj"], dt_)
    conv_buf = xr[:, -(cfg.conv_width - 1):, :].astype(jnp.float32)
    return y, (hlast, conv_buf)


def rglru_step(p, x, cfg: RGLRUConfig, state):
    dt_ = x.dtype
    h, buf = state
    gate = jax.nn.gelu(x @ cast(p["gate_proj"], dt_))
    xr = x @ cast(p["rec_proj"], dt_)
    xc, new_buf = conv1d_step(p["conv"], xr, buf)
    a, bterm = _rglru_core(p, xc, cfg)
    h = a[:, 0] * h + bterm[:, 0]
    y = (h[:, None, :].astype(dt_) * gate) @ cast(p["out_proj"], dt_)
    return y, (h, new_buf)


def init_rglru_state(batch: int, d: int, cfg: RGLRUConfig):
    w = cfg.lru_width or d
    return (jnp.zeros((batch, w), jnp.float32),
            jnp.zeros((batch, cfg.conv_width - 1, w), jnp.float32))
