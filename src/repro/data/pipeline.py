"""Deterministic synthetic token pipeline with exact skip-ahead resume.

The stream is a pure function of (seed, step): restoring a run at step k
regenerates exactly the batches a non-failing run would have seen — the
foundation of the exact checkpoint/restart guarantee (no iterator state to
snapshot, no data loss on preemption).

Sequences are learnable, not uniform noise: each sequence is an affine
progression  tok[t] = (a + b*t) % vocab  with per-sequence (a, b), corrupted
at `noise` rate. A model that infers (a, b) from context predicts the rest,
so training loss decreasing is a real signal (used by examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05


class TokenStream:
    """Stateless counted stream; `batch(step)` is pure and jit-able."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._base = jax.random.PRNGKey(cfg.seed)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(self._base, step)
        ka, kb, kn, km = jax.random.split(key, 4)
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        a = jax.random.randint(ka, (b, 1), 0, v)
        bb = jax.random.randint(kb, (b, 1), 1, min(v, 64))
        t = jnp.arange(s + 1, dtype=jnp.int32)[None, :]
        seq = (a + bb * t) % v
        noise_tok = jax.random.randint(kn, (b, s + 1), 0, v)
        corrupt = jax.random.bernoulli(km, cfg.noise, (b, s + 1))
        seq = jnp.where(corrupt, noise_tok, seq).astype(jnp.int32)
        return {
            "tokens": seq[:, :-1],
            "labels": seq[:, 1:],
        }

    def batches(self, start_step: int = 0):
        """Infinite iterator starting at `start_step` (resume = seek)."""
        step = start_step
        while True:
            yield step, self.batch(step)
            step += 1
