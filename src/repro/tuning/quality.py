"""The reduced-precision quality gate, measured in-library.

"Range, Not Precision" (arXiv 2605.28451): narrow matmul operands double
matrix-unit throughput at SAR-acceptable quality — but the GATE, not the
throughput, decides admissibility. The tuner (search.py) and the serving
admission check (service/service.py) both call
:func:`precision_snr_deviation`; it lives here, inside ``src/repro``, so
neither the compiler nor the service depends on the benchmarks package
(benchmarks/bench_quality.py re-exports it for the paper tables).
"""
from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp


@functools.lru_cache(maxsize=None)
def precision_snr_deviation(precision: str, n: int = 256,
                            variant: str = "fused3") -> float:
    """Max per-target SNR deviation (dB) of focusing the 5-point-target
    scene with ``precision`` matmul operands vs exact f32. Measured once
    per (precision, n, variant) per process (lru_cache)."""
    if precision in (None, "f32"):
        return 0.0
    from repro.core.sar import (          # deferred: quality -> sar -> plan
        build_pipeline,
        metrics,
        paper_targets,
        simulate_cached,
    )
    from repro.core.sar.geometry import test_scene
    cfg = test_scene(n)
    targets = paper_targets(cfg)
    raw = jnp.asarray(simulate_cached(cfg, targets))
    base = np.asarray(build_pipeline(cfg, variant, tune="off").run(raw))
    img = np.asarray(build_pipeline(cfg, variant, tune="off",
                                    precision=precision).run(raw))
    c = metrics.compare_pipelines(img, base, cfg, targets)
    return float(max(c["snr_delta_db"]))
