"""Versioned, schema-validated persistent tuning cache.

One JSON document per cache path, shared by every layer (kernel autotuner,
plan compiler, serving warm sweep), so a config measured anywhere is
reusable everywhere — including across process restarts, which is what
makes serving warms survive a redeploy.

Schema (version 2 — version 1 plus an optional per-entry ``schedule``)::

    {
      "schema": 2,
      "entries": {
        "<TuneKey.encode()>": {
          "config":  {block, n1, n2, n3, karatsuba, precision, col_block,
                      residency, phase_block, buffer_depth},
          "schedule": {segments: [{n1, n2, n3, karatsuba}, ...],
                       block, col_block, precision, residency,
                       phase_block, buffer_depth},   # optional
          "seconds": <measured wall seconds or null>,
          "source":  "search" | "sweep" | "migrated",
          "updated_utc": "YYYY-MM-DDTHH:MM:SSZ"
        }, ...
      }
    }

``config`` is always present — every consumer that only understands flat
configs (``get``) keeps working; ``schedule`` appears when the entry was
produced by the schedule-graph search and carries per-segment decisions
a flat config cannot express. ``get_schedule`` resolves EITHER form: an
entry without a ``schedule`` resolves as its config's degenerate
one-segment schedule, so schema-1 entries serve schedule consumers
without re-search.

Migrations, both transparent on load:

* schema 1 -> 2: entries pass through untouched (schema 1 is a strict
  subset); the file is rewritten in schema 2 on the next ``put``.
* the pre-subsystem flat cache (benchmarks/autotune.py) — a
  ``{"<backend>_B<batch>_n<n>": {config..., seconds}}`` dict with exact
  batch, no device fingerprint, no version: batch normalizes to its
  power-of-two bucket (the fastest entry wins a bucket collision), the
  current process's device fingerprint is stamped (the legacy cache was
  by definition measured here).

The in-process layer keeps the parsed document per path and re-reads only
when the file's mtime changes, so compile-time lookups (one per dispatch)
never re-parse JSON. Writes are atomic (tmp + rename) and lock-guarded.
"""
from __future__ import annotations

import contextlib
import json
import logging
import os
import threading
import time
from typing import Optional

try:
    import fcntl
except ImportError:          # non-POSIX: in-process locking only
    fcntl = None

from repro.tuning.space import (
    KIND_KERNEL,
    KernelConfig,
    Schedule,
    TuneKey,
    bucket_batch,
    device_fingerprint,
)

CACHE_SCHEMA = 2
# schema versions a loaded document may carry; anything else is rejected
_KNOWN_SCHEMAS = (1, CACHE_SCHEMA)

_logger = logging.getLogger(__name__)
# cache paths whose corruption has already been logged (log once per
# path per process — a corrupt file would otherwise warn on every load
# until the first put() rewrites it)
_QUARANTINE_WARNED: set = set()


def default_cache_path() -> str:
    """$REPRO_AUTOTUNE_CACHE if set, else the user cache directory
    ($XDG_CACHE_HOME or ~/.cache)/repro/autotune_cache.json — never
    inside the repo (*.autotune_cache.json is gitignored regardless)."""
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    return os.path.join(base, "repro", "autotune_cache.json")


def _utc_now() -> str:
    return time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())


def validate_cache_doc(doc: dict) -> dict:
    """Assert ``doc`` is a well-formed schema-1 or schema-2 cache; raises
    ValueError with the first defect, returns the doc so callers can
    chain. (Schema 1 stays valid: a loaded 1 migrates to 2 in memory —
    see ``migrate_schema1_doc`` — but rejecting it here would break every
    process still holding an un-rewritten file.)"""
    if not isinstance(doc, dict):
        raise ValueError("cache doc must be a JSON object")
    if doc.get("schema") not in _KNOWN_SCHEMAS:
        raise ValueError(
            f"cache schema {doc.get('schema')!r} not in {_KNOWN_SCHEMAS}")
    entries = doc.get("entries")
    if not isinstance(entries, dict):
        raise ValueError("cache entries must be an object")
    for key, entry in entries.items():
        TuneKey.decode(key)                      # raises on malformed keys
        if not isinstance(entry, dict) or "config" not in entry:
            raise ValueError(f"entry {key!r} missing 'config'")
        KernelConfig.from_dict(entry["config"])  # raises on bad knobs
        if entry.get("schedule") is not None:
            Schedule.from_dict(entry["schedule"])   # raises on bad knobs
        sec = entry.get("seconds")
        if sec is not None and not isinstance(sec, (int, float)):
            raise ValueError(f"entry {key!r}: seconds is not a number")
    return doc


def migrate_legacy_doc(doc: dict) -> dict:
    """A legacy flat ``{"backend_B<b>_n<n>": {...}}`` dict -> schema 1.

    Batch buckets to the serving power-of-two grid (fastest entry wins a
    collision); the current device fingerprint is stamped on every entry
    (a legacy cache was measured in-process, i.e. on this device kind).
    """
    device = device_fingerprint()
    entries: dict = {}
    for key, cfg in doc.items():
        try:
            backend, b_part, n_part = key.rsplit("_", 2)
            batch = int(b_part.lstrip("B"))
            n = int(n_part.lstrip("n"))
            config = KernelConfig.from_dict(cfg)
        except Exception:
            continue                              # unparseable: drop
        tk = TuneKey(kind=KIND_KERNEL, backend=backend, device=device,
                     n=n, batch=bucket_batch(batch), lines=16)
        seconds = cfg.get("seconds") if isinstance(cfg, dict) else None
        prev = entries.get(tk.encode())
        if prev is not None and seconds is not None \
                and prev.get("seconds") is not None \
                and prev["seconds"] <= seconds:
            continue                              # bucket collision: keep faster
        entries[tk.encode()] = {
            "config": config.to_dict(), "seconds": seconds,
            "source": "migrated", "updated_utc": _utc_now(),
        }
    return {"schema": CACHE_SCHEMA, "entries": entries}


def migrate_schema1_doc(doc: dict) -> dict:
    """A schema-1 document -> schema 2. Entries pass through untouched —
    schema 1 is a strict subset of 2 (no ``schedule`` field); a flat
    entry resolves through ``get_schedule`` as its degenerate one-segment
    schedule, so no re-search is ever needed."""
    out = dict(doc)
    out["schema"] = CACHE_SCHEMA
    return out


class TuneCache:
    """One cache file + its in-process layer. Thread-safe."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self._lock = threading.Lock()
        self._mtime: Optional[float] = None
        self._doc: Optional[dict] = None

    # -- document ------------------------------------------------------------
    def _load_locked(self) -> dict:
        try:
            mtime = os.path.getmtime(self.path)
        except OSError:
            self._mtime, self._doc = None, {"schema": CACHE_SCHEMA,
                                            "entries": {}}
            return self._doc
        if self._doc is not None and mtime == self._mtime:
            return self._doc
        try:
            with open(self.path) as f:
                raw = json.load(f)
            if "schema" not in raw:               # legacy flat autotune dict
                doc = migrate_legacy_doc(raw)
            else:
                doc = validate_cache_doc(raw)
                if doc.get("schema") == 1:        # schema 1: bump in memory
                    doc = migrate_schema1_doc(doc)
        except (ValueError, OSError, KeyError, TypeError,
                AttributeError) as e:
            # ValueError covers truncated/garbage JSON and schema
            # rejection; the rest cover well-formed JSON of the wrong
            # shape hitting the legacy migrator.
            # Corrupt cache file (truncated write, foreign schema, a
            # fault-injected chaos run): QUARANTINE it — move the bytes
            # aside for post-mortem instead of deleting evidence or
            # failing every warm() forever — and rebuild empty. The
            # tuner simply re-measures; a cache is a cache.
            self._quarantine_locked(e)
            self._mtime, self._doc = None, {"schema": CACHE_SCHEMA,
                                            "entries": {}}
            return self._doc
        self._mtime, self._doc = mtime, doc
        return doc

    def _quarantine_locked(self, err: Exception) -> None:
        corrupt = self.path + ".corrupt"
        try:
            os.replace(self.path, corrupt)
            moved = True
        except OSError:
            moved = False                # read-only dir: warn-only path
        if self.path not in _QUARANTINE_WARNED:   # log once per path
            _QUARANTINE_WARNED.add(self.path)
            _logger.warning(
                "tuning cache %s is unreadable (%s: %s); %s — rebuilding "
                "an empty cache", self.path, type(err).__name__, err,
                f"quarantined to {corrupt}" if moved
                else "could not quarantine (filesystem error)")

    def doc(self) -> dict:
        """The parsed (and, if needed, migrated) schema-2 document."""
        with self._lock:
            return self._load_locked()

    def _save_locked(self, doc: dict) -> None:
        validate_cache_doc(doc)
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        os.replace(tmp, self.path)
        try:
            self._mtime = os.path.getmtime(self.path)
        except OSError:
            self._mtime = None
        self._doc = doc

    # -- entries -------------------------------------------------------------
    def get_entry(self, key: TuneKey) -> Optional[dict]:
        with self._lock:
            return self._load_locked()["entries"].get(key.encode())

    def get(self, key: TuneKey) -> Optional[KernelConfig]:
        entry = self.get_entry(key)
        if entry is None:
            return None
        return KernelConfig.from_dict(entry["config"])

    def get_schedule(self, key: TuneKey) -> Optional[Schedule]:
        """The entry's Schedule: the stored one when the graph search
        persisted it, else the flat config's degenerate one-segment
        schedule — so schema-1(-migrated) entries serve schedule
        consumers WITHOUT re-search."""
        entry = self.get_entry(key)
        if entry is None:
            return None
        if entry.get("schedule") is not None:
            return Schedule.from_dict(entry["schedule"])
        return Schedule.from_config(KernelConfig.from_dict(entry["config"]))

    @contextlib.contextmanager
    def _file_lock(self):
        """Advisory cross-process lock around read-modify-write: two
        serving processes warming different keys against the shared cache
        must not overwrite each other's just-persisted sweeps."""
        if fcntl is None:
            yield
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(self.path + ".lock", "w") as lf:
            fcntl.flock(lf, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lf, fcntl.LOCK_UN)

    def _put_entry(self, key: TuneKey, entry: dict) -> None:
        """Insert/replace one entry and persist atomically (also rewrites
        a legacy- or schema-1-format file in schema 2). The merge is done
        under a cross-process file lock against a freshly re-read
        document, so concurrent writers keep each other's entries."""
        with self._lock, self._file_lock():
            self._mtime = None           # force a re-read under the lock
            self._doc = None
            doc = dict(self._load_locked())
            doc["entries"] = dict(doc["entries"])
            doc["entries"][key.encode()] = entry
            self._save_locked(doc)

    def put(self, key: TuneKey, config: KernelConfig,
            seconds: Optional[float] = None, source: str = "search") -> None:
        """Insert/replace the flat-config entry for ``key``."""
        self._put_entry(key, {
            "config": config.to_dict(),
            "seconds": None if seconds is None else float(seconds),
            "source": source, "updated_utc": _utc_now(),
        })

    def put_schedule(self, key: TuneKey, schedule: Schedule,
                     seconds: Optional[float] = None,
                     source: str = "search") -> None:
        """Insert/replace a Schedule entry for ``key``. The flat-config
        view is derived and stored alongside, so flat-only consumers
        (``get``) keep resolving the entry."""
        self._put_entry(key, {
            "config": schedule.to_config().to_dict(),
            "schedule": schedule.to_dict(),
            "seconds": None if seconds is None else float(seconds),
            "source": source, "updated_utc": _utc_now(),
        })


# per-path singletons so every layer shares one in-process view
_CACHES: dict = {}
_CACHES_LOCK = threading.Lock()


def get_cache(path: Optional[str] = None) -> TuneCache:
    p = path or default_cache_path()
    with _CACHES_LOCK:
        if p not in _CACHES:
            _CACHES[p] = TuneCache(p)
        return _CACHES[p]


def clear_memory_cache() -> None:
    """Drop every in-process cache view (tests; the files are untouched)."""
    with _CACHES_LOCK:
        _CACHES.clear()
