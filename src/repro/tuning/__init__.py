"""repro.tuning — the single owner of kernel-config decisions.

The paper's 22x comes from picking the right kernel shape (radix split,
line block, precision) per dispatch. This subsystem owns that decision
for every layer — kernels, the plan compiler, the serving warm path, and
the CLI tuner all resolve configs here, through one typed key space and
one persistent device-fingerprinted cache:

* :mod:`repro.tuning.space`  — :class:`TuneKey` (problem shape + device
  fingerprint, batch normalized to serving buckets) and
  :class:`KernelConfig` (the one config record all layers share).
* :mod:`repro.tuning.cost`   — analytic roofline model ranking candidates
  without running them (matmul-DFT FLOPs, bytes per pass, VMEM cut).
* :mod:`repro.tuning.search` — cost-ordered measured search with
  successive-halving early stopping and the SNR quality gate.
* :mod:`repro.tuning.cache`  — versioned schema-validated JSON cache with
  transparent migration from the legacy flat autotune format.
* :mod:`repro.tuning.quality`— the measured precision-SNR gate (imported
  lazily: it pulls in the full SAR pipeline).

Layering: ``repro.tuning`` sits above ``repro.kernels`` and below
``repro.core.plan`` / ``repro.service``; nothing in ``src/repro`` imports
from ``benchmarks/`` (enforced by tests/test_tuning.py) — the benchmarks
package is a thin CLI/reporting shim over this subsystem.
"""
from repro.tuning.cache import (
    CACHE_SCHEMA,
    TuneCache,
    clear_memory_cache,
    default_cache_path,
    get_cache,
    migrate_legacy_doc,
    migrate_schema1_doc,
    validate_cache_doc,
)
from repro.tuning.search import (
    DEFAULT_SNR_GATE_DB,
    TIMING_REPEATS_FLOOR,
    SearchResult,
    kernel_measure,
    best_config,
    cached_config,
    cached_schedule,
    measured_search,
    mega_measure,
    schedule_frontier,
    search_kernel,
    search_schedule,
)
from repro.tuning.space import (
    CONFIG_KEYS,
    KIND_KERNEL,
    KIND_PIPELINE,
    MEGA_KEYS,
    SEGMENT_KEYS,
    SPECTRAL_KEYS,
    KernelConfig,
    Schedule,
    ScheduleProblem,
    SegmentConfig,
    SegmentShape,
    TuneKey,
    bucket_batch,
    candidates,
    device_fingerprint,
    factorizations,
)
from repro.tuning import cost

__all__ = [
    "CACHE_SCHEMA", "CONFIG_KEYS", "DEFAULT_SNR_GATE_DB", "KIND_KERNEL",
    "KIND_PIPELINE", "KernelConfig", "MEGA_KEYS", "SEGMENT_KEYS",
    "SPECTRAL_KEYS", "Schedule", "ScheduleProblem", "SearchResult",
    "SegmentConfig", "SegmentShape", "TIMING_REPEATS_FLOOR",
    "TuneCache", "TuneKey", "best_config", "bucket_batch", "cached_config",
    "cached_schedule", "candidates", "clear_memory_cache", "cost",
    "default_cache_path", "device_fingerprint", "factorizations",
    "get_cache", "kernel_measure", "measured_search", "mega_measure",
    "migrate_legacy_doc", "migrate_schema1_doc", "schedule_frontier",
    "search_kernel", "search_schedule", "validate_cache_doc",
]
