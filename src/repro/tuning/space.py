"""The tuning search space: typed keys and configs.

Two types replace the ad-hoc dicts that used to travel between
benchmarks/autotune.py, core/plan.py, and service/backends.py:

:class:`TuneKey`
    WHAT a tuned config is for — problem shape (FFT length, batch
    bucket, line count, requested precision) plus WHERE it was measured
    (jax backend and the device fingerprint, e.g.
    ``jax.devices()[0].device_kind``). "Beating vDSP" (arXiv 2603.27569)
    shows the winning tile decomposition is device-specific, so a config
    tuned on one device kind must never be served to another. Batch is
    normalized to the serving batcher's power-of-two buckets at key
    construction (see :func:`bucket_batch`): the service pads partial
    micro-batches up to a bucket before dispatch, so exact-batch keys
    would systematically miss.

:class:`KernelConfig`
    HOW to run the dispatch — the tunable knobs of one fused spectral
    dispatch (``block``, mixed-radix ``n1/n2/n3``, ``karatsuba``,
    ``precision``) plus the pipeline-level ``col_block`` (the
    columns-dispatch line block the service's warm sweep used to keep in
    its own private dict). Kernels consume the spectral subset via
    :meth:`KernelConfig.spectral_kwargs`; plans and the service consume
    the whole record.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import jax

from repro.kernels.fft4step import (
    MAX_FACTOR,
    RESIDENT_STAGED,
    RESIDENT_VMEM,
    SpectralSpec,
    default_factorization,
    resolve_precision,
)

KIND_KERNEL = "kernel"       # one fused spectral dispatch (rows, fwd+inv)
KIND_PIPELINE = "pipeline"   # a whole compiled plan (service warm sweep)

SPECTRAL_KEYS = ("block", "n1", "n2", "n3", "karatsuba", "precision")
# megakernel (fused1) knobs: execution-residency mode of a cross-axis
# single-dispatch step and its staged-phase line block
MEGA_KEYS = ("residency", "phase_block")
CONFIG_KEYS = SPECTRAL_KEYS + ("col_block",) + MEGA_KEYS


def bucket_batch(b: int) -> int:
    """The serving batcher's power-of-two batch bucket containing ``b``.

    Every distinct batch shape costs one jit trace, so the service pads
    partial micro-batches with zero scenes up to the next power of two
    (see service/backends.py). Tune keys use the same buckets: a config
    tuned for the padded shape is the config that actually runs."""
    return 1 << max(0, b - 1).bit_length()


def device_fingerprint() -> str:
    """The device kind the process would tune on (first jax device),
    sanitized for use inside an encoded cache key."""
    try:
        kind = jax.devices()[0].device_kind
    except Exception:
        kind = "unknown"
    return str(kind).strip().replace(" ", "-").replace("|", "-")


@dataclasses.dataclass(frozen=True)
class TuneKey:
    """One slot in the tuning cache: problem shape + measurement device."""

    kind: str                        # KIND_KERNEL | KIND_PIPELINE
    backend: str                     # jax.default_backend() at tune time
    device: str                      # device fingerprint (device_kind)
    n: int                           # FFT length (kernel) / nr (pipeline)
    batch: int                       # power-of-two batch bucket
    lines: int                       # free-axis length (kernel: timing
                                     # proxy; pipeline: na)
    precision: Optional[str] = None  # requested policy (pipeline kind);
                                     # None for kernel keys — precision is
                                     # part of the searched config there
    variant: Optional[str] = None    # plan variant (pipeline kind)

    def __post_init__(self):
        if self.batch != bucket_batch(self.batch):
            raise ValueError(
                f"TuneKey.batch must be a power-of-two bucket, got "
                f"{self.batch} (use TuneKey.kernel()/pipeline() or "
                f"bucket_batch())")

    @classmethod
    def kernel(cls, n: int, batch: int = 1, lines: int = 16,
               backend: Optional[str] = None,
               device: Optional[str] = None) -> "TuneKey":
        """Key for one fused rows dispatch; batch normalizes to its
        power-of-two bucket so padded service batches hit the cache."""
        return cls(kind=KIND_KERNEL,
                   backend=backend or jax.default_backend(),
                   device=device or device_fingerprint(),
                   n=int(n), batch=bucket_batch(int(batch)),
                   lines=int(lines))

    @classmethod
    def pipeline(cls, variant: str, na: int, nr: int, batch: int = 1,
                 precision: Optional[str] = None,
                 backend: Optional[str] = None,
                 device: Optional[str] = None) -> "TuneKey":
        """Key for a whole compiled plan on an (na, nr) scene geometry —
        the service's warm-time (block, col_block) sweep slot."""
        return cls(kind=KIND_PIPELINE,
                   backend=backend or jax.default_backend(),
                   device=device or device_fingerprint(),
                   n=int(nr), batch=bucket_batch(int(batch)),
                   lines=int(na), precision=precision, variant=variant)

    def encode(self) -> str:
        """Stable string form used as the JSON cache key."""
        return "|".join((
            self.kind, self.backend, self.device, f"n{self.n}",
            f"B{self.batch}", f"L{self.lines}",
            self.precision or "-", self.variant or "-",
        ))

    @classmethod
    def decode(cls, s: str) -> "TuneKey":
        parts = s.split("|")
        if len(parts) != 8:
            raise ValueError(f"malformed TuneKey string {s!r}")
        kind, backend, device, n, b, lines, prec, var = parts
        return cls(kind=kind, backend=backend, device=device,
                   n=int(n.lstrip("n")), batch=int(b.lstrip("B")),
                   lines=int(lines.lstrip("L")),
                   precision=None if prec == "-" else prec,
                   variant=None if var == "-" else var)


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One candidate (or winning) kernel/pipeline configuration.

    ``None`` means "defer to the next layer's default" (library
    factorization, block 8 rows / 128 cols, f32). ``col_block`` belongs
    to the columns dispatch of a compiled plan — kernels never see it
    (:meth:`spectral_kwargs` excludes it); ``-1`` means "all lines" and
    is resolved against the scene by the consumer."""

    block: Optional[int] = None
    n1: Optional[int] = None
    n2: Optional[int] = None
    n3: Optional[int] = None
    karatsuba: Optional[bool] = None     # tri-state: None defers too
    precision: Optional[str] = None
    col_block: Optional[int] = None
    residency: Optional[str] = None      # megakernel mode: vmem | staged
    phase_block: Optional[int] = None    # staged-phase line block

    def __post_init__(self):
        if self.precision is not None:
            resolve_precision(self.precision)   # raises on unknown policy
        for name in ("n1", "n2", "n3"):
            f = getattr(self, name)
            if f is not None and (f < 1 or f & (f - 1) or f > MAX_FACTOR):
                raise ValueError(
                    f"{name}={f} is not a power of two <= {MAX_FACTOR}")
        if self.residency not in (None, RESIDENT_VMEM, RESIDENT_STAGED):
            raise ValueError(
                f"residency={self.residency!r} is not one of "
                f"{(RESIDENT_VMEM, RESIDENT_STAGED)}")
        pb = self.phase_block
        if pb is not None and (pb < 1 or pb & (pb - 1)):
            raise ValueError(
                f"phase_block={pb} is not a power of two (staged phases "
                "strip power-of-two scene axes)")

    # -- views ---------------------------------------------------------------
    def spectral_kwargs(self) -> dict:
        """The kernel-facing subset as ``ops.spectral_op`` kwargs.
        ``None`` entries (karatsuba included — it is tri-state) are
        dropped so downstream defaults apply."""
        d = {k: getattr(self, k) for k in SPECTRAL_KEYS}
        return {k: v for k, v in d.items() if v is not None}

    def factors(self) -> Optional[tuple]:
        """The explicit factorization (n1, n2[, n3]), or None if deferred."""
        if self.n1 is None:
            return None
        fs = [self.n1]
        if self.n2 is not None:
            fs.append(self.n2)
        if self.n3 is not None:
            fs.append(self.n3)
        return tuple(fs)

    def apply(self, spec: SpectralSpec) -> SpectralSpec:
        """A SpectralSpec with this config's non-None knobs applied —
        the one config path into kernels/fft4step.build_spectral_call."""
        updates = {k: v for k, v in self.spectral_kwargs().items()}
        if self.factors() is not None:
            # an explicit factorization replaces the spec's wholesale:
            # mixing factors from two configs would break n = n1*n2[*n3]
            updates.setdefault("n2", None)
            updates.setdefault("n3", None)
        return dataclasses.replace(spec, **updates)

    def merge_overrides(self, overrides: dict) -> "KernelConfig":
        """This config with explicit per-compile overrides (e.g.
        ``compile_plan``'s ``fft_kw``) applied on top. An override that
        names ANY of n1/n2/n3 replaces the factorization wholesale —
        mixing factors from two configs would break n = n1*n2[*n3]."""
        d = self.to_dict()
        if any(k in overrides for k in ("n1", "n2", "n3")):
            for k in ("n1", "n2", "n3"):
                d[k] = overrides.get(k)
        for k in ("block", "karatsuba", "precision", "col_block") + MEGA_KEYS:
            if overrides.get(k) is not None:
                d[k] = overrides[k]
        return KernelConfig.from_dict(d)

    # -- (de)serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        return {k: getattr(self, k) for k in CONFIG_KEYS}

    @classmethod
    def from_dict(cls, d: dict) -> "KernelConfig":
        """Build from a dict, tolerating extra keys (legacy autotune cache
        entries carry ``seconds`` etc.)."""
        return cls(**{k: d[k] for k in CONFIG_KEYS if k in d})


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

def factorizations(n: int) -> list[tuple[int, ...]]:
    """Candidate mixed-radix splits of ``n``: every sorted-descending
    2-factor decomposition into powers of two <= MAX_FACTOR, switching to
    3-factor decompositions past MAX_FACTOR**2 (the four-step recursion's
    3-stage regime). Invariants (tested): factors sorted descending, every
    factor <= MAX_FACTOR, product == n, non-empty up to MAX_FACTOR**3."""
    if n < 2 or n & (n - 1):
        raise ValueError(f"FFT length must be a power of two >= 2, got {n}")
    p = n.bit_length() - 1
    out: list[tuple[int, ...]] = []
    if n <= MAX_FACTOR * MAX_FACTOR:
        for p1 in range((p + 1) // 2, p + 1):
            n1, n2 = 1 << p1, 1 << (p - p1)
            if n1 <= MAX_FACTOR and n1 >= n2 >= 1:
                out.append((n1, n2))
    else:
        for p1 in range(1, p - 1):
            for p2 in range(1, p - p1):
                fs = (1 << p1, 1 << p2, 1 << (p - p1 - p2))
                if all(f <= MAX_FACTOR for f in fs) and fs[0] >= fs[1] >= fs[2]:
                    out.append(fs)
    return out or [default_factorization(n)]


def candidates(n: int, blocks=(4, 8, 16),
               precisions=("f32",)) -> list[KernelConfig]:
    """The kernel search space for one FFT length: factorization x line
    block x karatsuba x precision, as typed configs."""
    out = []
    for fs, blk, kara, prec in itertools.product(
            factorizations(n), blocks, (False, True), precisions):
        out.append(KernelConfig(
            block=blk, karatsuba=kara, n1=fs[0], n2=fs[1],
            n3=fs[2] if len(fs) > 2 else None, precision=prec))
    return out
