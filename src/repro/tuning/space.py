"""The tuning search space: typed keys and configs.

Two types replace the ad-hoc dicts that used to travel between
benchmarks/autotune.py, core/plan.py, and service/backends.py:

:class:`TuneKey`
    WHAT a tuned config is for — problem shape (FFT length, batch
    bucket, line count, requested precision) plus WHERE it was measured
    (jax backend and the device fingerprint, e.g.
    ``jax.devices()[0].device_kind``). "Beating vDSP" (arXiv 2603.27569)
    shows the winning tile decomposition is device-specific, so a config
    tuned on one device kind must never be served to another. Batch is
    normalized to the serving batcher's power-of-two buckets at key
    construction (see :func:`bucket_batch`): the service pads partial
    micro-batches up to a bucket before dispatch, so exact-batch keys
    would systematically miss.

:class:`KernelConfig`
    HOW to run the dispatch — the tunable knobs of one fused spectral
    dispatch (``block``, mixed-radix ``n1/n2/n3``, ``karatsuba``,
    ``precision``) plus the pipeline-level ``col_block`` (the
    columns-dispatch line block the service's warm sweep used to keep in
    its own private dict). Kernels consume the spectral subset via
    :meth:`KernelConfig.spectral_kwargs`; plans and the service consume
    the whole record.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import jax

from repro.kernels.fft4step import (
    MAX_FACTOR,
    RESIDENT_STAGED,
    RESIDENT_VMEM,
    SpectralSpec,
    default_factorization,
    resolve_precision,
)

KIND_KERNEL = "kernel"       # one fused spectral dispatch (rows, fwd+inv)
KIND_PIPELINE = "pipeline"   # a whole compiled plan (service warm sweep)

SPECTRAL_KEYS = ("block", "n1", "n2", "n3", "karatsuba", "precision")
# megakernel (fused1) knobs: execution-residency mode of a cross-axis
# single-dispatch step, its staged-phase line block, and the staged DMA
# double-buffer depth
MEGA_KEYS = ("residency", "phase_block", "buffer_depth")
CONFIG_KEYS = SPECTRAL_KEYS + ("col_block",) + MEGA_KEYS
# the per-segment scheduling decisions a Schedule can vary where a flat
# KernelConfig holds one global value
SEGMENT_KEYS = ("n1", "n2", "n3", "karatsuba")


def bucket_batch(b: int) -> int:
    """The serving batcher's power-of-two batch bucket containing ``b``.

    Every distinct batch shape costs one jit trace, so the service pads
    partial micro-batches with zero scenes up to the next power of two
    (see service/backends.py). Tune keys use the same buckets: a config
    tuned for the padded shape is the config that actually runs."""
    return 1 << max(0, b - 1).bit_length()


def device_fingerprint() -> str:
    """The device kind the process would tune on (first jax device),
    sanitized for use inside an encoded cache key."""
    try:
        kind = jax.devices()[0].device_kind
    except Exception:
        kind = "unknown"
    return str(kind).strip().replace(" ", "-").replace("|", "-")


@dataclasses.dataclass(frozen=True)
class TuneKey:
    """One slot in the tuning cache: problem shape + measurement device."""

    kind: str                        # KIND_KERNEL | KIND_PIPELINE
    backend: str                     # jax.default_backend() at tune time
    device: str                      # device fingerprint (device_kind)
    n: int                           # FFT length (kernel) / nr (pipeline)
    batch: int                       # power-of-two batch bucket
    lines: int                       # free-axis length (kernel: timing
                                     # proxy; pipeline: na)
    precision: Optional[str] = None  # requested policy (pipeline kind);
                                     # None for kernel keys — precision is
                                     # part of the searched config there
    variant: Optional[str] = None    # plan variant (pipeline kind)

    def __post_init__(self):
        if self.batch != bucket_batch(self.batch):
            raise ValueError(
                f"TuneKey.batch must be a power-of-two bucket, got "
                f"{self.batch} (use TuneKey.kernel()/pipeline() or "
                f"bucket_batch())")

    @classmethod
    def kernel(cls, n: int, batch: int = 1, lines: int = 16,
               backend: Optional[str] = None,
               device: Optional[str] = None) -> "TuneKey":
        """Key for one fused rows dispatch; batch normalizes to its
        power-of-two bucket so padded service batches hit the cache."""
        return cls(kind=KIND_KERNEL,
                   backend=backend or jax.default_backend(),
                   device=device or device_fingerprint(),
                   n=int(n), batch=bucket_batch(int(batch)),
                   lines=int(lines))

    @classmethod
    def pipeline(cls, variant: str, na: int, nr: int, batch: int = 1,
                 precision: Optional[str] = None,
                 backend: Optional[str] = None,
                 device: Optional[str] = None) -> "TuneKey":
        """Key for a whole compiled plan on an (na, nr) scene geometry —
        the service's warm-time (block, col_block) sweep slot."""
        return cls(kind=KIND_PIPELINE,
                   backend=backend or jax.default_backend(),
                   device=device or device_fingerprint(),
                   n=int(nr), batch=bucket_batch(int(batch)),
                   lines=int(na), precision=precision, variant=variant)

    def encode(self) -> str:
        """Stable string form used as the JSON cache key."""
        return "|".join((
            self.kind, self.backend, self.device, f"n{self.n}",
            f"B{self.batch}", f"L{self.lines}",
            self.precision or "-", self.variant or "-",
        ))

    @classmethod
    def decode(cls, s: str) -> "TuneKey":
        parts = s.split("|")
        if len(parts) != 8:
            raise ValueError(f"malformed TuneKey string {s!r}")
        kind, backend, device, n, b, lines, prec, var = parts
        return cls(kind=kind, backend=backend, device=device,
                   n=int(n.lstrip("n")), batch=int(b.lstrip("B")),
                   lines=int(lines.lstrip("L")),
                   precision=None if prec == "-" else prec,
                   variant=None if var == "-" else var)


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One candidate (or winning) kernel/pipeline configuration.

    ``None`` means "defer to the next layer's default" (library
    factorization, block 8 rows / 128 cols, f32). ``col_block`` belongs
    to the columns dispatch of a compiled plan — kernels never see it
    (:meth:`spectral_kwargs` excludes it); ``-1`` means "all lines" and
    is resolved against the scene by the consumer."""

    block: Optional[int] = None
    n1: Optional[int] = None
    n2: Optional[int] = None
    n3: Optional[int] = None
    karatsuba: Optional[bool] = None     # tri-state: None defers too
    precision: Optional[str] = None
    col_block: Optional[int] = None
    residency: Optional[str] = None      # megakernel mode: vmem | staged
    phase_block: Optional[int] = None    # staged-phase line block
    buffer_depth: Optional[int] = None   # staged DMA double-buffer depth

    def __post_init__(self):
        if self.precision is not None:
            resolve_precision(self.precision)   # raises on unknown policy
        for name in ("n1", "n2", "n3"):
            f = getattr(self, name)
            if f is not None and (f < 1 or f & (f - 1) or f > MAX_FACTOR):
                raise ValueError(
                    f"{name}={f} is not a power of two <= {MAX_FACTOR}")
        if self.residency not in (None, RESIDENT_VMEM, RESIDENT_STAGED):
            raise ValueError(
                f"residency={self.residency!r} is not one of "
                f"{(RESIDENT_VMEM, RESIDENT_STAGED)}")
        pb = self.phase_block
        if pb is not None and (pb < 1 or pb & (pb - 1)):
            raise ValueError(
                f"phase_block={pb} is not a power of two (staged phases "
                "strip power-of-two scene axes)")
        bd = self.buffer_depth
        if bd is not None and (not isinstance(bd, int) or bd < 1):
            raise ValueError(
                f"buffer_depth={bd!r} is not a positive integer")

    # -- views ---------------------------------------------------------------
    def spectral_kwargs(self) -> dict:
        """The kernel-facing subset as ``ops.spectral_op`` kwargs.
        ``None`` entries (karatsuba included — it is tri-state) are
        dropped so downstream defaults apply."""
        d = {k: getattr(self, k) for k in SPECTRAL_KEYS}
        return {k: v for k, v in d.items() if v is not None}

    def factors(self) -> Optional[tuple]:
        """The explicit factorization (n1, n2[, n3]), or None if deferred."""
        if self.n1 is None:
            return None
        fs = [self.n1]
        if self.n2 is not None:
            fs.append(self.n2)
        if self.n3 is not None:
            fs.append(self.n3)
        return tuple(fs)

    def apply(self, spec: SpectralSpec) -> SpectralSpec:
        """A SpectralSpec with this config's non-None knobs applied —
        the one config path into kernels/fft4step.build_spectral_call."""
        updates = {k: v for k, v in self.spectral_kwargs().items()}
        if self.factors() is not None:
            # an explicit factorization replaces the spec's wholesale:
            # mixing factors from two configs would break n = n1*n2[*n3]
            updates.setdefault("n2", None)
            updates.setdefault("n3", None)
        return dataclasses.replace(spec, **updates)

    def merge_overrides(self, overrides: dict) -> "KernelConfig":
        """This config with explicit per-compile overrides (e.g.
        ``compile_plan``'s ``fft_kw``) applied on top. An override that
        names ANY of n1/n2/n3 replaces the factorization wholesale —
        mixing factors from two configs would break n = n1*n2[*n3]."""
        d = self.to_dict()
        if any(k in overrides for k in ("n1", "n2", "n3")):
            for k in ("n1", "n2", "n3"):
                d[k] = overrides.get(k)
        for k in ("block", "karatsuba", "precision", "col_block") + MEGA_KEYS:
            if overrides.get(k) is not None:
                d[k] = overrides[k]
        return KernelConfig.from_dict(d)

    # -- (de)serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        return {k: getattr(self, k) for k in CONFIG_KEYS}

    @classmethod
    def from_dict(cls, d: dict) -> "KernelConfig":
        """Build from a dict, tolerating extra keys (legacy autotune cache
        entries carry ``seconds`` etc.)."""
        return cls(**{k: d[k] for k in CONFIG_KEYS if k in d})


# ---------------------------------------------------------------------------
# Schedule IR — per-segment decisions over a multi-segment dispatch
# ---------------------------------------------------------------------------
#
# A flat KernelConfig holds ONE global factorization/karatsuba for every
# transform segment of a dispatch. A Schedule is the generalized record:
# one SegmentConfig per segment (the per-segment edge choices of the
# schedule DAG — factorization and complex-product algorithm) plus the
# dispatch-global lane decisions (block, precision, residency,
# phase_block, buffer_depth). KernelConfig is the degenerate one-segment
# (or uniform) schedule: Schedule.from_config / Schedule.to_config
# convert losslessly in that case.

@dataclasses.dataclass(frozen=True)
class SegmentConfig:
    """Per-segment scheduling decisions: the mixed-radix factorization of
    THIS segment's transform and its complex-product algorithm. ``None``
    defers to the next layer's default, exactly like KernelConfig."""

    n1: Optional[int] = None
    n2: Optional[int] = None
    n3: Optional[int] = None
    karatsuba: Optional[bool] = None     # tri-state, like KernelConfig

    def __post_init__(self):
        for name in ("n1", "n2", "n3"):
            f = getattr(self, name)
            if f is not None and (f < 1 or f & (f - 1) or f > MAX_FACTOR):
                raise ValueError(
                    f"{name}={f} is not a power of two <= {MAX_FACTOR}")

    def factors(self) -> Optional[tuple]:
        """The explicit factorization (n1, n2[, n3]), or None if deferred."""
        if self.n1 is None:
            return None
        fs = [self.n1]
        if self.n2 is not None:
            fs.append(self.n2)
        if self.n3 is not None:
            fs.append(self.n3)
        return tuple(fs)

    def to_dict(self) -> dict:
        return {k: getattr(self, k) for k in SEGMENT_KEYS}

    @classmethod
    def from_dict(cls, d: dict) -> "SegmentConfig":
        return cls(**{k: d[k] for k in SEGMENT_KEYS if k in d})


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One complete path through the schedule DAG: per-segment decisions
    (``segments``) plus the dispatch-global lane (block/precision/
    residency/phase_block/buffer_depth). Hashable and JSON-serializable —
    schedules persist in the schema-2 tuning cache and key the compiled-
    pipeline cache."""

    segments: tuple = ()                 # tuple[SegmentConfig, ...]
    block: Optional[int] = None
    col_block: Optional[int] = None
    precision: Optional[str] = None
    residency: Optional[str] = None      # megakernel mode: vmem | staged
    phase_block: Optional[int] = None    # staged-phase line block
    buffer_depth: Optional[int] = None   # staged DMA buffer depth

    def __post_init__(self):
        segs = tuple(
            s if isinstance(s, SegmentConfig) else SegmentConfig.from_dict(s)
            for s in self.segments)
        object.__setattr__(self, "segments", segs)
        # reuse KernelConfig's knob validation for the global lane
        KernelConfig(block=self.block, col_block=self.col_block,
                     precision=self.precision, residency=self.residency,
                     phase_block=self.phase_block,
                     buffer_depth=self.buffer_depth)

    def segment(self, i: int) -> SegmentConfig:
        """Segment ``i``'s decisions; a deferred (all-None) config past
        the end, so consumers never index-error on shorter schedules."""
        if 0 <= i < len(self.segments):
            return self.segments[i]
        return SegmentConfig()

    def uniform(self) -> bool:
        """Whether every segment carries identical decisions (the flat-
        KernelConfig-expressible subset of the schedule space)."""
        return len(set(self.segments)) <= 1

    # -- KernelConfig bridge -------------------------------------------------
    def to_config(self) -> KernelConfig:
        """The flat-config view: exact when the schedule is uniform (or
        empty); otherwise the per-segment fields drop to None — a
        non-uniform schedule is NOT expressible as a KernelConfig, which
        is the point of the IR."""
        d = dict(block=self.block, col_block=self.col_block,
                 precision=self.precision, residency=self.residency,
                 phase_block=self.phase_block,
                 buffer_depth=self.buffer_depth)
        if self.segments and self.uniform():
            d.update(self.segments[0].to_dict())
        return KernelConfig(**d)

    @classmethod
    def from_config(cls, config: KernelConfig,
                    n_segments: int = 1) -> "Schedule":
        """The degenerate schedule a flat KernelConfig denotes: the same
        per-segment decisions replicated across ``n_segments``."""
        seg = SegmentConfig(n1=config.n1, n2=config.n2, n3=config.n3,
                            karatsuba=config.karatsuba)
        return cls(segments=(seg,) * max(1, n_segments),
                   block=config.block, col_block=config.col_block,
                   precision=config.precision, residency=config.residency,
                   phase_block=config.phase_block,
                   buffer_depth=config.buffer_depth)

    # -- (de)serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "segments": [s.to_dict() for s in self.segments],
            "block": self.block, "col_block": self.col_block,
            "precision": self.precision, "residency": self.residency,
            "phase_block": self.phase_block,
            "buffer_depth": self.buffer_depth,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Schedule":
        """Build from a dict, tolerating extra keys (cache entries carry
        ``seconds`` etc. alongside)."""
        keys = ("block", "col_block", "precision", "residency",
                "phase_block", "buffer_depth")
        kw = {k: d[k] for k in keys if k in d}
        return cls(segments=tuple(
            SegmentConfig.from_dict(s) for s in d.get("segments", ())), **kw)


@dataclasses.dataclass(frozen=True)
class SegmentShape:
    """The WORKLOAD of one schedule-DAG layer: which scene axis the
    segment transforms, in which directions, and whether a filter
    multiply rides along. The transform length and free-axis line count
    derive from the owning ScheduleProblem's scene geometry."""

    axis: int                            # 0 = columns, 1 = rows
    fwd: bool = False
    inv: bool = False
    filtered: bool = False

    def __post_init__(self):
        if self.axis not in (0, 1):
            raise ValueError(f"axis must be 0 or 1, got {self.axis}")


@dataclasses.dataclass(frozen=True)
class ScheduleProblem:
    """What the schedule-graph search optimizes over: an (na, nr) scene,
    a batch, and the ordered transform segments. ``mega=False`` is the
    single-dispatch rows problem the flat kernel tuner times (one
    segment, so the graph degenerates to the old product sweep);
    ``mega=True`` is a cross-axis megakernel whose segments may each pick
    their own factorization — the part of the space no flat KernelConfig
    can express."""

    na: int
    nr: int
    batch: int = 1
    segments: tuple = ()                 # tuple[SegmentShape, ...]
    mega: bool = False
    devices: int = 1                     # shard_map mesh size (1 = local)

    def __post_init__(self):
        segs = tuple(
            s if isinstance(s, SegmentShape) else SegmentShape(**s)
            for s in self.segments)
        object.__setattr__(self, "segments", segs)
        if self.devices < 1:
            raise ValueError(f"devices must be >= 1, got {self.devices}")
        if self.devices > 1 and (self.na % self.devices
                                 or self.nr % self.devices):
            raise ValueError(
                f"scene {self.na}x{self.nr} not divisible by "
                f"{self.devices} devices")

    @classmethod
    def kernel(cls, n: int, batch: int = 1, lines: int = 16
               ) -> "ScheduleProblem":
        """The flat kernel tuner's workload: one fused fwd+inv filtered
        rows dispatch on a (batch, lines, n) slab."""
        return cls(na=int(lines), nr=int(n), batch=int(batch),
                   segments=(SegmentShape(axis=1, fwd=True, inv=True,
                                          filtered=True),), mega=False)

    @classmethod
    def mega_2d(cls, na: int, nr: int, segments, batch: int = 1,
                devices: int = 1) -> "ScheduleProblem":
        """A cross-axis megakernel workload; ``segments`` is a sequence
        of SegmentShape (or kwargs dicts) in dispatch order. ``devices``
        > 1 models the shard_map lowering: each device holds a 1/P slab
        sharded along every segment's free axis (the transform axis stays
        whole on-slab) and corner turns become all_to_all collectives."""
        return cls(na=int(na), nr=int(nr), batch=int(batch),
                   segments=tuple(segments), mega=True,
                   devices=int(devices))

    def seg_n(self, shape: SegmentShape) -> int:
        """The transform length of a segment (the scene axis it strips).
        Sharding never splits this axis — transforms stay slab-local."""
        return self.nr if shape.axis == 1 else self.na

    def seg_lines(self, shape: SegmentShape) -> int:
        """The free-axis line count the segment's matmuls fold over —
        PER DEVICE: the shard_map lowering shards exactly this axis."""
        return (self.na if shape.axis == 1 else self.nr) // self.devices

    def turns(self) -> int:
        """Corner turns between consecutive segments on different axes."""
        return sum(1 for a, b in zip(self.segments, self.segments[1:])
                   if a.axis != b.axis)


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

def factorizations(n: int) -> list[tuple[int, ...]]:
    """Candidate mixed-radix splits of ``n``: every sorted-descending
    2-factor decomposition into powers of two <= MAX_FACTOR, switching to
    3-factor decompositions past MAX_FACTOR**2 (the four-step recursion's
    3-stage regime). Invariants (tested): factors sorted descending, every
    factor <= MAX_FACTOR, product == n, non-empty up to MAX_FACTOR**3."""
    if n < 2 or n & (n - 1):
        raise ValueError(f"FFT length must be a power of two >= 2, got {n}")
    p = n.bit_length() - 1
    out: list[tuple[int, ...]] = []
    if n <= MAX_FACTOR * MAX_FACTOR:
        for p1 in range((p + 1) // 2, p + 1):
            n1, n2 = 1 << p1, 1 << (p - p1)
            if n1 <= MAX_FACTOR and n1 >= n2 >= 1:
                out.append((n1, n2))
    else:
        for p1 in range(1, p - 1):
            for p2 in range(1, p - p1):
                fs = (1 << p1, 1 << p2, 1 << (p - p1 - p2))
                if all(f <= MAX_FACTOR for f in fs) and fs[0] >= fs[1] >= fs[2]:
                    out.append(fs)
    return out or [default_factorization(n)]


def candidates(n: int, blocks=(4, 8, 16),
               precisions=("f32",)) -> list[KernelConfig]:
    """The kernel search space for one FFT length: factorization x line
    block x karatsuba x precision, as typed configs."""
    out = []
    for fs, blk, kara, prec in itertools.product(
            factorizations(n), blocks, (False, True), precisions):
        out.append(KernelConfig(
            block=blk, karatsuba=kara, n1=fs[0], n2=fs[1],
            n3=fs[2] if len(fs) > 2 else None, precision=prec))
    return out
