"""Analytic roofline cost model for fused spectral dispatch candidates.

Ranks :class:`~repro.tuning.space.KernelConfig` candidates WITHOUT running
them, so the measured search (search.py) times only the promising few
instead of the whole space ("Shortest-Path FFT", arXiv 2604.04311: guided
search beats enumeration once the implementation space is large).

The model prices one fused ``[FFT] · H · [IFFT]`` rows dispatch on a
``(batch, lines, n)`` slab as ``max(compute, memory)`` — the roofline —
with three ingredients (formulas in docs/tuning.md):

**Matmul-DFT FLOPs.** Stage ``i`` of the four-step recursion contracts
every length-``n`` line with an ``f_i × f_i`` DFT matrix: ``n · f_i``
complex MACs per line, i.e. ``8 n f_i`` real FLOPs (``6 n f_i`` with
Karatsuba's 3-matmul product). The matrix unit is ``MAX_FACTOR`` wide, so
a factor-``f`` matmul runs at ``(f / MAX_FACTOR) ** 0.5`` of peak (small
operands waste the systolic array; the square root reflects that one of
the two matmul dims — the folded line batch — is already large). Twiddle
and filter pointwise multiplies are priced at the vector unit's rate.
``fft4step._flops_per_line`` (the nominal ``5 n log2 n`` algorithmic
count) is the numerator of the reported efficiency, never the cost — a
matmul FFT does MORE arithmetic than nominal; that is the point.

**Bytes per pass.** The slab is read and written once per dispatch
(``16 n`` bytes per line: split re/im float32 in and out), and every grid
step re-loads the DFT constants (matrices + twiddles) — so a small
``block`` pays the constant traffic ``lines / block`` times. Narrow
matmul operands do not shrink HBM traffic (inputs stay f32; only the
in-VMEM operand cast narrows).

**VMEM feasibility.** A grid step must hold its x/y slabs (double for
the out-of-place stages), the DFT constants, and the filter block inside
the ~16 MiB VMEM budget — the TPU analogue of the paper's 32 KiB
threadgroup-memory constraint. Infeasible candidates are cut before
ranking; the cut can never empty a candidate set that contains the
library default (tested).
"""
from __future__ import annotations

import math
from typing import Optional

from repro.kernels.fft4step import (
    MAX_FACTOR,
    RESIDENT_STAGED,
    RESIDENT_VMEM,
    SpectralSpec,
    _flops_per_line,
    default_factorization,
    resolve_precision,
)
from repro.tuning.space import (
    KernelConfig,
    Schedule,
    ScheduleProblem,
    SegmentConfig,
    SegmentShape,
    TuneKey,
    bucket_batch,
)

# Nominal device constants. Ranking, not prediction, is the contract:
# these are TPU-class magnitudes (peak matrix FLOP/s, HBM bytes/s, VMEM
# bytes) whose RATIO sets the roofline ridge; absolute wall-clock on any
# one device is calibrated away by the measured rungs that follow.
PEAK_MATMUL_FLOPS = 2.0e14      # dense f32 matrix throughput
PEAK_VPU_FLOPS = 4.0e12         # pointwise (twiddle/filter) throughput
PEAK_HBM_BYTES = 1.2e12         # HBM <-> VMEM bandwidth
VMEM_BUDGET_BYTES = 16 * 2**20  # per-grid-step on-chip footprint budget
PEAK_LINK_BYTES = 5.0e10        # per-device inter-chip (ICI-class) b/w

# Matmul-throughput multiplier per operand precision ("Range, Not
# Precision": narrow operands double matrix-unit throughput; bs16 spends
# a little of it on the block-exponent prologue/epilogue).
_PRECISION_SPEEDUP = {"f32": 1.0, "bf16": 2.0, "f16": 2.0, "bs16": 1.9}


def _factors(config: KernelConfig, n: int) -> tuple:
    return config.factors() or default_factorization(n)


def _const_bytes(factors: tuple) -> int:
    """DFT matrices + inter-stage twiddles, split re/im float32 — the
    broadcast operands every grid step re-loads."""
    b = sum(2 * 4 * f * f for f in factors)
    for i in range(len(factors) - 1):
        rest = math.prod(factors[i + 1:])
        b += 2 * 4 * factors[i] * rest
    return b


def vmem_bytes(config: KernelConfig, key: TuneKey) -> int:
    """Approximate per-grid-step VMEM footprint of one fused dispatch."""
    n = key.n
    block = config.block or 8
    slab = 2 * 4 * block * key.batch * n     # split re/im f32, one slab
    # x in + y out + one out-of-place intermediate per live stage pair
    footprint = 3 * slab
    footprint += _const_bytes(_factors(config, n))
    footprint += 2 * 4 * n                   # shared filter vector block
    if resolve_precision(config.precision).block_scaled:
        footprint += slab // 2               # f16 scaled copy of the slab
    return footprint


def structurally_feasible(config: KernelConfig, key: TuneKey) -> bool:
    """Shape legality: the config can build a kernel for ``key`` at all."""
    n = key.n
    fs = _factors(config, n)
    if math.prod(fs) != n:
        return False
    if any(f > MAX_FACTOR or f & (f - 1) for f in fs):
        return False
    block = config.block or 8
    # ops.spectral_op PADS lines up to a block multiple, so a block that
    # does not divide lines is still runnable (the pad is timed, and
    # priced, honestly); only block > lines is pure waste — the whole
    # dispatch would be mostly padding. Same rule as the legacy sweep.
    if block > key.lines and key.lines % block:
        return False
    return True


def feasible(config: KernelConfig, key: TuneKey,
             vmem_budget: int = VMEM_BUDGET_BYTES) -> bool:
    """Structural + footprint feasibility cut (never measured if False)."""
    return structurally_feasible(config, key) and \
        vmem_bytes(config, key) <= vmem_budget


def _dispatch_terms(*, n: int, lines: int, batch: int, factors: tuple,
                    karatsuba, precision, transforms: int, filtered: bool,
                    block: int) -> dict:
    """The roofline ingredients of one fused dispatch, itemized.

    This is THE cost kernel: `predicted_seconds` (flat configs), the
    schedule-graph edge weights (`segment_seconds`), and the CLI
    `--explain` breakdown all price through this one function, so a
    schedule edge and the equivalent flat config are costed by
    bit-identical arithmetic."""
    lines_total = batch * lines
    prec = resolve_precision(precision)
    matmul_rate = PEAK_MATMUL_FLOPS * _PRECISION_SPEEDUP[prec.name]

    # compute: per-stage dense-DFT matmuls at factor-dependent efficiency
    mac_flops = 6.0 if karatsuba else 8.0
    matmul = 0.0
    for f in factors:
        util = (f / MAX_FACTOR) ** 0.5
        matmul += transforms * lines_total * mac_flops * n * f / (
            matmul_rate * util)
    # twiddles (one complex multiply per element per stage boundary) and
    # the filter multiply run on the vector unit
    pointwise = transforms * (len(factors) - 1) * 6.0 * n * lines_total
    if filtered:
        pointwise += 6.0 * n * lines_total
    vpu = pointwise / PEAK_VPU_FLOPS
    compute = matmul + vpu

    # memory: slab in+out once per dispatch, constants once per grid step
    grid_steps = max(1, math.ceil(lines / block))
    bytes_moved = 2 * 2 * 4 * n * lines_total          # x and y, re+im f32
    bytes_moved += grid_steps * _const_bytes(factors)
    if filtered:
        bytes_moved += 2 * 4 * n                       # shared filter
    memory = bytes_moved / PEAK_HBM_BYTES

    return {
        "matmul_seconds": matmul,
        "vpu_seconds": vpu,
        "compute_seconds": compute,
        "bytes_moved": bytes_moved,
        "memory_seconds": memory,
        "predicted_seconds": max(compute, memory) + 0.3 * min(compute,
                                                              memory),
    }


def predicted_seconds(config: KernelConfig, key: TuneKey,
                      fwd: bool = True, inv: bool = True,
                      filtered: bool = True) -> float:
    """Roofline time estimate for one fused dispatch under ``config``.

    Relative ordering is the contract (search.py measures the top of the
    ranking); see the module docstring for the model.
    """
    terms = _dispatch_terms(
        n=key.n, lines=key.lines, batch=key.batch,
        factors=_factors(config, key.n), karatsuba=config.karatsuba,
        precision=config.precision,
        transforms=(1 if fwd else 0) + (1 if inv else 0),
        filtered=filtered, block=config.block or 8)
    return terms["predicted_seconds"]


def cost_breakdown(config: KernelConfig, key: TuneKey,
                   fwd: bool = True, inv: bool = True,
                   filtered: bool = True,
                   vmem_budget: int = VMEM_BUDGET_BYTES) -> dict:
    """The itemized cost-model verdict on one candidate — what the CLI's
    ``--explain`` prints so schedule choices are debuggable: matmul vs
    VPU vs bytes seconds, the roofline total, and both feasibility cuts."""
    terms = _dispatch_terms(
        n=key.n, lines=key.lines, batch=key.batch,
        factors=_factors(config, key.n), karatsuba=config.karatsuba,
        precision=config.precision,
        transforms=(1 if fwd else 0) + (1 if inv else 0),
        filtered=filtered, block=config.block or 8)
    vb = vmem_bytes(config, key)
    terms.update({
        "vmem_bytes": vb,
        "vmem_feasible": vb <= vmem_budget,
        "structurally_feasible": structurally_feasible(config, key),
    })
    return terms


# ---------------------------------------------------------------------------
# Megakernel (fused1) residency feasibility
# ---------------------------------------------------------------------------
#
# The single-dispatch megakernel has two execution modes and ONE decision:
# does a whole (Bb, na, nr) scene slab — plus both axes' DFT constants and
# the resident filter payloads — fit the ~16 MiB VMEM budget? If yes, the
# VMEM-resident mode realizes the paper's zero-HBM-intermediate claim; if
# not, the scratch-staged two-phase layout keeps the dispatch count at 1
# while double-buffered DMA hides the corner-turn traffic. This is the
# paper's 32 KiB threadgroup-memory cut, one tier up.

def mega_vmem_bytes(na: int, nr: int, batch_block: int = 1,
                    precision: Optional[str] = None,
                    filter_bytes: int = 0) -> int:
    """Approximate VMEM footprint of one VMEM-resident megakernel grid
    step: the split re/im slab x3 (in + out + one out-of-place stage
    intermediate), both axes' DFT constants, and the filter payloads."""
    slab = 2 * 4 * batch_block * na * nr
    footprint = 3 * slab
    footprint += _const_bytes(default_factorization(nr))
    footprint += _const_bytes(default_factorization(na))
    footprint += filter_bytes
    if resolve_precision(precision).block_scaled:
        footprint += slab // 2               # f16 scaled copy of the slab
    return footprint


def staged_vmem_bytes(na: int, nr: int, phase_block: int = 8,
                      filter_bytes: int = 0) -> int:
    """VMEM footprint of the scratch-staged two-phase layout: the
    double-buffered row and column line slabs (2 slots x re/im each, and
    potentially a FULL-filter slab alongside), plus DFT constants for
    both axes. The scene itself lives in the HBM scratch."""
    pb_r = min(phase_block, na)
    pb_c = min(phase_block, nr)
    bufs = 2 * 2 * 4 * (pb_r * nr + na * pb_c)
    bufs *= 2                                # worst case: FULL-filter slabs
    bufs += _const_bytes(default_factorization(nr))
    bufs += _const_bytes(default_factorization(na))
    return bufs + filter_bytes


def mega_residency(na: int, nr: int, batch_block: int = 1,
                   precision: Optional[str] = None, filter_bytes: int = 0,
                   vmem_budget: int = VMEM_BUDGET_BYTES) -> str:
    """The residency mode the compiler picks when none is pinned: VMEM-
    resident iff the whole slab fits the budget, else scratch-staged."""
    fits = mega_vmem_bytes(na, nr, batch_block, precision,
                           filter_bytes) <= vmem_budget
    return RESIDENT_VMEM if fits else RESIDENT_STAGED


# ---------------------------------------------------------------------------
# Schedule-graph edge weights
# ---------------------------------------------------------------------------
#
# The schedule DAG (docs/tuning.md §Schedule DAG) layers one node set per
# transform segment; an edge through layer i fixes that segment's
# factorization and complex-product algorithm, and the lane (precision,
# block / residency, phase_block, buffer_depth) is fixed per path. Edge
# weights reuse the SAME roofline terms as `predicted_seconds`
# (`_dispatch_terms`), plus a corner-turn term between segments on
# different axes — zero for a VMEM-resident slab (the turn is a logical
# index remap), HBM round-trip bytes for the scratch-staged tier, scaled
# down when double-buffered DMA overlaps the turn with compute (the
# Radix-8 Stockham two-tier observation, arXiv 2603.27569).

# fraction of the corner-turn HBM traffic left on the critical path when
# depth>=2 double-buffering overlaps DMA with the neighbor segment's DFTs
TURN_OVERLAP = 0.6


def segment_seconds(problem: ScheduleProblem, shape: SegmentShape,
                    seg: SegmentConfig, *, precision=None,
                    karatsuba=None, block: Optional[int] = None,
                    residency: Optional[str] = None,
                    phase_block: Optional[int] = None) -> float:
    """Roofline seconds for ONE schedule-DAG segment edge.

    For a staged megakernel the segment streams its lines through VMEM in
    phase_block blocks (constants re-loaded per step, slab in+out through
    the scratch); for a VMEM-resident one the slab is already on-chip, so
    only the compute terms and one constants load remain."""
    n = problem.seg_n(shape)
    lines = problem.seg_lines(shape)
    fs = seg.factors() or default_factorization(n)
    kara = seg.karatsuba if seg.karatsuba is not None else karatsuba
    transforms = (1 if shape.fwd else 0) + (1 if shape.inv else 0)
    if problem.mega and residency == RESIDENT_VMEM:
        # slab resident: no per-segment HBM slab traffic — price compute
        # plus one constants load (entry/exit slab traffic is charged
        # once per path in schedule_seconds)
        terms = _dispatch_terms(
            n=n, lines=lines, batch=problem.batch, factors=fs,
            karatsuba=kara, precision=precision, transforms=transforms,
            filtered=shape.filtered, block=lines)
        return terms["compute_seconds"] + _const_bytes(fs) / PEAK_HBM_BYTES
    eff_block = phase_block if problem.mega else block
    terms = _dispatch_terms(
        n=n, lines=lines, batch=problem.batch, factors=fs,
        karatsuba=kara, precision=precision, transforms=transforms,
        filtered=shape.filtered, block=eff_block or 8)
    return terms["predicted_seconds"]


def collective_turn_bytes(na: int, nr: int, batch: int = 1,
                          devices: int = 1, elem_bytes: int = 4,
                          precision: Optional[str] = None) -> int:
    """Per-device all_to_all wire bytes of ONE corner turn: each device
    holds a split re/im 1/P slab and keeps 1/P of it, so (P-1)/P of the
    slab crosses links (docs/distributed.md §collective bytes; halve via
    ``turn_dtype=bfloat16`` -> elem_bytes=2).

    A block-scaled ``precision`` (bs16) adds the carried per-line
    exponent vector: one f32 per line of the turned axis, all_gathered
    alongside the slab so every device can unscale its re-sharded slab
    (distributed.lower_pipeline). The turned axis is not known here, so
    the longer scene axis bounds it."""
    p = max(1, devices)
    slab = 2 * elem_bytes * na * nr * batch // p
    wire = slab * (devices - 1) // p
    if resolve_precision(precision).block_scaled:
        wire += 4 * max(na, nr) * batch * (devices - 1) // p
    return wire


def turn_seconds(problem: ScheduleProblem, *,
                 residency: Optional[str] = None,
                 buffer_depth: Optional[int] = None,
                 precision: Optional[str] = None) -> float:
    """The corner-turn edge weight between two segments on different
    axes.

    Local (devices == 1): free for a VMEM-resident slab (logical remap),
    an HBM write+read of the scene for the staged tier — overlapped with
    compute when the DMA is double-buffered (depth >= 2).

    Sharded (devices > 1): every turn is a dispatch-boundary all_to_all
    regardless of residency — each device writes its 1/P slab out, moves
    (P-1)/P of it over inter-chip links, and reads the re-sharded slab
    back. The link term dominates (PEAK_LINK_BYTES << PEAK_HBM_BYTES);
    with ``buffer_depth >= 2`` the staged megakernel's double-buffered
    DMA phases earn the same TURN_OVERLAP credit as the local tier (the
    collective for block j+1 overlaps block j's DFT matmuls)."""
    if problem.devices > 1:
        p = problem.devices
        slab = 2 * 2 * 4 * problem.na * problem.nr * problem.batch // p
        wire = collective_turn_bytes(problem.na, problem.nr,
                                     problem.batch, p,
                                     precision=precision)
        secs = slab * 2 / PEAK_HBM_BYTES + wire / PEAK_LINK_BYTES
        overlap = TURN_OVERLAP if (buffer_depth or 2) >= 2 else 1.0
        return secs * overlap
    if residency != RESIDENT_STAGED:
        return 0.0
    traffic = 2 * 2 * 4 * problem.na * problem.nr * problem.batch
    overlap = TURN_OVERLAP if (buffer_depth or 2) >= 2 else 1.0
    return traffic / PEAK_HBM_BYTES * overlap


def schedule_vmem_bytes(schedule: Schedule,
                        problem: ScheduleProblem,
                        filter_bytes: int = 0) -> int:
    """Per-grid-step VMEM footprint of a whole schedule.

    Flat problems defer to `vmem_bytes` via the flat-config view. Mega
    problems price the residency tier's slabs plus one set of DFT
    constants per DISTINCT (axis, factorization) — per-segment
    factorizations that agree share their constants, differing ones
    each pay."""
    if not problem.mega:
        key = TuneKey(kind="kernel", backend="-", device="-",
                      n=problem.nr, batch=bucket_batch(problem.batch),
                      lines=problem.na)
        return vmem_bytes(schedule.to_config(), key)
    const = 0
    seen = set()
    for i, shape in enumerate(problem.segments):
        fs = schedule.segment(i).factors() or default_factorization(
            problem.seg_n(shape))
        if (shape.axis, fs) in seen:
            continue
        seen.add((shape.axis, fs))
        const += _const_bytes(fs)
    if schedule.residency == RESIDENT_STAGED:
        pb = schedule.phase_block or 8
        pb_r = min(pb, problem.na)
        pb_c = min(pb, problem.nr)
        depth = schedule.buffer_depth or 2
        bufs = depth * 2 * 4 * (pb_r * problem.nr + problem.na * pb_c)
        bufs *= 2                        # worst case: FULL-filter slabs
        return bufs + const + filter_bytes
    # devices > 1: each device's VMEM holds a 1/P slab (the staged line
    # buffers above are NOT divided — their long axis is the transform
    # axis, which sharding never splits)
    slab = 2 * 4 * problem.batch * problem.na * problem.nr \
        // problem.devices
    footprint = 3 * slab + const + filter_bytes
    if resolve_precision(schedule.precision).block_scaled:
        footprint += slab // 2
    return footprint


def schedule_structurally_feasible(schedule: Schedule,
                                   problem: ScheduleProblem) -> bool:
    """Shape legality of every segment's factorization for its length."""
    for i, shape in enumerate(problem.segments):
        n = problem.seg_n(shape)
        fs = schedule.segment(i).factors() or default_factorization(n)
        if math.prod(fs) != n:
            return False
        if any(f > MAX_FACTOR or f & (f - 1) for f in fs):
            return False
    if not problem.mega:
        block = schedule.block or 8
        lines = problem.na
        if block > lines and lines % block:
            return False
    return True


def schedule_feasible(schedule: Schedule, problem: ScheduleProblem,
                      filter_bytes: int = 0,
                      vmem_budget: int = VMEM_BUDGET_BYTES) -> bool:
    """Structural + VMEM feasibility of a complete schedule path."""
    return schedule_structurally_feasible(schedule, problem) and \
        schedule_vmem_bytes(schedule, problem, filter_bytes) <= vmem_budget


def schedule_seconds(schedule: Schedule,
                     problem: ScheduleProblem) -> float:
    """Predicted seconds of a complete schedule: the sum of the SAME
    per-segment and per-turn edge weights the graph search accumulates
    (plus, for mega problems, the scene slab's one HBM entry/exit)."""
    total = 0.0
    for i, shape in enumerate(problem.segments):
        total += segment_seconds(
            problem, shape, schedule.segment(i),
            precision=schedule.precision, block=schedule.block,
            residency=schedule.residency,
            phase_block=schedule.phase_block)
    prev = None
    for shape in problem.segments:
        if prev is not None and prev.axis != shape.axis:
            total += turn_seconds(problem, residency=schedule.residency,
                                  buffer_depth=schedule.buffer_depth,
                                  precision=schedule.precision)
        prev = shape
    if problem.mega:
        # the scene enters and leaves HBM exactly once per dispatch —
        # 1/P of it per device when sharded
        slab_io = (2 * 2 * 4 * problem.na * problem.nr * problem.batch
                   / problem.devices)
        total += slab_io / PEAK_HBM_BYTES
    return total


# RDA-family megakernel shape (fused1 / csa_fused1 / omegak_fused1 all
# lower to an azimuth -> range -> azimuth segment chain): the canonical
# workload `sharded_preferred` prices when the caller has no plan in hand.
_MEGA_SEGMENTS_2D = (
    SegmentShape(axis=0, fwd=True, inv=False, filtered=False),
    SegmentShape(axis=1, fwd=True, inv=True, filtered=True),
    SegmentShape(axis=0, fwd=False, inv=True, filtered=True),
)


def _default_mega_schedule(na: int, nr: int, devices: int = 1,
                           precision: Optional[str] = None,
                           filter_bytes: int = 0) -> Schedule:
    """The schedule the compiler would pick unprompted: auto residency on
    the (per-device) slab, default phase_block/buffer_depth."""
    res = mega_residency(na // devices if devices > 1 else na, nr,
                         precision=precision, filter_bytes=filter_bytes)
    return Schedule(segments=(SegmentConfig(),) * len(_MEGA_SEGMENTS_2D),
                    precision=precision, residency=res,
                    phase_block=8, buffer_depth=2)


def sharded_preferred(na: int, nr: int, batch: int = 1, devices: int = 1,
                      precision: Optional[str] = None,
                      filter_bytes: int = 0) -> bool:
    """Whether the roofline prefers the P-device sharded megakernel over
    ONE local dispatch for this scene — the service's big-scene routing
    predicate (`LocalBackend.execute_streamed`).

    Prices the canonical azimuth->range->azimuth megakernel both ways
    with `schedule_seconds`: locally the corner turns are free (VMEM) or
    HBM-priced (staged); sharded they become all_to_all collectives
    (`collective_turn_bytes` over PEAK_LINK_BYTES) but every compute and
    slab-I/O term divides by P. Scenes whose whole slab fits the local
    VMEM budget never shard — the local single-dispatch megakernel route
    already serves them with zero HBM intermediates, and a collective
    would only add latency; a staged (over-budget) scene shards whenever
    the roofline says P slabs + wire beat one staged device."""
    if devices <= 1 or na % devices or nr % devices:
        return False
    if mega_residency(na, nr, precision=precision,
                      filter_bytes=filter_bytes) == RESIDENT_VMEM:
        return False
    local = ScheduleProblem.mega_2d(na, nr, _MEGA_SEGMENTS_2D, batch=batch)
    shard = ScheduleProblem.mega_2d(na, nr, _MEGA_SEGMENTS_2D, batch=batch,
                                    devices=devices)
    local_s = schedule_seconds(
        _default_mega_schedule(na, nr, 1, precision, filter_bytes), local)
    shard_s = schedule_seconds(
        _default_mega_schedule(na, nr, devices, precision, filter_bytes),
        shard)
    return shard_s < local_s


def serve_batch_seconds(na: int, nr: int, batch: int = 1,
                        precision: Optional[str] = None,
                        streamed: bool = False) -> float:
    """Predicted seconds of ONE served micro-batch — the worker pool's
    lane-routing weight (`repro.service.workers.WorkerPool.route`).

    Prices the canonical azimuth->range->azimuth megakernel (the shape
    every served RDA-family variant lowers to) with `schedule_seconds`,
    at the residency the compiler would pick for the scene — pinned to
    the scratch-staged tier for ``streamed`` keys, whose scenes are over
    the device budget by definition. Relative ordering across keys is
    the contract, exactly as for the kernel search: a 1024² batch must
    weigh a lane's backlog more than a 256² one, by roughly the roofline
    ratio."""
    problem = ScheduleProblem.mega_2d(na, nr, _MEGA_SEGMENTS_2D,
                                      batch=max(1, batch))
    res = (RESIDENT_STAGED if streamed
           else mega_residency(na, nr, precision=precision))
    sched = Schedule(
        segments=(SegmentConfig(),) * len(_MEGA_SEGMENTS_2D),
        precision=precision, residency=res, phase_block=8, buffer_depth=2)
    return schedule_seconds(sched, problem)


def nominal_flops(key: TuneKey, fwd: bool = True, inv: bool = True,
                  filtered: bool = True) -> float:
    """The algorithmic 5 n log2 n count (fft4step._flops_per_line) for the
    whole slab — the numerator of reported efficiency, not the cost."""
    spec = SpectralSpec(
        n=key.n, fwd=fwd, inv=inv,
        filter_mode="shared" if filtered else "none")
    return _flops_per_line(spec) * key.batch * key.lines


def rank(configs, key: TuneKey, vmem_budget: int = VMEM_BUDGET_BYTES,
         **kw) -> list:
    """Feasible configs sorted by predicted cost, cheapest first.

    The VMEM cut must never exclude EVERY candidate (a problem so large
    that no block fits the budget still has to run — smallest footprint
    first, and the measured rungs drop anything the kernel build itself
    rejects): when it would, the cut falls back to structural feasibility
    with the footprint folded into the ordering."""
    feas = [c for c in configs if feasible(c, key, vmem_budget)]
    if feas:
        return sorted(feas, key=lambda c: predicted_seconds(c, key, **kw))
    feas = [c for c in configs if structurally_feasible(c, key)]
    return sorted(feas, key=lambda c: (vmem_bytes(c, key),
                                       predicted_seconds(c, key, **kw)))
