"""Cost-model-guided measured search with successive-halving early stopping.

Replaces the exhaustive ``itertools.product`` sweep of the old
benchmarks/autotune.py: candidates are feasibility-cut and RANKED by the
analytic roofline model (cost.py) first, only the top of the ranking is
ever timed, and the timed set shrinks by half per rung while the per-rung
measurement budget grows — so the search reaches the same winner as the
exhaustive sweep while timing strictly fewer candidates ("Shortest-Path
FFT", arXiv 2604.04311: guided beats enumeration).

Two entry points:

* :func:`measured_search` — the generic engine: any candidate list, any
  measure callable. The serving warm sweep (service/backends.py) runs its
  (block, col_block) pipeline candidates through this.
* :func:`search_kernel` — the kernel tuner: builds the candidate space
  for a :class:`TuneKey`, applies the cost ranking, the SNR gate (non-f32
  precisions must pass ``repro.tuning.quality`` at <= ``snr_gate_db``),
  times the fused fwd+inv rows dispatch, and persists the winner to the
  shared cache.

Plus the cache-only lookups the plan compiler uses at compile time
(:func:`cached_config`, never sweeps) and :func:`best_config`
(cached-or-tuned, the CLI/bench entry).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.tuning import cache as cachelib
from repro.tuning import cost as costlib
from repro.tuning.space import KernelConfig, TuneKey, candidates

DEFAULT_SNR_GATE_DB = 0.1


def _timeit(fn, warmup: int = 1, iters: int = 2) -> float:
    """Median wall seconds per call (blocks on jax arrays)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


@dataclasses.dataclass
class SearchResult:
    """Outcome + audit trail of one guided search."""

    key: TuneKey
    config: KernelConfig              # the winner
    seconds: float                    # its best measured time
    measured: int                     # distinct candidates actually timed
    space: int                        # full candidate-space size
    predicted_rank: Optional[int]     # winner's rank in the cost ordering
    trace: list = dataclasses.field(default_factory=list)
    # trace rows: (config, seconds | None if infeasible at measure time)


def measured_search(cands: Sequence, measure: Callable,
                    order: Optional[Callable] = None,
                    max_measure: Optional[int] = None,
                    rungs: Sequence[int] = (1, 3),
                    log: Optional[Callable] = None):
    """Successive-halving over ``cands``.

    measure(candidate, iters) -> wall seconds (may raise: the candidate is
    dropped as infeasible). ``order`` ranks candidates cheapest-first
    without running them (the cost model); ``max_measure`` caps how many
    enter rung 0. Each rung times the survivors with ``rungs[i]``
    iterations and keeps the fastest half. Returns
    (best_candidate, best_seconds, trace) with trace = [(cand, secs|None)].
    """
    pool = list(cands)
    if order is not None:
        pool = order(pool)
    if max_measure is not None:
        pool = pool[:max(1, max_measure)]
    trace: list = []
    timed: list = []                          # (seconds, index, cand)
    for r, iters in enumerate(rungs):
        survivors = pool if r == 0 else [c for _, _, c in timed]
        timed = []
        for i, cand in enumerate(survivors):
            try:
                t = measure(cand, iters)
            except Exception:
                if r == 0:
                    trace.append((cand, None))
                continue
            trace.append((cand, t))
            timed.append((t, i, cand))
            if log is not None:
                log(cand, t, r)
        if not timed:
            raise RuntimeError("no feasible candidate survived measurement")
        timed.sort(key=lambda x: x[0])
        if r < len(rungs) - 1:
            timed = timed[:max(1, math.ceil(len(timed) / 2))]
    best_t, _, best = timed[0]
    return best, best_t, trace


def _default_gate(precision: str) -> float:
    from repro.tuning import quality          # deferred: pulls in core.sar
    return quality.precision_snr_deviation(precision)


def kernel_measure(key: TuneKey, seed: int = 0) -> Callable:
    """measure(config, iters) for the fused fwd+inv rows dispatch — the
    same workload the old exhaustive autotuner timed."""
    from repro.kernels import ops             # deferred: keeps import light
    rng = np.random.default_rng(seed)
    shape = (key.batch, key.lines, key.n)
    xr = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    xi = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    hr = jnp.asarray(rng.standard_normal(key.n), jnp.float32)
    hi = jnp.asarray(rng.standard_normal(key.n), jnp.float32)

    def measure(config: KernelConfig, iters: int) -> float:
        kw = config.spectral_kwargs()
        return _timeit(lambda: ops.fused_fft_mult_ifft_rows(
            xr, xi, hr, hi, **kw), warmup=1, iters=iters)

    return measure


def search_kernel(key: TuneKey, *,
                  precisions: Sequence[str] = ("f32",),
                  blocks: Sequence[int] = (4, 8, 16),
                  snr_gate_db: float = DEFAULT_SNR_GATE_DB,
                  gate: Optional[Callable] = None,
                  measure: Optional[Callable] = None,
                  measure_fraction: float = 0.6,
                  rungs: Sequence[int] = (1, 2),
                  cache: Optional[cachelib.TuneCache] = None,
                  persist: bool = True,
                  log: Optional[Callable] = None) -> SearchResult:
    """Guided search for the best kernel config at ``key``; persists the
    winner to the shared cache (so plan compiles and serving warms on any
    later process reuse it).

    ``measure_fraction`` bounds the measured set to that fraction of the
    feasible space (at least 3): the cost model decides WHICH fraction.
    The 0.6 default leaves headroom for measurement noise around
    near-tied configs while still timing strictly fewer candidates than
    the exhaustive sweep. Non-f32 precisions are admitted only if
    ``gate`` (default: the measured point-target SNR deviation) stays
    <= ``snr_gate_db``.
    """
    space = candidates(key.n, blocks=blocks, precisions=tuple(precisions))
    space_size = len(space)

    admitted: dict = {}
    pool = []
    for c in space:
        p = c.precision or "f32"
        if p != "f32":
            if p not in admitted:
                dev = (gate or _default_gate)(p)
                admitted[p] = dev <= snr_gate_db
                if log is not None:
                    log(f"gate_{p}", dev, admitted[p])
            if not admitted[p]:
                continue
        pool.append(c)

    ranked = costlib.rank(pool, key)
    if not ranked:
        raise RuntimeError(f"feasibility cut emptied the space for {key}")
    max_measure = max(3, math.ceil(len(ranked) * measure_fraction))
    max_measure = min(max_measure, len(ranked))

    measure = measure or kernel_measure(key)
    best, best_t, trace = measured_search(
        ranked, measure, max_measure=max_measure, rungs=rungs,
        log=(lambda c, t, r: log(c, t, r)) if log is not None else None)

    measured = len({c for c, t in trace if t is not None})
    result = SearchResult(
        key=key, config=best, seconds=best_t, measured=measured,
        space=space_size, predicted_rank=ranked.index(best), trace=trace)
    if persist:
        (cache or cachelib.get_cache()).put(key, best, seconds=best_t,
                                            source="search")
    return result


# ---------------------------------------------------------------------------
# Lookups — the compile-time path (never sweeps) and the cached-or-tuned path
# ---------------------------------------------------------------------------

def cached_config(n: int, batch: int = 1, lines: int = 16,
                  cache: Optional[cachelib.TuneCache] = None
                  ) -> Optional[KernelConfig]:
    """Best-known kernel config for (n, batch-bucket) on THIS device, or
    None. Pure cache lookup — compile time must never trigger a sweep."""
    try:
        key = TuneKey.kernel(n, batch, lines=lines)
        return (cache or cachelib.get_cache()).get(key)
    except Exception:
        return None


def best_config(n: int, batch: int = 1, lines: int = 16,
                tune_missing: bool = True,
                cache: Optional[cachelib.TuneCache] = None,
                **search_kw) -> KernelConfig:
    """Cached best config for (n, batch); runs the guided search on a
    miss (``tune_missing=False`` falls back to library defaults)."""
    key = TuneKey.kernel(n, batch, lines=lines)
    hit = (cache or cachelib.get_cache()).get(key)
    if hit is not None:
        return hit
    if tune_missing:
        return search_kernel(key, cache=cache, **search_kw).config
    return KernelConfig(block=8)
