"""Cost-model-guided measured search with successive-halving early stopping.

Replaces the exhaustive ``itertools.product`` sweep of the old
benchmarks/autotune.py: candidates are feasibility-cut and RANKED by the
analytic roofline model (cost.py) first, only the top of the ranking is
ever timed, and the timed set shrinks by half per rung while the per-rung
measurement budget grows — so the search reaches the same winner as the
exhaustive sweep while timing strictly fewer candidates ("Shortest-Path
FFT", arXiv 2604.04311: guided beats enumeration).

Three entry points:

* :func:`measured_search` — the generic engine: any candidate list, any
  measure callable. The serving warm sweep (service/backends.py) runs its
  (block, col_block) pipeline candidates through this.
* :func:`search_kernel` — the kernel tuner: builds the schedule graph
  for a :class:`TuneKey`, solves it for the ranked frontier
  (:func:`schedule_frontier`), applies the SNR gate (non-f32 precisions
  must pass ``repro.tuning.quality`` at <= ``snr_gate_db``), times the
  fused fwd+inv rows dispatch, and persists the winner to the shared
  cache.
* :func:`search_schedule` — the megakernel schedule tuner: solves a
  multi-segment :class:`~repro.tuning.space.ScheduleProblem` (where
  per-segment factorizations make the space exponential in the segment
  count — exactly where shortest-path enumeration beats the product
  sweep), measures the top of the frontier, persists the winning
  Schedule.

Plus the cache-only lookups the plan compiler uses at compile time
(:func:`cached_config` / :func:`cached_schedule`, never sweep) and
:func:`best_config` (cached-or-tuned, the CLI/bench entry).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import time
from typing import Callable, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.tuning import cache as cachelib
from repro.tuning import cost as costlib
from repro.tuning.space import (
    KernelConfig,
    Schedule,
    ScheduleProblem,
    SegmentConfig,
    TuneKey,
    candidates,
    factorizations,
)

DEFAULT_SNR_GATE_DB = 0.1

# Timing-jitter floor: every measured rung times a candidate at least
# this many times and takes the median, regardless of how few iterations
# the rung schedule asks for — a 1-iteration rung 0 on a noisy host
# otherwise crowns whichever candidate got lucky.
TIMING_REPEATS_FLOOR = 3


def _timeit(fn, warmup: int = 1, iters: int = 2,
            min_repeats: Optional[int] = None) -> float:
    """Median wall seconds per call (blocks on jax arrays).

    Runs ``max(iters, min_repeats)`` timed repeats (the floor defaults to
    :data:`TIMING_REPEATS_FLOOR`) so a low-iteration successive-halving
    rung still medians away scheduler hiccups instead of ranking on a
    single sample."""
    floor = TIMING_REPEATS_FLOOR if min_repeats is None else min_repeats
    repeats = max(int(iters), int(floor), 1)
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


@dataclasses.dataclass
class SearchResult:
    """Outcome + audit trail of one guided search."""

    key: TuneKey
    config: KernelConfig              # the winner (flat view)
    seconds: float                    # its best measured time
    measured: int                     # distinct candidates actually timed
    space: int                        # full candidate-space size
    predicted_rank: Optional[int]     # winner's rank in the cost ordering
    trace: list = dataclasses.field(default_factory=list)
    # trace rows: (config, seconds | None if infeasible at measure time)
    schedule: Optional[Schedule] = None   # the winner as a Schedule


def measured_search(cands: Sequence, measure: Callable,
                    order: Optional[Callable] = None,
                    max_measure: Optional[int] = None,
                    rungs: Sequence[int] = (1, 3),
                    log: Optional[Callable] = None):
    """Successive-halving over ``cands``.

    measure(candidate, iters) -> wall seconds (may raise: the candidate is
    dropped as infeasible). ``order`` ranks candidates cheapest-first
    without running them (the cost model); ``max_measure`` caps how many
    enter rung 0. Each rung times the survivors with ``rungs[i]``
    iterations and keeps the fastest half. Returns
    (best_candidate, best_seconds, trace) with trace = [(cand, secs|None)].
    """
    pool = list(cands)
    if order is not None:
        pool = order(pool)
    if max_measure is not None:
        pool = pool[:max(1, max_measure)]
    trace: list = []
    timed: list = []                          # (seconds, index, cand)
    for r, iters in enumerate(rungs):
        survivors = pool if r == 0 else [c for _, _, c in timed]
        timed = []
        for i, cand in enumerate(survivors):
            try:
                t = measure(cand, iters)
            except Exception:
                if r == 0:
                    trace.append((cand, None))
                continue
            trace.append((cand, t))
            timed.append((t, i, cand))
            if log is not None:
                log(cand, t, r)
        if not timed:
            raise RuntimeError("no feasible candidate survived measurement")
        timed.sort(key=lambda x: x[0])
        if r < len(rungs) - 1:
            timed = timed[:max(1, math.ceil(len(timed) / 2))]
    best_t, _, best = timed[0]
    return best, best_t, trace


# ---------------------------------------------------------------------------
# Schedule-graph solver
# ---------------------------------------------------------------------------
#
# The schedule space is a layered DAG: layer i's nodes are "segments 0..i
# scheduled", an edge through layer i fixes segment i's factorization and
# complex-product algorithm, and every path additionally commits to one
# LANE — the dispatch-global decisions (precision and line block for a
# flat kernel; precision, residency tier, phase block, and DMA buffer
# depth for a megakernel). Edge weights come from cost.segment_seconds /
# cost.turn_seconds (the same roofline terms as cost.predicted_seconds),
# so a uniform path and the equivalent flat KernelConfig are priced by
# bit-identical arithmetic. Uniform-cost (Dijkstra-style) expansion over
# one shared heap emits COMPLETE paths in increasing predicted cost —
# k-shortest enumeration, lazy, so a 6-segment megakernel with 7
# factorization choices per segment never materializes its ~10^5-path
# product space ("Shortest-Path FFT", arXiv 2604.04311).

# backstop against pathological exploration when every path is VMEM-
# infeasible and the caller asked for a large k
_FRONTIER_POP_BUDGET = 500_000


def _lane_schedules(problem: ScheduleProblem, blocks, precisions,
                    residencies, phase_blocks, buffer_depths) -> list:
    """The dispatch-global decision lanes of the schedule DAG."""
    lanes = []
    if problem.mega:
        if residencies is None:
            residencies = (costlib.RESIDENT_VMEM, costlib.RESIDENT_STAGED)
        for prec in precisions:
            for res in residencies:
                if res == costlib.RESIDENT_STAGED:
                    for pb in phase_blocks:
                        for bd in buffer_depths:
                            lanes.append(dict(
                                precision=prec, residency=res,
                                phase_block=pb, buffer_depth=bd))
                else:
                    lanes.append(dict(precision=prec, residency=res))
    else:
        for prec in precisions:
            for blk in blocks:
                lanes.append(dict(precision=prec, block=blk))
    return lanes


def schedule_frontier(problem: ScheduleProblem, *,
                      k: Optional[int] = None,
                      blocks: Sequence[int] = (4, 8, 16),
                      precisions: Sequence[str] = ("f32",),
                      residencies: Optional[Sequence[str]] = None,
                      phase_blocks: Sequence[int] = (8,),
                      buffer_depths: Sequence[int] = (2,),
                      filter_bytes: int = 0,
                      vmem_budget: int = costlib.VMEM_BUDGET_BYTES
                      ) -> list:
    """Solve the schedule DAG: the ``k`` cheapest complete schedules in
    increasing predicted cost (``k=None`` enumerates the whole space —
    fine for flat kernel problems, exponential for multi-segment mega
    problems, so pass ``k`` there).

    VMEM-infeasible paths are cut like cost.rank's feasibility cut, with
    the same never-empty guarantee: if NO complete path fits the budget,
    the structurally-feasible paths are returned ordered by (footprint,
    predicted) instead."""
    segs = problem.segments
    if not segs:
        raise ValueError("ScheduleProblem has no segments to schedule")
    lanes = _lane_schedules(problem, blocks, precisions, residencies,
                            phase_blocks, buffer_depths)

    # per-(lane, layer) edge sets, weighted once and reused
    edge_cache: dict = {}

    def edges(lane_idx: int, depth: int):
        hit = edge_cache.get((lane_idx, depth))
        if hit is not None:
            return hit
        lane = lanes[lane_idx]
        shape = segs[depth]
        out = []
        for fs in factorizations(problem.seg_n(shape)):
            for kara in (False, True):
                seg = SegmentConfig(
                    n1=fs[0], n2=fs[1],
                    n3=fs[2] if len(fs) > 2 else None, karatsuba=kara)
                w = costlib.segment_seconds(
                    problem, shape, seg, precision=lane.get("precision"),
                    block=lane.get("block"),
                    residency=lane.get("residency"),
                    phase_block=lane.get("phase_block"))
                out.append((w, seg))
        edge_cache[(lane_idx, depth)] = out
        return out

    heap: list = []
    counter = itertools.count()       # insertion-order tie break
    for i, lane in enumerate(lanes):
        # lane-level fixed weight: corner turns + (mega) slab entry/exit
        base = problem.turns() * costlib.turn_seconds(
            problem, residency=lane.get("residency"),
            buffer_depth=lane.get("buffer_depth"),
            precision=lane.get("precision"))
        if problem.mega:
            # per-device slab entry/exit (devices == 1 for local problems;
            # a sharded problem's corner-turn collectives are priced in
            # costlib.turn_seconds via problem.devices)
            base += (2 * 2 * 4 * problem.na * problem.nr * problem.batch
                     / problem.devices / costlib.PEAK_HBM_BYTES)
        heapq.heappush(heap, (base, next(counter), i, ()))

    feasible: list = []
    over_budget: list = []            # (vmem_bytes, cost, schedule)
    pops = 0
    while heap and (k is None or len(feasible) < k) \
            and pops < _FRONTIER_POP_BUDGET:
        pops += 1
        cost_so_far, _, lane_idx, chosen = heapq.heappop(heap)
        if len(chosen) == len(segs):
            sched = Schedule(segments=chosen, **lanes[lane_idx])
            if costlib.schedule_feasible(sched, problem, filter_bytes,
                                         vmem_budget):
                feasible.append(sched)
            elif costlib.schedule_structurally_feasible(sched, problem):
                over_budget.append((
                    costlib.schedule_vmem_bytes(sched, problem,
                                                filter_bytes),
                    cost_so_far, sched))
            continue
        for w, seg in edges(lane_idx, len(chosen)):
            heapq.heappush(heap, (cost_so_far + w, next(counter),
                                  lane_idx, chosen + (seg,)))
    if feasible:
        return feasible               # popped in increasing cost already
    over_budget.sort(key=lambda t: (t[0], t[1]))
    out = [s for _, _, s in over_budget]
    return out[:k] if k is not None else out


def _default_gate(precision: str) -> float:
    from repro.tuning import quality          # deferred: pulls in core.sar
    return quality.precision_snr_deviation(precision)


def kernel_measure(key: TuneKey, seed: int = 0) -> Callable:
    """measure(config, iters) for the fused fwd+inv rows dispatch — the
    same workload the old exhaustive autotuner timed."""
    from repro.kernels import ops             # deferred: keeps import light
    rng = np.random.default_rng(seed)
    shape = (key.batch, key.lines, key.n)
    xr = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    xi = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    hr = jnp.asarray(rng.standard_normal(key.n), jnp.float32)
    hi = jnp.asarray(rng.standard_normal(key.n), jnp.float32)

    def measure(config: KernelConfig, iters: int) -> float:
        kw = config.spectral_kwargs()
        return _timeit(lambda: ops.fused_fft_mult_ifft_rows(
            xr, xi, hr, hi, **kw), warmup=1, iters=iters)

    return measure


def search_kernel(key: TuneKey, *,
                  precisions: Sequence[str] = ("f32",),
                  blocks: Sequence[int] = (4, 8, 16),
                  snr_gate_db: float = DEFAULT_SNR_GATE_DB,
                  gate: Optional[Callable] = None,
                  measure: Optional[Callable] = None,
                  measure_fraction: float = 0.6,
                  rungs: Sequence[int] = (1, 2),
                  cache: Optional[cachelib.TuneCache] = None,
                  persist: bool = True,
                  log: Optional[Callable] = None) -> SearchResult:
    """Guided search for the best kernel config at ``key``; persists the
    winner to the shared cache (so plan compiles and serving warms on any
    later process reuse it).

    ``measure_fraction`` bounds the measured set to that fraction of the
    feasible space (at least 3): the cost model decides WHICH fraction.
    The 0.6 default leaves headroom for measurement noise around
    near-tied configs while still timing strictly fewer candidates than
    the exhaustive sweep. Non-f32 precisions are admitted only if
    ``gate`` (default: the measured point-target SNR deviation) stays
    <= ``snr_gate_db``.
    """
    space = candidates(key.n, blocks=blocks, precisions=tuple(precisions))
    space_size = len(space)

    admitted: dict = {}
    pool = []
    for c in space:
        p = c.precision or "f32"
        if p != "f32":
            if p not in admitted:
                dev = (gate or _default_gate)(p)
                admitted[p] = dev <= snr_gate_db
                if log is not None:
                    log(f"gate_{p}", dev, admitted[p])
            if not admitted[p]:
                continue
        pool.append(c)

    # Solve the (degenerate, one-segment) schedule DAG for this key: the
    # frontier's flat-config views are the schedulable subset of the
    # product space. Keeping the pool in candidates() order and ranking
    # through cost.rank preserves the legacy ordering bit-for-bit — the
    # graph search strictly generalizes the flat sweep, it never times
    # more than it.
    problem = ScheduleProblem.kernel(key.n, batch=key.batch,
                                     lines=key.lines)
    gated_precisions = tuple(
        p for p in dict.fromkeys(c.precision or "f32" for c in pool))
    frontier = schedule_frontier(problem, blocks=tuple(blocks),
                                 precisions=gated_precisions or ("f32",))
    allowed = {s.to_config() for s in frontier}
    pool = [c for c in pool if c in allowed]

    ranked = costlib.rank(pool, key)
    if not ranked:
        raise RuntimeError(f"feasibility cut emptied the space for {key}")
    max_measure = max(3, math.ceil(len(ranked) * measure_fraction))
    max_measure = min(max_measure, len(ranked))

    measure = measure or kernel_measure(key)
    best, best_t, trace = measured_search(
        ranked, measure, max_measure=max_measure, rungs=rungs,
        log=(lambda c, t, r: log(c, t, r)) if log is not None else None)

    measured = len({c for c, t in trace if t is not None})
    result = SearchResult(
        key=key, config=best, seconds=best_t, measured=measured,
        space=space_size, predicted_rank=ranked.index(best), trace=trace,
        schedule=Schedule.from_config(best))
    if persist:
        (cache or cachelib.get_cache()).put(key, best, seconds=best_t,
                                            source="search")
    return result


# ---------------------------------------------------------------------------
# Megakernel schedule search
# ---------------------------------------------------------------------------

def mega_measure(problem: ScheduleProblem, seed: int = 0) -> Callable:
    """measure(schedule, iters) for a cross-axis megakernel problem:
    times ops.mega_spectral_op with the schedule's per-segment
    factorizations/karatsuba carried in extended segment tuples."""
    from repro.kernels import ops             # deferred: keeps import light
    rng = np.random.default_rng(seed)
    shape = (problem.batch, problem.na, problem.nr)
    xr = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    xi = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    filters = []
    modes = []
    for s in problem.segments:
        modes.append("shared" if s.filtered else "none")
        if s.filtered:
            n = problem.seg_n(s)
            filters.append(jnp.asarray(rng.standard_normal(n), jnp.float32))
            filters.append(jnp.asarray(rng.standard_normal(n), jnp.float32))

    def measure(schedule: Schedule, iters: int) -> float:
        segments = tuple(
            (s.axis, s.fwd, s.inv, modes[i],
             schedule.segment(i).n1, schedule.segment(i).n2,
             schedule.segment(i).n3, schedule.segment(i).karatsuba)
            for i, s in enumerate(problem.segments))
        kw = dict(segments=segments)
        if schedule.residency is not None:
            kw["residency"] = schedule.residency
        if schedule.phase_block is not None:
            kw["phase_block"] = schedule.phase_block
        if schedule.buffer_depth is not None:
            kw["buffer_depth"] = schedule.buffer_depth
        if schedule.precision is not None:
            kw["precision"] = schedule.precision
        return _timeit(lambda: ops.mega_spectral_op(xr, xi, *filters, **kw),
                       warmup=1, iters=iters)

    return measure


def search_schedule(problem: ScheduleProblem, key: Optional[TuneKey] = None,
                    *, k: int = 8,
                    measure: Optional[Callable] = None,
                    rungs: Sequence[int] = (1, 2),
                    cache: Optional[cachelib.TuneCache] = None,
                    persist: bool = True,
                    log: Optional[Callable] = None,
                    **frontier_kw) -> SearchResult:
    """Graph-guided schedule search for a multi-segment problem: solve
    the DAG for the ``k`` cheapest schedules, refine them through the
    same successive-halving engine the flat tuner uses, persist the
    winning Schedule (schema-2 cache) under ``key``.

    This is the search the flat ``candidates()`` sweep cannot express:
    the frontier's paths may give every segment its own factorization and
    complex-product algorithm."""
    frontier = schedule_frontier(problem, k=k, **frontier_kw)
    if not frontier:
        raise RuntimeError(
            f"schedule graph produced no feasible path for {problem}")
    measure = measure or mega_measure(problem)
    best, best_t, trace = measured_search(
        frontier, measure, rungs=rungs,
        log=(lambda c, t, r: log(c, t, r)) if log is not None else None)
    measured = len({s for s, t in trace if t is not None})
    result = SearchResult(
        key=key, config=best.to_config(), seconds=best_t,
        measured=measured, space=len(frontier),
        predicted_rank=frontier.index(best), trace=trace, schedule=best)
    if persist and key is not None:
        (cache or cachelib.get_cache()).put_schedule(
            key, best, seconds=best_t, source="search")
    return result


# ---------------------------------------------------------------------------
# Lookups — the compile-time path (never sweeps) and the cached-or-tuned path
# ---------------------------------------------------------------------------

def cached_config(n: int, batch: int = 1, lines: int = 16,
                  cache: Optional[cachelib.TuneCache] = None
                  ) -> Optional[KernelConfig]:
    """Best-known kernel config for (n, batch-bucket) on THIS device, or
    None. Pure cache lookup — compile time must never trigger a sweep."""
    try:
        key = TuneKey.kernel(n, batch, lines=lines)
        return (cache or cachelib.get_cache()).get(key)
    except Exception:
        return None


def cached_schedule(n: int, batch: int = 1, lines: int = 16,
                    cache: Optional[cachelib.TuneCache] = None
                    ) -> Optional[Schedule]:
    """Best-known Schedule for (n, batch-bucket) on THIS device, or None.
    A flat (schema-1-migrated) entry resolves as its degenerate
    one-segment schedule — no re-search. Pure lookup, like
    :func:`cached_config`."""
    try:
        key = TuneKey.kernel(n, batch, lines=lines)
        return (cache or cachelib.get_cache()).get_schedule(key)
    except Exception:
        return None


def best_config(n: int, batch: int = 1, lines: int = 16,
                tune_missing: bool = True,
                cache: Optional[cachelib.TuneCache] = None,
                **search_kw) -> KernelConfig:
    """Cached best config for (n, batch); runs the guided search on a
    miss (``tune_missing=False`` falls back to library defaults)."""
    key = TuneKey.kernel(n, batch, lines=lines)
    hit = (cache or cachelib.get_cache()).get(key)
    if hit is not None:
        return hit
    if tune_missing:
        return search_kernel(key, cache=cache, **search_kw).config
    return KernelConfig(block=8)
