from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    cosine_schedule,
    global_norm,
    init,
    make_train_step,
    update,
)
from repro.optim import compress  # noqa: F401
