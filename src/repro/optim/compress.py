"""Int8 gradient compression with error feedback for the DP all-reduce.

On a 1000+-node fleet the data-parallel gradient all-reduce is the dominant
cross-pod collective; 4x compression cuts it to int8 with per-tensor scales.
Error feedback (Seide et al.; Karimireddy et al.) accumulates the
quantization residual locally and re-injects it next step, preserving
convergence (the residual never escapes, it is only delayed).

`compressed_psum` is used inside a shard_map over the DP axes; composition
with tensor-parallel einsum collectives is via auto axes (the model axis
stays un-mapped). tests/test_compress.py checks the error-feedback
convergence property.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8: returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, error, axis_name):
    """All-reduce-mean `grads` in int8 with error feedback.

    grads/error: pytrees of f32 local gradients / residuals.
    Returns (mean_grads f32, new_error). Must run inside shard_map with
    `axis_name` mapped over the DP axes.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = quantize_int8(x)
        deq = dequantize_int8(q, scale)
        new_e = x - deq
        # int8 payload summed as f32 after local dequant models the
        # compressed wire format (each hop carries int8 + one f32 scale)
        total = jax.lax.psum(deq, axis_name)
        return total / n, new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree.unflatten(tree, [o[0] for o in out])
    new_err = jax.tree.unflatten(tree, [o[1] for o in out])
    return mean, new_err


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_bytes(params) -> int:
    """Wire bytes per all-reduce hop with int8 + per-tensor scale."""
    return sum(p.size + 4 for p in jax.tree.leaves(params))
