"""AdamW with global-norm clipping, cosine schedule, grad accumulation.

States are pytrees mirroring params, so whatever sharding the launcher puts
on the parameters applies verbatim to mu/nu (ZeRO-style: with FSDP'd params
the optimizer states are sharded identically for free).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def cosine_schedule(cfg: AdamWConfig) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = cfg.lr_peak * jnp.minimum(1.0, step / max(cfg.warmup_steps, 1))
        t = jnp.clip((step - cfg.warmup_steps) /
                     max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < cfg.warmup_steps, warm, cos)
    return lr


def init(params) -> dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = cosine_schedule(cfg)(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / b1c, v / b2c
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * step_).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, stats


def make_train_step(loss_fn, cfg: AdamWConfig, accum_steps: int = 1):
    """Builds train_step(params, opt_state, batch) -> (params, state, stats).

    accum_steps > 1: the global batch is split along axis 0 into microbatches
    scanned sequentially with gradient accumulation (the standard
    memory/throughput trade at large batch)."""

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(_, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return None, (l, g)
            mbs = jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]), batch)
            _, (losses, grads) = jax.lax.scan(micro, None, mbs)
            loss = losses.mean()
            grads = jax.tree.map(lambda g: g.mean(0), grads)
        new_params, new_state, stats = update(params, grads, opt_state, cfg)
        stats = dict(stats, loss=loss)
        return new_params, new_state, stats

    return train_step
