"""Sharded npz checkpoints: atomic, keep-k, async, reshard-on-restore.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json        tree structure, shapes, dtypes, leaf->file map
        shard_000.npz        leaf arrays (host numpy), chunked ~512 MB
    <dir>/step_000123.tmp_*  staging dir, os.rename'd into place (atomic on
                             POSIX within a filesystem)

Restore takes an optional `shardings` pytree: leaves are device_put with the
NEW sharding, so a checkpoint written on one mesh restores onto a different
mesh (elastic restart after losing nodes). Parameters are stored unsharded
host-side (gathered), which is the simple-and-correct baseline for this
container; the multi-host variant writes per-host shards with the same
manifest format (documented in DESIGN.md §fault-tolerance).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np

_SHARD_BYTES = 512 * 1024 * 1024


def _paths_and_leaves(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> str:
        """Write checkpoint for `step`. blocking=False returns immediately and
        writes on a background thread (training continues)."""
        keys, leaves, _ = _paths_and_leaves(tree)
        host = [np.asarray(x) for x in leaves]  # device->host copy now
        if blocking:
            return self._write(step, keys, host)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, keys, host), daemon=True)
        self._thread.start()
        return self._final_dir(step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _final_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def _write(self, step: int, keys, arrays) -> str:
        final = self._final_dir(step)
        tmp = tempfile.mkdtemp(prefix=f"step_{step:09d}.tmp_", dir=self.dir)
        try:
            manifest = {"step": step, "leaves": {}, "shards": []}
            shard, shard_bytes, shard_idx = {}, 0, 0

            def flush():
                nonlocal shard, shard_bytes, shard_idx
                if not shard:
                    return
                fname = f"shard_{shard_idx:03d}.npz"
                np.savez(os.path.join(tmp, fname), **shard)
                manifest["shards"].append(fname)
                shard, shard_bytes, shard_idx = {}, 0, shard_idx + 1

            for i, (k, a) in enumerate(zip(keys, arrays)):
                skey = f"a{i:06d}"
                manifest["leaves"][k] = {
                    "shard": f"shard_{shard_idx:03d}.npz", "key": skey,
                    "shape": list(a.shape), "dtype": str(a.dtype)}
                shard[skey] = a
                shard_bytes += a.nbytes
                if shard_bytes >= _SHARD_BYTES:
                    flush()
            flush()
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._final_dir(s), ignore_errors=True)

    # ---- restore --------------------------------------------------------------
    def all_steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple:
        """Restore into the structure of `like`. Returns (tree, step).

        shardings: optional pytree of jax.sharding.Sharding matching `like` —
        leaves are device_put accordingly (reshard-on-restore)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._final_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        cache = {}

        def load(key):
            info = manifest["leaves"][key]
            if info["shard"] not in cache:
                cache[info["shard"]] = np.load(os.path.join(d, info["shard"]))
            return cache[info["shard"]][info["key"]]

        keys, leaves, treedef = _paths_and_leaves(like)
        sh_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
                     if shardings is not None else [None] * len(leaves))
        out = []
        for k, ref, sh in zip(keys, leaves, sh_leaves):
            a = load(k)
            assert list(a.shape) == list(ref.shape), (k, a.shape, ref.shape)
            out.append(jax.device_put(a, sh) if sh is not None
                       else jax.device_put(a))
        return jax.tree_util.tree_unflatten(treedef, out), step
