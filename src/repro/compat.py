"""Version compatibility shims for the jax API surface this repo targets.

The codebase is written against the modern jax API (``jax.shard_map``,
``jax.sharding.AxisType``, the ``jax.enable_x64`` context manager). The
pinned runtime in this container is jax 0.4.37, where those spellings either
live under ``jax.experimental`` or do not exist yet. Everything that touches
one of those APIs goes through this module so the rest of the code reads as
if it were on current jax.

Exports
-------
``shard_map(f, mesh, in_specs, out_specs, check_vma=...)``
    Dispatches to ``jax.shard_map`` when present, else
    ``jax.experimental.shard_map.shard_map`` (mapping the renamed
    ``check_vma`` kwarg back to ``check_rep``).
``enable_x64(enabled=True)``
    Context manager toggling the ``jax_enable_x64`` config flag and
    restoring the previous value on exit (the removed ``jax.enable_x64``).
``make_mesh(shape, axis_names, axis_types=None)``
    ``jax.make_mesh`` that silently drops ``axis_types`` on versions whose
    signature predates it.
``AXIS_TYPE_AUTO``
    ``jax.sharding.AxisType.Auto`` when it exists, else ``None`` (callers
    pass it straight to ``make_mesh`` above, which ignores it on old jax).
"""
from __future__ import annotations

import contextlib
import functools
import inspect

import jax

__all__ = ["shard_map", "enable_x64", "make_mesh", "AXIS_TYPE_AUTO"]


# -- shard_map ---------------------------------------------------------------

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    _shard_map_impl = jax.shard_map
    _CHECK_KWARG = "check_vma"
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _CHECK_KWARG = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """`jax.shard_map` spelling that works on both old and new jax."""
    kw = {}
    if check_vma is not None:
        kw[_CHECK_KWARG] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)


# -- enable_x64 --------------------------------------------------------------

@contextlib.contextmanager
def enable_x64(enabled: bool = True):
    """Replacement for the removed ``jax.enable_x64`` context manager."""
    prev = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", bool(enabled))
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", prev)


# -- make_mesh / AxisType ----------------------------------------------------

AXIS_TYPE_AUTO = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)

_MAKE_MESH_HAS_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


@functools.wraps(jax.make_mesh)
def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
    # Callers pass (AXIS_TYPE_AUTO,) * k unconditionally; when AxisType is
    # missing (old jax) those entries are None AND the kwarg is unsupported,
    # so the tuple is dropped here rather than guarded at every call site.
    if (axis_types is not None and _MAKE_MESH_HAS_AXIS_TYPES
            and not any(t is None for t in axis_types)):
        kw["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kw)
