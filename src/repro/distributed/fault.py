"""Fault tolerance: failure injection, straggler watchdog, restart policy.

On a real fleet these hook the TPU runtime's preemption notice and the
coordinator's health checks; in this container the failure paths are
exercised in-process (tests/test_fault.py) — the restart logic
(checkpoint -> reshard -> seek data stream -> resume) is the same code that
runs on a cluster.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional


class SimulatedFailure(RuntimeError):
    """Stands in for a node loss / preemption in tests."""


@dataclasses.dataclass
class FailureInjector:
    """Raises SimulatedFailure when `step` hits any value in `at_steps`
    (each fires once)."""
    at_steps: tuple = ()

    def __post_init__(self):
        self._pending = set(self.at_steps)

    def check(self, step: int):
        if step in self._pending:
            self._pending.discard(step)
            raise SimulatedFailure(f"injected failure at step {step}")


class StragglerWatchdog:
    """Tracks step wall times; flags steps slower than `factor` x the rolling
    median. On a fleet the launcher excludes the slow host and restarts from
    the last checkpoint (elastic re-mesh); here we record and report."""

    def __init__(self, factor: float = 3.0, window: int = 32):
        self.factor = factor
        self.window = window
        self.times: list = []
        self.flagged: list = []

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        recent = sorted(self.times[-self.window:])
        median = recent[len(recent) // 2]
        slow = len(self.times) > 4 and seconds > self.factor * median
        if slow:
            self.flagged.append((step, seconds, median))
        return slow


class PreemptionHandler:
    """SIGTERM -> request a final checkpoint before exit (cloud preemption
    notice). Poll `should_stop` inside the train loop."""

    def __init__(self, install: bool = True):
        self._stop = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._on_signal)
            except ValueError:
                pass  # not the main thread (tests)

    def _on_signal(self, signum, frame):
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop

    def trigger(self):  # for tests
        self._stop = True


def run_with_restarts(train_fn: Callable, restore_fn: Callable,
                      max_restarts: int = 3):
    """Generic restart-from-checkpoint policy.

    train_fn(state) -> state, raises SimulatedFailure on fault.
    restore_fn() -> state (latest checkpoint + data seek).
    """
    state = restore_fn()
    restarts = 0
    while True:
        try:
            return train_fn(state), restarts
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            state = restore_fn()
