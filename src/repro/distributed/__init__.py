from repro.distributed.fault import (  # noqa: F401
    FailureInjector,
    PreemptionHandler,
    SimulatedFailure,
    StragglerWatchdog,
    run_with_restarts,
)
