"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 attn:recurrent
pattern. 38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000
[arXiv:2402.19427; unverified]."""
from repro.models.config import ModelConfig, RGLRUConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        d_ff=12288,
        vocab_size=256_000,
        pattern=("rglru", "rglru", "local"),
        window=2048,
        rglru=RGLRUConfig(conv_width=4, lru_width=4096),
        act="gelu",
    )
