"""yi-34b [dense] — llama-arch GQA. 60L d_model=7168 56H (GQA kv=8)
d_ff=20480 vocab=64000 [arXiv:2403.04652; hf]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab_size=64_000,
        pattern=("global",),
        rope_theta=5_000_000.0,
        tie_embeddings=False,
    )
