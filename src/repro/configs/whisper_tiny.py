"""whisper-tiny [audio] — encoder-decoder; the conv/log-mel frontend is a
stub (input_specs supplies precomputed frame embeddings). 4L enc + 4L dec
d_model=384 6H (kv=6) d_ff=1536 vocab=51865 [arXiv:2212.04356; unverified]."""
from repro.models.config import EncoderConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        n_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab_size=51_865,
        pattern=("global",),
        encoder=EncoderConfig(n_layers=4, n_frames=1500),
        act="gelu",
        frontend="audio_stub",
    )
