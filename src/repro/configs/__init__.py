"""Per-architecture configs (exact assigned dimensions) + SAR scenes."""
