"""stablelm-1.6b [dense] — MHA. 24L d_model=2048 32H (kv=32) d_ff=5632
vocab=100352 [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab_size=100_352,
        pattern=("global",),
    )
