"""Architecture + shape registry: the 40 assigned (arch x shape) cells.

`get(name)` -> full ModelConfig (exact assigned dimensions).
`smoke(name)` -> reduced same-family config for CPU smoke tests.
`cells()` -> the dry-run matrix with the long_500k skip rules applied
             (sub-quadratic archs run it; pure full-attention archs skip,
             recorded with the reason — see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs import (
    falcon_mamba_7b,
    gemma3_12b,
    granite_moe_3b_a800m,
    llama4_scout_17b_a16e,
    minitron_4b,
    qwen2_vl_72b,
    recurrentgemma_9b,
    stablelm_1_6b,
    whisper_tiny,
    yi_34b,
)
from repro.models.config import (
    EncoderConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)

ARCHS = {
    "recurrentgemma-9b": recurrentgemma_9b.config,
    "minitron-4b": minitron_4b.config,
    "gemma3-12b": gemma3_12b.config,
    "stablelm-1.6b": stablelm_1_6b.config,
    "yi-34b": yi_34b.config,
    "qwen2-vl-72b": qwen2_vl_72b.config,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e.config,
    "granite-moe-3b-a800m": granite_moe_3b_a800m.config,
    "whisper-tiny": whisper_tiny.config,
    "falcon-mamba-7b": falcon_mamba_7b.config,
}


def get(name: str) -> ModelConfig:
    return ARCHS[name]()


def smoke(name: str, seq: int = 64) -> ModelConfig:
    """Reduced same-family config: same pattern/ffn/mixers, tiny dims."""
    cfg = get(name)
    period = len(cfg.pattern)
    n_layers = period * 2 + (1 if cfg.remainder_kinds else 0)
    # capacity_factor = n_experts makes routing dropless, so smoke tests can
    # check prefill/decode == full-forward exactly (capacity drops depend on
    # token grouping and legitimately break that equivalence).
    moe = cfg.moe and MoEConfig(
        n_experts=min(cfg.moe.n_experts, 8),
        top_k=min(cfg.moe.top_k, 2),
        capacity_factor=float(min(cfg.moe.n_experts, 8)),
        shared_expert=cfg.moe.shared_expert,
        group_size=seq,
    )
    enc = cfg.encoder and EncoderConfig(n_layers=2, n_frames=32)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, 4 * cfg.n_kv_heads // cfg.n_heads),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        window=min(cfg.window, seq // 4),
        moe=moe,
        ssm=cfg.ssm and SSMConfig(state_dim=4, conv_width=4, expand=2),
        rglru=cfg.rglru and RGLRUConfig(conv_width=4, lru_width=64),
        encoder=enc,
        mrope_sections=cfg.mrope_sections and (4, 2, 2),
        dtype="float32",
        loss_chunk=32,
        remat=False,
    )


# ---------------------------------------------------------------------------
# Shapes (the per-arch input-shape set from the assignment)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k runs only for archs that decode 500k with bounded attention
# (SSM / recurrent / local-dominant); pure full-attention archs skip it.
LONG_OK = {"recurrentgemma-9b", "falcon-mamba-7b", "gemma3-12b"}
SKIP_REASONS = {
    ("minitron-4b", "long_500k"): "pure full attention (O(S) KV per layer)",
    ("stablelm-1.6b", "long_500k"): "pure full attention",
    ("yi-34b", "long_500k"): "pure full attention",
    ("qwen2-vl-72b", "long_500k"): "pure full attention",
    ("llama4-scout-17b-a16e", "long_500k"):
        "1-in-4 global full-attention layers at 500k batch-1 decode",
    ("granite-moe-3b-a800m", "long_500k"): "pure full attention",
    ("whisper-tiny", "long_500k"):
        "enc-dec: decoder positions bounded by design; 500k inapplicable",
}


def cells(include_skipped: bool = False):
    """The (arch, shape, skip_reason|None) dry-run matrix — 40 cells."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES.values():
            reason = None
            if shape.name == "long_500k" and arch not in LONG_OK:
                reason = SKIP_REASONS[(arch, shape.name)]
            if reason is None or include_skipped:
                out.append((arch, shape.name, reason))
    return out
