"""falcon-mamba-7b [ssm] — Mamba-1, attention-free. 64L d_model=4096
ssm_state=16 vocab=65024 [arXiv:2410.05355; unverified]."""
from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        n_layers=64,
        d_model=4096,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=65_024,
        pattern=("mamba",),
        ffn="none",
        ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    )
