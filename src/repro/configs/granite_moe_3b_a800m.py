"""granite-moe-3b-a800m [moe] — 40 experts top-8. 32L d_model=1536 24H
(GQA kv=8) d_ff=512 (per expert) vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49_155,
        pattern=("global",),
        ffn="moe",
        # group_size 256: top-8 of 40 puts dispatch bytes at
        # tokens * group * k * cf — 4x smaller groups keep it ~10 GB global
        moe=MoEConfig(n_experts=40, top_k=8, group_size=256),
    )
