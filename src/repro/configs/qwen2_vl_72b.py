"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution (vision frontend is a
stub: input_specs supplies precomputed patch embeddings). 80L d_model=8192
64H (GQA kv=8) d_ff=29568 vocab=152064 [arXiv:2409.12191; hf]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=29568,
        vocab_size=152_064,
        pattern=("global",),
        mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
        frontend="vision_stub",
        tie_embeddings=False,
    )
