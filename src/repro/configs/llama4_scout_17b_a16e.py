"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert, early
fusion, iRoPE-style 3:1 chunked-local:global attention. 48L d_model=5120
40H (GQA kv=8) d_ff=8192 vocab=202048
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202_048,
        pattern=("local", "local", "local", "global"),
        window=8192,
        ffn="moe",
        moe=MoEConfig(n_experts=16, top_k=1, shared_expert=True),
        rope_theta=500_000.0,
        tie_embeddings=False,
    )
