"""Service observability: latency / throughput / queue-depth counters
emitted in the repo's BENCH_*.json (schema 2) artifact format.

The service records per-request latency (admission -> future resolved),
deadline outcomes (met / missed-but-served / dropped), per-batch wall
time, size, FILL FRACTION (batch size over the max_batch the scheduler
aimed for), the lane each batch ran on, per-lane occupancy, queue-depth
samples, and every rejection class (backpressure, SNR gate, overload
shed, client cancel). `to_bench_doc()` renders the snapshot as the same
schema-2 document benchmarks/common.write_bench_json produces (git SHA,
backend, ISO-8601 UTC timestamp, rows of name/wall_ms/derived), so
serving metrics diff and upload exactly like the paper-table benchmarks.
The writer here is self-contained — `repro.service` must not depend on
the benchmarks package being importable in production — but tests assert
the documents validate against benchmarks.common.validate_bench_doc.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from collections import Counter
from typing import Dict, List, Optional

BENCH_SCHEMA = 2
_RESERVOIR_MAX = 100_000


def utc_now_iso() -> str:
    """ISO-8601 UTC, second precision — stable enough to diff artifacts."""
    return time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def percentile(values: List[float], p: float) -> float:
    """Nearest-rank percentile; 0.0 on empty input."""
    if not values:
        return 0.0
    xs = sorted(values)
    k = min(len(xs) - 1, max(0, int(round(p / 100.0 * (len(xs) - 1)))))
    return xs[k]


class ServiceMetrics:
    """Mutable counters for one service instance (not thread-safe beyond
    the GIL — the service mutates it from the event-loop thread only)."""

    def __init__(self):
        self.t_start = time.monotonic()
        self.submitted = 0
        self.completed = 0
        self.rejected = 0            # backpressure rejections
        self.gate_rejected = 0       # SNR-gate rejections
        self.shed = 0                # latest-deadline work shed at overload
        self.cancelled = 0           # dropped before dispatch (client
                                     # cancel or past-deadline sweep)
        self.deadline_dropped = 0    # subset of cancelled: deadline sweep
        self.deadline_missed = 0     # served, but after t_deadline
        self.deadline_met = 0        # served within t_deadline
        self.failed = 0
        self.streamed = 0
        # -- resilience counters (the failure-domain layer) ------------------
        self.dispatch_failures = 0   # batch attempts that raised
        self.retries = 0             # bounded-retry re-dispatches
        self.bisections = 0          # poison-batch splits
        self.lane_stalls = 0         # stall-watchdog lane restarts
        self.corrupted = 0           # sentinel-flagged scenes
        self.tier_fallbacks = 0      # precision-tier (bs16 -> f32) falls
        self.latencies_ms: List[float] = []
        self.batch_sizes: Counter = Counter()
        self.batch_fill: Counter = Counter()     # fill fraction histogram
        self.batch_wall_ms: List[float] = []
        self.depth_samples: List[int] = []
        self.lane_batches: Counter = Counter()   # batches per lane
        self.lane_busy_ms: Counter = Counter()   # device-thread ms per lane
        self._lane_occupancy: Dict[str, float] = {}

    # -- recording ----------------------------------------------------------
    def observe_submit(self, depth: int) -> None:
        self.submitted += 1
        self.depth_samples.append(depth)

    def observe_reject(self) -> None:
        self.rejected += 1

    def observe_gate_reject(self) -> None:
        self.gate_rejected += 1

    def observe_shed(self) -> None:
        self.shed += 1

    def observe_cancelled(self, reason: str = "client_cancelled") -> None:
        self.cancelled += 1
        if reason == "deadline":
            self.deadline_dropped += 1

    def observe_batch(self, size: int, wall_ms: float,
                      streamed: bool = False,
                      lane: Optional[str] = None,
                      max_batch: Optional[int] = None) -> None:
        self.batch_sizes[size] += 1
        self.batch_wall_ms.append(wall_ms)
        if streamed:
            self.streamed += size
        if lane is not None:
            self.lane_batches[lane] += 1
            self.lane_busy_ms[lane] += wall_ms
        if max_batch:
            # fill fraction quantized to max_batch-ths: the histogram key
            # is exact (no float binning), e.g. "3/4"
            self.batch_fill[f"{min(size, max_batch)}/{max_batch}"] += 1

    def observe_done(self, latency_ms: float,
                     deadline_met: Optional[bool] = None) -> None:
        self.completed += 1
        if deadline_met is True:
            self.deadline_met += 1
        elif deadline_met is False:
            self.deadline_missed += 1
        if len(self.latencies_ms) < _RESERVOIR_MAX:
            self.latencies_ms.append(latency_ms)

    def observe_failure(self) -> None:
        self.failed += 1

    def observe_dispatch_failure(self) -> None:
        self.dispatch_failures += 1

    def observe_retry(self) -> None:
        self.retries += 1

    def observe_bisect(self) -> None:
        self.bisections += 1

    def observe_stall(self) -> None:
        self.lane_stalls += 1

    def observe_corrupt(self, scenes: int = 1) -> None:
        self.corrupted += scenes

    def observe_tier_fallback(self, scenes: int = 1) -> None:
        self.tier_fallbacks += scenes

    def set_lane_occupancy(self, occupancy: Dict[str, float]) -> None:
        """Latest per-lane busy fraction (WorkerPool.occupancy())."""
        self._lane_occupancy = dict(occupancy)

    # -- reporting ----------------------------------------------------------
    def snapshot(self) -> dict:
        elapsed = max(time.monotonic() - self.t_start, 1e-9)
        n_batches = sum(self.batch_sizes.values())
        coalesced = sum(k * v for k, v in self.batch_sizes.items())
        deadlined = (self.deadline_met + self.deadline_missed
                     + self.deadline_dropped)
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "gate_rejected": self.gate_rejected,
            "shed": self.shed,
            "cancelled": self.cancelled,
            "deadline_dropped": self.deadline_dropped,
            "deadline_missed": self.deadline_missed,
            "deadline_met": self.deadline_met,
            # misses + drops over every deadline-carrying outcome (0.0
            # when no request carried a deadline)
            "deadline_miss_rate": (
                (self.deadline_missed + self.deadline_dropped) / deadlined
                if deadlined else 0.0),
            "failed": self.failed,
            "streamed": self.streamed,
            "dispatch_failures": self.dispatch_failures,
            "retries": self.retries,
            "bisections": self.bisections,
            "lane_stalls": self.lane_stalls,
            "corrupted": self.corrupted,
            "tier_fallbacks": self.tier_fallbacks,
            "throughput_rps": self.completed / elapsed,
            # goodput: completions that met their deadline per second;
            # requests without a deadline always count as good
            "goodput_rps": (self.completed - self.deadline_missed)
            / elapsed,
            "latency_p50_ms": percentile(self.latencies_ms, 50),
            "latency_p99_ms": percentile(self.latencies_ms, 99),
            "latency_mean_ms": (sum(self.latencies_ms) /
                                len(self.latencies_ms)
                                if self.latencies_ms else 0.0),
            "mean_batch_size": coalesced / n_batches if n_batches else 0.0,
            "batch_size_hist": dict(sorted(self.batch_sizes.items())),
            "batch_fill_hist": dict(sorted(self.batch_fill.items())),
            "lane_batches": dict(sorted(self.lane_batches.items())),
            "lane_occupancy": dict(sorted(self._lane_occupancy.items())),
            "queue_depth_max": max(self.depth_samples, default=0),
        }

    def rows(self, section: str = "service") -> List[dict]:
        """Snapshot rendered as BENCH rows (wall_ms carries the metric's
        natural unit; non-latency metrics ride in `derived`)."""
        s = self.snapshot()
        rows = []
        for name in ("latency_p50_ms", "latency_p99_ms", "latency_mean_ms"):
            rows.append({"section": section, "name": name,
                         "wall_ms": s[name], "derived": ""})
        rows.append({
            "section": section, "name": "throughput",
            "wall_ms": 0.0,
            "derived": f"rps={s['throughput_rps']:.2f};"
                       f"goodput_rps={s['goodput_rps']:.2f};"
                       f"completed={s['completed']};"
                       f"rejected={s['rejected']};"
                       f"gate_rejected={s['gate_rejected']};"
                       f"shed={s['shed']};"
                       f"cancelled={s['cancelled']};"
                       f"deadline_miss_rate={s['deadline_miss_rate']:.4f};"
                       f"streamed={s['streamed']}",
        })
        rows.append({
            "section": section, "name": "batching",
            "wall_ms": 0.0,
            "derived": f"mean_batch={s['mean_batch_size']:.2f};"
                       f"hist={s['batch_size_hist']};"
                       f"fill_hist={s['batch_fill_hist']};"
                       f"queue_depth_max={s['queue_depth_max']}",
        })
        rows.append({
            "section": section, "name": "resilience",
            "wall_ms": 0.0,
            "derived": f"dispatch_failures={s['dispatch_failures']};"
                       f"retries={s['retries']};"
                       f"bisections={s['bisections']};"
                       f"lane_stalls={s['lane_stalls']};"
                       f"corrupted={s['corrupted']};"
                       f"tier_fallbacks={s['tier_fallbacks']};"
                       f"failed={s['failed']}",
        })
        occ = ";".join(f"occ_{name}={frac:.4f}"
                       for name, frac in s["lane_occupancy"].items())
        per_lane = ";".join(f"batches_{name}={n}"
                            for name, n in s["lane_batches"].items())
        rows.append({
            "section": section, "name": "lanes",
            "wall_ms": 0.0,
            "derived": ";".join(p for p in (
                f"lanes={len(s['lane_occupancy'])}", occ, per_lane) if p),
        })
        return rows

    def to_bench_doc(self, section: str = "service", **meta) -> dict:
        """The schema-2 BENCH_*.json document for this snapshot."""
        try:
            import jax
            backend = jax.default_backend()
            jax_version = jax.__version__
        except Exception:                              # pragma: no cover
            backend, jax_version = "unknown", "unknown"
        return {
            "schema": BENCH_SCHEMA,
            "git_sha": _git_sha(),
            "backend": backend,
            "jax_version": jax_version,
            "python": sys.version.split()[0],
            "generated_utc": utc_now_iso(),
            **meta,
            "rows": self.rows(section),
        }

    def write_bench_json(self, path: str, section: str = "service",
                         **meta) -> None:
        with open(path, "w") as f:
            json.dump(self.to_bench_doc(section, **meta), f, indent=2)
