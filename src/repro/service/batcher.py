"""Deadline/max-batch micro-batcher.

Coalesces same-:class:`~repro.service.queue.BatchKey` requests into
``(B, na, nr)`` micro-batches: a key's first pending request starts a
flush deadline (``max_delay_ms``); the bucket flushes when it reaches
``max_batch`` or the deadline fires, whichever is first. Requests routed
through the streaming executor never coalesce (one host-resident scene is
already over the device budget; B of them certainly are).

One batch executes at a time, awaited inline: while a batch runs on
device, newly arrived requests accumulate in the queue and form the next
batch — under load the batcher converges to full batches with no timer
involved (classic adaptive batching), and when idle the deadline bounds
the latency a lone request pays waiting for company.
"""
from __future__ import annotations

from typing import Awaitable, Callable, Dict, List

from repro.service.queue import (
    STOP,
    BatchKey,
    FocusRequest,
    RequestQueue,
    now,
)

ExecuteFn = Callable[[BatchKey, List[FocusRequest]], Awaitable[None]]


class MicroBatcher:
    """Pulls from the queue, buckets by key, flushes on size or deadline."""

    def __init__(self, queue: RequestQueue, execute: ExecuteFn,
                 max_batch: int = 4, max_delay_ms: float = 5.0):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.queue = queue
        self.execute = execute
        self.max_batch = max_batch
        self.max_delay_s = max_delay_ms / 1e3
        self._pending: Dict[BatchKey, List[FocusRequest]] = {}
        self._deadline: Dict[BatchKey, float] = {}

    def pending_count(self) -> int:
        return sum(len(v) for v in self._pending.values())

    async def run(self) -> None:
        """The batcher task. Exits after draining when STOP is dequeued."""
        stop = False
        while not stop:
            timeout = None
            if self._deadline:
                timeout = max(0.0, min(self._deadline.values()) - now())
            req = await self.queue.get(timeout)
            # Drain the whole backlog into buckets BEFORE any deadline
            # check: requests that queued up behind an executing batch are
            # past their deadline on arrival here, and flushing them as
            # they surface would degenerate every backlog into B=1
            # batches. Draining first lets the backlog coalesce to
            # max_batch; the deadline only governs requests still waiting
            # for company once the queue is empty.
            while req is not None:
                if req is STOP:
                    stop = True
                    break
                bucket = self._pending.setdefault(req.key, [])
                if not bucket:
                    self._deadline[req.key] = (req.t_submit
                                               + self.max_delay_s)
                bucket.append(req)
                if len(bucket) >= self.max_batch or req.stream:
                    await self._flush(req.key)
                req = await self.queue.get(0)
            if stop:
                break
            t = now()
            for key in [k for k, d in self._deadline.items() if d <= t]:
                await self._flush(key)
        for key in list(self._pending):
            await self._flush(key)

    async def _flush(self, key: BatchKey) -> None:
        reqs = self._pending.pop(key, [])
        self._deadline.pop(key, None)
        if reqs:
            await self.execute(key, reqs)
