"""Deadline-aware continuous micro-batcher.

Coalesces same-:class:`~repro.service.queue.BatchKey` requests into
``(B, na, nr)`` micro-batches: a key's first pending request starts a
flush deadline (``max_delay_ms``); the bucket flushes when it reaches
``max_batch`` or the deadline fires, whichever is first. Requests routed
through the streaming executor never coalesce (one host-resident scene is
already over the device budget; B of them certainly are).

**Continuous batching.** ``execute`` is a HAND-OFF, not a wait: the
service's dispatch callback acquires a worker-pool lane slot, schedules
the device work as a background task, and returns — so the batcher
resumes draining immediately and batch k+1 coalesces, sweeps, and pads
while batch k runs on device. Backpressure re-appears exactly where it
belongs: when every slot of the routed lane is in flight, the hand-off
awaits a slot (the per-lane in-flight cap), the batcher parks mid-flush,
and the queue backlog coalesces into full batches behind it.

**Deadline scheduling.** Buckets whose flush deadline has fired are
flushed in earliest-request-deadline order (EDF; priority breaks ties).
At flush time, before any padding, each bucket is swept: requests whose
client cancelled the returned future are silently dropped, and requests
already past their ``deadline_ms`` are dropped with
:class:`~repro.service.queue.RequestCancelled` — a request that can no
longer meet its deadline must not cost a dispatch. On shutdown (STOP),
remaining buckets flush in the same EDF order — including when STOP is
dequeued mid-drain with non-stale buckets still pending (the pre-PR-9
loop broke out before the final sweep and flushed in dict order).

Under overload the service sheds the LATEST-deadline pending request
(:meth:`MicroBatcher.shed_latest`) instead of rejecting an
earlier-deadline arrival at admission.
"""
from __future__ import annotations

import math
from typing import Awaitable, Callable, Dict, List, Optional, Tuple

from repro.service.queue import (
    STOP,
    BatchKey,
    FocusRequest,
    RequestCancelled,
    RequestQueue,
    now,
)

ExecuteFn = Callable[[BatchKey, List[FocusRequest]], Awaitable[None]]
DropFn = Callable[[FocusRequest, str], None]


class MicroBatcher:
    """Pulls from the queue, buckets by key, flushes on size or deadline
    (EDF across buckets), hands flushes off without waiting for device
    completion."""

    def __init__(self, queue: RequestQueue, execute: ExecuteFn,
                 max_batch: int = 4, max_delay_ms: float = 5.0,
                 on_drop: Optional[DropFn] = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.queue = queue
        self.execute = execute
        self.max_batch = max_batch
        self.max_delay_s = max_delay_ms / 1e3
        self.on_drop = on_drop
        self._pending: Dict[BatchKey, List[FocusRequest]] = {}
        self._flush_deadline: Dict[BatchKey, float] = {}
        # requests popped from their bucket but still awaiting a lane
        # slot inside execute(): they are backlog (admission counts them)
        # but no longer sheddable/coalescible
        self._dispatching = 0

    def pending_count(self) -> int:
        """Not-yet-dispatched backlog: bucketed + awaiting a lane slot."""
        return (sum(len(v) for v in self._pending.values())
                + self._dispatching)

    # -- overload shedding --------------------------------------------------
    def shed_latest(self, before: float,
                    priority: int = 0) -> Optional[FocusRequest]:
        """Remove and return the pending request whose deadline is the
        LATEST — provided it is strictly later than ``before`` (the
        incoming request's deadline) or, at equal deadlines, of strictly
        lower ``priority``. Returns None when nothing pending is a worse
        candidate than the arrival, i.e. shedding would not help."""
        worst: Optional[Tuple[float, int, BatchKey, int]] = None
        for key, reqs in self._pending.items():
            for i, r in enumerate(reqs):
                cand = (r.t_deadline, -r.priority, key, i)
                if worst is None or cand[:2] > worst[:2]:
                    worst = cand
        if worst is None:
            return None
        t_dead, neg_prio, key, i = worst
        if not (t_dead > before
                or (t_dead == before and -neg_prio < priority)):
            return None
        victim = self._pending[key].pop(i)
        if not self._pending[key]:
            del self._pending[key]
            self._flush_deadline.pop(key, None)
        return victim

    # -- scheduling ---------------------------------------------------------
    def _bucket_rank(self, key: BatchKey) -> Tuple[float, int, float]:
        """EDF sort key for a bucket: earliest request deadline first,
        then highest priority, then earliest flush deadline."""
        reqs = self._pending.get(key, ())
        t_dead = min((r.t_deadline for r in reqs), default=math.inf)
        prio = max((r.priority for r in reqs), default=0)
        return (t_dead, -prio, self._flush_deadline.get(key, math.inf))

    def _edf_order(self, keys) -> List[BatchKey]:
        return sorted(keys, key=self._bucket_rank)

    async def run(self) -> None:
        """The batcher task. Exits after draining when STOP is dequeued."""
        stop = False
        while not stop:
            timeout = None
            if self._flush_deadline:
                timeout = max(0.0,
                              min(self._flush_deadline.values()) - now())
            req = await self.queue.get(timeout)
            # Drain the whole backlog into buckets BEFORE any deadline
            # check: requests that queued up behind an executing batch
            # would otherwise degenerate into B=1 flushes; draining first
            # lets the backlog coalesce to max_batch. The flush deadline
            # only governs requests still waiting for company once the
            # queue is empty.
            while req is not None:
                if req is STOP:
                    stop = True
                    break
                bucket = self._pending.setdefault(req.key, [])
                if not bucket:
                    self._flush_deadline[req.key] = (req.t_submit
                                                     + self.max_delay_s)
                bucket.append(req)
                if len(bucket) >= self.max_batch or req.stream:
                    await self._flush(req.key)
                req = await self.queue.get(0)
            # The deadline sweep runs on EVERY loop iteration — including
            # the one that dequeued STOP mid-drain: buckets whose flush
            # deadline fired while the backlog drained must still go out
            # in EDF order, not fall through to the shutdown flush.
            t = now()
            expired = [k for k, d in self._flush_deadline.items()
                       if d <= t]
            for key in self._edf_order(expired):
                await self._flush(key)
        for key in self._edf_order(list(self._pending)):
            await self._flush(key)

    async def _flush(self, key: BatchKey) -> None:
        reqs = self._pending.pop(key, [])
        self._flush_deadline.pop(key, None)
        live = self._sweep(reqs)
        if not live:
            return
        # hand-off: execute() returns once the batch holds a lane slot
        # and its device task is scheduled — NOT when the device is done.
        # The popped requests count as backlog until the hand-off lands.
        self._dispatching += len(live)
        try:
            await self.execute(key, live)
        finally:
            self._dispatching -= len(live)

    def _sweep(self, reqs: List[FocusRequest]) -> List[FocusRequest]:
        """Drop client-cancelled and past-deadline requests BEFORE the
        batch pads: neither may cost device work. Past-deadline futures
        resolve with RequestCancelled; cancelled futures are already
        resolved by the client."""
        t = now()
        live = []
        for r in reqs:
            if r.future.cancelled():
                if self.on_drop:
                    self.on_drop(r, "client_cancelled")
                continue
            if r.t_deadline <= t:
                if not r.future.done():
                    r.future.set_exception(RequestCancelled(
                        f"deadline_ms={r.deadline_ms:g} exceeded "
                        f"{(t - r.t_deadline) * 1e3:.1f} ms before "
                        "dispatch; dropped without device work"))
                if self.on_drop:
                    self.on_drop(r, "deadline")
                continue
            live.append(r)
        return live
