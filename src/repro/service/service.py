"""FocusService — the async micro-batched SAR focusing front end.

Request lifecycle (docs/serving.md has the full walkthrough):

1. **Admission** — ``focus()`` checks the per-request SNR gate (a
   precision whose measured deviation exceeds ``snr_gate_db`` is rejected
   before it costs a dispatch), sizes the scene against the device-memory
   budget (oversized scenes take the streaming route), and enqueues into
   the bounded request queue — or raises :class:`ServiceOverloaded`.
2. **Coalescing** — the batcher buckets requests by
   ``(SceneConfig, variant, precision)`` and flushes at ``max_batch`` or
   after ``max_delay_ms``, whichever first.
3. **Execution** — the batch is stacked to ``(B, na, nr)`` and handed to
   the backend (``local`` warm-cached jitted pipelines, or ``sharded``
   shard_map corner-turn slabs) on an executor thread, so the event loop
   keeps admitting (and coalescing) requests while the device computes.
4. **Completion** — per-request futures resolve with each request's
   ``(na, nr)`` image; batching is a kernel-grid extension, so the
   coalesced image is bit-identical to an unbatched ``Pipeline.run``.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sar.geometry import SceneConfig
from repro.service import backends as backends_mod
from repro.service.batcher import MicroBatcher
from repro.service.metrics import ServiceMetrics
from repro.service.queue import (
    BatchKey,
    FocusRequest,
    RequestQueue,
    ServiceOverloaded,
    SnrGateViolation,
    now,
)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Service-level policy knobs (per-request knobs ride on the request).

    variant: default plan variant for requests that don't name one.
    precision: default precision tier for requests that don't name one.
      The shipping default is 'bs16' (block-scaled f16 — per-line
      exponents carried through the kernels, throughput tier); it is
      still subject to the SNR gate like any explicit request. Set None
      (or 'f32') for the full-precision verification path, which never
      consults the gate.
    backend: 'local' | 'sharded' (see repro.service.backends).
    max_batch: coalescing bound B — requests per micro-batch.
    max_delay_ms: deadline a lone request waits for batch company.
    max_queue: admission bound; beyond it submits raise ServiceOverloaded.
    snr_gate_db: per-request precision quality gate — a request asking
      for a precision whose measured point-target SNR deviation exceeds
      this raises SnrGateViolation at admission ("Range, Not Precision":
      the gate, not throughput, decides admissibility).
    device_budget_bytes: scenes larger than this take the streaming route
      (Pipeline.run_streamed strips on 'local'; mesh slabs on 'sharded').
      None disables the check.
    stream_strips: strip count for the streaming route.
    schedule: sharded backend schedule ('corner2' generic plan lowering,
      'halo' single-turn RDA).
    """

    variant: str = "fused3"
    precision: Optional[str] = "bs16"
    backend: str = "local"
    max_batch: int = 4
    max_delay_ms: float = 5.0
    max_queue: int = 64
    snr_gate_db: float = 0.1
    device_budget_bytes: Optional[int] = None
    stream_strips: int = 4
    schedule: str = "corner2"


def _default_precision_deviation(precision: str) -> float:
    """Measured SNR deviation (dB) for a precision policy, from the
    in-library quality harness (repro.tuning.quality — the same gate the
    kernel tuner applies). Fails CLOSED: if the harness is not importable
    the deviation is +inf and every non-f32 request is rejected — a
    service must never silently skip its quality gate."""
    try:
        from repro.tuning.quality import precision_snr_deviation
    except Exception:
        return math.inf
    return precision_snr_deviation(precision)


class FocusService:
    """Async front end over the SpectralPlan executor. Construct, then
    ``await start()`` (optionally with warm keys); submit via ``focus``;
    ``await stop()`` drains and joins the batcher."""

    def __init__(self, config: ServiceConfig = ServiceConfig(),
                 backend=None, precision_deviation=None):
        self.config = config
        self.metrics = ServiceMetrics()
        self.queue = RequestQueue(config.max_queue)
        if backend is None:
            backend = (backends_mod.ShardedBackend(schedule=config.schedule)
                       if config.backend == "sharded"
                       else backends_mod.LocalBackend())
        self.backend = backend
        self.batcher = MicroBatcher(self.queue, self._execute,
                                    max_batch=config.max_batch,
                                    max_delay_ms=config.max_delay_ms)
        self._precision_deviation = (precision_deviation
                                     or _default_precision_deviation)
        self._gate_cache: Dict[str, float] = {}
        self._task: Optional[asyncio.Task] = None
        # ONE worker for all device work (warm, batches, gate
        # measurements): it keeps the event loop free without ever
        # running two jax computations concurrently — the quality
        # harness toggles the process-global x64 flag (compat.enable_x64
        # in simulate()), which would corrupt a batch executing on
        # another thread. Recreated by start() after a stop().
        self._executor: Optional[
            concurrent.futures.ThreadPoolExecutor] = None

    # -- lifecycle ----------------------------------------------------------
    async def start(self, warm: Sequence[Tuple[SceneConfig, str,
                                               Optional[str]]] = ()) -> None:
        """Spawn the batcher task; pre-warm backend caches for each
        (scene, variant, precision) triple so the first real requests pay
        no compile/trace/filter cost."""
        loop = asyncio.get_running_loop()
        if self._executor is None:
            self._executor = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="focus-device")
        for scene, variant, precision in warm:
            key = BatchKey(scene, variant, precision, False)
            await loop.run_in_executor(
                self._executor, lambda k=key: self.backend.warm(
                    k, self.config.max_batch))
        self._task = asyncio.create_task(self.batcher.run())

    async def stop(self) -> None:
        """Flush pending batches and join the batcher task. Requests that
        raced admission behind the shutdown sentinel are failed (their
        futures raise) rather than left pending forever."""
        if self._task is not None:
            self.queue.put_stop()
            await self._task
            self._task = None
        for req in self.queue.drain_nowait():
            if not req.future.done():
                req.future.set_exception(
                    RuntimeError("service stopped before execution"))
            self.metrics.observe_failure()
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None        # start() makes a fresh one

    # -- admission ----------------------------------------------------------
    async def _ensure_gate_measured(self, precision: Optional[str]) -> None:
        """Populate the gate cache for ``precision`` off the event loop:
        the first measurement focuses a full quality scene (seconds in
        interpret mode), which must not stall the batcher's deadlines or
        concurrent admissions. It runs on the service's single device
        executor, serialized against batch execution (the measurement
        toggles global jax config). Cached checks stay synchronous."""
        if precision in (None, "f32") or precision in self._gate_cache:
            return
        loop = asyncio.get_running_loop()
        dev = await loop.run_in_executor(
            self._executor, self._precision_deviation, precision)
        self._gate_cache[precision] = float(dev)

    def _check_gate(self, precision: Optional[str]) -> None:
        """Lookup-only: admission must await _ensure_gate_measured first.
        Measuring here would put a multi-second jax computation on the
        event-loop thread, outside the serialized device executor."""
        if precision in (None, "f32"):
            return
        if precision not in self._gate_cache:
            raise RuntimeError(
                f"SNR gate for {precision!r} consulted before it was "
                "measured (call _ensure_gate_measured first)")
        dev = self._gate_cache[precision]
        if dev > self.config.snr_gate_db:
            self.metrics.observe_gate_reject()
            raise SnrGateViolation(
                f"precision {precision!r}: measured SNR deviation "
                f"{dev:.3f} dB exceeds the {self.config.snr_gate_db} dB "
                "gate")

    async def focus(self, raw, scene: SceneConfig,
                    variant: Optional[str] = None,
                    precision: Optional[str] = None) -> np.ndarray:
        """Submit one scene; resolves to its focused (na, nr) image.

        ``precision=None`` takes the service's default tier
        (``ServiceConfig.precision``, 'bs16' out of the box); pass 'f32'
        explicitly for the verification path. The resolved tier — default
        or per-request — is what the SNR gate checks and what the batcher
        coalesces on.

        Raises SnrGateViolation (quality gate) or ServiceOverloaded
        (queue at bound) at admission — both BEFORE any device work —
        and RuntimeError when the service is not running (not started,
        stopped, or the batcher task died)."""
        if self._task is None or self._task.done():
            raise RuntimeError(
                "service is not running (call start() first; submissions "
                "after stop() are rejected)")
        if precision is None:
            precision = self.config.precision
        await self._ensure_gate_measured(precision)
        self._check_gate(precision)
        raw = np.ascontiguousarray(np.asarray(raw, np.complex64))
        if raw.shape != (scene.na, scene.nr):
            raise ValueError(
                f"scene shape {raw.shape} != ({scene.na}, {scene.nr})")
        stream = (self.config.device_budget_bytes is not None
                  and raw.nbytes > self.config.device_budget_bytes)
        loop = asyncio.get_running_loop()
        req = FocusRequest(
            raw=raw, scene=scene, variant=variant or self.config.variant,
            precision=precision, future=loop.create_future(),
            t_submit=now(), stream=stream)
        try:
            self.queue.put(req)
        except ServiceOverloaded:
            self.metrics.observe_reject()
            raise
        self.metrics.observe_submit(self.queue.depth()
                                    + self.batcher.pending_count())
        return await req.future

    # -- execution (called by the batcher) ----------------------------------
    async def _execute(self, key: BatchKey, reqs: List[FocusRequest]) -> None:
        loop = asyncio.get_running_loop()
        t0 = time.perf_counter()
        try:
            if key.stream:
                images = []
                for r in reqs:
                    images.append(await loop.run_in_executor(
                        self._executor, self.backend.execute_streamed,
                        key, r.raw, self.config.stream_strips))
            else:
                batch = np.stack([r.raw for r in reqs])
                images = await loop.run_in_executor(
                    self._executor, self.backend.execute, key, batch)
        except Exception as e:
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)
                self.metrics.observe_failure()
            return
        wall_ms = (time.perf_counter() - t0) * 1e3
        self.metrics.observe_batch(len(reqs), wall_ms, streamed=key.stream)
        t_done = now()
        for r, img in zip(reqs, images):
            if not r.future.done():
                r.future.set_result(np.asarray(img))
            self.metrics.observe_done((t_done - r.t_submit) * 1e3)
