"""FocusService — the async continuous-batching SAR focusing front end.

Request lifecycle (docs/serving.md has the full walkthrough):

1. **Admission** — ``focus()`` checks the per-request SNR gate (a
   precision whose measured deviation exceeds ``snr_gate_db`` is rejected
   before it costs a dispatch), sizes the scene against the device-memory
   budget (oversized scenes take the streaming route), and enqueues into
   the bounded request queue. At the bound, the service first tries to
   SHED the latest-deadline pending request (its future raises
   :class:`RequestCancelled`) to admit earlier-deadline work; only when
   nothing pending is a worse candidate does the caller see
   :class:`ServiceOverloaded` (which carries depth/bound/retry hint).
2. **Coalescing** — the batcher buckets requests by
   ``(SceneConfig, variant, precision)`` and flushes at ``max_batch`` or
   after ``max_delay_ms``; flush-ready buckets go out in earliest-
   deadline order, and client-cancelled or past-deadline requests are
   dropped before the batch pads.
3. **Dispatch** — the flush is a HAND-OFF: the batch acquires a slot on
   a worker-pool lane (``fused<i>`` lanes for coalesced batches, the
   ``stream`` lane for over-budget scenes; routing weighs lanes by the
   roofline's predicted seconds) and runs as a background task, so the
   batcher resumes draining immediately — batch k+1 coalesces and pads
   on the event loop while batch k computes on a lane thread
   (continuous batching; the per-lane in-flight cap is the backpressure).
4. **Completion** — per-request futures resolve with each request's
   ``(na, nr)`` image; batching is a kernel-grid extension, so the
   coalesced image is bit-identical to an unbatched ``Pipeline.run``.
"""
from __future__ import annotations

import asyncio
import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.sar.geometry import SceneConfig
from repro.service import backends as backends_mod
from repro.service.batcher import MicroBatcher
from repro.service.metrics import ServiceMetrics
from repro.service.queue import (
    BatchKey,
    FocusRequest,
    RequestCancelled,
    RequestQueue,
    ServiceOverloaded,
    SnrGateViolation,
    now,
)
from repro.service.resilience import (
    BreakerBoard,
    HealthSentinel,
    LaneStalled,
    OutputCorrupted,
    RetryPolicy,
)
from repro.service.workers import Lane, WorkerPool

# poison-batch bisection recursion bound: max_batch is small (single
# digits), so 4 halvings always reach singletons
_MAX_BISECT_DEPTH = 4


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Service-level policy knobs (per-request knobs ride on the request).

    variant: default plan variant for requests that don't name one.
    precision: default precision tier for requests that don't name one.
      The shipping default is 'bs16' (block-scaled f16 — per-line
      exponents carried through the kernels, throughput tier); it is
      still subject to the SNR gate like any explicit request. Set None
      (or 'f32') for the full-precision verification path, which never
      consults the gate.
    backend: 'local' | 'sharded' (see repro.service.backends).
    max_batch: coalescing bound B — requests per micro-batch.
    max_delay_ms: deadline a lone request waits for batch company.
    max_queue: admission bound on the pre-dispatch backlog (queued +
      bucketed requests); beyond it submits shed latest-deadline pending
      work or raise ServiceOverloaded.
    lanes: worker-pool fused-batch lanes (plus one dedicated stream
      lane). Each lane is one executor thread; >1 overlaps host staging
      and device compute across batches.
    inflight_cap: in-flight batches per lane (2 = one on device + one
      staged, double-buffered host staging). The batcher parks when the
      routed lane is at its cap.
    shed: at the admission bound, drop the latest-deadline pending
      request (RequestCancelled) to admit an earlier-deadline arrival;
      False restores reject-at-bound.
    snr_gate_db: per-request precision quality gate — a request asking
      for a precision whose measured point-target SNR deviation exceeds
      this raises SnrGateViolation at admission ("Range, Not Precision":
      the gate, not throughput, decides admissibility).
    device_budget_bytes: scenes larger than this take the streaming route
      (Pipeline.run_streamed strips on 'local'; mesh slabs on 'sharded').
      None disables the check.
    stream_strips: strip count for the streaming route.
    schedule: sharded backend schedule ('corner2' generic plan lowering,
      'halo' single-turn RDA).
    """

    variant: str = "fused3"
    precision: Optional[str] = "bs16"
    backend: str = "local"
    max_batch: int = 4
    max_delay_ms: float = 5.0
    max_queue: int = 64
    lanes: int = 2
    inflight_cap: int = 2
    shed: bool = True
    snr_gate_db: float = 0.1
    device_budget_bytes: Optional[int] = None
    stream_strips: int = 4
    schedule: str = "corner2"
    # -- failure-domain knobs (docs/serving.md "Failure handling") -----------
    # max_retries: failed batch dispatches re-run up to this many times
    #   with jittered exponential backoff, never scheduled past the
    #   earliest live deadline in the batch.
    # retry_backoff_ms / retry_seed: the backoff base and the jitter
    #   PRNG seed (seeded -> chaos replays are deterministic).
    # bisect: a batch that exhausts its retries and holds >1 request is
    #   split in half and each half served independently, so one poison
    #   scene fails alone instead of killing its coalesced neighbors.
    # sentinel / sentinel_envelope: per-scene output health check
    #   (finite values + in/out energy envelope) converting silent
    #   numerical corruption into a retry, then OutputCorrupted.
    # stall_factor / stall_floor_s: lane supervision — a dispatch
    #   exceeding max(floor, factor x slowest completed batch) declares
    #   the lane dead; the lane restarts and the batch retries. None
    #   factor disables the watchdog.
    # tier_fallback: a DEFAULT-tier precision whose SNR gate trips (or
    #   whose output keeps failing the sentinel) falls back to the f32
    #   verification tier instead of erroring; explicit per-request
    #   precisions still raise SnrGateViolation — the caller asked for
    #   that tier by name.
    max_retries: int = 1
    retry_backoff_ms: float = 25.0
    retry_seed: int = 0
    bisect: bool = True
    sentinel: bool = True
    sentinel_envelope: float = 1e6
    stall_factor: Optional[float] = 6.0
    stall_floor_s: float = 30.0
    tier_fallback: bool = True
    breaker_threshold: int = 3
    breaker_cooldown_s: float = 30.0


def _default_precision_deviation(precision: str) -> float:
    """Measured SNR deviation (dB) for a precision policy, from the
    in-library quality harness (repro.tuning.quality — the same gate the
    kernel tuner applies). Fails CLOSED: if the harness is not importable
    the deviation is +inf and every non-f32 request is rejected — a
    service must never silently skip its quality gate."""
    try:
        from repro.tuning.quality import precision_snr_deviation
    except Exception:
        return math.inf
    return precision_snr_deviation(precision)


class FocusService:
    """Async front end over the SpectralPlan executor. Construct, then
    ``await start()`` (optionally with warm keys); submit via ``focus``;
    ``await stop()`` drains and joins the batcher and every in-flight
    lane task."""

    def __init__(self, config: ServiceConfig = ServiceConfig(),
                 backend=None, precision_deviation=None):
        self.config = config
        self.metrics = ServiceMetrics()
        self.queue = RequestQueue(config.max_queue)
        if backend is None:
            backend = (backends_mod.ShardedBackend(schedule=config.schedule)
                       if config.backend == "sharded"
                       else backends_mod.LocalBackend())
        self.backend = backend
        self.batcher = MicroBatcher(self.queue, self._dispatch,
                                    max_batch=config.max_batch,
                                    max_delay_ms=config.max_delay_ms,
                                    on_drop=self._on_drop)
        self._precision_deviation = (precision_deviation
                                     or _default_precision_deviation)
        self._gate_cache: Dict[str, float] = {}
        # -- failure-domain policy (see resilience.py) -----------------------
        self._retry = RetryPolicy(max_retries=config.max_retries,
                                  backoff_s=config.retry_backoff_ms / 1e3,
                                  seed=config.retry_seed)
        self._sentinel = (HealthSentinel(config.sentinel_envelope)
                          if config.sentinel else None)
        # tier breakers: "tier:<precision>" opens after repeated gate
        # trips / sentinel corruption on the DEFAULT precision tier, so
        # admission skips straight to f32 until the cooldown re-probes
        self._tier_breakers = BreakerBoard(
            threshold=config.breaker_threshold,
            cooldown_s=config.breaker_cooldown_s)
        self._task: Optional[asyncio.Task] = None
        # The worker pool owns EVERY device-work thread (batches,
        # streams, warms, gate measurements). Batches run under the
        # shared side of the pool's gate lock, gate measurements under
        # the exclusive side — the quality harness toggles the
        # process-global x64 flag (compat.enable_x64 in simulate()),
        # which would corrupt a batch executing concurrently on another
        # lane. Lanes are (re)started by start() after a stop().
        self.pool = WorkerPool(lanes=config.lanes,
                               inflight_cap=config.inflight_cap)
        self._inflight_tasks: Set[asyncio.Task] = set()

    # -- lifecycle ----------------------------------------------------------
    async def start(self, warm: Sequence[Tuple[SceneConfig, str,
                                               Optional[str]]] = ()) -> None:
        """Spawn the lanes and the batcher task; pre-warm backend caches
        for each (scene, variant, precision) triple so the first real
        requests pay no compile/trace/filter cost."""
        if not self.pool.started:
            self.pool.start()
        for scene, variant, precision in warm:
            key = BatchKey(scene, variant, precision, False)
            await self.pool.run_exclusive(
                self.backend.warm, key, self.config.max_batch)
        self._task = asyncio.create_task(self.batcher.run())

    async def stop(self) -> None:
        """Flush pending batches (earliest-deadline first), join the
        batcher, await every in-flight lane task, and fail requests that
        raced admission behind the shutdown sentinel (their futures
        raise) rather than leaving them pending forever."""
        if self._task is not None:
            self.queue.put_stop()
            await self._task
            self._task = None
        # the batcher has joined, so no new dispatches: one gather over
        # the snapshot covers every in-flight lane task
        tasks = list(self._inflight_tasks)
        if tasks:
            await asyncio.gather(*tasks, return_exceptions=True)
        self._inflight_tasks.clear()
        for req in self.queue.drain_nowait():
            if not req.future.done():
                req.future.set_exception(
                    RuntimeError("service stopped before execution"))
            self.metrics.observe_failure()
        self.metrics.set_lane_occupancy(self.pool.occupancy())
        self.pool.shutdown()                 # start() re-creates the lanes

    # -- admission ----------------------------------------------------------
    async def _ensure_gate_measured(self, precision: Optional[str]) -> None:
        """Populate the gate cache for ``precision`` off the event loop:
        the first measurement focuses a full quality scene (seconds in
        interpret mode), which must not stall the batcher's deadlines or
        concurrent admissions. It runs under the worker pool's EXCLUSIVE
        lock, serialized against every lane (the measurement toggles
        global jax config). Cached checks stay synchronous."""
        if precision in (None, "f32") or precision in self._gate_cache:
            return
        dev = await self.pool.run_exclusive(
            self._precision_deviation, precision)
        self._gate_cache[precision] = float(dev)

    def _check_gate(self, precision: Optional[str]) -> None:
        """Lookup-only: admission must await _ensure_gate_measured first.
        Measuring here would put a multi-second jax computation on the
        event-loop thread, outside the exclusive lock."""
        if precision in (None, "f32"):
            return
        if precision not in self._gate_cache:
            raise RuntimeError(
                f"SNR gate for {precision!r} consulted before it was "
                "measured (call _ensure_gate_measured first)")
        dev = self._gate_cache[precision]
        if dev > self.config.snr_gate_db:
            self.metrics.observe_gate_reject()
            raise SnrGateViolation(
                f"precision {precision!r}: measured SNR deviation "
                f"{dev:.3f} dB exceeds the {self.config.snr_gate_db} dB "
                "gate")

    async def _admit_precision(self, precision: Optional[str],
                               explicit: bool) -> Optional[str]:
        """Resolve the precision tier a request will actually serve at.

        An EXPLICIT per-request precision keeps the strict contract: a
        tripped gate raises SnrGateViolation (the caller asked for that
        tier by name). The DEFAULT tier degrades instead of erroring —
        a gate trip (or an open "tier:<precision>" breaker, fed by
        runtime sentinel corruption) falls back to the f32 verification
        tier, which never consults the gate. The breaker's cooldown
        re-probes the fast tier so a transient trip does not pin the
        service at f32 forever."""
        if precision in (None, "f32"):
            return precision
        fall = self.config.tier_fallback and not explicit
        breaker = self._tier_breakers.get(f"tier:{precision}")
        if fall and not breaker.allow():
            self.metrics.observe_tier_fallback()
            return "f32"
        await self._ensure_gate_measured(precision)
        try:
            self._check_gate(precision)
        except SnrGateViolation:
            if not fall:
                raise
            breaker.record_failure()
            self.metrics.observe_tier_fallback()
            return "f32"
        return precision

    def _admit(self, req: FocusRequest) -> None:
        """Enqueue, shedding latest-deadline pending work at the bound
        when the arrival's deadline is earlier (EDF admission)."""
        try:
            self.queue.put(req, extra=self.batcher.pending_count())
        except ServiceOverloaded:
            victim = (self.batcher.shed_latest(req.t_deadline, req.priority)
                      if self.config.shed else None)
            if victim is None:
                self.metrics.observe_reject()
                raise
            if not victim.future.done():
                victim.future.set_exception(RequestCancelled(
                    "shed under overload: this request's deadline "
                    f"({'none' if victim.deadline_ms is None else f'{victim.deadline_ms:g} ms'}) "
                    "is the latest in the backlog and an earlier-deadline "
                    "request arrived at the admission bound"))
            self.metrics.observe_shed()
            self.queue.put(req, extra=self.batcher.pending_count())

    async def focus(self, raw, scene: SceneConfig,
                    variant: Optional[str] = None,
                    precision: Optional[str] = None,
                    deadline_ms: Optional[float] = None,
                    priority: int = 0) -> np.ndarray:
        """Submit one scene; resolves to its focused (na, nr) image.

        ``precision=None`` takes the service's default tier
        (``ServiceConfig.precision``, 'bs16' out of the box); pass 'f32'
        explicitly for the verification path. The resolved tier — default
        or per-request — is what the SNR gate checks and what the batcher
        coalesces on.

        ``deadline_ms`` is the completion deadline relative to
        submission: buckets flush earliest-deadline first, a request
        still pending past its deadline is dropped before padding
        (raises RequestCancelled), and under overload the latest-deadline
        pending request is shed to admit earlier-deadline work.
        ``priority`` breaks deadline ties (higher wins). A request
        without a deadline is never dropped, but is the first shed.

        Raises SnrGateViolation (quality gate), ServiceOverloaded
        (backlog at bound, nothing sheddable), or RequestCancelled
        (dropped by deadline or shed) — the first two BEFORE any device
        work — and RuntimeError when the service is not running (not
        started, stopped, or the batcher task died)."""
        if self._task is None or self._task.done():
            raise RuntimeError(
                "service is not running (call start() first; submissions "
                "after stop() are rejected)")
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be > 0, got {deadline_ms}")
        explicit = precision is not None
        if precision is None:
            precision = self.config.precision
        precision = await self._admit_precision(precision, explicit)
        raw = np.ascontiguousarray(np.asarray(raw, np.complex64))
        if raw.shape != (scene.na, scene.nr):
            raise ValueError(
                f"scene shape {raw.shape} != ({scene.na}, {scene.nr})")
        stream = (self.config.device_budget_bytes is not None
                  and raw.nbytes > self.config.device_budget_bytes)
        loop = asyncio.get_running_loop()
        req = FocusRequest(
            raw=raw, scene=scene, variant=variant or self.config.variant,
            precision=precision, future=loop.create_future(),
            t_submit=now(), stream=stream, deadline_ms=deadline_ms,
            priority=priority)
        self._admit(req)
        self.metrics.observe_submit(self.queue.depth()
                                    + self.batcher.pending_count())
        return await req.future

    # -- dispatch (called by the batcher) ------------------------------------
    def _on_drop(self, req: FocusRequest, reason: str) -> None:
        self.metrics.observe_cancelled(reason)

    async def _dispatch(self, key: BatchKey, reqs: List[FocusRequest]) -> None:
        """The batcher's hand-off: route to a lane, take an in-flight
        slot (parking here is the in-flight-cap backpressure), schedule
        the device work as a background task, return immediately so the
        batcher keeps draining while this batch runs."""
        lane = self.pool.route(key)
        predicted_s = self.pool.predicted_seconds(key, batch=len(reqs))
        await lane.acquire(predicted_s)
        task = asyncio.get_running_loop().create_task(
            self._run_batch(lane, predicted_s, key, reqs))
        self._inflight_tasks.add(task)
        task.add_done_callback(self._inflight_tasks.discard)

    async def _run_batch(self, lane: Lane, predicted_s: float,
                         key: BatchKey, reqs: List[FocusRequest]) -> None:
        """Resilient batch executor: every request in ``reqs`` resolves
        to an image or a TYPED error — a fault never leaves a future
        pending and never silently fails healthy coalesced neighbors.
        Streamed keys serve per scene (each its own failure domain)."""
        t0 = time.perf_counter()
        busy = [0.0]
        try:
            if key.stream:
                for r in reqs:
                    await self._serve_batch(lane, key, [r], busy)
            else:
                await self._serve_batch(lane, key, reqs, busy)
            wall_ms = (time.perf_counter() - t0) * 1e3
            self.metrics.observe_batch(
                len(reqs), wall_ms, streamed=key.stream, lane=lane.name,
                max_batch=None if key.stream else self.config.max_batch)
            self.queue.note_service_time(wall_ms / 1e3 / len(reqs))
        finally:
            lane.release(predicted_s, busy_s=busy[0])
            self.metrics.set_lane_occupancy(self.pool.occupancy())

    def _stall_timeout(self, lane: Lane) -> Optional[float]:
        if self.config.stall_factor is None:
            return None
        return lane.stall_timeout(self.config.stall_factor,
                                  self.config.stall_floor_s)

    async def _attempt(self, lane: Lane, key: BatchKey,
                       reqs: List[FocusRequest]):
        """One dispatch of ``reqs`` on ``lane`` under the stall
        watchdog; returns (images, device seconds)."""
        if key.stream:
            img, secs = await self.pool.run_batch(
                lane, self.backend.execute_streamed, key, reqs[0].raw,
                self.config.stream_strips,
                stall_timeout=self._stall_timeout(lane))
            return [img], secs
        # host staging happens HERE, on the event loop — while other
        # lanes' batches compute on their threads
        batch = np.stack([r.raw for r in reqs])
        images, secs = await self.pool.run_batch(
            lane, self.backend.execute, key, batch,
            stall_timeout=self._stall_timeout(lane))
        return list(images), secs

    def _resolve(self, r: FocusRequest, img) -> None:
        if not r.future.done():
            r.future.set_result(np.asarray(img))
        t_done = now()
        self.metrics.observe_done(
            (t_done - r.t_submit) * 1e3,
            deadline_met=(None if r.deadline_ms is None
                          else t_done <= r.t_deadline))

    def _fail(self, r: FocusRequest, exc: Exception) -> None:
        if not r.future.done():
            r.future.set_exception(exc)
        self.metrics.observe_failure()

    async def _serve_batch(self, lane: Lane, key: BatchKey,
                           reqs: List[FocusRequest], busy: List[float],
                           depth: int = 0) -> None:
        """Serve one failure domain: dispatch, then walk the recovery
        ladder until every request is resolved (image or typed error).

        * a dispatch error (including LaneStalled from the lane
          supervisor) is retried up to ``max_retries`` times with
          seeded-jitter exponential backoff, never scheduled past the
          earliest live deadline in the domain;
        * a domain that exhausts retries with >1 request BISECTS — each
          half recurses independently, so a single poison scene ends as
          a singleton typed error while its neighbors serve;
        * after a successful dispatch the output sentinel checks each
          scene; healthy scenes resolve immediately, corrupted scenes
          re-dispatch on the retry budget — with a reduced default tier
          re-running at f32 (the verification tier) and feeding the
          "tier:<precision>" breaker — and raise OutputCorrupted when
          the budget is spent.

        Never raises: failures land on the request futures."""
        attempt = 0
        while True:
            live = [r for r in reqs if not r.future.done()]
            if not live:
                return
            try:
                images, secs = await self._attempt(lane, key, live)
                busy[0] += secs
            except Exception as e:       # noqa: BLE001 — failure domain edge
                if isinstance(e, LaneStalled):
                    self.metrics.observe_stall()
                self.metrics.observe_dispatch_failure()
                delay = self._retry.budget(
                    attempt, min(r.t_deadline for r in live))
                if delay is not None:
                    attempt += 1
                    self.metrics.observe_retry()
                    await asyncio.sleep(delay)
                    continue
                if (len(live) > 1 and self.config.bisect
                        and depth < _MAX_BISECT_DEPTH):
                    self.metrics.observe_bisect()
                    mid = len(live) // 2
                    await self._serve_batch(lane, key, live[:mid], busy,
                                            depth + 1)
                    await self._serve_batch(lane, key, live[mid:], busy,
                                            depth + 1)
                    return
                if (key.precision not in (None, "f32")
                        and self.config.tier_fallback):
                    # terminal dispatch failure at a reduced tier MUST
                    # record an outcome on the tier breaker: a half-open
                    # probe that dies on this path would otherwise wedge
                    # the breaker half_open forever (no success, no
                    # failure — allow() never admits another probe) and
                    # pin the default tier to f32
                    self._tier_breakers.get(
                        f"tier:{key.precision}").record_failure()
                for r in live:
                    self._fail(r, e)
                return
            # -- per-scene output health --------------------------------
            bad: List[Tuple[FocusRequest, str]] = []
            for r, img in zip(live, images):
                reason = (self._sentinel.check(r.raw, img)
                          if self._sentinel is not None else None)
                if reason is None:
                    self._resolve(r, img)
                else:
                    bad.append((r, reason))
            if key.precision not in (None, "f32") and len(bad) < len(live):
                self._tier_breakers.get(
                    f"tier:{key.precision}").record_success()
            if not bad:
                return
            self.metrics.observe_corrupt(len(bad))
            reqs = [r for r, _ in bad]
            if (key.precision not in (None, "f32")
                    and self.config.tier_fallback):
                # corruption on a reduced tier: re-run at f32 and feed
                # the tier breaker so repeated corruption re-routes
                # admission until the cooldown probe
                self._tier_breakers.get(
                    f"tier:{key.precision}").record_failure()
                key = key._replace(precision="f32")
                self.metrics.observe_tier_fallback(len(bad))
            delay = self._retry.budget(
                attempt, min(r.t_deadline for r in reqs))
            if delay is None:
                for r, reason in bad:
                    self._fail(r, OutputCorrupted(reason))
                return
            attempt += 1
            self.metrics.observe_retry()
            await asyncio.sleep(delay)
