"""Worker pool: N executor lanes over a serving backend.

The pre-PR-9 service ran every batch on ONE executor thread, awaited
inline by the batcher — the device idled while the next batch padded and
uploaded, and the queue drained in lockstep with device completions
(single-flight). The pool replaces that thread with **lanes**:

* ``fused<i>`` lanes (``ServiceConfig.lanes`` of them) carry coalesced
  in-memory micro-batches. Admission routes each
  :class:`~repro.service.queue.BatchKey` to the lane with the least
  predicted backlog, weighted by the roofline model's
  :func:`repro.tuning.cost.serve_batch_seconds` — the same
  predicted-seconds arithmetic that ranks kernel schedules prices lane
  load, so a 1024² batch counts for more backlog than a 256² one.
* the ``stream`` lane carries over-budget scenes (the
  ``run_streamed`` / sharded-megakernel route) so a multi-second big
  scene never heads-of-line-blocks the coalesced small-scene traffic.

Each lane is one executor thread plus an asyncio semaphore of
``inflight_cap`` slots (default 2: one batch on device, one staged —
double-buffered host staging). The batcher's hand-off acquires a slot
and returns; when a lane's slots are full the hand-off parks, which is
the in-flight-cap backpressure that lets the queue backlog coalesce.

Device-global serialization: the SNR-gate quality harness toggles the
process-global x64 flag (compat.enable_x64 inside simulate()), which
would corrupt any batch executing concurrently on another lane. Lanes
therefore run batches under the read side of a reader-writer lock and
gate measurements (plus warms) take the write side — many concurrent
batches, never a batch concurrent with a global-config toggle.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import threading
import time
from typing import Dict, List, Optional

from repro.distributed.fault import StragglerWatchdog
from repro.service.queue import BatchKey
from repro.service.resilience import LaneStalled
from repro import tuning


class _ReadToken:
    """One read-side hold on the RW lock. ``release()`` is idempotent
    and callable from ANY thread: when a stall watchdog restarts a lane,
    the abandoned device thread may still hold the read side — the
    restart force-releases its token so a pending gate writer is never
    deadlocked, and the abandoned thread's own eventual release is a
    no-op."""

    __slots__ = ("_lock", "_released")

    def __init__(self, lock: "_RWLock"):
        self._lock = lock
        self._released = False

    def release(self) -> None:
        with self._lock._cond:
            if self._released:
                return
            self._released = True
            self._lock._readers -= 1
            if self._lock._readers == 0:
                self._lock._cond.notify_all()


class _RWLock:
    """Minimal reader-writer lock: many readers (lane batches) or one
    writer (gate measurement / warm), writer-preferring so a pending
    exclusive task is not starved by a stream of batches."""

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self) -> _ReadToken:
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1
            return _ReadToken(self)

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    def acquire_write(self) -> None:
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writer or self._readers:
                    self._cond.wait()
            finally:
                self._writers_waiting -= 1
            self._writer = True

    def release_write(self) -> None:
        with self._cond:
            self._writer = False
            self._cond.notify_all()


class _Dispatch:
    """Supervision record for one hand-off to a lane thread. ``t_start``
    is written by the lane thread the moment the callable actually
    begins (time queued behind a sibling on the lane's single worker
    thread never counts toward the stall clock) and read by the
    event-loop supervisor; ``token`` is this dispatch's gate-lock read
    hold, so a stall force-releases exactly the stalled dispatch's
    token and never a healthy sibling's."""

    __slots__ = ("t_start", "token")

    def __init__(self):
        self.t_start: Optional[float] = None
        self.token: Optional[_ReadToken] = None


class Lane:
    """One executor lane: a device-work thread, an in-flight slot
    semaphore, and occupancy/backlog accounting."""

    def __init__(self, name: str, kind: str, inflight_cap: int):
        if inflight_cap < 1:
            raise ValueError("inflight_cap must be >= 1")
        self.name = name
        self.kind = kind                  # "batch" | "stream"
        self.inflight_cap = inflight_cap
        self.inflight = 0
        self.backlog_s = 0.0              # predicted seconds in flight
        self.busy_s = 0.0                 # measured device-thread seconds
        self.batches = 0
        # -- supervision state: an EWMA of completed-batch seconds (the
        # stall watchdog's baseline), a monotonic max (robust to lanes
        # serving mixed scene sizes), the distributed straggler watchdog
        # flagging slow-but-alive dispatches, and a restart generation.
        self.ewma_s: Optional[float] = None
        self.max_s = 0.0
        self.generation = 0
        self.stalls = 0
        self.watchdog = StragglerWatchdog()
        self._sem: Optional[asyncio.Semaphore] = None
        self._executor: Optional[
            concurrent.futures.ThreadPoolExecutor] = None

    def start(self) -> None:
        """(Re)create the loop-bound semaphore and the executor thread —
        called from the running event loop by WorkerPool.start()."""
        self._sem = asyncio.Semaphore(self.inflight_cap)
        self._executor = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"lane-{self.name}")

    def shutdown(self) -> None:
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None
        self._sem = None

    # -- supervision ---------------------------------------------------------
    def note_done(self, seconds: float) -> None:
        """Fold one COMPLETED batch's device-RUN seconds into the stall
        baseline (failures and stalls are excluded — they would bias the
        watchdog toward false positives after fast failures; queue wait
        behind a lane sibling is measured out on the lane thread, so it
        neither inflates the baseline nor double-counts busy time)."""
        self.ewma_s = (seconds if self.ewma_s is None
                       else 0.3 * seconds + 0.7 * self.ewma_s)
        self.max_s = max(self.max_s, seconds)
        self.watchdog.record(self.batches, seconds)

    def stall_timeout(self, factor: float, floor_s: float) -> float:
        """Seconds a dispatch may run before the lane is declared dead.
        Based on the slowest completed batch (not the EWMA alone) so a
        lane serving mixed scene sizes never false-trips on its largest
        key; the floor covers the cold lane before any completion."""
        base = max(self.max_s, self.ewma_s or 0.0)
        return max(floor_s, factor * base)

    def restart(self, stalled: Optional[_Dispatch] = None) -> None:
        """Replace the executor thread after a stall. The semaphore is
        KEPT: hand-offs already parked on `acquire` simply dispatch onto
        the fresh executor — that is the not-yet-dispatched-work requeue.
        Hand-offs already QUEUED on the dead executor are cancelled by
        the teardown; WorkerPool.run_batch translates that cancellation
        into a retryable LaneStalled so they re-run through the normal
        recovery ladder instead of leaving request futures pending.
        Only the STALLED dispatch's gate-lock read token is
        force-released (idempotently) — a healthy dispatch still running
        keeps its hold, so a gate writer can never toggle global config
        under live device work — which is enough to unblock a pending
        writer because the abandoned thread will never release it."""
        old = self._executor
        if old is not None:
            old.shutdown(wait=False, cancel_futures=True)
        if stalled is not None and stalled.token is not None:
            stalled.token.release()
        self.generation += 1
        self.stalls += 1
        self._executor = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"lane-{self.name}")

    async def acquire(self, predicted_s: float = 0.0) -> None:
        """Take one in-flight slot (parks when the lane is at its cap —
        the batcher's backpressure point)."""
        await self._sem.acquire()
        self.inflight += 1
        self.backlog_s += predicted_s

    def release(self, predicted_s: float = 0.0,
                busy_s: float = 0.0) -> None:
        self.inflight -= 1
        self.backlog_s = max(0.0, self.backlog_s - predicted_s)
        self.busy_s += busy_s
        self.batches += 1
        self._sem.release()


class WorkerPool:
    """Lane container + router. Owns every device-work thread of the
    service (batches, streams, gate measurements, warms)."""

    def __init__(self, lanes: int = 2, inflight_cap: int = 2):
        if lanes < 1:
            raise ValueError("worker pool needs at least one lane")
        self.gate_lock = _RWLock()
        self.batch_lanes: List[Lane] = [
            Lane(f"fused{i}", "batch", inflight_cap)
            for i in range(lanes)]
        self.stream_lane = Lane("stream", "stream", inflight_cap)
        self.lanes: List[Lane] = [*self.batch_lanes, self.stream_lane]
        self._started = False
        self.t_start = time.monotonic()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        """Create executors + loop-bound semaphores. Must run inside the
        event loop the lanes will serve (semaphores bind to it)."""
        for lane in self.lanes:
            lane.start()
        self.t_start = time.monotonic()
        self._started = True

    def shutdown(self) -> None:
        for lane in self.lanes:
            lane.shutdown()
        self._started = False

    @property
    def started(self) -> bool:
        return self._started

    # -- routing ------------------------------------------------------------
    def predicted_seconds(self, key: BatchKey, batch: int = 1) -> float:
        """The roofline's price of one batch under this key — the lane
        routing weight (tuning.cost.serve_batch_seconds)."""
        return tuning.cost.serve_batch_seconds(
            key.scene.na, key.scene.nr, batch=batch,
            precision=key.precision, streamed=key.stream)

    def route(self, key: BatchKey) -> Lane:
        """Streamed (over-budget) keys go to the dedicated stream lane;
        coalesced batches go to the least-backlogged fused lane by
        predicted seconds (ties resolve to the lowest lane index, so
        routing is deterministic)."""
        if key.stream:
            return self.stream_lane
        return min(self.batch_lanes,
                   key=lambda lane: (lane.backlog_s, lane.name))

    # -- execution ----------------------------------------------------------
    async def run_batch(self, lane: Lane, fn, *args,
                        stall_timeout: Optional[float] = None):
        """Await ``fn(*args)`` on the lane thread (shared lock held);
        returns (result, seconds the callable RAN on the lane thread —
        time queued behind a lane sibling is excluded from both the
        supervision baseline and busy accounting).

        ``stall_timeout`` arms the lane supervisor: a dispatch that
        neither returns nor raises within the timeout of RUNNING time
        (the clock starts when the callable begins on the lane thread,
        not at submit — a batch queued behind its sibling on the lane's
        single worker thread accrues no stall credit) is declared a dead
        lane — the lane's executor is replaced (work already parked on
        its in-flight semaphore re-dispatches onto the fresh thread) and
        :class:`~repro.service.resilience.LaneStalled` is raised so the
        caller's retry policy can re-run the batch.

        A hand-off still QUEUED on an executor torn down by a sibling's
        restart is cancelled by that teardown; the cancellation is
        translated into LaneStalled here — CancelledError is a
        BaseException the service's `except Exception` recovery ladder
        would never see, and an untranslated escape would leave the
        batch's request futures pending forever."""
        disp = _Dispatch()
        cfut = lane._executor.submit(self._shared_call, lane, disp,
                                     fn, *args)
        fut = asyncio.wrap_future(cfut)
        try:
            if stall_timeout is None:
                result, secs = await fut
            else:
                result, secs = await self._supervise(
                    lane, disp, fut, stall_timeout)
        except asyncio.CancelledError:
            if not cfut.cancelled():
                raise                      # genuine task cancellation
            raise LaneStalled(
                f"lane {lane.name}: queued hand-off cancelled by a lane "
                f"restart (generation {lane.generation}); eligible for "
                "re-dispatch on the fresh executor") from None
        lane.note_done(secs)
        return result, secs

    async def _supervise(self, lane: Lane, disp: _Dispatch,
                         fut: "asyncio.Future", stall_timeout: float):
        """Await ``fut`` under the stall watchdog, counting only RUNNING
        time: while ``disp.t_start`` is None the hand-off is still
        queued behind a sibling (whose own watchdog covers a hang there)
        and each wait simply re-arms."""
        while True:
            started = disp.t_start
            if started is None:
                timeout = stall_timeout
            else:
                timeout = stall_timeout - (time.perf_counter() - started)
                if timeout <= 0.0:
                    self.restart_lane(lane, disp)
                    raise LaneStalled(
                        f"lane {lane.name}: dispatch exceeded the "
                        f"{stall_timeout:.2f}s stall watchdog; lane "
                        f"restarted (generation {lane.generation})"
                    ) from None
            try:
                return await asyncio.wait_for(asyncio.shield(fut), timeout)
            except asyncio.TimeoutError:
                continue

    def restart_lane(self, lane: Lane,
                     stalled: Optional[_Dispatch] = None) -> None:
        """Supervisor action: replace a dead lane's executor thread.
        Parked hand-offs keep their semaphore slots and re-dispatch onto
        the fresh thread; the stalled dispatch's shared-lock hold is
        force-released (see Lane.restart)."""
        lane.restart(stalled)

    def _shared_call(self, lane: Lane, disp: _Dispatch, fn, *args):
        token = self.gate_lock.acquire_read()
        disp.token = token          # before t_start: the supervisor only
        disp.t_start = time.perf_counter()   # acts once t_start is set
        try:
            return fn(*args), time.perf_counter() - disp.t_start
        finally:
            token.release()

    async def run_exclusive(self, fn, *args):
        """Await ``fn(*args)`` on lane 0's thread under the EXCLUSIVE
        side of the gate lock — for work that toggles process-global jax
        config (the SNR-gate measurement) or mutates warm caches."""
        return await asyncio.wrap_future(
            self.batch_lanes[0]._executor.submit(
                self._exclusive_call, fn, *args))

    def _exclusive_call(self, fn, *args):
        self.gate_lock.acquire_write()
        try:
            return fn(*args)
        finally:
            self.gate_lock.release_write()

    # -- observability ------------------------------------------------------
    def occupancy(self) -> Dict[str, float]:
        """Per-lane busy fraction since start() — the metrics export."""
        elapsed = max(time.monotonic() - self.t_start, 1e-9)
        return {lane.name: min(1.0, lane.busy_s / elapsed)
                for lane in self.lanes}

    def snapshot(self) -> Dict[str, dict]:
        return {lane.name: {
            "kind": lane.kind,
            "inflight": lane.inflight,
            "inflight_cap": lane.inflight_cap,
            "backlog_s": lane.backlog_s,
            "busy_s": lane.busy_s,
            "batches": lane.batches,
            "stalls": lane.stalls,
            "generation": lane.generation,
        } for lane in self.lanes}
