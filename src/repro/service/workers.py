"""Worker pool: N executor lanes over a serving backend.

The pre-PR-9 service ran every batch on ONE executor thread, awaited
inline by the batcher — the device idled while the next batch padded and
uploaded, and the queue drained in lockstep with device completions
(single-flight). The pool replaces that thread with **lanes**:

* ``fused<i>`` lanes (``ServiceConfig.lanes`` of them) carry coalesced
  in-memory micro-batches. Admission routes each
  :class:`~repro.service.queue.BatchKey` to the lane with the least
  predicted backlog, weighted by the roofline model's
  :func:`repro.tuning.cost.serve_batch_seconds` — the same
  predicted-seconds arithmetic that ranks kernel schedules prices lane
  load, so a 1024² batch counts for more backlog than a 256² one.
* the ``stream`` lane carries over-budget scenes (the
  ``run_streamed`` / sharded-megakernel route) so a multi-second big
  scene never heads-of-line-blocks the coalesced small-scene traffic.

Each lane is one executor thread plus an asyncio semaphore of
``inflight_cap`` slots (default 2: one batch on device, one staged —
double-buffered host staging). The batcher's hand-off acquires a slot
and returns; when a lane's slots are full the hand-off parks, which is
the in-flight-cap backpressure that lets the queue backlog coalesce.

Device-global serialization: the SNR-gate quality harness toggles the
process-global x64 flag (compat.enable_x64 inside simulate()), which
would corrupt any batch executing concurrently on another lane. Lanes
therefore run batches under the read side of a reader-writer lock and
gate measurements (plus warms) take the write side — many concurrent
batches, never a batch concurrent with a global-config toggle.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import threading
import time
from typing import Dict, List, Optional

from repro.service.queue import BatchKey
from repro import tuning


class _RWLock:
    """Minimal reader-writer lock: many readers (lane batches) or one
    writer (gate measurement / warm), writer-preferring so a pending
    exclusive task is not starved by a stream of batches."""

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self) -> None:
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    def acquire_write(self) -> None:
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writer or self._readers:
                    self._cond.wait()
            finally:
                self._writers_waiting -= 1
            self._writer = True

    def release_write(self) -> None:
        with self._cond:
            self._writer = False
            self._cond.notify_all()


class Lane:
    """One executor lane: a device-work thread, an in-flight slot
    semaphore, and occupancy/backlog accounting."""

    def __init__(self, name: str, kind: str, inflight_cap: int):
        if inflight_cap < 1:
            raise ValueError("inflight_cap must be >= 1")
        self.name = name
        self.kind = kind                  # "batch" | "stream"
        self.inflight_cap = inflight_cap
        self.inflight = 0
        self.backlog_s = 0.0              # predicted seconds in flight
        self.busy_s = 0.0                 # measured device-thread seconds
        self.batches = 0
        self._sem: Optional[asyncio.Semaphore] = None
        self._executor: Optional[
            concurrent.futures.ThreadPoolExecutor] = None

    def start(self) -> None:
        """(Re)create the loop-bound semaphore and the executor thread —
        called from the running event loop by WorkerPool.start()."""
        self._sem = asyncio.Semaphore(self.inflight_cap)
        self._executor = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"lane-{self.name}")

    def shutdown(self) -> None:
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None
        self._sem = None

    async def acquire(self, predicted_s: float = 0.0) -> None:
        """Take one in-flight slot (parks when the lane is at its cap —
        the batcher's backpressure point)."""
        await self._sem.acquire()
        self.inflight += 1
        self.backlog_s += predicted_s

    def release(self, predicted_s: float = 0.0,
                busy_s: float = 0.0) -> None:
        self.inflight -= 1
        self.backlog_s = max(0.0, self.backlog_s - predicted_s)
        self.busy_s += busy_s
        self.batches += 1
        self._sem.release()


class WorkerPool:
    """Lane container + router. Owns every device-work thread of the
    service (batches, streams, gate measurements, warms)."""

    def __init__(self, lanes: int = 2, inflight_cap: int = 2):
        if lanes < 1:
            raise ValueError("worker pool needs at least one lane")
        self.gate_lock = _RWLock()
        self.batch_lanes: List[Lane] = [
            Lane(f"fused{i}", "batch", inflight_cap)
            for i in range(lanes)]
        self.stream_lane = Lane("stream", "stream", inflight_cap)
        self.lanes: List[Lane] = [*self.batch_lanes, self.stream_lane]
        self._started = False
        self.t_start = time.monotonic()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        """Create executors + loop-bound semaphores. Must run inside the
        event loop the lanes will serve (semaphores bind to it)."""
        for lane in self.lanes:
            lane.start()
        self.t_start = time.monotonic()
        self._started = True

    def shutdown(self) -> None:
        for lane in self.lanes:
            lane.shutdown()
        self._started = False

    @property
    def started(self) -> bool:
        return self._started

    # -- routing ------------------------------------------------------------
    def predicted_seconds(self, key: BatchKey, batch: int = 1) -> float:
        """The roofline's price of one batch under this key — the lane
        routing weight (tuning.cost.serve_batch_seconds)."""
        return tuning.cost.serve_batch_seconds(
            key.scene.na, key.scene.nr, batch=batch,
            precision=key.precision, streamed=key.stream)

    def route(self, key: BatchKey) -> Lane:
        """Streamed (over-budget) keys go to the dedicated stream lane;
        coalesced batches go to the least-backlogged fused lane by
        predicted seconds (ties resolve to the lowest lane index, so
        routing is deterministic)."""
        if key.stream:
            return self.stream_lane
        return min(self.batch_lanes,
                   key=lambda lane: (lane.backlog_s, lane.name))

    # -- execution ----------------------------------------------------------
    async def run_batch(self, lane: Lane, fn, *args):
        """Await ``fn(*args)`` on the lane thread (shared lock held);
        returns (result, seconds busy on the device thread)."""
        t0 = time.perf_counter()
        result = await asyncio.wrap_future(
            lane._executor.submit(self._shared_call, fn, *args))
        return result, time.perf_counter() - t0

    def _shared_call(self, fn, *args):
        self.gate_lock.acquire_read()
        try:
            return fn(*args)
        finally:
            self.gate_lock.release_read()

    async def run_exclusive(self, fn, *args):
        """Await ``fn(*args)`` on lane 0's thread under the EXCLUSIVE
        side of the gate lock — for work that toggles process-global jax
        config (the SNR-gate measurement) or mutates warm caches."""
        return await asyncio.wrap_future(
            self.batch_lanes[0]._executor.submit(
                self._exclusive_call, fn, *args))

    def _exclusive_call(self, fn, *args):
        self.gate_lock.acquire_write()
        try:
            return fn(*args)
        finally:
            self.gate_lock.release_write()

    # -- observability ------------------------------------------------------
    def occupancy(self) -> Dict[str, float]:
        """Per-lane busy fraction since start() — the metrics export."""
        elapsed = max(time.monotonic() - self.t_start, 1e-9)
        return {lane.name: min(1.0, lane.busy_s / elapsed)
                for lane in self.lanes}

    def snapshot(self) -> Dict[str, dict]:
        return {lane.name: {
            "kind": lane.kind,
            "inflight": lane.inflight,
            "inflight_cap": lane.inflight_cap,
            "backlog_s": lane.backlog_s,
            "busy_s": lane.busy_s,
            "batches": lane.batches,
        } for lane in self.lanes}
