"""Failure-domain primitives for the focusing service.

The serving stack degrades along TIERS, never cliffs: a failing
single-dispatch megakernel route falls back to its per-axis twin
(bit-identical), a failing per-axis dispatch falls back to the defused
chain (numerically equivalent, not bit-identical), a tripped bs16 SNR
gate falls back to the f32 verification tier, and a poisoned coalesced
batch bisects so one bad scene fails alone. Four small primitives carry
that policy:

``CircuitBreaker``   Per-route failure counter with cooldown/half-open
                     probing, so a persistently broken route stops being
                     retried on the hot path but is re-probed after the
                     cooldown (one request at a time) and closes again
                     the moment a probe succeeds.
``RetryPolicy``      Deadline-aware bounded retry: seeded jittered
                     exponential backoff whose sleep is NEVER scheduled
                     past the earliest live request deadline — a retry
                     that cannot finish in time is not attempted.
``HealthSentinel``   Output health check per scene (finite values +
                     input/output energy envelope) that converts silent
                     numerical corruption (NaN/Inf, zeroed or exploded
                     output) into a typed per-request error instead of a
                     wrong image handed to the caller.
``LaneStalled`` / ``OutputCorrupted``  The typed errors the degraded
                     paths raise, so callers (and the chaos harness) can
                     tell a supervised recovery from an unknown crash.

Everything here is pure policy — no asyncio, no device work — so it is
unit-testable with a fake clock and reusable outside the service.
"""
from __future__ import annotations

import math
import random
import threading
import time
from typing import Dict, Optional

import numpy as np


class LaneStalled(RuntimeError):
    """A lane's device thread exceeded its stall watchdog timeout; the
    lane was restarted and the batch is eligible for retry."""


class OutputCorrupted(RuntimeError):
    """The output health sentinel rejected a focused image (non-finite
    values or energy outside the physical envelope) and retries were
    exhausted — the caller gets this instead of a silently wrong image."""


class CircuitBreaker:
    """Three-state (closed / open / half_open) failure breaker.

    closed     the route serves normally; ``threshold`` consecutive
               failures open it.
    open       ``allow()`` is False until ``cooldown_s`` elapses, then
               the breaker moves to half_open and admits ONE probe.
    half_open  the probe's outcome decides: success closes, failure
               re-opens (and re-arms the cooldown). A probe that
               VANISHES without an outcome (the probe request was shed
               or deadline-dropped before its dispatch resolved) does
               not wedge the breaker: after another ``cooldown_s`` with
               no outcome recorded, ``allow()`` admits a fresh probe.

    ``clock`` is injectable for deterministic tests. Thread-safe: routes
    are consulted from lane threads and the event loop.
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 30.0,
                 clock=time.monotonic):
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._lock = threading.Lock()
        self.state = "closed"
        self.failures = 0
        self.trips = 0
        self._t_open = -math.inf
        self._t_probe = -math.inf

    def allow(self) -> bool:
        """May this route serve the next request? In half_open only the
        single call that observes the cooldown expiry gets True (the
        probe); concurrent callers keep seeing False until the probe
        resolves — or, if the probe vanished without recording an
        outcome, until another cooldown elapses and a fresh probe is
        admitted."""
        with self._lock:
            if self.state == "closed":
                return True
            if self.state == "open":
                if self._clock() - self._t_open >= self.cooldown_s:
                    self.state = "half_open"
                    self._t_probe = self._clock()
                    return True
                return False
            # half_open: probe in flight, unless it evaporated
            if self._clock() - self._t_probe >= self.cooldown_s:
                self._t_probe = self._clock()
                return True
            return False

    def record_success(self) -> None:
        with self._lock:
            self.state = "closed"
            self.failures = 0

    def record_failure(self) -> None:
        with self._lock:
            self.failures += 1
            if self.state == "half_open" or self.failures >= self.threshold:
                if self.state != "open":
                    self.trips += 1
                self.state = "open"
                self._t_open = self._clock()


class BreakerBoard:
    """Named-breaker registry (one breaker per route x scene-shape x
    precision). Lazily creates breakers with shared defaults."""

    def __init__(self, threshold: int = 3, cooldown_s: float = 30.0,
                 clock=time.monotonic):
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._lock = threading.Lock()
        self._breakers: Dict[str, CircuitBreaker] = {}

    def get(self, name: str) -> CircuitBreaker:
        with self._lock:
            br = self._breakers.get(name)
            if br is None:
                br = CircuitBreaker(self.threshold, self.cooldown_s,
                                    clock=self._clock)
                self._breakers[name] = br
            return br

    def snapshot(self) -> Dict[str, dict]:
        with self._lock:
            return {name: {"state": br.state, "failures": br.failures,
                           "trips": br.trips}
                    for name, br in sorted(self._breakers.items())}


class RetryPolicy:
    """Bounded, seeded-jitter, deadline-aware retry budget.

    ``budget(attempt, t_deadline)`` returns the backoff sleep (seconds)
    for retry number ``attempt`` (0-based count of retries already
    spent), or None when the budget is exhausted — either ``max_retries``
    is reached or the sleep would land past ``t_deadline`` (monotonic
    seconds; the retry itself would be wasted work that cannot meet the
    deadline). Jitter is drawn from a seeded PRNG so replays are
    deterministic.
    """

    def __init__(self, max_retries: int = 2, backoff_s: float = 0.025,
                 multiplier: float = 2.0, jitter: float = 0.5,
                 seed: int = 0, clock=time.monotonic):
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.multiplier = multiplier
        self.jitter = jitter
        self._rng = random.Random(seed)
        self._clock = clock

    def backoff(self, attempt: int) -> float:
        base = self.backoff_s * self.multiplier ** max(0, attempt)
        return base * (1.0 + self.jitter * self._rng.random())

    def budget(self, attempt: int,
               t_deadline: float = math.inf) -> Optional[float]:
        if attempt >= self.max_retries:
            return None
        delay = self.backoff(attempt)
        if self._clock() + delay >= t_deadline:
            return None
        return delay


class HealthSentinel:
    """Per-scene output health check: finite values and an input/output
    energy envelope.

    The focusing chains conserve energy up to a shape-dependent constant
    (measured out/in ratios run ~1 for CSA and ~n/2 for the unnormalized
    RDA/omega-K ffts — well inside 1e6 either way), so the envelope is a
    coarse physical sanity band, not a tolerance: a healthy pipeline
    passes with orders of magnitude of margin while zeroed, exploded, or
    NaN/Inf output — the silent-corruption modes a dying accelerator
    produces — is flagged and converted into a typed per-request error.

    ``check`` returns None for a healthy image, else a human-readable
    reason string.
    """

    def __init__(self, envelope: float = 1e6):
        if envelope <= 1.0:
            raise ValueError("envelope must be > 1")
        self.envelope = envelope

    def check(self, raw: np.ndarray, image: np.ndarray) -> Optional[str]:
        img = np.asarray(image)
        if not np.all(np.isfinite(img.view(np.float32)
                                  if img.dtype == np.complex64 else img)):
            return "non-finite values in focused image"
        e_in = float(np.sum(np.abs(np.asarray(raw)) ** 2))
        if e_in == 0.0:
            return None                     # zero scene: nothing to compare
        e_out = float(np.sum(np.abs(img) ** 2))
        if e_out == 0.0:
            return "all-zero focused image for a non-zero scene"
        ratio = e_out / e_in
        if ratio > self.envelope or ratio < 1.0 / self.envelope:
            return (f"focused-image energy ratio {ratio:.3e} outside "
                    f"[{1.0 / self.envelope:.0e}, {self.envelope:.0e}] "
                    "envelope")
        return None
