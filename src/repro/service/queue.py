"""Bounded async request queue — the service's backpressure boundary.

Every request enters through :meth:`RequestQueue.put`, which REJECTS
(raises :class:`ServiceOverloaded`) instead of blocking once the bound is
reached: under sustained overload an unbounded queue only converts
throughput saturation into unbounded latency, so the service sheds load
at admission and the caller decides whether to retry. Accepted requests
carry an :class:`asyncio.Future` the batcher resolves with the focused
image (or an exception).
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import NamedTuple, Optional

import numpy as np

from repro.core.sar.geometry import SceneConfig


class ServiceOverloaded(RuntimeError):
    """Admission rejected: the request queue is at its configured bound."""


class SnrGateViolation(ValueError):
    """The requested precision's measured SNR deviation exceeds the
    service's quality gate (ServiceConfig.snr_gate_db)."""


class BatchKey(NamedTuple):
    """Requests coalesce into one micro-batch iff their keys are equal:
    same scene geometry (filters, FFT lengths), same plan variant, same
    precision policy, and the same streamed-vs-in-memory route."""

    scene: SceneConfig
    variant: str
    precision: Optional[str]
    stream: bool


@dataclasses.dataclass
class FocusRequest:
    """One in-flight focusing request (host scene -> focused image)."""

    raw: np.ndarray                 # (na, nr) complex64 host scene
    scene: SceneConfig
    variant: str
    precision: Optional[str]
    future: asyncio.Future          # resolves to the (na, nr) image
    t_submit: float                 # monotonic seconds at admission
    stream: bool = False            # over device budget: run_streamed route

    @property
    def key(self) -> BatchKey:
        return BatchKey(self.scene, self.variant, self.precision,
                        self.stream)


class _Stop:
    pass


STOP = _Stop()


class RequestQueue:
    """asyncio FIFO with an explicit admission bound."""

    def __init__(self, bound: int):
        if bound < 1:
            raise ValueError("queue bound must be >= 1")
        self.bound = bound
        self._q: asyncio.Queue = asyncio.Queue()

    def depth(self) -> int:
        return self._q.qsize()

    def put(self, req: FocusRequest) -> None:
        """Admit a request or raise :class:`ServiceOverloaded`."""
        if self._q.qsize() >= self.bound:
            raise ServiceOverloaded(
                f"queue at bound ({self.bound}); request rejected")
        self._q.put_nowait(req)

    def put_stop(self) -> None:
        """Enqueue the shutdown sentinel (bypasses the bound)."""
        self._q.put_nowait(STOP)

    def drain_nowait(self) -> list:
        """Remove and return everything currently queued (shutdown path:
        requests that raced admission behind the STOP sentinel must be
        failed, not leaked as forever-pending futures)."""
        out = []
        while True:
            try:
                item = self._q.get_nowait()
            except asyncio.QueueEmpty:
                return out
            if item is not STOP:
                out.append(item)

    async def get(self, timeout: Optional[float] = None):
        """Next request, STOP, or None when `timeout` elapses first."""
        if timeout is None:
            return await self._q.get()
        if timeout <= 0:
            try:
                return self._q.get_nowait()
            except asyncio.QueueEmpty:
                return None
        try:
            return await asyncio.wait_for(self._q.get(), timeout)
        except asyncio.TimeoutError:
            return None


def now() -> float:
    return time.monotonic()
