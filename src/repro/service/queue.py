"""Bounded async request queue — the service's backpressure boundary.

Every request enters through :meth:`RequestQueue.put`, which REJECTS
(raises :class:`ServiceOverloaded`) instead of blocking once the bound is
reached: under sustained overload an unbounded queue only converts
throughput saturation into unbounded latency, so the service sheds load
at admission and the caller decides whether to retry. The exception
carries the observed backlog, the bound, and a ``retry_after_hint``
(seconds, derived from an EWMA of recent per-request service time) so
callers can back off intelligently instead of hammering the bound.
Accepted requests carry an :class:`asyncio.Future` the scheduler resolves
with the focused image (or an exception).

Requests may also carry a ``deadline_ms``: the scheduler flushes buckets
in earliest-deadline order, drops requests already past their deadline
before padding them into a batch (their futures raise
:class:`RequestCancelled`), and under overload sheds the LATEST-deadline
pending work first rather than rejecting an earlier-deadline arrival
blindly.
"""
from __future__ import annotations

import asyncio
import dataclasses
import math
import time
from typing import NamedTuple, Optional

import numpy as np

from repro.core.sar.geometry import SceneConfig

# seed for the service-time EWMA before the first batch completes: the
# hint only has to be a sane order of magnitude, not a prediction
_SERVICE_TIME_SEED_S = 0.05
_EWMA_ALPHA = 0.2
# floor for retry_after_hint: the EWMA can be driven arbitrarily small
# by a run of fast (or clock-degenerate) batches, and a non-positive
# hint tells callers to retry immediately — exactly the hammering the
# hint exists to prevent
_RETRY_HINT_FLOOR_S = 1e-3


class ServiceOverloaded(RuntimeError):
    """Admission rejected: the request backlog is at its configured bound.

    Machine-readable attributes (also rendered into the message):

    ``depth``             backlog observed at rejection (queued requests
                          plus the scheduler's not-yet-dispatched buckets)
    ``bound``             the configured admission bound
    ``retry_after_hint``  seconds a caller should wait before retrying —
                          the backlog times an EWMA of recent per-request
                          service time, i.e. roughly when the current
                          backlog will have drained
    """

    def __init__(self, depth: int, bound: int, retry_after_hint: float):
        self.depth = int(depth)
        self.bound = int(bound)
        self.retry_after_hint = float(retry_after_hint)
        super().__init__(
            f"backlog at bound (depth {self.depth} >= bound {self.bound}); "
            f"request rejected; retry_after_hint={self.retry_after_hint:.3f}s")


class RequestCancelled(RuntimeError):
    """The request was dropped before execution: past its deadline at
    flush time, or shed under overload to admit earlier-deadline work."""


class SnrGateViolation(ValueError):
    """The requested precision's measured SNR deviation exceeds the
    service's quality gate (ServiceConfig.snr_gate_db)."""


class BatchKey(NamedTuple):
    """Requests coalesce into one micro-batch iff their keys are equal:
    same scene geometry (filters, FFT lengths), same plan variant, same
    precision policy, and the same streamed-vs-in-memory route.
    Deadlines and priorities are per-request scheduling state, NOT part
    of the key — a tight-deadline request still coalesces with a lax one."""

    scene: SceneConfig
    variant: str
    precision: Optional[str]
    stream: bool


@dataclasses.dataclass
class FocusRequest:
    """One in-flight focusing request (host scene -> focused image)."""

    raw: np.ndarray                 # (na, nr) complex64 host scene
    scene: SceneConfig
    variant: str
    precision: Optional[str]
    future: asyncio.Future          # resolves to the (na, nr) image
    t_submit: float                 # monotonic seconds at admission
    stream: bool = False            # over device budget: run_streamed route
    deadline_ms: Optional[float] = None  # completion deadline, relative to
                                         # submission; None = no deadline
    priority: int = 0               # EDF/shed tiebreak: higher wins

    @property
    def key(self) -> BatchKey:
        return BatchKey(self.scene, self.variant, self.precision,
                        self.stream)

    @property
    def t_deadline(self) -> float:
        """Absolute monotonic deadline (+inf when none was requested)."""
        if self.deadline_ms is None:
            return math.inf
        return self.t_submit + self.deadline_ms / 1e3


class _Stop:
    pass


STOP = _Stop()


class RequestQueue:
    """asyncio FIFO with an explicit admission bound.

    The bound covers the whole pre-dispatch backlog, not just this FIFO:
    the scheduler drains the FIFO into coalescing buckets aggressively,
    so callers pass their bucketed count via ``extra`` and the bound is
    enforced against ``qsize + extra``."""

    def __init__(self, bound: int):
        if bound < 1:
            raise ValueError("queue bound must be >= 1")
        self.bound = bound
        self._q: asyncio.Queue = asyncio.Queue()
        self._service_time_s = _SERVICE_TIME_SEED_S

    def depth(self) -> int:
        return self._q.qsize()

    def note_service_time(self, seconds_per_request: float) -> None:
        """Fold one completed request's service time into the EWMA that
        prices ``retry_after_hint`` (called by the service per batch)."""
        if seconds_per_request > 0:
            self._service_time_s = (
                _EWMA_ALPHA * seconds_per_request
                + (1.0 - _EWMA_ALPHA) * self._service_time_s)

    def retry_after_hint(self, depth: int) -> float:
        """Seconds until a backlog of ``depth`` requests should have
        drained at the recently observed service rate. Clamped to a
        positive floor: a cold or degenerate EWMA must never tell
        callers to retry after 0 (or negative) seconds."""
        return max(_RETRY_HINT_FLOOR_S,
                   (depth + 1) * self._service_time_s)

    def put(self, req: FocusRequest, extra: int = 0) -> None:
        """Admit a request or raise :class:`ServiceOverloaded`.

        ``extra`` is backlog held outside this FIFO (the scheduler's
        pending buckets); the bound applies to the total."""
        depth = self._q.qsize() + max(0, extra)
        if depth >= self.bound:
            raise ServiceOverloaded(
                depth=depth, bound=self.bound,
                retry_after_hint=self.retry_after_hint(depth))
        self._q.put_nowait(req)

    def put_stop(self) -> None:
        """Enqueue the shutdown sentinel (bypasses the bound)."""
        self._q.put_nowait(STOP)

    def drain_nowait(self) -> list:
        """Remove and return everything currently queued (shutdown path:
        requests that raced admission behind the STOP sentinel must be
        failed, not leaked as forever-pending futures)."""
        out = []
        while True:
            try:
                item = self._q.get_nowait()
            except asyncio.QueueEmpty:
                return out
            if item is not STOP:
                out.append(item)

    async def get(self, timeout: Optional[float] = None):
        """Next request, STOP, or None when `timeout` elapses first."""
        if timeout is None:
            return await self._q.get()
        if timeout <= 0:
            try:
                return self._q.get_nowait()
            except asyncio.QueueEmpty:
                return None
        try:
            return await asyncio.wait_for(self._q.get(), timeout)
        except asyncio.TimeoutError:
            return None


def now() -> float:
    return time.monotonic()
