"""Seam-level fault injection for the serving stack.

Chaos engineering needs faults at the seams the real fleet breaks at,
not `raise` statements sprinkled into product code. This module wraps
any serving backend (`ChaosBackend`) and injects failures from a SEEDED,
deterministic schedule at the exact seams `service._run_batch` has to
survive:

``dispatch_error``  the backend raises mid-dispatch (OOM, runtime error)
``nan_output``      the dispatch "succeeds" but scene 0 of the returned
                    batch is silently corrupted with NaNs — exercising
                    the output health sentinel, not the except path
``lane_hang``       the lane thread blocks (dead device queue) until the
                    stall watchdog restarts the lane; the injector keeps
                    a release hook so tests/benches never leak a hung
                    thread past process exit
``straggler``       the dispatch completes but ``delay_s`` late
``cache_corrupt``   the tuning cache file is truncated mid-flight,
                    exercising the quarantine-and-rebuild path
``poison_scene``    any batch containing a registered scene (matched by
                    content digest) fails deterministically EVERY time —
                    the bisection seam: retries don't help, only
                    splitting the batch isolates the poison

It unifies `repro.distributed.fault` — `SimulatedFailure` is the one
injected-error type across the distributed layer and the service, the
step-keyed `FailureInjector` drives dispatch-ordinal placement, and the
`StragglerWatchdog` is re-exported for lane-level slow-dispatch
flagging — rather than growing a second fault toolkit.
"""
from __future__ import annotations

import dataclasses
import hashlib
import random
import re
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.distributed.fault import (       # noqa: F401  (re-exports)
    FailureInjector,
    SimulatedFailure,
    StragglerWatchdog,
)

SEAMS = ("dispatch_error", "nan_output", "lane_hang", "straggler",
         "cache_corrupt", "poison_scene")

_LANE_THREAD_RE = re.compile(r"^lane-([^_]+)_\d+$")


def current_lane() -> Optional[str]:
    """The worker-pool lane name this thread belongs to (None off-lane).
    Lane executors name their threads ``lane-<name>_<i>``."""
    m = _LANE_THREAD_RE.match(threading.current_thread().name)
    return m.group(1) if m else None


def scene_digest(raw: np.ndarray) -> str:
    """Content digest of one host scene — how poison faults identify
    their target across batching, padding, and bisection."""
    arr = np.ascontiguousarray(np.asarray(raw))
    return hashlib.blake2b(arr.tobytes(), digest_size=8).hexdigest()


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    seam          one of SEAMS.
    at_dispatch   the 0-based dispatch ordinal the fault fires at
                  (counted across execute + execute_streamed calls;
                  None for content-keyed poison_scene faults).
    lane          restrict to dispatches running on this lane (None =
                  any lane); a fault whose ordinal arrives on another
                  lane simply fires there — the ordinal, not the lane,
                  is the primary key.
    delay_s       straggler delay.
    match         scene_digest() of the poisoned scene.
    """

    seam: str
    at_dispatch: Optional[int] = None
    lane: Optional[str] = None
    delay_s: float = 0.0
    match: Optional[str] = None

    def __post_init__(self):
        if self.seam not in SEAMS:
            raise ValueError(f"unknown seam {self.seam!r}; known: {SEAMS}")
        if self.seam == "poison_scene":
            if self.match is None:
                raise ValueError("poison_scene needs a scene digest")
        elif self.at_dispatch is None:
            raise ValueError(f"{self.seam} needs at_dispatch")


def seeded_schedule(seed: int, n_dispatches: int,
                    seams: Sequence[str] = ("dispatch_error", "nan_output",
                                            "lane_hang"),
                    first: int = 2, delay_s: float = 0.25,
                    ) -> List[FaultSpec]:
    """Deterministic fault schedule: one fault per requested seam,
    placed at distinct dispatch ordinals in ``[first, n_dispatches)``
    drawn from a seeded PRNG (the chaos-replay harness's schedule —
    same seed, same faults). ``first`` keeps the earliest dispatches
    clean so lane service-time EWMAs warm before the first stall."""
    ordinals = list(range(first, max(n_dispatches, first + len(seams))))
    rng = random.Random(seed)
    rng.shuffle(ordinals)
    specs = []
    for seam, at in zip(seams, sorted(ordinals[:len(seams)])):
        specs.append(FaultSpec(seam=seam, at_dispatch=at,
                               delay_s=delay_s if seam == "straggler"
                               else 0.0))
    return specs


class FaultInjector:
    """Replays a fault schedule keyed by dispatch ordinal.

    Thread-safe: lane threads call ``begin``/``finish`` around each
    backend dispatch. Each ordinal-keyed fault fires once (the
    underlying `distributed.fault.FailureInjector` semantics); poison
    faults fire on EVERY dispatch whose batch contains the poisoned
    scene, which is what makes bisection — not retry — the only cure.
    """

    def __init__(self, faults: Sequence[FaultSpec] = (),
                 hang_timeout_s: float = 120.0,
                 on_cache_corrupt: Optional[Callable[[], None]] = None):
        self._lock = threading.Lock()
        self._dispatch = 0
        self._by_ordinal: Dict[int, FaultSpec] = {}
        self._poison: Dict[str, FaultSpec] = {}
        for spec in faults:
            if spec.seam == "poison_scene":
                self._poison[spec.match] = spec
            else:
                if spec.at_dispatch in self._by_ordinal:
                    raise ValueError(
                        f"two faults at dispatch {spec.at_dispatch}")
                self._by_ordinal[spec.at_dispatch] = spec
        # ordinal-keyed faults fire once each — delegated to the
        # distributed layer's step-keyed injector for the bookkeeping
        self._armed = FailureInjector(tuple(self._by_ordinal))
        self.hang_timeout_s = hang_timeout_s
        self.on_cache_corrupt = on_cache_corrupt
        self.fired: List[Tuple[int, FaultSpec]] = []
        self._hangs: List[threading.Event] = []

    # -- lifecycle -----------------------------------------------------------
    def release_hangs(self) -> None:
        """Unblock every injected hang immediately. Tests and benches
        MUST call this in teardown: lane restarts abandon the hung
        thread, but ThreadPoolExecutor joins all threads at interpreter
        exit, so an un-released hang would stall process shutdown until
        ``hang_timeout_s``."""
        with self._lock:
            hangs = list(self._hangs)
        for ev in hangs:
            ev.set()

    @property
    def faults_fired(self) -> int:
        return len(self.fired)

    def seams_fired(self) -> List[str]:
        return sorted({spec.seam for _, spec in self.fired})

    # -- injection seams -----------------------------------------------------
    def _take(self, scenes: Sequence[np.ndarray]
              ) -> Tuple[int, Optional[FaultSpec]]:
        with self._lock:
            ordinal = self._dispatch
            self._dispatch += 1
            # ordinal-keyed faults are consulted FIRST: a poison hit at
            # the same dispatch must not shadow (and silently swallow) a
            # one-shot fault scheduled there — the poison re-fires on
            # the scene's next dispatch anyway, the ordinal never
            # comes back
            spec = self._by_ordinal.get(ordinal)
            if spec is not None and spec.lane in (None, current_lane()):
                try:
                    self._armed.check(ordinal)     # fires once per ordinal
                except SimulatedFailure:
                    self.fired.append((ordinal, spec))
                    return ordinal, spec
            for raw in scenes:
                pspec = self._poison.get(scene_digest(raw))
                if pspec is not None:
                    self.fired.append((ordinal, pspec))
                    return ordinal, pspec
            return ordinal, None

    def begin(self, scenes: Sequence[np.ndarray]) -> Tuple[int,
                                                           Optional[FaultSpec]]:
        """Called on the lane thread before the inner dispatch. Raises,
        sleeps, or hangs according to the schedule; returns the ordinal
        and any pending output-corruption fault for ``finish``."""
        ordinal, spec = self._take(scenes)
        if spec is None:
            return ordinal, None
        if spec.seam == "poison_scene":
            raise SimulatedFailure(
                f"injected poison scene (digest {spec.match}) at "
                f"dispatch {ordinal}")
        if spec.seam == "dispatch_error":
            raise SimulatedFailure(
                f"injected dispatch error at dispatch {ordinal}")
        if spec.seam == "lane_hang":
            ev = threading.Event()
            with self._lock:
                self._hangs.append(ev)
            ev.wait(self.hang_timeout_s)
            # by now the stall watchdog has restarted the lane and
            # retried elsewhere; fail the abandoned call for hygiene
            raise SimulatedFailure(
                f"injected lane death at dispatch {ordinal} "
                f"(lane {current_lane()})")
        if spec.seam == "straggler":
            ev = threading.Event()       # interruptible sleep (release_hangs)
            with self._lock:
                self._hangs.append(ev)
            ev.wait(spec.delay_s)
            return ordinal, None
        if spec.seam == "cache_corrupt":
            if self.on_cache_corrupt is not None:
                self.on_cache_corrupt()
            return ordinal, None
        return ordinal, spec                       # nan_output: apply after

    def finish(self, pending: Optional[FaultSpec],
               images: np.ndarray) -> np.ndarray:
        """Apply a pending output-corruption fault to the completed
        dispatch's images (scene 0 only — its coalesced neighbors stay
        healthy, so the sentinel isolates exactly one request)."""
        if pending is None or pending.seam != "nan_output":
            return images
        out = np.array(images, copy=True)
        flat = out.reshape(out.shape[0], -1) if out.ndim > 1 \
            else out.reshape(1, -1)
        flat[0, :min(8, flat.shape[1])] = np.nan
        return out


def truncate_file(path: str, keep: int = 17) -> None:
    """Corrupt a file in place by truncating it mid-token (the
    cache_corrupt seam's default action)."""
    try:
        with open(path, "r+b") as f:
            f.truncate(keep)
    except OSError:
        pass                           # file absent: nothing to corrupt


class ChaosBackend:
    """Backend wrapper that replays a FaultInjector schedule around the
    inner backend's dispatches. `warm()` passes through un-faulted (the
    schedule counts SERVING dispatches), so warm-up stays deterministic
    and the ordinal clock starts at the first real request."""

    name = "chaos"

    def __init__(self, inner, injector: FaultInjector):
        self.inner = inner
        self.injector = injector

    def warm(self, key, max_batch: int = 4) -> None:
        self.inner.warm(key, max_batch)

    def execute(self, key, batch: np.ndarray) -> np.ndarray:
        _, pending = self.injector.begin(list(batch))
        out = self.inner.execute(key, batch)
        return self.injector.finish(pending, out)

    def execute_streamed(self, key, raw: np.ndarray,
                         strips: int = 4) -> np.ndarray:
        _, pending = self.injector.begin([raw])
        out = self.inner.execute_streamed(key, raw, strips)
        return self.injector.finish(pending, out[None])[0]
