"""Pluggable execution backends for the focusing service.

A backend turns one coalesced micro-batch into focused images, blocking
the calling thread (the service invokes it through an executor so the
event loop keeps admitting requests while the device computes). Two are
shipped:

``local``    One-device execution through the warm compiled-pipeline
             cache (`core.plan.cached_pipeline`): per BatchKey, ONE
             Pipeline whose jit traces, filter payloads, and tuned
             configs persist across requests. Scenes whose whole slab
             fits the VMEM budget are transparently routed from their
             per-axis variant to its single-dispatch megakernel twin
             (FUSED1_TWINS; bit-identical at every precision,
             `fused1="off"` opts out).
             `warm()` optionally sweeps
             a few (block, col_block) line-block configs on the real
             batched pipeline and pins the winner — interpret-mode CPU
             timing is too shape-dependent for the kernel-level cache
             alone (same rationale as benchmarks/bench_rda.run_batched).
             The sweep runs through `repro.tuning.measured_search` and
             its winner persists to the shared device-fingerprinted
             tuning cache under a pipeline-kind TuneKey, so serving
             warms survive process restarts: the next process's `warm()`
             is a cache hit and pays only the jit traces. Big streamed
             scenes route to the SHARDED megakernel twin when multiple
             devices are visible and the cost model prefers it
             (`sharded="off"` opts out; see `execute_streamed`).

``sharded``  Multi-device execution via the shard_map corner-turn
             lowering (`core.sar.distributed.build_sharded`): schedule
             'corner2' lowers the compiled plan generically (all_to_all
             at each transform-axis change), 'halo' uses the hand-written
             single-turn RDA schedule. Oversized scenes route through the
             mesh too — P devices hold P× the budget — so this backend
             has no separate streaming path.
"""
from __future__ import annotations

import time
from collections import Counter
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import plan as planlib
from repro.kernels.fft4step import resolve_precision
from repro.service.queue import BatchKey
from repro.service.resilience import BreakerBoard
from repro import tuning


def _resolve_blocks(cfg, block: Optional[int], col_block: Optional[int]):
    """-1 means 'all lines' for the respective dispatch orientation."""
    if block == -1:
        block = cfg.na
    if col_block == -1:
        col_block = cfg.nr
    return block, col_block


# Batch-size buckets are powers of two: every distinct batch shape costs
# one jit trace (hundreds of ms), so a partial batch pads with zero
# scenes up to the next pre-traced bucket instead of compiling a fresh
# executable mid-serving. Zero scenes are numerically inert (every stage
# maps 0 -> 0) and their rows are sliced off the reply. The SAME buckets
# key the tuning cache (tuning.TuneKey normalizes batch through this), so
# a padded batch always looks up the config tuned for the shape that
# actually runs.
_bucket = tuning.bucket_batch

# Per-axis variants with a single-dispatch megakernel twin: when the
# scene's whole slab fits the VMEM budget (repro.tuning.cost.mega_residency
# says 'vmem'), the local backend transparently serves these through the
# fused1 pipeline — same math bit-for-bit at EVERY precision (asserted in
# tests: bs16 carries per-line block exponents through the in-kernel
# corner turns, so the fused dispatch quantizes exactly like the per-axis
# chain), one dispatch and zero HBM intermediates instead of three
# round-trips.
FUSED1_TWINS = {
    "fused3": "fused1",
    "csa_fused": "csa_fused1",
    "omegak": "omegak_fused1",
}

# Last-resort degradation tier: the DEFUSED chain for a fused per-axis
# variant — more, smaller dispatches through the same plan stages. Unlike
# the fused1 twin this step is NOT bit-identical (stage-boundary rounding
# differs), so it only serves after both fused tiers have failed: a
# numerically equivalent image beats a failed request. omega-K has no
# defused sibling (its Stolt interpolation only exists fused), so its
# chain ends at the per-axis tier.
DEFUSED_FALLBACK = {
    "fused3": "unfused",
    "fused": "unfused",
    "csa_fused": "csa",
}


def _pad_batch(batch: np.ndarray) -> np.ndarray:
    b = batch.shape[0]
    pb = _bucket(b)
    if pb == b:
        return batch
    pad = np.zeros((pb - b, *batch.shape[1:]), batch.dtype)
    return np.concatenate([batch, pad])


class LocalBackend:
    """Single-device backend over the compiled-pipeline cache."""

    name = "local"

    def __init__(self, sweep: Sequence[Tuple[Optional[int], Optional[int]]]
                 = ((None, None), (32, -1)), tune_cache=None,
                 fused1: str = "auto", sharded: str = "auto",
                 fallback: str = "auto",
                 breakers: Optional[BreakerBoard] = None):
        if fused1 not in ("auto", "off"):
            raise ValueError(f"fused1 must be 'auto' or 'off', got "
                             f"{fused1!r}")
        if sharded not in ("auto", "off"):
            raise ValueError(f"sharded must be 'auto' or 'off', got "
                             f"{sharded!r}")
        if fallback not in ("auto", "off"):
            raise ValueError(f"fallback must be 'auto' or 'off', got "
                             f"{fallback!r}")
        self.sweep = tuple(sweep)
        self.fused1 = fused1
        self.sharded = sharded
        self.fallback = fallback            # "off" disables degraded tiers
        # per-route circuit breakers (route x variant x shape x precision):
        # a route that keeps failing is skipped on the hot path until its
        # cooldown expires, then re-probed half-open
        self.breakers = breakers if breakers is not None else BreakerBoard()
        self.fallbacks: Counter = Counter()  # degraded-route serve counts
        self._tune_cache = tune_cache       # None -> the shared default
        self._best: Dict[BatchKey, Tuple[Optional[int], Optional[int]]] = {}
        self._sched: Dict[BatchKey, "tuning.Schedule"] = {}
        self._fns: Dict[Tuple[BatchKey, str], callable] = {}
        self._sharded_fns: Dict[BatchKey, callable] = {}

    def _route_variant(self, key: BatchKey) -> str:
        """The variant actually compiled for a BatchKey: VMEM-fitting
        scenes requesting a per-axis variant with a megakernel twin are
        served by the single-dispatch fused1 pipeline (`fused1="off"`
        pins the requested variant). The route must be invisible — the
        served image equals the requested variant's bit-for-bit — and it
        is, at every precision: f32/bf16/f16 trivially (the fused kernel
        runs the identical per-segment math), and bs16 because the
        megakernel carries per-line block exponents through its in-kernel
        corner turns, quantizing exactly as the three dispatches would
        (the route-invisibility matrix in tests/test_service.py)."""
        twin = FUSED1_TWINS.get(key.variant)
        if (self.fused1 == "auto" and twin is not None
                and tuning.cost.mega_residency(key.scene.na, key.scene.nr)
                == "vmem"):
            return twin
        return key.variant

    def _pipeline(self, key: BatchKey, batch: int = 1,
                  variant: Optional[str] = None):
        """The compiled pipeline serving ``key`` — at the routed tier-0
        variant by default, or at an explicit ``variant`` (a degraded
        tier, or the requested per-axis variant for sweeps/streams)."""
        block, col_block = _resolve_blocks(
            key.scene, *self._best.get(key, (None, None)))
        kw = dict(batch=batch)
        if key.precision is not None:
            kw["precision"] = key.precision
        if block is not None:
            kw["block"] = block
        if col_block is not None:
            kw["col_block"] = col_block
        sched = self._sched.get(key)
        if sched is not None:
            kw["schedule"] = sched
        if variant is None:
            variant = self._route_variant(key)
        return planlib.cached_pipeline(key.scene, variant, **kw)

    def _fn(self, key: BatchKey, variant: Optional[str] = None):
        if variant is None:
            variant = self._route_variant(key)
        if (key, variant) not in self._fns:
            self._fns[(key, variant)] = \
                self._pipeline(key, variant=variant).jitted()
        return self._fns[(key, variant)]

    # -- tiered degradation --------------------------------------------------
    def _execute_tiers(self, key: BatchKey) -> List[Tuple[str, str]]:
        """Ordered (route_name, variant) tiers for a coalesced batch:
        the megakernel twin (when routed), the requested per-axis
        variant, and — unless ``fallback="off"`` — the defused chain.
        Tier 0 is EXACTLY what `_route_variant` serves on the fault-free
        path, so degradation never changes healthy results."""
        routed = self._route_variant(key)
        tiers = [("fused1" if routed != key.variant else "plan", routed)]
        if routed != key.variant:
            tiers.append(("plan", key.variant))
        if self.fallback == "auto":
            defused = DEFUSED_FALLBACK.get(key.variant)
            if defused is not None and defused != key.variant:
                tiers.append(("defused", defused))
        return tiers

    def _breaker(self, route: str, variant: str, key: BatchKey):
        cfg = key.scene
        return self.breakers.get(
            f"{route}:{variant}:{cfg.na}x{cfg.nr}:{key.precision}")

    def _tune_key(self, key: BatchKey, max_batch: int) -> "tuning.TuneKey":
        cfg = key.scene
        return tuning.TuneKey.pipeline(
            variant=key.variant, na=cfg.na, nr=cfg.nr, batch=max_batch,
            precision=key.precision)

    def warm(self, key: BatchKey, max_batch: int = 4) -> None:
        """Pre-pull everything a request would otherwise pay for: compile
        the plan (materializing filters + tuned kernel configs), resolve
        the (block, col_block) pipeline config — from the shared tuning
        cache when a previous process already swept this key, else by
        running the sweep through `repro.tuning.measured_search` on a
        B=max_batch scene batch and persisting the winner — and pre-trace
        the jit executable for every power-of-two batch bucket up to
        max_batch (partial batches pad to a bucket at execute time)."""
        cfg = key.scene
        zeros = jnp.zeros((_bucket(max_batch), cfg.na, cfg.nr),
                          jnp.complex64)
        if len(self.sweep) > 1 and key not in self._best:
            tune_cache = self._tune_cache or tuning.get_cache()
            tkey = self._tune_key(key, max_batch)
            try:
                hit = tune_cache.get(tkey)
                sched = tune_cache.get_schedule(tkey)
            except Exception:
                hit = sched = None
                              # corrupt/foreign-schema file: fall back to
                              # the in-process sweep, never fail warm-up
            if hit is not None:
                self._best[key] = (hit.block, hit.col_block)
                # a persisted graph-search Schedule carries per-segment
                # decisions the flat config can't express — compile the
                # served pipeline through it; a degenerate (flat-derived)
                # schedule adds nothing, so skip it and keep the cache
                # key identical to the pre-schedule one
                if sched is not None and \
                        sched != tuning.Schedule.from_config(hit):
                    self._sched[key] = sched
            else:
                def measure(cand, iters):
                    blk, cb = cand
                    self._best[key] = (blk, cb)
                    # sweep the REQUESTED per-axis pipeline: a mega-routed
                    # pipeline ignores (block, col_block), so timing it
                    # would persist a noise winner to the cache — the swept
                    # config is what execute_streamed and fused1="off"
                    # processes actually consume
                    f = self._pipeline(key, batch=max_batch,
                                       variant=key.variant).jitted()
                    jax.block_until_ready(f(zeros))   # compile
                    t0 = time.perf_counter()
                    jax.block_until_ready(f(zeros))
                    return time.perf_counter() - t0

                best, seconds, _ = tuning.measured_search(
                    self.sweep, measure, rungs=(1,))
                self._best[key] = best
                try:
                    tune_cache.put(
                        tkey,
                        tuning.KernelConfig(block=best[0],
                                            col_block=best[1]),
                        seconds=seconds, source="sweep")
                except Exception:
                    pass      # read-only cache dir: the sweep result still
                              # serves this process, it just won't persist
        f = self._fn(key)
        b = 1
        while b <= zeros.shape[0]:
            jax.block_until_ready(f(zeros[:b]))
            b *= 2

    def execute(self, key: BatchKey, batch: np.ndarray) -> np.ndarray:
        """(B, na, nr) host batch -> (B, na, nr) focused images.
        Pads to the nearest power-of-two bucket (see `_bucket`).

        Walks the degradation tiers (`_execute_tiers`): a tier whose
        circuit breaker is open is skipped (until its cooldown admits a
        half-open probe), a tier that raises records the failure and
        falls through to the next, and the LAST tier always runs so a
        request is never failed by an open breaker alone. On the
        fault-free path tier 0 serves and the result is bit-identical to
        the pre-resilience backend."""
        b = batch.shape[0]
        padded = jnp.asarray(_pad_batch(batch))
        tiers = self._execute_tiers(key)
        last_err: Optional[Exception] = None
        for i, (route, variant) in enumerate(tiers):
            br = self._breaker(route, variant, key)
            if i < len(tiers) - 1 and not br.allow():
                self.fallbacks[f"skip:{route}"] += 1
                continue
            try:
                out = np.asarray(self._fn(key, variant)(padded))
            except Exception as e:          # noqa: BLE001 — tier boundary
                br.record_failure()
                last_err = e
                continue
            br.record_success()
            if (route, variant) != tiers[0]:
                self.fallbacks[f"serve:{route}"] += 1
            return out[:b]
        raise last_err

    def _sharded_twin(self, key: BatchKey) -> Optional[str]:
        """The megakernel twin to run SHARDED for a big streamed scene,
        or None to keep the host-strip path. Routes when a twin exists
        (any precision — bs16's carried exponents all_gather across the
        corner turns, so the sharded image stays bit-identical), the
        scene tiles the mesh, and the roofline prefers P per-device
        megakernels plus collective corner turns over strip-streaming
        one device (`repro.tuning.cost.sharded_preferred`)."""
        twin = FUSED1_TWINS.get(key.variant)
        p = len(jax.devices())
        if (self.sharded != "auto" or self.fused1 == "off" or twin is None
                or p <= 1):
            return None
        cfg = key.scene
        prec = resolve_precision(key.precision).name
        if not tuning.cost.sharded_preferred(cfg.na, cfg.nr, devices=p,
                                             precision=prec):
            return None
        return twin

    def _sharded_fn(self, key: BatchKey):
        if key not in self._sharded_fns:
            from repro.core.sar.distributed import make_sar_mesh
            kw = {}
            if key.precision is not None:
                kw["precision"] = key.precision
            pipe = planlib.cached_pipeline(
                key.scene, self._sharded_twin(key), **kw)
            self._sharded_fns[key] = pipe.lower_sharded(make_sar_mesh())
        return self._sharded_fns[key]

    def execute_streamed(self, key: BatchKey, raw: np.ndarray,
                         strips: int = 4) -> np.ndarray:
        """One host-resident scene, over the single-device budget.

        Default path: Pipeline.run_streamed on the REQUESTED per-axis
        variant (strip transfer overlapped with compute; bit-identical
        to `execute`) — the streaming executor strips one free axis at a
        time, which a cross-axis megakernel step deliberately refuses.

        Multi-device path: when the cost model prefers it
        (`_sharded_twin`), the scene runs as the variant's megakernel
        twin lowered through shard_map — one staged megakernel dispatch
        per device per phase group, all_to_all corner turns between
        groups, each device holding a 1/P slab. Every precision is
        bit-identical to the per-axis strip path (asserted in tests;
        bs16's carried exponents ride the collectives), so the route
        stays invisible.

        Degradation: a failing (or breaker-open) sharded route falls
        back to the single-device strip path — sharded -> local is
        bit-identical, so the fallback is invisible beyond latency."""
        if self._sharded_twin(key) is not None:
            br = self._breaker("sharded", self._sharded_twin(key), key)
            if br.allow():
                try:
                    out = np.asarray(self._sharded_fn(key)(jnp.asarray(raw)))
                except Exception:           # noqa: BLE001 — tier boundary
                    br.record_failure()
                    self.fallbacks["serve:local_stream"] += 1
                else:
                    br.record_success()
                    return out
            else:
                self.fallbacks["skip:sharded"] += 1
        return np.asarray(self._pipeline(key, variant=key.variant)
                          .run_streamed(raw, strips=strips))


class ShardedBackend:
    """Multi-device backend over the shard_map corner-turn lowering."""

    name = "sharded"

    def __init__(self, mesh=None, axes=("data",), schedule: str = "corner2",
                 turn_dtype=None):
        if mesh is None:
            # multi-host capable: contiguous per-host device blocks
            # (corner2 layout) — see distributed.make_sar_mesh
            from repro.core.sar.distributed import make_sar_mesh
            mesh = make_sar_mesh(axes)
        self.mesh = mesh
        self.axes = axes
        self.schedule = schedule
        self.turn_dtype = turn_dtype
        self._fns: Dict[BatchKey, callable] = {}

    def _fn(self, key: BatchKey):
        if key not in self._fns:
            from repro.core.sar.distributed import build_sharded
            kw = {}
            if key.precision is not None:
                kw["precision"] = key.precision
            self._fns[key] = build_sharded(
                key.scene, key.variant, self.mesh, self.axes,
                schedule=self.schedule, turn_dtype=self.turn_dtype, **kw)
        return self._fns[key]

    def warm(self, key: BatchKey, max_batch: int = 4) -> None:
        cfg = key.scene
        fn = self._fn(key)
        if self.schedule == "halo":        # 2-D runner: one trace
            jax.block_until_ready(fn(jnp.zeros((cfg.na, cfg.nr),
                                               jnp.complex64)))
            return
        zeros = jnp.zeros((_bucket(max_batch), cfg.na, cfg.nr),
                          jnp.complex64)
        b = 1
        while b <= zeros.shape[0]:
            jax.block_until_ready(fn(zeros[:b]))
            b *= 2

    def execute(self, key: BatchKey, batch: np.ndarray) -> np.ndarray:
        fn = self._fn(key)
        if self.schedule == "halo":        # the halo runner is per-scene
            return np.stack([np.asarray(fn(jnp.asarray(x))) for x in batch])
        b = batch.shape[0]
        return np.asarray(fn(jnp.asarray(_pad_batch(batch))))[:b]

    def execute_streamed(self, key: BatchKey, raw: np.ndarray,
                         strips: int = 4) -> np.ndarray:
        # a scene over the single-device budget fits the mesh: the slabs
        # are 1/P of the scene each, so just run it sharded.
        return np.asarray(self._fn(key)(jnp.asarray(raw)))


BACKENDS = {"local": LocalBackend, "sharded": ShardedBackend}


def make_backend(name: str, **kw):
    if name not in BACKENDS:
        raise KeyError(f"unknown backend {name!r}; known: {sorted(BACKENDS)}")
    return BACKENDS[name](**kw)
