"""Production-shaped SAR focusing service over the SpectralPlan executor.

An asyncio request front end that coalesces same-(SceneConfig, variant,
Precision) requests into (B, na, nr) micro-batches under a
deadline/max-batch policy, hands each batch off to a worker pool of
executor lanes (continuous batching: batch k+1 coalesces and pads while
batch k computes; over-budget scenes stream on a dedicated lane),
schedules flushes earliest-deadline first with pre-dispatch cancellation
of past-deadline work, executes through warm per-plan caches on a
pluggable backend (single-device `local`, or `sharded` shard_map
corner-turn slabs), enforces a per-request precision SNR gate, applies
admission backpressure with deadline-aware shedding, and emits
latency/goodput/lane-occupancy metrics in the BENCH_*.json format.

    from repro.service import FocusService, ServiceConfig
    svc = FocusService(ServiceConfig(max_batch=4, max_delay_ms=5.0))
    await svc.start(warm=[(cfg, "fused3", None)])
    image = await svc.focus(raw, cfg, deadline_ms=250.0)

See docs/serving.md for the request lifecycle and policy semantics.
"""
from repro.service.backends import (  # noqa: F401
    BACKENDS,
    LocalBackend,
    ShardedBackend,
    make_backend,
)
from repro.service.batcher import MicroBatcher  # noqa: F401
from repro.service.faults import (  # noqa: F401
    ChaosBackend,
    FaultInjector,
    FaultSpec,
    SimulatedFailure,
    scene_digest,
    seeded_schedule,
)
from repro.service.metrics import ServiceMetrics  # noqa: F401
from repro.service.queue import (  # noqa: F401
    BatchKey,
    FocusRequest,
    RequestCancelled,
    RequestQueue,
    ServiceOverloaded,
    SnrGateViolation,
)
from repro.service.resilience import (  # noqa: F401
    BreakerBoard,
    CircuitBreaker,
    HealthSentinel,
    LaneStalled,
    OutputCorrupted,
    RetryPolicy,
)
from repro.service.service import (  # noqa: F401
    FocusService,
    ServiceConfig,
)
from repro.service.workers import (  # noqa: F401
    Lane,
    WorkerPool,
)
