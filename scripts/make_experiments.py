"""Generate EXPERIMENTS.md from experiments/dryrun/*.json + bench logs.

  PYTHONPATH=src python scripts/make_experiments.py

Sections §Dry-run and §Roofline are generated from the artifacts; §Perf and
§Paper-validation include the curated iteration logs (PERF_LOG below, updated
by hand as hillclimbing proceeds).
"""
from __future__ import annotations

import glob
import json
import os
import sys

OUT = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
DRY = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

ARCH_ORDER = [
    "recurrentgemma-9b", "minitron-4b", "gemma3-12b", "stablelm-1.6b",
    "yi-34b", "qwen2-vl-72b", "llama4-scout-17b-a16e",
    "granite-moe-3b-a800m", "whisper-tiny", "falcon-mamba-7b", "sar-rda-4k",
    "sar-rda-8k",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k", "n/a"]

SKIPS = [
    ("minitron-4b", "long_500k", "pure full attention"),
    ("gemma3-12b", None, None),
    ("stablelm-1.6b", "long_500k", "pure full attention"),
    ("yi-34b", "long_500k", "pure full attention"),
    ("qwen2-vl-72b", "long_500k", "pure full attention"),
    ("llama4-scout-17b-a16e", "long_500k",
     "1-in-4 global full-attention layers"),
    ("granite-moe-3b-a800m", "long_500k", "pure full attention"),
    ("whisper-tiny", "long_500k", "enc-dec, bounded decoder positions"),
]


def load():
    recs = {}
    for p in glob.glob(os.path.join(DRY, "*.json")):
        r = json.load(open(p))
        recs[(r["arch"], r["shape"], "multi" if r["devices"] == 512
              else "single")] = r
    return recs


def fmt_ms(s):
    return f"{s * 1e3:.2f}"


def gib(b):
    return f"{b / 2**30:.2f}"


def note_for(r):
    """One sentence: what would move the dominant term down."""
    roof = r["roofline"]
    b = roof["bottleneck"]
    arch, shape = r["arch"], r["shape"]
    if arch.startswith("sar-rda"):
        return ("interpret-HLO memory ~ the unfused pipeline; the fused "
                "kernel's BlockSpec bytes put the real bound on the corner "
                "turns (§Perf P1)")
    if b == "compute":
        uf = roof.get("useful_flops_fraction") or 0
        if uf and uf < 0.6:
            return (f"only {uf:.0%} of compiled FLOPs are model FLOPs — cut "
                    "remat recompute / attention waste")
        return ("near compute roofline; next: fewer rematerialized ops, "
                "bf16 everywhere")
    if b == "memory":
        if "decode" in shape or shape == "long_500k":
            return ("KV/state reads dominate (inherent at decode); raise "
                    "batch or quantize the cache to int8")
        return ("HBM traffic dominates: larger fusion regions, bf16 "
                "master-weight gathers, fewer layout copies")
    return ("collective-bound: overlap FSDP gathers with compute, compress "
            "cross-pod gradients (int8), or reshard to cut all-to-alls")


PAPER_VALIDATION = """
## §Paper-validation (faithful reproduction vs the paper's claims)

| Paper claim | Paper value | This repo (CPU-exact, 512^2 scene) | Where |
|---|---|---|---|
| Fused == unfused, L2 relative error | 2.44e-7 | **3.0e-7** (FP32 roundoff) | `benchmarks/bench_quality.py`, `tests/test_sar.py::test_fused_equals_unfused` |
| SNR delta, all 5 point targets | 0.0 dB | **0.0000 dB** | same |
| Max abs error | 3.81e-4 | 1.2e-4 | same |
| Per-target SNR ~45-47 dB | 45.2-47.3 dB | 58.0-58.3 dB (different noise accounting; delta is the claim) | same |
| Fused pipeline structure: range compression 1 dispatch, azimuth fused multiply+IFFT | Table III | identical step structure; dispatch counts 8 (fused) vs 7 (unfused XLA ops), HBM round-trips 8 vs 7 -> **4 (tfree)** -> **3 (fused3)** | `benchmarks/bench_rda.py` |
| IFFT = conj-FFT-conj, bit-comparable | Sec II-C | kernel property test `tests/test_kernels.py::test_ifft_inverts_fft` |
| MMA(matrix-unit) FFT within a few % of scalar | Table I (93%) | MXU-matmul vs VPU-stockham kernels both validated vs oracle; TPU ratio is roofline-derived (below), CPU interpret-mode timing in bench_fft | `benchmarks/bench_fft.py` |

Wall-clock speedup note: the paper's 22x is an Apple-M1 device-memory
effect. This container is CPU-only, so the reproduction validates the
*numerics* exactly and the *structure* (dispatch & HBM-round-trip counts);
the TPU performance claim is made through the roofline analysis below —
the fused pipeline's HBM traffic term is 8/3 = 2.7x lower than unfused at
identical FLOPs, and the kernel keeps each 4096-line resident in VMEM
(32 KiB/line vs 16 MiB VMEM = 128-line blocks per grid step).
"""

PERF_LOG = """
## §Perf (hillclimbing log: baseline -> optimized, three chosen cells)

Chosen cells (per assignment: worst roofline fraction, most collective-bound,
most representative of the paper's technique):

%PERF_BODY%
"""


def main():
    recs = load()
    lines = ["# EXPERIMENTS",
             "",
             "Hardware target: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, "
             "~50 GB/s/link ICI. Container is CPU-only: all TPU numbers are "
             "derived from AOT-compiled artifacts (memory_analysis / "
             "cost_analysis / SPMD HLO collective parse), wall-clock numbers "
             "are CPU and labelled as such.",
             ""]
    lines.append(PAPER_VALIDATION)

    # ----- dry run -------------------------------------------------------
    lines += ["## §Dry-run (lower + compile, every cell x both meshes)",
              "",
              "Meshes: single pod (16,16)=256 chips ('data','model'); "
              "multi-pod (2,16,16)=512 chips ('pod','data','model'). "
              "`compile OK` means jit(step).lower(...).compile() succeeded "
              "with the production shardings; bytes are per-device "
              "(arguments + temporaries).",
              ""]
    for tag in ("single", "multi"):
        lines += [f"### {tag} pod", "",
                  "| arch | shape | compile | GiB/dev args | GiB/dev temp | "
                  "peak GiB/dev | collectives (count) |", "|---|---|---|---|---|---|---|"]
        for a in ARCH_ORDER:
            for s in SHAPE_ORDER:
                r = recs.get((a, s, tag))
                if r is None:
                    continue
                cc = r["roofline"]["collective_counts"]
                ccs = " ".join(f"{k}:{v}" for k, v in sorted(cc.items()))
                m = r["memory"]
                lines.append(
                    f"| {a} | {s} | OK ({r['t_compile_s']:.0f}s) | "
                    f"{gib(m['argument_bytes'])} | {gib(m['temp_bytes'])} | "
                    f"{gib(m['peak_bytes_per_device'])} | {ccs} |")
        lines.append("")
    lines += ["Skipped cells (assignment long_500k rule):", ""]
    for a, s, why in SKIPS:
        if s:
            lines.append(f"- `{a}` x `{s}`: {why}")
    lines.append("")

    # ----- roofline ------------------------------------------------------
    lines += [
        "## §Roofline (single-pod, per-device terms in ms)", "",
        "compute = HLO_FLOPs/197e12 (scan bodies corrected x trip count); "
        "memory = HLO bytes/819e9; collective = ring-model link bytes/50e9. "
        "`useful` = MODEL_FLOPS (6ND, active-params for MoE) / HLO_FLOPs.",
        "",
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck | "
        "useful | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, "single"))
            if r is None:
                continue
            roof = r["roofline"]
            uf = roof.get("useful_flops_fraction")
            lines.append(
                f"| {a} | {s} | {fmt_ms(roof['t_compute_s'])} | "
                f"{fmt_ms(roof['t_memory_s'])} | "
                f"{fmt_ms(roof['t_collective_s'])} | {roof['bottleneck']} | "
                f"{uf:.2f} | {note_for(r)} |" if uf else
                f"| {a} | {s} | {fmt_ms(roof['t_compute_s'])} | "
                f"{fmt_ms(roof['t_memory_s'])} | "
                f"{fmt_ms(roof['t_collective_s'])} | {roof['bottleneck']} | "
                f"n/a | {note_for(r)} |")
    lines.append("")

    # ----- perf ----------------------------------------------------------
    perf_body_path = os.path.join(os.path.dirname(__file__), "perf_log.md")
    body = open(perf_body_path).read() if os.path.exists(perf_body_path) \
        else "(hillclimbing in progress — see scripts/perf_log.md)"
    lines.append(PERF_LOG.replace("%PERF_BODY%", body))

    lines.append("""
## §Beyond-paper summary

The paper-faithful reproduction (fused pipeline, conj-FFT-conj IFFT,
matrix-unit FFT, Table IV equivalence) is the baseline above; on top of it:

1. **3-dispatch RDA** (`fused3`): range compression commutes with the
   azimuth FFT, so RCMC (as an exact Fourier shift) and the range matched
   filter fuse into ONE dispatch — 3 HBM round-trips vs the paper's 8
   dispatches, and zero global transposes (the paper's 80%-of-runtime item).
2. **Rank-K on-the-fly phase synthesis** (FILTER_OUTER / SHARED_OUTER):
   RCMC + azimuth-compression filters synthesized in VMEM from O(N) vectors
   instead of O(N^2) filter reads — 1.33x on the fused HBM term, float32-safe
   via a wrapped rank-2 split.
3. **Distributed corner-turn schedules** (`corner2`, `halo`) with measured
   collective terms, an applicability bound for halo, and (IR-level) bf16
   turn payloads; multi-pod (512-chip) dry-run of the paper's own workload,
   plus the 8K x 8K future-work scene (sub-ms roofline bound vs Jetson
   Orin's 400 ms).
4. **The competitor algorithm (CSA) fused too**: all three of its stages are
   [FFT]*phase*[IFFT], so the paper's kernel runs it in 3 dispatches
   (`build_csa_fused`), equivalence-tested at FP32 roundoff.
5. **FFTConvMixer**: the fused kernel inside a Hyena-style LM block (the
   assigned archs are all input-gated, so this is the LTI demonstration of
   where the technique applies in LMs).
6. **MoE gather/scatter dispatch** (2.8x compute on granite), **GQA
   flat-head score sharding**, **chunked Mamba readout**, **seq-sharded
   residual constraints** — the LM-pool hillclimbs recorded in §Perf.
""")

    with open(OUT, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {OUT} ({len(lines)} lines, {len(recs)} cells)")


if __name__ == "__main__":
    main()
