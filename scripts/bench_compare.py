#!/usr/bin/env python
"""Bench ratchet: fail CI when a fused pipeline row regresses >1.3x.

Diffs a freshly generated ``BENCH_rda.json`` against the committed
baseline ``benchmarks/baseline_rda.json`` (BENCH_*.json itself is
gitignored — the baseline is a deliberately committed snapshot of one
smoke run) and exits non-zero if any fused row's wall-ms grew beyond the
threshold. This seeds the cross-PR perf trajectory: the committed
artifact is the ratchet, and a PR that slows a fused pipeline must
either fix it or consciously commit the slower baseline
(``cp BENCH_rda.json benchmarks/baseline_rda.json``).

Rules
-----
* Only rows whose name matches ``--pattern`` (default: fused rows of
  table_2, ``rda_(?!un).*fused`` — the lookahead keeps ``rda_unfused``
  out) are gated — the unfused oracle and per-step breakdowns are
  informational.
* Rows are matched by (section, name). Rows present on one side only are
  reported but never fail the ratchet (new rows land freely).
* Wall-ms is **normalized by a reference row** (``--reference``, default
  ``rda_unfused``) measured in the same run when present on both sides:
  the gated quantity is (fresh/fresh_ref) vs (base/base_ref), so a CI
  runner that is uniformly slower than the machine that produced the
  committed baseline does not trip the ratchet. Absolute wall-ms is the
  fallback when the reference row is missing on either side.
* A row pair is only compared when both sides carry the SAME ``interpret``
  flag: interpret-mode wall time measures the Pallas emulator, not the
  kernel, so an interpret row diffed against a compiled row (or against a
  pre-flag baseline) would be meaningless (see benchmarks/common.py).
* Sub-millisecond rows are skipped (``--min-ms``): at that scale CI
  timer noise swamps any real regression.

Usage::

    PYTHONPATH=src python scripts/bench_compare.py            # CI step
    python scripts/bench_compare.py --baseline old.json --fresh new.json
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys

# invoked as `python scripts/bench_compare.py`: the repo root (where the
# benchmarks package and the BENCH artifacts live) is the script's parent
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def load_rows(doc: dict) -> dict:
    rows = {}
    for row in doc.get("rows", []):
        rows[(row.get("section", ""), row["name"])] = row
    return rows


DEFAULT_BASELINE = os.path.join(_ROOT, "benchmarks", "baseline_rda.json")
DEFAULT_TUNING_BASELINE = os.path.join(_ROOT, "benchmarks",
                                       "baseline_tuning.json")
DEFAULT_SHARDED_BASELINE = os.path.join(_ROOT, "benchmarks",
                                        "baseline_sharded.json")
DEFAULT_SERVE_BASELINE = os.path.join(_ROOT, "benchmarks",
                                      "baseline_serve.json")


def baseline_doc(path_or_none: str, ref: str) -> dict:
    path = path_or_none or DEFAULT_BASELINE
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    # fallback: a repo that tracks BENCH_rda.json directly
    out = subprocess.run(
        ["git", "show", f"{ref}:BENCH_rda.json"],
        capture_output=True, text=True, cwd=_ROOT)
    if out.returncode != 0:
        raise SystemExit(
            f"no baseline at {path} and no BENCH_rda.json at {ref}: "
            f"{out.stderr.strip()}")
    return json.loads(out.stdout)


def _reference_ms(rows: dict, name: str):
    for (_, row_name), row in rows.items():
        if row_name == name and row["wall_ms"] > 0:
            return row["wall_ms"]
    return None


def compare(base: dict, fresh: dict, pattern: str, threshold: float,
            min_ms: float, reference: str = "rda_unfused") -> list[str]:
    """Returns the list of failure messages (empty = ratchet holds)."""
    pat = re.compile(pattern)
    base_rows, fresh_rows = load_rows(base), load_rows(fresh)
    # machine normalizer: the same reference row timed in each run
    base_ref = _reference_ms(base_rows, reference) if reference else None
    fresh_ref = _reference_ms(fresh_rows, reference) if reference else None
    norm = (fresh_ref / base_ref) if (base_ref and fresh_ref) else 1.0
    if norm != 1.0:
        print(f"  reference {reference}: {base_ref:.2f} -> {fresh_ref:.2f} "
              f"ms (machine factor {norm:.2f}x)")
    failures: list[str] = []
    compared = skipped = 0
    for key, new in sorted(fresh_rows.items()):
        if not pat.search(new["name"]):
            continue
        old = base_rows.get(key)
        if old is None:
            print(f"  new row (no baseline): {key[1]}")
            continue
        if old.get("interpret") != new.get("interpret"):
            print(f"  skipped (interpret flag mismatch "
                  f"{old.get('interpret')}->{new.get('interpret')}): "
                  f"{key[1]}")
            skipped += 1
            continue
        if old["wall_ms"] < min_ms:
            skipped += 1
            continue
        ratio = (new["wall_ms"] / (old["wall_ms"] * norm)
                 if old["wall_ms"] else 1.0)
        compared += 1
        status = "OK" if ratio <= threshold else "REGRESSION"
        print(f"  {key[1]}: {old['wall_ms']:.2f} -> {new['wall_ms']:.2f} "
              f"ms ({ratio:.2f}x normalized) {status}")
        if ratio > threshold:
            failures.append(
                f"{key[1]}: {ratio:.2f}x > {threshold:.2f}x normalized "
                f"({old['wall_ms']:.2f} -> {new['wall_ms']:.2f} ms)")
    print(f"# ratchet compared {compared} fused rows "
          f"({skipped} skipped, threshold {threshold:.2f}x)")
    return failures


def _derived(row: dict) -> dict:
    """A row's ``k=v;k=v`` derived string as a dict."""
    out = {}
    for part in (row.get("derived") or "").split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def compare_tuning(base: dict, fresh: dict) -> list[str]:
    """The table_7 policy ratchet over ``BENCH_tuning.json``.

    Wall time is the wrong gate for the tuner bench (interpret-mode
    timings measure the emulator); what must not regress is the SEARCH
    POLICY, which is deterministic. Every fresh ``tuning_graph_*`` row
    must (a) hold its in-run invariants — the schedule-graph search timed
    no more candidates than the flat successive-halving replay and its
    winner matched or beat the replay's on the shared memoized
    measurements — and (b) not time MORE candidates than the committed
    baseline's matching row (counts are deterministic, so this leg is
    machine-independent; rows are matched by name because the section
    header embeds the device fingerprint)."""
    base_by_name = {r["name"]: r for r in base.get("rows", [])}
    failures: list[str] = []
    compared = 0
    for row in sorted(fresh.get("rows", []), key=lambda r: r["name"]):
        if not row["name"].startswith("tuning_graph_"):
            continue
        compared += 1
        d = _derived(row)
        if d.get("no_more_timed") != "True":
            failures.append(
                f"{row['name']}: graph search timed more candidates than "
                f"the flat successive-halving replay (timed={d.get('timed')})")
        if d.get("winner_le") != "True":
            failures.append(
                f"{row['name']}: graph winner ({d.get('winner')}) slower "
                f"than the flat replay winner on shared measurements")
        old = base_by_name.get(row["name"])
        if old is None:
            print(f"  new row (no baseline): {row['name']}")
            continue
        ob, nb = _derived(old).get("timed"), d.get("timed")
        if ob is not None and nb is not None and int(nb) > int(ob):
            failures.append(f"{row['name']}: timed {nb} candidates > "
                            f"baseline {ob}")
        else:
            print(f"  {row['name']}: timed {nb} (baseline {ob}), "
                  f"winner {d.get('winner')} OK")
    if compared == 0:
        failures.append("no tuning_graph_* rows in the fresh artifact")
    print(f"# tuning ratchet compared {compared} graph rows")
    return failures


def compare_sharded(base: dict, fresh: dict) -> list[str]:
    """The table_8 architecture ratchet over ``BENCH_sharded.json``.

    Wall time is the wrong gate here too (the 8 devices are emulated and
    the kernels run through the Pallas interpreter); what must not regress
    is the DISPATCH STRUCTURE, which is deterministic: each device must
    still see exactly ``dispatches_per_device`` megakernel launches and
    the pipeline exactly ``turns`` collective corner turns. A PR that
    splits a phase group (more dispatches) or adds a corner turn (more
    collective payload) fails even on a fast machine. Rows match by name
    — the section header embeds the scene size, which --smoke vs --full
    legitimately changes — and device count, dispatch count, and turn
    count must not GROW versus the committed baseline."""
    base_by_name = {r["name"]: r for r in base.get("rows", [])}
    failures: list[str] = []
    compared = 0
    for row in sorted(fresh.get("rows", []), key=lambda r: r["name"]):
        if not row["name"].endswith("_sharded"):
            continue
        compared += 1
        d = _derived(row)
        old = base_by_name.get(row["name"])
        if old is None:
            print(f"  new row (no baseline): {row['name']}")
            continue
        od = _derived(old)
        for key in ("devices", "dispatches_per_device", "turns"):
            ov, nv = od.get(key), d.get(key)
            if ov is None or nv is None:
                failures.append(
                    f"{row['name']}: derived field {key!r} missing "
                    f"(baseline={ov}, fresh={nv})")
            elif int(nv) > int(ov):
                failures.append(
                    f"{row['name']}: {key} grew {ov} -> {nv} (more "
                    "dispatches/collectives per device than the baseline)")
        if not any(f.startswith(row["name"]) for f in failures):
            print(f"  {row['name']}: devices={d.get('devices')} "
                  f"dispatches_per_device={d.get('dispatches_per_device')} "
                  f"turns={d.get('turns')} OK")
    if compared == 0:
        failures.append("no *_sharded rows in the fresh artifact")
    print(f"# sharded ratchet compared {compared} rows")
    return failures


def compare_serve(base: dict, fresh: dict,
                  epsilon: float = 1e-6) -> list[str]:
    """The table_6 quality ratchet over ``BENCH_serve.json``.

    Wall time and throughput are NOT gated (interpret-mode serving
    latency measures the emulator and the asyncio scheduler); what must
    not regress is the QUALITY of the default serving tier, which is
    deterministic in interpret mode: the ``serve_tier_gate_*`` rows'
    measured SNR deviation must stay admitted (<= its own gate_db) and
    must not grow versus the committed baseline. The throughput-tier
    rows themselves (``serve_tier_{f32,bs16}_burst_*``) must exist —
    a PR that silently drops the tier family fails — but their wall
    numbers are informational.

    The load-replay family (``serve_load_*``) is gated STRUCTURALLY the
    same way: the seeded burst-replay rows and the
    ``serve_load_goodput_gain`` row must exist, and on the deterministic
    ``serve_load_smoke`` row the worker-pool lane count must not shrink
    and the deadline-miss rate (exactly 0 at smoke load by construction
    — generous deadlines) must not grow versus the committed baseline.
    Goodput/latency wall numbers stay informational.

    The chaos family (``serve_chaos_*``) is gated the same structural
    way: on ``serve_chaos_smoke`` the seeded fault replay must lose ZERO
    requests and fire every scheduled seam (>= 3), and
    ``serve_chaos_goodput_ratio`` must stay at or above its own bar —
    both deterministic invariants of the failure-domain layer, not wall
    time. A PR that drops the chaos family entirely fails."""
    base_by_name = {r["name"]: r for r in base.get("rows", [])}
    failures: list[str] = []
    gates = tiers = loads = chaos = 0
    have_gain_row = False
    for row in sorted(fresh.get("rows", []), key=lambda r: r["name"]):
        if row["name"] == "serve_chaos_smoke":
            chaos += 1
            d = _derived(row)
            lost, seams = d.get("lost"), d.get("seams")
            if lost is None or seams is None:
                failures.append(f"{row['name']}: lost/seams missing from "
                                "derived fields")
                continue
            if int(lost) != 0:
                failures.append(
                    f"{row['name']}: {lost} request(s) lost under the "
                    "seeded fault replay (every fault in the schedule is "
                    "recoverable by construction)")
            if int(seams) < 3:
                failures.append(
                    f"{row['name']}: only {seams} fault seams fired "
                    "(schedule expects >= 3: dispatch error, NaN output, "
                    "lane death)")
            if not any(f.startswith(row["name"]) for f in failures):
                print(f"  {row['name']}: lost={lost} seams={seams} "
                      f"completed={d.get('completed')}/"
                      f"{d.get('requests')} OK")
        elif row["name"] == "serve_chaos_goodput_ratio":
            chaos += 1
            d = _derived(row)
            ratio_s, bar_s = d.get("ratio_vs_fault_free"), d.get("bar")
            if ratio_s is None or bar_s is None:
                failures.append(f"{row['name']}: ratio_vs_fault_free/bar "
                                "missing from derived fields")
                continue
            ratio = float(ratio_s.rstrip("x"))
            bar = float(bar_s.rstrip("x"))
            if ratio < bar:
                failures.append(
                    f"{row['name']}: goodput under faults {ratio:.2f}x "
                    f"fault-free < {bar}x bar — recovery overhead "
                    "regressed")
            else:
                print(f"  {row['name']}: {ratio:.2f}x vs bar {bar}x OK")
        elif row["name"].startswith("serve_chaos_"):
            chaos += 1
            print(f"  {row['name']}: wall_ms={row['wall_ms']:.2f} "
                  f"(informational)")
        elif row["name"] == "serve_load_smoke":
            loads += 1
            d = _derived(row)
            old = base_by_name.get(row["name"])
            od = _derived(old) if old is not None else {}
            lanes, miss = d.get("lanes"), d.get("deadline_miss_rate")
            if lanes is None or miss is None:
                failures.append(f"{row['name']}: lanes/deadline_miss_rate "
                                "missing from derived fields")
                continue
            if od.get("lanes") is not None and int(lanes) < int(od["lanes"]):
                failures.append(
                    f"{row['name']}: lane count shrank "
                    f"{od['lanes']} -> {lanes} (worker pool lost lanes)")
            ob_miss = od.get("deadline_miss_rate")
            if ob_miss is not None and \
                    float(miss) > float(ob_miss) + epsilon:
                failures.append(
                    f"{row['name']}: deadline_miss_rate grew "
                    f"{ob_miss} -> {miss} at smoke load (deterministic "
                    "by construction — a real scheduling regression)")
            if not any(f.startswith(row["name"]) for f in failures):
                print(f"  {row['name']}: lanes={lanes} "
                      f"deadline_miss_rate={miss} "
                      f"(baseline lanes={od.get('lanes')}, "
                      f"miss={ob_miss}) OK")
        elif row["name"] == "serve_load_goodput_gain":
            loads += 1
            have_gain_row = True
            d = _derived(row)
            print(f"  {row['name']}: "
                  f"{d.get('gain_vs_single_flight')} vs bar "
                  f"{d.get('bar')} (informational)")
        elif row["name"].startswith("serve_load_"):
            loads += 1
            print(f"  {row['name']}: wall_ms={row['wall_ms']:.2f} "
                  f"(informational)")
        elif row["name"].startswith("serve_tier_gate_"):
            gates += 1
            d = _derived(row)
            dev, gate = d.get("snr_deviation_db"), d.get("gate_db")
            if dev is None or gate is None:
                failures.append(f"{row['name']}: snr_deviation_db/gate_db "
                                "missing from derived fields")
                continue
            if d.get("admitted") != "True" or float(dev) > float(gate):
                failures.append(
                    f"{row['name']}: deviation {dev} dB out of the "
                    f"{gate} dB gate — the default tier is inadmissible")
            old = base_by_name.get(row["name"])
            if old is None:
                print(f"  new row (no baseline): {row['name']}")
                continue
            ob = _derived(old).get("snr_deviation_db")
            if ob is not None and float(dev) > float(ob) + epsilon:
                failures.append(
                    f"{row['name']}: deviation grew {ob} -> {dev} dB "
                    "(deterministic in interpret mode — a real quality "
                    "regression, not noise)")
            else:
                print(f"  {row['name']}: deviation {dev} dB "
                      f"(baseline {ob}, gate {gate}) OK")
        elif row["name"].startswith("serve_tier_"):
            tiers += 1
            print(f"  {row['name']}: wall_ms={row['wall_ms']:.2f} "
                  f"(informational)")
    if gates == 0:
        failures.append("no serve_tier_gate_* rows in the fresh artifact")
    if tiers == 0:
        failures.append("no serve_tier_* throughput rows in the fresh "
                        "artifact — the precision-tier family is gone")
    if loads == 0:
        failures.append("no serve_load_* rows in the fresh artifact — "
                        "the load-replay family is gone")
    elif not have_gain_row:
        failures.append("serve_load_goodput_gain row missing from the "
                        "fresh artifact")
    if chaos == 0:
        failures.append("no serve_chaos_* rows in the fresh artifact — "
                        "the chaos-replay family is gone")
    print(f"# serve ratchet compared {gates} gate rows, {tiers} tier rows, "
          f"{loads} load-replay rows, {chaos} chaos rows")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", default="BENCH_rda.json",
                    help="freshly generated artifact (default: working tree)")
    ap.add_argument("--baseline", default=None,
                    help="baseline artifact path (default: "
                         "benchmarks/baseline_rda.json)")
    ap.add_argument("--ref", default="HEAD",
                    help="git ref holding the committed baseline")
    ap.add_argument("--pattern", default=r"rda_(?!un).*fused",
                    help="regex selecting the gated rows (the default "
                         "lookahead keeps rda_unfused informational)")
    ap.add_argument("--threshold", type=float, default=1.3,
                    help="fail when normalized fresh/base exceeds this")
    ap.add_argument("--min-ms", type=float, default=1.0,
                    help="skip rows whose baseline is below this (noise)")
    ap.add_argument("--reference", default="rda_unfused",
                    help="in-run reference row normalizing machine speed "
                         "('' disables)")
    ap.add_argument("--tuning", action="store_true",
                    help="ratchet the table_7 tuner-policy artifact "
                         "(BENCH_tuning.json vs benchmarks/"
                         "baseline_tuning.json) instead of wall time")
    ap.add_argument("--sharded", action="store_true",
                    help="ratchet the table_8 sharded-megakernel artifact "
                         "(BENCH_sharded.json vs benchmarks/"
                         "baseline_sharded.json): gate dispatch and "
                         "collective-turn counts, not wall time")
    ap.add_argument("--serve", action="store_true",
                    help="ratchet the table_6 serving artifact "
                         "(BENCH_serve.json vs benchmarks/"
                         "baseline_serve.json): gate the bs16 tier's "
                         "SNR deviation and the load-replay structure "
                         "(lane count, smoke deadline-miss rate), not "
                         "wall time")
    args = ap.parse_args()

    from benchmarks.common import validate_bench_doc
    if args.serve:
        fresh_path = ("BENCH_serve.json" if args.fresh == "BENCH_rda.json"
                      else args.fresh)
        with open(fresh_path) as f:
            fresh = validate_bench_doc(json.load(f))
        bpath = args.baseline or DEFAULT_SERVE_BASELINE
        if not os.path.exists(bpath):
            raise SystemExit(f"no serve baseline at {bpath}")
        with open(bpath) as f:
            base = json.load(f)
        failures = compare_serve(base, fresh)
        if failures:
            print("# SERVE RATCHET FAILED:")
            for msg in failures:
                print(f"#   {msg}")
            return 1
        return 0
    if args.sharded:
        fresh_path = ("BENCH_sharded.json" if args.fresh == "BENCH_rda.json"
                      else args.fresh)
        with open(fresh_path) as f:
            fresh = validate_bench_doc(json.load(f))
        bpath = args.baseline or DEFAULT_SHARDED_BASELINE
        if not os.path.exists(bpath):
            raise SystemExit(f"no sharded baseline at {bpath}")
        with open(bpath) as f:
            base = json.load(f)
        failures = compare_sharded(base, fresh)
        if failures:
            print("# SHARDED RATCHET FAILED:")
            for msg in failures:
                print(f"#   {msg}")
            return 1
        return 0
    if args.tuning:
        fresh_path = ("BENCH_tuning.json" if args.fresh == "BENCH_rda.json"
                      else args.fresh)
        with open(fresh_path) as f:
            fresh = validate_bench_doc(json.load(f))
        bpath = args.baseline or DEFAULT_TUNING_BASELINE
        if not os.path.exists(bpath):
            raise SystemExit(f"no tuning baseline at {bpath}")
        with open(bpath) as f:
            base = json.load(f)
        failures = compare_tuning(base, fresh)
        if failures:
            print("# TUNING RATCHET FAILED:")
            for msg in failures:
                print(f"#   {msg}")
            return 1
        return 0
    with open(args.fresh) as f:
        fresh = validate_bench_doc(json.load(f))
    base = baseline_doc(args.baseline, args.ref)

    failures = compare(base, fresh, args.pattern, args.threshold,
                       args.min_ms, reference=args.reference)
    if failures:
        print("# BENCH RATCHET FAILED:")
        for msg in failures:
            print(f"#   {msg}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
