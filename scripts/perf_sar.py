"""§Perf hillclimb driver — the SAR cell (the paper's own workload).

Lowers distributed-RDA schedule variants on the production single-pod mesh
(256 devices) and reports the three roofline terms per variant, plus the
BlockSpec-guaranteed HBM bytes of the real fused kernel (the interpret-mode
HLO materializes the kernel's internals, so its memory term approximates the
UNFUSED pipeline — the analytic kernel bytes are what the Mosaic kernel
moves by construction).

  PYTHONPATH=src python scripts/perf_sar.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json
import time

import jax
import jax.numpy as jnp

from repro.compat import AXIS_TYPE_AUTO, make_mesh
from repro.core.sar import paper_scene
from repro.core.sar import filters
from repro.core.sar.distributed import build_corner2, build_halo
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh

CFG = paper_scene()
N_PTS = CFG.na * CFG.nr


def analytic_fused_bytes(n_dispatches: int, filter_full_dispatches: int = 0,
                         shared_filters: int = 1) -> int:
    """HBM bytes the Pallas pipeline moves by BlockSpec construction:
    each dispatch reads + writes the full split-complex scene once
    (2 x 2 x 4 bytes per point per dispatch); FULL 2-D filters add one scene
    read; shared/rank-K filters and DFT matrices are O(N) (counted once)."""
    scene = N_PTS * 2 * 4
    total = n_dispatches * 2 * scene
    total += filter_full_dispatches * scene
    total += shared_filters * CFG.nr * 2 * 4
    return total


def measure(name, build_fn, mesh=None, **kw):
    mesh = mesh or make_production_mesh()
    axes = tuple(mesh.axis_names)
    run = build_fn(CFG, mesh, axes=axes, interpret=True, **kw)
    raw = jax.ShapeDtypeStruct((CFG.na, CFG.nr), jnp.complex64)
    t0 = time.time()
    compiled = jax.jit(lambda x: run(x)).lower(raw).compile()
    dt = time.time() - t0
    import math
    model_flops = (2 * 5 * N_PTS * math.log2(CFG.nr)
                   + 2 * 5 * N_PTS * math.log2(CFG.na) + 3 * 6 * N_PTS)
    roof = rf.from_compiled(compiled, mesh.devices.size,
                            model_flops / mesh.devices.size)
    mem = compiled.memory_analysis()
    rec = {
        "variant": name,
        "t_compile_s": round(dt, 1),
        "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                     + mem.temp_size_in_bytes),
        **roof.to_dict(),
    }
    print(f"{name}: t_comp={roof.t_compute*1e6:.1f}us "
          f"t_mem(HLO~unfused)={roof.t_memory*1e6:.1f}us "
          f"t_coll={roof.t_collective*1e6:.1f}us "
          f"colls={roof.collectives.counts} "
          f"link_bytes/dev={roof.collectives.link_bytes/1e6:.2f}MB",
          flush=True)
    return rec


def main():
    out = []
    # baseline: corner2 (2 all-to-alls, 3 fused dispatches, rank-K phases)
    out.append(measure("corner2_256", build_corner2))
    # halo needs halo_cols <= nr/P: at 256 devices the slab is 16 columns ==
    # the halo itself (the exchange degenerates to a corner turn), so the
    # schedule comparison runs at 64 devices where its premise holds.
    mesh64 = make_mesh((64,), ("data",), axis_types=(AXIS_TYPE_AUTO,))
    out.append(measure("corner2_64", build_corner2, mesh=mesh64))
    out.append(measure("halo_64", build_halo, mesh=mesh64))
    # iteration 3: bf16 corner-turn payload (dominant term / 2?)
    out.append(measure("corner2_256_bf16turn", build_corner2,
                       turn_dtype=jnp.bfloat16))

    chips = 256
    for rec in out:
        # analytic fused-kernel HBM term (what Mosaic moves by construction)
        nd = 3 if "corner2" in rec["variant"] else 4
        fb = analytic_fused_bytes(nd)
        chips = 64 if rec["variant"].endswith("_64") else 256
        rec["analytic_fused_hbm_bytes"] = fb
        rec["t_mem_fused_analytic_s"] = fb / chips / rf.HBM_BW
        # unfused baseline: 9 scene round trips (3 RC + 1 azFFT + 1 RCMC +
        # 2 AC + transposes are free in XLA-fused form) — conservative 7
        ub = 7 * 2 * N_PTS * 8
        rec["t_mem_unfused_s"] = ub / chips / rf.HBM_BW
        print(f"{rec['variant']}: analytic fused t_mem="
              f"{rec['t_mem_fused_analytic_s']*1e6:.1f}us vs unfused~"
              f"{rec['t_mem_unfused_s']*1e6:.1f}us; bound="
              f"{max(rec['t_mem_fused_analytic_s'], rec['t_collective_s'], rec['t_compute_s'])*1e6:.1f}us")

    os.makedirs("experiments/perf", exist_ok=True)
    with open("experiments/perf/sar_schedules.json", "w") as f:
        json.dump(out, f, indent=1)
    print("wrote experiments/perf/sar_schedules.json")


if __name__ == "__main__":
    main()
