"""table_7 — tuner quality: cost-model-guided search vs exhaustive sweep.

For each reference (n, batch) point this bench runs BOTH searches on the
same workload (the fused fwd+inv rows dispatch):

* **exhaustive** — time every feasible candidate, the pre-subsystem
  benchmarks/autotune.py behavior;
* **guided** — `repro.tuning.search_kernel`: roofline-cost ranking,
  measure only the top fraction, successive halving;
* **graph** — `repro.tuning.search_schedule`: the schedule-DAG
  shortest-path frontier (budgeted to the guided replay's measurement
  count) refined by the same halving engine, replayed against the
  exhaustive pass's memoized timings. Its row carries the two booleans
  the CI tuning ratchet gates (`scripts/bench_compare.py --tuning`
  against ``benchmarks/baseline_tuning.json``): it timed no more
  candidates than the guided replay, and its winner matched or beat the
  guided winner on the shared numbers.

and records, per point: each search's winner + wall time, how many
candidates each actually timed (the guided search must time strictly
fewer — the acceptance bar), whether the winners agree, the cost model's
predicted rank of the measured-exhaustive winner, and a Spearman rank
correlation between predicted and measured orderings (predicted-vs-
measured rank quality over the whole feasible space).

Also the CI tuner smoke (``python -m benchmarks.bench_tuning --smoke``):
a cold-cache guided search at 256^2 that asserts a config LANDS in the
persistent cache and the cache document schema-validates.
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import emit, header
from repro import tuning
from repro.tuning import cost


def _spearman(pred_order, measured):
    """Spearman rho between the cost model's ordering and measured times.
    pred_order: configs cheapest-first; measured: {config: seconds}."""
    cands = [c for c in pred_order if c in measured]
    if len(cands) < 3:
        return float("nan")
    pred_rank = {c: i for i, c in enumerate(cands)}
    meas_sorted = sorted(cands, key=lambda c: measured[c])
    meas_rank = {c: i for i, c in enumerate(meas_sorted)}
    d2 = sum((pred_rank[c] - meas_rank[c]) ** 2 for c in cands)
    k = len(cands)
    return 1.0 - 6.0 * d2 / (k * (k * k - 1))


def exhaustive_sweep(key, precisions=("f32",), iters=2):
    """Time EVERY candidate the kernel build accepts — the legacy
    autotune policy, deliberately INDEPENDENT of the cost model's
    feasibility cut so table_7 can catch a model cut that excludes the
    true winner (such a config shows up as predicted_rank -1).
    Returns (best_config, best_seconds, timed_count, {config: seconds})."""
    measure = tuning.kernel_measure(key)
    best = None
    measured: dict = {}
    for cand in tuning.candidates(key.n, precisions=precisions):
        try:
            t = measure(cand, iters)
        except Exception:           # shape/VMEM-infeasible at trace time
            continue
        measured[cand] = t
        if best is None or t < best[1]:
            best = (cand, t)
    assert best is not None, f"no feasible config for {key}"
    return best[0], best[1], len(measured), measured


def run_point(n: int, batch: int, lines: int = 16,
              precisions=("f32",)) -> dict:
    """One reference point: exhaustive vs guided, emitted as bench rows.

    Two guided passes are recorded: a LIVE one (its own fresh timings —
    the honest search wall time), and a POLICY replay against the
    exhaustive pass's memoized measurements, so `same_winner` compares
    the search policies on ONE shared set of numbers instead of two
    independent noisy timing runs (interpret-mode CPU timings jitter more
    than the gap between near-tied configs)."""
    key = tuning.TuneKey.kernel(n, batch, lines=lines)

    t0 = time.perf_counter()
    ex_cfg, ex_t, ex_timed, measured = exhaustive_sweep(
        key, precisions=precisions, iters=3)
    ex_wall = time.perf_counter() - t0

    t0 = time.perf_counter()
    live = tuning.search_kernel(key, precisions=precisions, persist=False)
    g_wall = time.perf_counter() - t0

    replay = tuning.search_kernel(
        key, precisions=precisions, persist=False,
        measure=lambda c, iters: measured[c])

    # graph-search policy replay against the SAME memoized measurements,
    # with the frontier budget set to the flat replay's measurement count
    # (budget parity): the schedule-graph search must time no more
    # candidates than successive halving while matching or beating its
    # winner — the two booleans the table_7 ratchet
    # (scripts/bench_compare.py --tuning) gates in CI.
    problem = tuning.ScheduleProblem.kernel(n, batch=batch, lines=lines)
    graph = tuning.search_schedule(
        problem, key, persist=False, precisions=precisions,
        k=max(1, replay.measured),
        measure=lambda s, iters: measured[s.to_config()])

    ranked = cost.rank(list(measured), key)
    pred_rank_of_winner = ranked.index(ex_cfg) if ex_cfg in ranked else -1
    rho = _spearman(ranked, measured)
    same = replay.config == ex_cfg

    def _fmt(c):
        return (f"{c.n1}x{c.n2}{'x%d' % c.n3 if c.n3 else ''}"
                f"_blk{c.block}{'_kara' if c.karatsuba else ''}"
                f"_{c.precision}")

    emit(f"tuning_exhaustive_B{key.batch}_n{n}", ex_t,
         f"winner={_fmt(ex_cfg)};timed={ex_timed};"
         f"search_wall_ms={ex_wall * 1e3:.1f}")
    emit(f"tuning_guided_B{key.batch}_n{n}", live.seconds,
         f"winner={_fmt(live.config)};timed={live.measured};"
         f"search_wall_ms={g_wall * 1e3:.1f};space={live.space};"
         f"fewer_timed={live.measured < ex_timed}")
    emit(f"tuning_policy_B{key.batch}_n{n}", replay.seconds,
         f"winner={_fmt(replay.config)};timed={replay.measured};"
         f"same_winner={same};"
         f"fewer_timed={replay.measured < ex_timed}")
    emit(f"tuning_graph_B{key.batch}_n{n}", graph.seconds,
         f"winner={_fmt(graph.config)};timed={graph.measured};"
         f"space={graph.space};"
         f"no_more_timed={graph.measured <= replay.measured};"
         f"winner_le={graph.seconds <= replay.seconds}")
    emit(f"tuning_rank_quality_B{key.batch}_n{n}", 0.0,
         f"spearman_rho={rho:.3f};"
         f"predicted_rank_of_measured_best={pred_rank_of_winner};"
         f"feasible={len(ranked)}")
    return {"same_winner": same,
            "fewer_timed": replay.measured < ex_timed,
            "guided_timed": replay.measured, "exhaustive_timed": ex_timed,
            "graph_timed": graph.measured,
            "graph_no_more_timed": graph.measured <= replay.measured,
            "graph_winner_le": graph.seconds <= replay.seconds}


def run(full: bool = False, smoke: bool = False) -> None:
    points = ((256, 1), (512, 4)) if not full else ((1024, 1), (4096, 4))
    if smoke:
        points = ((128, 1), (256, 2))
    header(f"table_7: guided vs exhaustive tuning search "
           f"(device={tuning.device_fingerprint()})")
    for n, b in points:
        run_point(n, b)


def smoke_check(n: int = 256, batch: int = 1) -> None:
    """The CI tuner smoke: cold-cache guided search at n^2; assert the
    winner LANDS in the persistent cache and the document validates."""
    path = tuning.default_cache_path()
    print(f"# tuner smoke: cold-cache search n={n} B={batch} -> {path}",
          flush=True)
    key = tuning.TuneKey.kernel(n, batch)
    res = tuning.search_kernel(key)          # persists to the default cache
    cache = tuning.TuneCache(path)           # fresh view: re-reads the file
    doc = tuning.validate_cache_doc(cache.doc())
    entry = cache.get_entry(key)
    assert entry is not None, f"search did not land in the cache for {key}"
    assert tuning.KernelConfig.from_dict(entry["config"]) == res.config
    print(f"# tuner smoke OK: {key.encode()} -> {entry['config']} "
          f"({len(doc['entries'])} entries, schema {doc['schema']})",
          flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="CI tuner smoke: cold-cache search + cache "
                         "schema assertion (set REPRO_AUTOTUNE_CACHE to "
                         "a throwaway path for a genuinely cold run)")
    args = ap.parse_args()
    if args.smoke:
        smoke_check(args.n, args.batch)
    else:
        print("name,us_per_call,derived")
        run_point(args.n, args.batch)


if __name__ == "__main__":
    main()
