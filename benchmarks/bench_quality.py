"""Paper Table IV — radar image quality: fused vs unfused (L2 relative
error, max abs error, per-target SNR, SNR delta), plus the SNR-deviation
gate the autotuner uses to admit reduced-precision kernel configs."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, header
from repro.core.sar import build_pipeline, metrics, paper_targets, \
    simulate_cached
from repro.core.sar.geometry import paper_scene, test_scene
# the gate itself lives in-library so the tuner and the serving admission
# check can use it without depending on benchmarks/; re-exported here for
# the paper tables and back-compat
from repro.tuning.quality import precision_snr_deviation  # noqa: F401


def run(n: int = 512, full: bool = False):
    cfg = paper_scene() if full else test_scene(n)
    targets = paper_targets(cfg)
    raw = jnp.asarray(simulate_cached(cfg, targets))
    header(f"table_4: quality fused vs unfused {cfg.na}x{cfg.nr}")

    un = np.asarray(build_pipeline(cfg, "unfused").run(raw))
    fu = np.asarray(build_pipeline(cfg, "fused").run(raw))
    c = metrics.compare_pipelines(fu, un, cfg, targets)
    emit("l2_relative_error", 0.0, f"{c['l2_relative_error']:.3e}")
    emit("max_abs_error", 0.0, f"{c['max_abs_error']:.3e}")
    emit("snr_delta_max_db", 0.0, f"{max(c['snr_delta_db']):.4f}")
    names = ["center", "range_offset", "azimuth_offset", "diagonal", "far"]
    for i, (a, b) in enumerate(zip(c["snr_a_db"], c["snr_b_db"])):
        emit(f"target_{i}_{names[i]}_snr", 0.0,
             f"fused={a:.1f}dB;unfused={b:.1f}dB")
    reps = c["reports_b"]
    for i, r in enumerate(reps):
        emit(f"target_{i}_{names[i]}_pslr", 0.0,
             f"range={r.pslr_range_db:.1f}dB;azimuth={r.pslr_azimuth_db:.1f}dB")

    # beyond-paper variants keep quality too (including the ω-K plan)
    for v in ("fused_tfree", "fused3", "csa_fused", "omegak"):
        img = np.asarray(build_pipeline(cfg, v).run(raw))
        cc = metrics.compare_pipelines(img, un, cfg, targets)
        emit(f"{v}_snr_delta_max_db", 0.0, f"{max(cc['snr_delta_db']):.4f}")

    # the autotuner's reduced-precision gate values
    for p in ("bf16", "bs16"):
        emit(f"precision_{p}_snr_dev_db", 0.0,
             f"{precision_snr_deviation(p):.4f}")
