"""table_6: the focusing service — offered load vs p50/p99 latency, and
the micro-batching throughput gain over the sequential per-request
baseline.

The baseline is the repo's pre-service serving story: one blocking
`Pipeline.run` per request (eager per-step dispatch, one scene at a
time). The service point runs the SAME requests through
repro.service.FocusService — warm jitted per-plan cache, B=max_batch
coalescing — first as a closed burst (the coalescing ceiling), then as an
open-loop arrival sweep at multiples of the baseline throughput,
reporting per-point p50/p99/achieved-rps/mean-batch/rejections. The
acceptance bar tracked across PRs: burst throughput at B=4 coalescing
>= 1.5x the sequential baseline on 512^2 scenes (CPU numbers are
interpret-mode illustrative, like every other table here).

The serve_tier_* row family measures the precision tiers: the bs16
default serving tier (block-scaled f16, per-line exponents carried
through the kernels, admitted through the measured SNR gate) against the
explicit f32 verification path, burst-loaded on the same warm backend.
The gate row's snr_deviation_db is deterministic in interpret mode and
ratcheted by scripts/bench_compare.py --serve; wall-clock tier numbers
are illustrative like the rest.
"""
from __future__ import annotations

import asyncio
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, header
from repro.core.sar import build_pipeline, paper_targets, simulate_cached
from repro.core.sar.geometry import test_scene
from repro.service import FocusService, LocalBackend, ServiceConfig
from repro.service.metrics import percentile

VARIANT = "fused3"
MAX_BATCH = 4


def _sequential_baseline(cfg, raw, n_requests: int):
    """Per-request blocking Pipeline.run — latency list + throughput."""
    pipe = build_pipeline(cfg, VARIANT)
    jax.block_until_ready(pipe.run(raw))          # warm filters/devices
    lats = []
    t0 = time.perf_counter()
    for _ in range(n_requests):
        t1 = time.perf_counter()
        np.asarray(pipe.run(raw))                 # host result, like a reply
        lats.append((time.perf_counter() - t1) * 1e3)
    rps = n_requests / (time.perf_counter() - t0)
    return lats, rps


async def _serve_point(backend, cfg, raw, n_requests: int,
                       rate_rps: float | None, precision=None):
    """One service measurement: burst (rate None) or open-loop arrivals.
    precision=None pins the f32 verification path (the legacy rows'
    baseline semantics); the serve_tier_* rows pass a tier explicitly."""
    svc = FocusService(
        ServiceConfig(variant=VARIANT, precision=precision,
                      max_batch=MAX_BATCH, max_delay_ms=20.0,
                      max_queue=max(64, 2 * n_requests)),
        backend=backend)
    await svc.start()
    t0 = time.perf_counter()

    async def one():
        return await svc.focus(raw, cfg)

    if rate_rps is None:
        results = await asyncio.gather(*[one() for _ in range(n_requests)])
    else:
        tasks = []
        for i in range(n_requests):
            tasks.append(asyncio.ensure_future(one()))
            await asyncio.sleep(1.0 / rate_rps)
        results = await asyncio.gather(*tasks)
    elapsed = time.perf_counter() - t0
    await svc.stop()
    assert all(r.shape == (cfg.na, cfg.nr) for r in results)
    snap = svc.metrics.snapshot()
    snap["achieved_rps"] = n_requests / elapsed
    return snap


def run(full: bool = False, smoke: bool = False):
    n = 1024 if full else 512
    n_requests = 16 if smoke else 32
    cfg = test_scene(n)
    raw = np.asarray(simulate_cached(cfg, paper_targets(cfg)))

    header(f"table_6: serving {cfg.na}x{cfg.nr} variant={VARIANT} "
           f"max_batch={MAX_BATCH} requests={n_requests} "
           "(sequential blocking Pipeline.run vs async coalescing service)")

    base_lats, base_rps = _sequential_baseline(cfg, jnp.asarray(raw),
                                               n_requests)
    emit("serve_seq_baseline_per_request",
         float(np.mean(base_lats)) / 1e3,
         f"p50_ms={percentile(base_lats, 50):.1f};"
         f"p99_ms={percentile(base_lats, 99):.1f};rps={base_rps:.2f}")

    # ONE warm backend for every service point: the per-plan cache
    # (compiled pipeline + swept block config + jit traces) is service
    # state, not per-measurement state.
    backend = LocalBackend()
    from repro.service.queue import BatchKey
    backend.warm(BatchKey(cfg, VARIANT, None, False), MAX_BATCH)

    # the burst point uses 2x the requests: the coalescing ceiling is a
    # steady-state number, and more full batches amortize the fixed
    # per-measurement costs (gather setup, first-batch ramp)
    burst = asyncio.run(_serve_point(backend, cfg, raw, 2 * n_requests,
                                     None))
    gain = burst["achieved_rps"] / base_rps
    emit("serve_burst_B4_per_request",
         1.0 / max(burst["achieved_rps"], 1e-9),
         f"p50_ms={burst['latency_p50_ms']:.1f};"
         f"p99_ms={burst['latency_p99_ms']:.1f};"
         f"rps={burst['achieved_rps']:.2f};"
         f"mean_batch={burst['mean_batch_size']:.2f}")
    emit("serve_throughput_gain_B4", 0.0,
         f"gain_vs_sequential={gain:.2f}x;bar=1.5x")

    for mult in (0.75, 1.5, 3.0):
        rate = mult * base_rps
        snap = asyncio.run(
            _serve_point(backend, cfg, raw, n_requests, rate))
        emit(f"serve_load_{mult:g}x_baseline",
             snap["latency_p50_ms"] / 1e3,
             f"offered_rps={rate:.2f};achieved_rps={snap['achieved_rps']:.2f};"
             f"p50_ms={snap['latency_p50_ms']:.1f};"
             f"p99_ms={snap['latency_p99_ms']:.1f};"
             f"mean_batch={snap['mean_batch_size']:.2f};"
             f"queue_depth_max={snap['queue_depth_max']};"
             f"rejected={snap['rejected']}")

    # -- precision tiers: bs16 default serving tier vs f32 verification --
    # The gate measurement is the same harness the service consults at
    # admission (repro.tuning.quality, lru-cached), so the service points
    # below pay it exactly once. snr_deviation_db is deterministic in
    # interpret mode and ratcheted across PRs; tier wall times are not.
    from repro.tuning.quality import precision_snr_deviation
    dev = precision_snr_deviation("bs16")
    emit("serve_tier_gate_bs16", 0.0,
         f"snr_deviation_db={dev:.4f};gate_db=0.1;"
         f"admitted={dev <= 0.1}")
    tiers = {}
    for prec in ("f32", "bs16"):
        backend.warm(BatchKey(cfg, VARIANT, prec, False), MAX_BATCH)
        snap = asyncio.run(_serve_point(backend, cfg, raw, n_requests,
                                        None, precision=prec))
        tiers[prec] = snap["achieved_rps"]
        emit(f"serve_tier_{prec}_burst_B4_per_request",
             1.0 / max(snap["achieved_rps"], 1e-9),
             f"p50_ms={snap['latency_p50_ms']:.1f};"
             f"p99_ms={snap['latency_p99_ms']:.1f};"
             f"rps={snap['achieved_rps']:.2f};"
             f"mean_batch={snap['mean_batch_size']:.2f}")
    emit("serve_tier_bs16_gain", 0.0,
         f"gain_vs_f32={tiers['bs16'] / max(tiers['f32'], 1e-9):.2f}x;"
         "default_tier=bs16")
    return gain
